"""Tentpole tests: the hierarchical two-level runtime through every surface.

Conservation itself is hammered by ``test_invariants.py``; this module
locks the hierarchical-specific contracts -- the facade entry point, the
per-level RMW accounting, the window composition, node mapping, lifecycle
(reset/state/restore), and the argument validation.
"""
import threading

import numpy as np
import pytest

from repro import dls
from repro.core import (
    HierarchicalRuntime,
    HierarchicalWindow,
    LoopSpec,
    SimWindow,
    ThreadWindow,
)


def test_facade_acceptance_shape_drains():
    """The ISSUE's acceptance call: gss / P=288 / hierarchical / nodes=8."""
    N = 5_000
    hits = np.zeros(N, np.int64)
    lock = threading.Lock()

    def work(a, b):
        with lock:
            hits[a:b] += 1

    s = dls.loop(N, technique="gss", P=288, runtime="hierarchical", nodes=8)
    report = s.execute(work, executor="threads", n_threads=16)
    assert (hits == 1).all()
    assert report.runtime == "hierarchical"
    assert report.total_iters == N
    assert s.drained() and s.remaining() == 0


def test_report_carries_per_level_rmw_counts():
    s = dls.loop(2_000, technique="gss", P=8, runtime="hierarchical", nodes=2)
    report = s.execute(lambda a, b: None, executor="serial")
    assert report.n_rmw_global is not None and report.n_rmw_global > 0
    assert report.n_rmw_local is not None
    # local sub-scheduling must dominate: global RMWs are 2 per super-chunk
    assert report.n_rmw_local > report.n_rmw_global
    assert f"rmw_g={report.n_rmw_global}" in report.summary()


def test_sim_executor_reports_rmw_reduction_vs_flat():
    N, P = 4_000, 64
    costs = np.full(N, 1e-3)
    flat = dls.loop(N, technique="ss", P=P).execute(
        None, executor="sim", costs=costs)
    hier = dls.loop(N, technique="gss", P=P, runtime="hierarchical",
                    nodes=8).execute(None, executor="sim", costs=costs)
    assert flat.total_iters == hier.total_iters == N
    assert hier.n_rmw_global * 2 <= flat.n_rmw_global
    assert hier.n_rmw_local > 0 and flat.n_rmw_local == 0


def test_flat_sim_window_counts_as_global():
    s = dls.loop(500, technique="ss", P=4, window="sim")
    report = s.execute(lambda a, b: None, executor="serial")
    assert report.n_rmw_global == s.runtime.window.n_rmw > 0
    assert report.n_rmw_local == 0


def test_hierarchical_window_accounting_and_clocks():
    win = HierarchicalWindow.sim(2, o_rma_global=1e-5, o_rma_local=1e-7)
    rt = HierarchicalRuntime(LoopSpec("gss", N=1_000, P=8), nodes=2,
                             window=win)
    while any(rt.claim(pe) for pe in range(8)):
        pass
    assert win.n_rmw_global > 0 and win.n_rmw_local > 0
    clocks = win.clocks()
    assert clocks["global"] == pytest.approx(win.n_rmw_global * 1e-5)
    assert clocks["local"] > 0
    win.reset_clock()
    assert win.n_rmw_global == win.n_rmw_local == 0
    assert win.clocks() == {"global": 0.0, "local": 0.0}


def test_sim_window_reset_clock():
    w = SimWindow(o_rma=1e-6)
    w.fetch_add("k", 1)
    assert w.n_rmw == 1 and w.clock == pytest.approx(1e-6)
    w.reset_clock()
    assert w.n_rmw == 0 and w.clock == 0.0
    assert w.read("k") == 1  # counters survive; only accounting resets


def test_node_mapping_contiguous_and_total():
    rt = HierarchicalRuntime(LoopSpec("gss", N=100, P=10), nodes=3)
    nodes = [rt.node_of(pe) for pe in range(10)]
    assert nodes == sorted(nodes)  # contiguous blocks
    assert set(nodes) == {0, 1, 2}  # every node populated
    # out-of-range PEs clamp instead of crashing (session _ensure_pe growth)
    assert rt.node_of(99) == 2


def test_outer_technique_runs_over_nodes():
    """The outer spec is the session technique with P=nodes: with GSS the
    first super-chunk is ~N/nodes, far larger than any flat chunk."""
    rt = HierarchicalRuntime(LoopSpec("gss", N=10_000, P=100), nodes=4)
    first = rt.claim(0)
    assert first is not None
    assert first.size == 1  # the local claim itself is SS-sized
    # ...but the node's super-chunk grabbed GSS(K_0) = N/nodes globally
    assert rt.window.read(rt._gl) == 2_500
    # and nothing is lost: super-chunk remainder + global tail == N - 1
    assert rt.remaining_lower_bound() == 10_000 - 1


def test_reset_restarts_rmw_accounting():
    """reset() must clear metrics: the second loop's RMW counts start at
    zero instead of inheriting the first loop's totals from the window."""
    s = dls.loop(2_000, technique="gss", P=8, runtime="hierarchical",
                 nodes=2, window="sim")
    r1 = s.execute(lambda a, b: None, executor="serial")
    s.reset()
    r2 = s.execute(lambda a, b: None, executor="serial")
    assert r2.n_rmw_global == r1.n_rmw_global  # same loop, not 2x
    assert r2.n_rmw_local == r1.n_rmw_local


def test_des_hierarchical_honors_weights():
    """WF over nodes in the DES: with weights matching the speed mix, the
    weighted schedule balances the nodes (the DES must aggregate weights
    exactly like HierarchicalRuntime, not silently simulate uniform)."""
    from repro.core import SimConfig, simulate

    N, P = 8_000, 8
    speeds = np.array([2.0] * 4 + [0.5] * 4)  # node 0 fast, node 1 slow
    costs = np.full(N, 1e-3)
    w = tuple([2.0] * 4 + [0.5] * 4)
    wr = simulate(SimConfig(
        LoopSpec("wf", N=N, P=P, weights=w), speeds, costs,
        impl="hierarchical", nodes=2, inner_technique="ss"))
    ur = simulate(SimConfig(
        LoopSpec("wf", N=N, P=P), speeds, costs,
        impl="hierarchical", nodes=2, inner_technique="ss"))
    assert wr.per_pe_iters.sum() == ur.per_pe_iters.sum() == N
    # weighted super-chunks keep the slow node's share near speed-parity
    # and collapse the finish-time imbalance vs uniform weights
    assert wr.T_loop < ur.T_loop
    assert wr.cov < 0.5 * ur.cov
    assert wr.per_pe_iters[:4].sum() > ur.per_pe_iters[:4].sum()


def test_reset_opens_fresh_loop_on_same_window():
    s = dls.loop(800, technique="gss", P=8, runtime="hierarchical", nodes=2)
    assert sum(c.size for pe in range(8) for c in s.claims(pe)) == 800
    s.reset()
    assert s.remaining() == 800
    assert sum(c.size for pe in range(8) for c in s.claims(pe)) == 800


def test_session_state_restore_roundtrip_hierarchical():
    s = dls.loop(2_000, technique="gss", P=8, runtime="hierarchical", nodes=2)
    served = sum(s.claim(pe).size for pe in (0, 1, 4, 5))
    st = s.state()
    s2 = dls.loop(2_000, technique="gss", P=8, runtime="hierarchical",
                  nodes=2)
    s2.restore(st)
    tail = 0
    done = [False] * 8
    while not all(done):
        for pe in range(8):
            if not done[pe]:
                c = s2.claim(pe)
                if c is None:
                    done[pe] = True
                else:
                    tail += c.size
    assert served + tail == 2_000


def test_weighted_outer_aggregates_node_weights():
    """WF over nodes: per-PE weights aggregate to node weights summing to
    ``nodes``, so fast nodes get proportionally larger super-chunks."""
    w = tuple([2.0] * 4 + [0.5] * 4)  # node 0 fast, node 1 slow (sum != P ok)
    rt = HierarchicalRuntime(LoopSpec("wf", N=10_000, P=8, weights=w),
                             nodes=2)
    ow = rt._outer_spec.weights
    assert len(ow) == 2
    assert ow[0] > ow[1]
    assert sum(ow) == pytest.approx(2.0)


def test_validation_errors():
    with pytest.raises(ValueError, match="nodes"):
        dls.loop(100, technique="gss", P=4, runtime="hierarchical")
    with pytest.raises(ValueError, match="nodes"):
        dls.loop(100, technique="gss", P=4, runtime="one_sided", nodes=2)
    with pytest.raises(ValueError, match="inner_technique"):
        dls.loop(100, technique="gss", P=4, inner_technique="tss")
    with pytest.raises(ValueError, match="nodes must be in"):
        HierarchicalRuntime(LoopSpec("gss", N=100, P=4), nodes=8)
    with pytest.raises(ValueError, match="inner technique"):
        HierarchicalRuntime(LoopSpec("gss", N=100, P=4), nodes=2,
                            inner_technique="nope")
    with pytest.raises(ValueError, match="node levels"):
        HierarchicalRuntime(LoopSpec("gss", N=100, P=4), nodes=2,
                            window=HierarchicalWindow(3))


def test_plain_window_becomes_global_level():
    """Passing a flat Window uses it as the global level -- the deployment
    shape where the global window is the KV store and locals are in-process."""
    g = ThreadWindow()
    s = dls.loop(600, technique="gss", P=6, runtime="hierarchical", nodes=2,
                 window=g)
    assert s.runtime.window.global_window is g
    assert sum(c.size for pe in range(6) for c in s.claims(pe)) == 600
    # the global window carries only the outer counters (super-chunk claims)
    assert any("lp" in k for k in g._v)
