"""Serving-engine correctness: batched generation and admission scheduling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serve import ContinuousBatcher, Engine, Request


def _cfg():
    return ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                       n_heads=2, n_kv_heads=2, d_ff=128, vocab=97,
                       dtype="float32")


@pytest.mark.slow
def test_generate_matches_stepwise_greedy():
    """Engine.generate == manual prefill + argmax decode loop."""
    cfg = _cfg()
    params = api.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params)
    prompts = np.random.default_rng(1).integers(0, 97, (3, 6)).astype(np.int32)

    out = eng.generate(prompts, max_new=4)

    cache = api.init_cache(cfg, 3, 32)
    lg, cache = api.prefill(params, cfg, {"tokens": jnp.asarray(prompts)}, cache)
    toks = []
    t = jnp.argmax(lg, -1).astype(jnp.int32)
    for _ in range(4):
        toks.append(np.asarray(t))
        lg, cache = api.decode_step(params, cfg, t, cache)
        t = jnp.argmax(lg, -1).astype(jnp.int32)
    np.testing.assert_array_equal(out, np.stack(toks, 1))


def test_generate_batch_independence():
    """Each sequence's output is independent of its batch-mates."""
    cfg = _cfg()
    params = api.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params)
    rng = np.random.default_rng(2)
    a = rng.integers(0, 97, (1, 6)).astype(np.int32)
    b = rng.integers(0, 97, (1, 6)).astype(np.int32)
    solo = eng.generate(a, max_new=4)
    pair = eng.generate(np.concatenate([a, b]), max_new=4)
    np.testing.assert_array_equal(solo[0], pair[0])


def test_batcher_serves_every_request_once():
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32)) for i in range(101)]
    seen = []

    def process(chunk, worker):
        seen.extend(r.rid for r in chunk)
        return 0.01 * len(chunk)

    cb = ContinuousBatcher(n_workers=5, technique="fac2")
    done = cb.schedule(reqs, process)
    assert sorted(seen) == list(range(101))
    assert (done > 0).all()


def test_plan_jax_inside_jit():
    """The on-device batched planner is jit-compatible (TPU planning path)."""
    from repro.core import LoopSpec, plan, plan_jax

    spec = LoopSpec("gss", N=5000, P=12)

    @jax.jit
    def planner():
        return plan_jax(spec)

    sizes, starts, n = planner()
    np_sizes, np_starts = plan(spec)
    n = int(n)
    np.testing.assert_array_equal(np.asarray(sizes[:n]), np_sizes)
    np.testing.assert_array_equal(np.asarray(starts[:n]), np_starts)


def test_encdec_cross_kv_precompute_equals_recompute():
    """Decode-time cached cross-KV == recomputing from encoder output."""
    from repro.models import encdec
    from repro.models.layers import attention_block, attention_with_kv, project_kv

    cfg = ModelConfig(name="e", family="encdec", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=50,
                      enc_layers=1, dtype="float32")
    params = encdec.init_params(jax.random.key(0), cfg)
    lp = jax.tree.map(lambda a: a[0], params["dec_layers"])
    src = jax.random.normal(jax.random.key(1), (2, 12, 64))
    x = jax.random.normal(jax.random.key(2), (2, 5, 64))
    k, v = project_kv(lp["cross_attn"], src, cfg)
    out_cached = attention_with_kv(lp["cross_attn"], x, k, v, cfg)
    out_direct, _ = attention_block(lp["cross_attn"], x, cfg, causal=False,
                                    xattn_kv=src)
    np.testing.assert_allclose(np.asarray(out_cached), np.asarray(out_direct),
                               atol=1e-5)
