"""Serving-engine correctness: batched generation, admission scheduling,
and the open-loop scenario suite (traffic -> SLO metrics -> online
re-selection -> chaos), pinned by a deterministic regression grid."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serve import (
    RESELECT_ROSTER,
    SLO,
    ContinuousBatcher,
    Engine,
    Request,
    ScenarioReport,
    ServeCostModel,
    TenantClass,
    generate_stream,
    run_scenario,
)
from repro.sim import PEFailure, Straggler


def _cfg():
    return ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                       n_heads=2, n_kv_heads=2, d_ff=128, vocab=97,
                       dtype="float32")


@pytest.mark.slow
def test_generate_matches_stepwise_greedy():
    """Engine.generate == manual prefill + argmax decode loop."""
    cfg = _cfg()
    params = api.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params)
    prompts = np.random.default_rng(1).integers(0, 97, (3, 6)).astype(np.int32)

    out = eng.generate(prompts, max_new=4)

    cache = api.init_cache(cfg, 3, 32)
    lg, cache = api.prefill(params, cfg, {"tokens": jnp.asarray(prompts)}, cache)
    toks = []
    t = jnp.argmax(lg, -1).astype(jnp.int32)
    for _ in range(4):
        toks.append(np.asarray(t))
        lg, cache = api.decode_step(params, cfg, t, cache)
        t = jnp.argmax(lg, -1).astype(jnp.int32)
    np.testing.assert_array_equal(out, np.stack(toks, 1))


def test_generate_batch_independence():
    """Each sequence's output is independent of its batch-mates."""
    cfg = _cfg()
    params = api.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params)
    rng = np.random.default_rng(2)
    a = rng.integers(0, 97, (1, 6)).astype(np.int32)
    b = rng.integers(0, 97, (1, 6)).astype(np.int32)
    solo = eng.generate(a, max_new=4)
    pair = eng.generate(np.concatenate([a, b]), max_new=4)
    np.testing.assert_array_equal(solo[0], pair[0])


def test_batcher_serves_every_request_once():
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32)) for i in range(101)]
    seen = []

    def process(chunk, worker):
        seen.extend(r.rid for r in chunk)
        return 0.01 * len(chunk)

    cb = ContinuousBatcher(n_workers=5, technique="fac2")
    done = cb.schedule(reqs, process)
    assert sorted(seen) == list(range(101))
    assert (done > 0).all()


def test_batcher_populates_request_timing_fields():
    """The once-dead ``t_submit``/``t_first``/``t_done`` fields are filled
    from the simulated clock; TTFT is the chunk's first token, not its
    completion."""
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32)) for i in range(40)]
    cb = ContinuousBatcher(n_workers=3, technique="gss")
    done = cb.schedule(reqs, lambda chunk, w: 0.02 * len(chunk))
    for i, r in enumerate(reqs):
        assert r.t_submit == 0.0
        assert r.t_submit <= r.t_first < r.t_done
        assert r.t_done == pytest.approx(done[i])
        # first token strictly precedes chunk completion (chunks are >= 1
        # requests at 0.02 s each)
        assert r.t_done - r.t_first >= 0.02 - 1e-12


# ---------------------------------------------------------------------------
# open-loop scenario suite: deterministic regression grid
# ---------------------------------------------------------------------------

#: arrival pattern x technique (incl. auto) x chaos on/off
SCENARIO_GRID = [
    ("poisson", "gss", False),
    ("poisson", "auto", False),
    ("bursty", "fac2", True),
    ("bursty", "auto", True),
    ("diurnal", "tss", False),
    ("diurnal", "static", True),
]

_CHAOS = (PEFailure(1, at=0.4), Straggler(2, at=0.2, factor=0.5))


def _scenario(arrival, technique, chaos, *, n=80, seed=0):
    stream = generate_stream(n, arrival=arrival, rate=25.0, seed=5,
                             tenants=[TenantClass("free", 0.7, 0),
                                      TenantClass("pro", 0.3, 2)])
    return run_scenario(
        stream, n_workers=4, technique=technique,
        perturbations=_CHAOS if chaos else (),
        reselect_every_s=0.5 if technique == "auto" else None,
        seed=seed)


@pytest.mark.parametrize("arrival,technique,chaos", SCENARIO_GRID)
def test_scenario_exactly_once(arrival, technique, chaos):
    """Every request completes exactly once -- through priority batches,
    re-selection switches, and worker-death requeues alike."""
    rep = _scenario(arrival, technique, chaos)
    rids = [r["rid"] for r in rep.requests]
    assert sorted(rids) == list(range(80))
    assert rep.slo.n_completed == 80
    for r in rep.requests:
        assert r["t_submit"] <= r["t_first"] <= r["t_done"]
    if chaos:
        assert rep.chaos, "chaos scenario logged no events"


def _strip_wall_clock(obj):
    """Drop ``sweep_s`` keys (measured wall time, the one report field
    that is *meant* to differ run to run) before byte comparison."""
    if isinstance(obj, dict):
        return {k: _strip_wall_clock(v) for k, v in obj.items()
                if k != "sweep_s"}
    if isinstance(obj, list):
        return [_strip_wall_clock(v) for v in obj]
    return obj


@pytest.mark.parametrize("arrival,technique,chaos", SCENARIO_GRID)
def test_scenario_report_deterministic(arrival, technique, chaos):
    """Same stream + seed -> byte-identical scenario report JSON
    (modulo measured sweep wall time, which is wall-clock by design)."""
    a = _strip_wall_clock(_scenario(arrival, technique, chaos).to_dict())
    b = _strip_wall_clock(_scenario(arrival, technique, chaos).to_dict())
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_scenario_report_roundtrip():
    rep = _scenario("bursty", "auto", True)
    back = ScenarioReport.from_json(rep.to_json())
    assert back.to_json() == rep.to_json()
    assert back.final_technique == rep.final_technique
    with pytest.raises(ValueError):
        ScenarioReport.from_dict({"schema_version": 999})


def test_reselection_decisions_recorded_with_full_ranking():
    rep = _scenario("poisson", "auto", False)
    assert rep.reselections, "auto scenario recorded no decisions"
    boot = rep.reselections[0]
    assert boot["from"] == "auto" and boot["switched"]
    for d in rep.reselections:
        assert set(d) >= {"t", "epoch", "from", "to", "switched",
                          "sweep_s", "decision"}
        # the sweep's own cost is part of the record: wall time at both
        # levels, execution route per candidate
        assert d["sweep_s"] is not None and d["sweep_s"] >= 0.0
        assert d["decision"]["sweep_s"] == d["sweep_s"]
        ranking = d["decision"]["ranking"]
        assert len(ranking) == len(RESELECT_ROSTER)
        assert d["decision"]["chosen"] == ranking[0]["technique"]
        assert d["to"] in RESELECT_ROSTER
        for p in ranking:
            assert p["engine"] in ("fast-batch", "fast", "kernel")
    # live windowed re-selections (not the hints bootstrap) carry the
    # fitted constants that warm-start the next tick
    live = [d for d in rep.reselections
            if d["decision"]["source"] == "trace"]
    assert live, "scenario produced no live-trace re-selections"
    for d in live:
        assert set(d["decision"]["fitted"]) == {"o_rma", "o_rma_local",
                                                "o_serve"}


def test_priority_classes_shape_tenant_ttft():
    """Under backlog, the high-priority tenant's median TTFT beats the
    low-priority tenant's (priority-ordered admission)."""
    cm = ServeCostModel(prefill_per_token=2e-5, tok_seconds=8e-4,
                        sched_overhead=0.01)
    stream = generate_stream(200, arrival="bursty", rate=80.0, seed=11,
                             tenants=[TenantClass("free", 0.7, 0),
                                      TenantClass("pro", 0.3, 5)])
    rep = run_scenario(stream, n_workers=4, technique="gss",
                       cost_model=cm, seed=0, keep_requests=False)
    pt = rep.slo.per_tenant
    assert pt["pro"]["ttft_p50"] < pt["free"]["ttft_p50"]


def test_chaos_death_requeues_and_conserves():
    """A worker dying mid-decode requeues its unfinished requests; they
    still complete exactly once on the survivors, and the accounting
    (chaos log, requeue counters, SLO plane) agrees."""
    stream = generate_stream(120, arrival="poisson", rate=40.0, seed=3)
    rep = run_scenario(stream, n_workers=4, technique="static",
                       perturbations=(PEFailure(0, at=0.05),), seed=0)
    assert sorted(r["rid"] for r in rep.requests) == list(range(120))
    deaths = [e for e in rep.chaos if e["kind"] == "death"]
    assert len(deaths) == 1 and deaths[0]["worker"] == 0
    assert rep.n_requeued == deaths[0]["requeued"] > 0
    assert rep.slo.n_requeued == sum(r["requeues"] for r in rep.requests)
    # every surviving row ran on a surviving worker after the death
    for r in rep.requests:
        if r["requeues"]:
            assert r["worker"] != 0


def test_epoch_reports_carry_slo_and_reselections():
    """Per-epoch ``SessionReport``s round-trip with the new ``slo`` +
    ``reselections`` fields attached."""
    from repro.dls import SessionReport
    from repro.serve import SLOReport

    rep = _scenario("bursty", "auto", False, n=60)
    assert rep.epoch_reports is None  # off by default
    rep = run_scenario(
        generate_stream(60, arrival="bursty", rate=25.0, seed=5),
        n_workers=4, technique="auto", reselect_every_s=0.5, seed=0,
        keep_epoch_reports=True)
    assert rep.epoch_reports
    first = SessionReport.from_dict(rep.epoch_reports[0])
    assert first.reselections and first.reselections[0]["from"] == "auto"
    for d in rep.epoch_reports:
        sr = SessionReport.from_dict(d)
        if sr.slo is not None:
            SLOReport.from_dict(sr.slo)  # valid versioned SLO slice


def test_trace_window_rebases_and_calibrates():
    """``Trace.window`` keeps only chunks live in the window, rebased to
    t=0, and the windowed trace still calibrates."""
    from repro.replay import ChunkRecord, Trace, calibrate

    recs = [ChunkRecord(pe=i % 2, step=i, start=4 * i, size=4,
                        t0=float(i), t1=float(i) + 0.9, lat=0.01)
            for i in range(10)]
    tr = Trace(technique="ss", N=40, P=2, runtime="one_sided",
               executor="serve", wall_time=10.0, records=recs)
    w = tr.window(5.0, 8.0)
    assert len(w.records) == 3  # t1 > 5 and t0 < 8: chunks 5, 6, 7
    assert w.records[0].t0 == pytest.approx(0.0)
    assert w.N == sum(r.size for r in w.records)
    assert w.meta["window"] == [5.0, 8.0]
    calib = calibrate(w, seed=0)
    assert calib.costs.shape == (w.N,)
    assert tr.window(100.0).records == []


def test_overload_reselection_beats_worst_fixed():
    """THE acceptance pin: under seeded overload the online controller
    switches technique mid-stream and beats the worst fixed technique on
    both p99 TTFT and goodput (mirrored by benchmarks/serving_slo.py)."""
    cm = ServeCostModel(prefill_per_token=2e-5, tok_seconds=8e-4,
                        sched_overhead=0.03)
    stream = generate_stream(300, arrival="bursty", rate=60.0, seed=7,
                             max_new_tail=1.1, max_new_scale=20.0,
                             max_new_cap=512)
    slo = SLO(ttft_s=0.25)
    fixed = {t: run_scenario(stream, n_workers=4, technique=t,
                             cost_model=cm, slo=slo, seed=0,
                             keep_requests=False)
             for t in ("static", "ss", "gss", "fac2", "tss")}
    auto = run_scenario(stream, n_workers=4, technique="auto",
                        cost_model=cm, slo=slo, seed=0,
                        reselect_every_s=1.0, keep_requests=False)

    # the controller actually re-selected mid-stream (not just bootstrap)
    assert auto.n_switches >= 1
    mid = [d for d in auto.reselections if d["switched"] and d["t"] > 0.5]
    assert mid, "no mid-stream switch"

    worst = max(fixed.values(), key=lambda r: r.slo.ttft["p99"])
    assert worst.technique == "ss"  # fine-grained claims drown in overhead
    assert auto.slo.ttft["p99"] < worst.slo.ttft["p99"]
    assert auto.slo.goodput_tokens_per_s > worst.slo.goodput_tokens_per_s
    # pin the decision path: bootstrap adopts fac2, live trace exposes the
    # claim overhead and the controller re-selects gss
    assert auto.reselections[0]["to"] == "fac2"
    assert mid[0]["to"] == "gss"


def test_plan_jax_inside_jit():
    """The on-device batched planner is jit-compatible (TPU planning path)."""
    from repro.core import LoopSpec, plan, plan_jax

    spec = LoopSpec("gss", N=5000, P=12)

    @jax.jit
    def planner():
        return plan_jax(spec)

    sizes, starts, n = planner()
    np_sizes, np_starts = plan(spec)
    n = int(n)
    np.testing.assert_array_equal(np.asarray(sizes[:n]), np_sizes)
    np.testing.assert_array_equal(np.asarray(starts[:n]), np_starts)


def test_encdec_cross_kv_precompute_equals_recompute():
    """Decode-time cached cross-KV == recomputing from encoder output."""
    from repro.models import encdec
    from repro.models.layers import attention_block, attention_with_kv, project_kv

    cfg = ModelConfig(name="e", family="encdec", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=50,
                      enc_layers=1, dtype="float32")
    params = encdec.init_params(jax.random.key(0), cfg)
    lp = jax.tree.map(lambda a: a[0], params["dec_layers"])
    src = jax.random.normal(jax.random.key(1), (2, 12, 64))
    x = jax.random.normal(jax.random.key(2), (2, 5, 64))
    k, v = project_kv(lp["cross_attn"], src, cfg)
    out_cached = attention_with_kv(lp["cross_attn"], x, k, v, cfg)
    out_direct, _ = attention_block(lp["cross_attn"], x, cfg, causal=False,
                                    xattn_kv=src)
    np.testing.assert_allclose(np.asarray(out_cached), np.asarray(out_direct),
                               atol=1e-5)
