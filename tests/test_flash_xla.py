"""Pure-XLA flash attention (chunked + custom VJP) vs dense autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _sdpa_chunked, _sdpa_xla


def _inputs(B, Tq, Tk, H, Hkv, D, seed=0):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(B, Tq, H, D)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, Tk, Hkv, D)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, Tk, Hkv, D)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("B,Tq,Tk,H,Hkv,D,causal,window", [
    (1, 256, 256, 4, 2, 32, True, None),     # GQA causal
    (2, 200, 200, 2, 2, 32, True, None),     # ragged (padding path)
    (1, 256, 256, 4, 4, 32, True, 64),       # SWA band
    (1, 128, 320, 2, 1, 32, False, None),    # cross lengths, bidirectional
])
def test_flash_forward_matches_dense(B, Tq, Tk, H, Hkv, D, causal, window):
    q, k, v = _inputs(B, Tq, Tk, H, Hkv, D)
    out = _sdpa_chunked(q, k, v, causal=causal, window=window,
                        blk_q=64, blk_k=64)
    ref = _sdpa_xla(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal,window,Hkv", [
    (True, None, 2), (True, 48, 4), (False, None, 1),
])
def test_flash_vjp_matches_dense_autodiff(causal, window, Hkv):
    B, T, H, D = 1, 192, 4, 32
    q, k, v = _inputs(B, T, T, H, Hkv, D, seed=3)

    def loss_flash(q, k, v):
        o = _sdpa_chunked(q, k, v, causal=causal, window=window,
                          blk_q=64, blk_k=64)
        return jnp.sum(jnp.sin(o))

    def loss_dense(q, k, v):
        o = _sdpa_xla(q, k, v, causal=causal, window=window)
        return jnp.sum(jnp.sin(o))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gd, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4, err_msg=f"d{name}")


def test_flash_vjp_no_nan_on_fully_masked_rows():
    """Padded/fully-masked rows must produce zero grads, not NaN."""
    B, T, H, D = 1, 100, 2, 16  # pads to 128 with blk 64: 28 dead rows
    q, k, v = _inputs(B, T, T, H, H, D, seed=5)

    def loss(q, k, v):
        return jnp.sum(_sdpa_chunked(q, k, v, causal=True, window=None,
                                     blk_q=64, blk_k=64) ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for a in g:
        assert bool(jnp.isfinite(a).all())


def test_train_path_uses_flash_above_threshold():
    """A 4096-token train forward must route through the chunked path
    (no (T, T) f32 tensor anywhere in the jaxpr)."""
    from repro.configs.base import ModelConfig
    from repro.models import api

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=128,
                      n_heads=2, n_kv_heads=2, d_ff=128, vocab=64,
                      dtype="float32")
    toks = jnp.zeros((1, 4096), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda p: api.forward(p, cfg, {"tokens": toks})
    )(api.init_params(jax.random.key(0), cfg))
    big = 4096 * 4096
    for eqn in jaxpr.jaxpr.eqns:
        for var in eqn.outvars:
            if hasattr(var, "aval") and hasattr(var.aval, "shape"):
                import math

                assert math.prod(var.aval.shape or (1,)) < big, (
                    f"materialized {var.aval.shape} in {eqn.primitive}")
