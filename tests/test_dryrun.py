"""Dry-run machinery tests (scaled-down meshes; production runs in sweep).

These run dryrun.py as a subprocess (the 8-device host-platform override
must happen before jax init, and the main test process must keep 1 device).
"""
import json
import subprocess
import sys

import pytest

REPO = __file__.rsplit("/tests/", 1)[0]


def run_cell(arch, shape, mesh, tmp, extra=()):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", mesh,
           "--devices", "8", "--batch", "16", "--out", str(tmp), *extra]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=1500,
                       cwd=REPO, env={"PYTHONPATH": f"{REPO}/src",
                                      "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    rec = json.load(open(f"{tmp}/{arch}_{shape}_{mesh}.json"))
    return rec


@pytest.mark.slow
def test_dryrun_train_cell_pod(tmp_path):
    rec = run_cell("tinyllama-1.1b", "train_4k", "pod", tmp_path)
    assert rec["status"] == "ok"
    assert rec["cost"]["flops"] > 1e13  # trip-adjusted, not loop-body-once
    assert rec["cost"]["flops"] > 10 * rec["cost"]["xla_flops_raw"]
    assert rec["memory"]["peak_bytes"] > 0
    assert rec["collective_moved_bytes"] > 0
    assert "all-gather" in rec["collectives"] or "all-reduce" in rec["collectives"]


@pytest.mark.slow
def test_dryrun_decode_cell_multipod(tmp_path):
    rec = run_cell("mamba2-370m", "decode_32k", "multipod", tmp_path)
    assert rec["status"] == "ok"
    assert rec["mesh_shape"]["pod"] == 2


@pytest.mark.slow
def test_dryrun_skip_policy(tmp_path):
    rec = run_cell("deepseek-67b", "long_500k", "pod", tmp_path)
    assert rec["status"] == "skipped"
    rec = run_cell("h2o-danube-3-4b", "long_500k", "pod", tmp_path)
    assert rec["status"] == "ok"  # SWA is sub-quadratic


def test_hlo_analysis_on_sample():
    """Analyzer math on a handcrafted mini-HLO."""
    from repro.launch.hlo_analysis import analyze_hlo

    txt = """\
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %d = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,16] all-gather(%d), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ag)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[8,16]) tuple(%z, %a)
  %w2 = (s32[], f32[8,16]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %o = f32[8,16] get-tuple-element(%w2), index=1
}
"""
    r = analyze_hlo(txt)
    # dot: 2*8*16*16 = 4096 flops, x5 trips
    assert r["flops"] == 5 * 4096, r["flops"]
    ag = r["collectives"]["all-gather"]
    assert ag["count"] == 5
    # ring model: result 8*16*4 bytes * (4-1)/4 per execution
    assert abs(ag["moved_bytes"] - 5 * 512 * 0.75) < 1e-6


def test_shard_rules_cover_all_archs():
    """Every param leaf of every arch gets a rank-matching PartitionSpec."""
    import jax

    from repro.configs import ARCHS
    from repro.models import api
    from repro.shard import params_pspecs

    for name, cfg in ARCHS.items():
        sds = api.abstract_params(cfg)
        specs = params_pspecs(sds)
        arr_leaves = jax.tree_util.tree_flatten(sds)[0]
        from jax.sharding import PartitionSpec as P

        spec_leaves = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
        assert len(arr_leaves) == len(spec_leaves)
        for a, s in zip(arr_leaves, spec_leaves):
            assert len(s) == a.ndim, (name, a.shape, s)


def test_fix_divisibility_drops_bad_axes():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.shard import fix_divisibility

    mesh = jax.make_mesh((1,), ("model",))  # model size 1: everything divides
    tree = {"a": jax.ShapeDtypeStruct((7, 8), jnp.float32)}
    fixed = fix_divisibility(tree, {"a": P("model", None)}, mesh)
    assert fixed["a"] == P("model", None)

    # fake a 4-wide axis via test mesh helper semantics
    import numpy as np

    class FakeMesh:
        axis_names = ("model",)
        devices = np.empty((4,), dtype=object)

    fixed = fix_divisibility(tree, {"a": P("model", None)}, FakeMesh())
    assert fixed["a"] == P(None, None)  # 7 % 4 != 0 -> dropped
    fixed = fix_divisibility({"a": jax.ShapeDtypeStruct((8, 7), jnp.float32)},
                             {"a": P("model", None)}, FakeMesh())
    assert fixed["a"] == P("model", None)
