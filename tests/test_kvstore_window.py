"""Real coordination-service window: the production multi-host claim path.

Runs jax.distributed.initialize() in a subprocess (single-process service)
and exercises KVStoreWindow's atomic fetch-add + a full OneSidedRuntime loop
against it -- validating the exact code path a TPU cluster would use.
"""
import subprocess
import sys

REPO = __file__.rsplit("/tests/", 1)[0]

SCRIPT = r"""
import jax
jax.distributed.initialize(coordinator_address="localhost:12355",
                           num_processes=1, process_id=0)
from repro.core import LoopSpec, OneSidedRuntime
from repro.core.rma import KVStoreWindow

win = KVStoreWindow(namespace="test/dls")
# atomic fetch-add semantics: returns the OLD value
assert win.fetch_add("ctr", 5) == 0
assert win.fetch_add("ctr", 3) == 5
assert win.read("ctr") == 8

# full self-scheduled loop through the coordination service
spec = LoopSpec("fac2", N=1000, P=4)
rt = OneSidedRuntime(spec, win, loop_id=7)
total, claims = 0, 0
while True:
    c = rt.claim(0)
    if c is None:
        break
    total += c.size
    claims += 1
assert total == 1000, total
print(f"KVSTORE_OK claims={claims}")
"""


def test_kvstore_window_real_coordination_service():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=300, cwd=REPO,
        env={"PYTHONPATH": f"{REPO}/src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "KVSTORE_OK" in r.stdout
