"""Real coordination-service window: the production multi-host claim path.

Runs jax.distributed.initialize() in a subprocess (single-process service)
and exercises KVStoreWindow's atomic fetch-add + a full one-sided session
against it -- validating the exact code path a TPU cluster would use.

Skipped (not failed) when the installed jax's coordination client lacks
``key_value_increment``: without a server-side atomic RMW there is nothing
correct to build a passive-target window on (see KVStoreWindow.available).
"""
import subprocess
import sys

import pytest

REPO = __file__.rsplit("/tests/", 1)[0]

SCRIPT = r"""
import jax
jax.distributed.initialize(coordinator_address="localhost:12355",
                           num_processes=1, process_id=0)
from repro import dls
from repro.core.rma import KVStoreWindow

win = KVStoreWindow(namespace="test/dls")
# atomic fetch-add semantics: returns the OLD value
assert win.fetch_add("ctr", 5) == 0
assert win.fetch_add("ctr", 3) == 5
assert win.read("ctr") == 8

# full self-scheduled loop through the coordination service
session = dls.loop(1000, technique="fac2", P=4, window=win, loop_id=7)
total = sum(c.size for c in session.claims(0))
assert total == 1000, total
assert session.drained()
print(f"KVSTORE_OK claims={session.report().steps}")
"""


def test_kvstore_window_real_coordination_service():
    from repro.core.rma import KVStoreWindow

    ok, reason = KVStoreWindow.availability()
    if not ok:
        pytest.skip(f"KVStoreWindow unavailable: {reason}")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=300, cwd=REPO,
        env={"PYTHONPATH": f"{REPO}/src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "KVSTORE_OK" in r.stdout
