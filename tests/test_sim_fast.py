"""Differential equivalence harness: fast path vs the event kernel.

The contract of ``repro.sim.fast`` (DESIGN.md Sec. 12) is *equivalence*,
not approximation: for every qualifying configuration,
``simulate_fast(cf)`` must return byte-for-byte the same ``SimResult``
the event kernel returns -- same canonical-JSON encoding, same floats,
same event ordering observable through latencies and grant counts.
Three layers enforce it:

  * the shared golden grid of ``_sim_golden_cases`` (rebuilt with
    ``collect_trace=False`` so the cases qualify), every qualifying
    case run through both engines and compared canonically;
  * a seeded random grid over technique x topology x P up to 1024 --
    heterogeneous continuous speeds (no structural boundary ties) and
    lognormal costs on both polling policies, which exercises the
    vectorized round, the tie walk, and the hazard-truncation path;
  * a hypothesis fuzz layer (when hypothesis is importable) over the
    same differential property plus the conservation-to-N and seed
    determinism invariants of ``test_invariants.py``.

Also covered: the ``_MTReplay`` Mersenne-Twister clone against CPython's
``random.Random`` (the Lock-Polling grant order must be bit-identical),
and the opt-in jax backend's 1e-9 relative contract.
"""
import dataclasses
import json
import random

import numpy as np
import pytest

import _sim_golden_cases as gc
from repro.core.chunk_calculus import LoopSpec
from repro.core.sim import SimConfig, simulate
from repro.sim import (
    SweepCache,
    fast_qualifies,
    simulate_fast,
    simulate_fast_many,
    simulate_many,
)
from repro.sim.fast import _MTReplay

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the no-hypothesis job
    HAVE_HYPOTHESIS = False


def canon(r) -> str:
    return json.dumps(gc.encode_result(r), sort_keys=True)


def assert_same(cf, msg=""):
    """The differential property: fast == kernel, byte for byte."""
    rk = simulate(cf, engine="kernel")
    rf = simulate_fast(cf)
    assert canon(rk) == canon(rf), \
        f"fast path drifted from the event kernel: {msg}"


# ---------------------------------------------------------------------------
# golden grid, re-qualified (collect_trace off)
# ---------------------------------------------------------------------------

_CASES = gc.cases()
_KEYS = [c["key"] for c in _CASES]


def _no_trace(case: dict) -> SimConfig:
    return dataclasses.replace(gc.build_config(case), collect_trace=False)


@pytest.mark.parametrize("key", _KEYS)
def test_golden_grid_differential(key):
    case = next(c for c in _CASES if c["key"] == key)
    cf = _no_trace(case)
    assert fast_qualifies(cf)
    assert_same(cf, key)


def test_golden_grid_has_all_topologies():
    routed = {c["runtime"] for c in _CASES}
    assert routed == {"one_sided", "two_sided", "hierarchical"}


# ---------------------------------------------------------------------------
# seeded random grid (vector round, tie walk, hazard truncation)
# ---------------------------------------------------------------------------

_GRID = [
    (tech, impl, P)
    for tech in gc.NON_ADAPTIVE
    for impl in ("one_sided", "two_sided", "hierarchical")
    for P in (4, 64, 288, 1024)
]


def _random_config(tech, impl, P, seed, *, polling, continuous):
    rng = np.random.default_rng(seed)
    N = {4: 300, 64: 1500, 288: 4000, 1024: 8000}[P]
    sigma = np.sqrt(np.log(1.0 + 0.25))
    costs = rng.lognormal(np.log(2e-4) - sigma ** 2 / 2, sigma, size=N)
    if continuous:  # no structural boundary ties: the pure vector round
        speeds = rng.uniform(0.25, 1.0, size=P)
    else:  # golden-style speed tiles: exact ties + near-EPS hazards
        speeds = np.tile([1.0, 0.5, 0.25], P // 3 + 1)[:P]
    kw = {}
    if impl == "hierarchical":
        kw = dict(nodes=max(P // 32, 1), inner_technique="ss")
    return SimConfig(LoopSpec(tech, N=N, P=P), speeds, costs, impl=impl,
                     seed=seed, lock_polling_random=polling,
                     collect_trace=False, **kw)


@pytest.mark.parametrize("tech,impl,P", _GRID)
def test_random_grid_differential(tech, impl, P):
    # derive per-case determinism from the grid position
    seed = (hash((tech, impl)) & 0xFFFF) + P
    polling = (P % 2 == 0) if impl == "one_sided" else True
    cf = _random_config(tech, impl, P, seed,
                        polling=polling, continuous=(P % 3 != 0))
    assert_same(cf, f"{tech}/{impl}/P={P}")


@pytest.mark.parametrize("polling", [False, True])
@pytest.mark.parametrize("continuous", [False, True])
def test_contended_fifo_round(polling, continuous):
    """The regime the batch round targets: big FIFO backlog, window-
    bound workload -- both with structural ties (tiled speeds) and
    without (continuous speeds)."""
    cf = _random_config("ss", "one_sided", 288, 99,
                        polling=polling, continuous=continuous)
    cf = dataclasses.replace(cf, costs=np.full(cf.spec.N, 1e-5))
    assert_same(cf, f"contended polling={polling} continuous={continuous}")


def test_conservation_and_determinism():
    cf = _random_config("gss", "one_sided", 64, 5,
                        polling=True, continuous=True)
    r1 = simulate_fast(cf)
    r2 = simulate_fast(cf)
    assert canon(r1) == canon(r2)  # same seed -> same bytes
    assert int(np.sum(r1.per_pe_iters)) == cf.spec.N


# ---------------------------------------------------------------------------
# MT19937 replay: the Lock-Polling grant order must be bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 20240807, 999983])
def test_mt_replay_matches_random_random(seed):
    ref = random.Random(seed)
    rep = _MTReplay(seed)
    sizes = [1, 2, 3, 4, 5, 7, 8, 31, 32, 33, 100, 624, 625, 65537] * 60
    for n in sizes:
        assert rep.randrange(n) == ref.randrange(n)


def test_mt_replay_across_twist_boundary():
    # 624-word state: cross several refills with draws that reject often
    ref = random.Random(42)
    rep = _MTReplay(42)
    for _ in range(5000):
        assert rep.randrange(3) == ref.randrange(3)


# ---------------------------------------------------------------------------
# jax backend: 1e-9 relative, opt-in, x64 only
# ---------------------------------------------------------------------------

def test_jax_backend_close():
    jax = pytest.importorskip("jax")
    was = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        cf = _random_config("ss", "one_sided", 64, 17,
                            polling=False, continuous=True)
        rn = simulate_fast(cf, backend="numpy")
        rj = simulate_fast(cf, backend="jax")
        np.testing.assert_allclose(rj.finish, rn.finish, rtol=1e-9)
        np.testing.assert_allclose(rj.T_loop, rn.T_loop, rtol=1e-9)
        assert rj.n_claims == rn.n_claims
        assert list(rj.per_pe_iters) == list(rn.per_pe_iters)
    finally:
        jax.config.update("jax_enable_x64", was)


def test_jax_backend_requires_x64():
    jax = pytest.importorskip("jax")
    if jax.config.jax_enable_x64:  # pragma: no cover - env-dependent
        pytest.skip("x64 already on in this environment")
    import repro.sim.fast as fast_mod
    fast_mod._JAX_CORE = None  # drop any x64-built cache
    cf = _random_config("ss", "one_sided", 64, 17,
                        polling=False, continuous=True)
    with pytest.raises(RuntimeError, match="x64"):
        simulate_fast(cf, backend="jax")
    fast_mod._JAX_CORE = None


def test_unknown_backend_rejected():
    cf = _random_config("ss", "one_sided", 4, 0,
                        polling=True, continuous=True)
    with pytest.raises(ValueError, match="backend"):
        simulate_fast(cf, backend="cuda")


# ---------------------------------------------------------------------------
# hypothesis layer
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(
        tech=st.sampled_from(gc.NON_ADAPTIVE),
        impl=st.sampled_from(["one_sided", "two_sided", "hierarchical"]),
        P=st.integers(min_value=1, max_value=40),
        N=st.integers(min_value=1, max_value=600),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        polling=st.booleans(),
        tiled=st.booleans(),
    )
    def test_fuzz_differential(tech, impl, P, N, seed, polling, tiled):
        rng = np.random.default_rng(seed)
        costs = rng.lognormal(np.log(1e-4), 0.5, size=N)
        speeds = (np.tile([1.0, 0.5, 0.25], P // 3 + 1)[:P] if tiled
                  else rng.uniform(0.2, 1.0, size=P))
        kw = dict(nodes=max(P // 8, 1), inner_technique="ss") \
            if impl == "hierarchical" else {}
        cf = SimConfig(LoopSpec(tech, N=N, P=P), speeds, costs,
                       impl=impl, seed=seed, lock_polling_random=polling,
                       collect_trace=False, **kw)
        rk = simulate(cf, engine="kernel")
        rf = simulate_fast(cf)
        assert canon(rk) == canon(rf)
        assert int(np.sum(rf.per_pe_iters)) == N  # conservation to N


# ---------------------------------------------------------------------------
# batched sweeps: simulate_fast_many over one shared SweepCache
# ---------------------------------------------------------------------------


def test_batched_matches_per_config_on_golden_grid():
    """Sharing sweep setup must not change a single byte: the whole
    golden roster batched through one cache == per-config fast path."""
    cfs = [_no_trace(c) for c in _CASES]
    info = {}
    batched = simulate_fast_many(cfs, info=info)
    assert info["engines"] == ["fast-batch"] * len(cfs)
    for case, cf, r in zip(_CASES, cfs, batched):
        assert canon(r) == canon(simulate_fast(cf)), case["key"]


def _shared_roster(seed=0, P=64, N=1500):
    """A selection-style roster: every candidate references the *same*
    costs/speeds arrays (what replay.sweep builds from one calibration)."""
    rng = np.random.default_rng(seed)
    costs = rng.lognormal(np.log(2e-4), 0.5, size=N)
    speeds = rng.uniform(0.25, 1.0, size=P)
    out = []
    for tech in gc.NON_ADAPTIVE:
        for impl in ("one_sided", "two_sided", "hierarchical"):
            kw = dict(nodes=P // 16, inner_technique="ss") \
                if impl == "hierarchical" else {}
            out.append(SimConfig(LoopSpec(tech, N=N, P=P), speeds, costs,
                                 impl=impl, seed=seed, collect_trace=False,
                                 **kw))
    return out


def test_batched_shared_costs_random_roster():
    roster = _shared_roster(seed=7)
    cache = SweepCache()
    batched = simulate_fast_many(roster, cache=cache)
    # one shared cost array -> exactly one prefix-sum entry; the three
    # runtime variants of each technique share one chunk-table build
    assert len(cache._pref) == 1
    assert len(cache._speeds) == 1
    for cf, r in zip(roster, batched):
        assert canon(r) == canon(simulate_fast(cf))


def test_batched_mixed_roster_demotes_nonqualifying():
    """Adaptive / perturbed / traced candidates drop to the kernel
    mid-roster; their fast-qualifying peers stay batched."""
    roster = _shared_roster(seed=11)[:4]
    adaptive = dataclasses.replace(
        roster[0], spec=dataclasses.replace(roster[0].spec,
                                            technique="awf_b"))
    traced = dataclasses.replace(roster[1], collect_trace=True)
    mixed = [roster[0], adaptive, roster[2], traced, roster[3]]
    info = {}
    batched = simulate_fast_many(mixed, info=info)
    assert info["engines"] == ["fast-batch", "kernel", "fast-batch",
                               "kernel", "fast-batch"]
    for cf, r in zip(mixed, batched):
        assert canon(r) == canon(simulate(cf, engine="auto"))


def test_batched_hazard_demotion_mid_batch():
    """A tie/hazard-prone candidate (tiled speeds: exact boundary ties)
    mid-batch falls back to its serial cooldown without perturbing its
    batch peers."""
    roster = _shared_roster(seed=3)[:3]
    P, N = 64, 1500
    rng = np.random.default_rng(3)
    tiled = SimConfig(
        LoopSpec("ss", N=N, P=P),
        np.tile([1.0, 0.5, 0.25], P // 3 + 1)[:P],
        np.full(N, 1e-5),  # contended: backlogged window, max ties
        impl="one_sided", seed=3, collect_trace=False)
    batch = [roster[0], tiled, roster[1], roster[2]]
    for cf, r in zip(batch, simulate_fast_many(batch)):
        assert canon(r) == canon(simulate(cf, engine="kernel"))


def test_batched_budget_first_always_evaluated():
    roster = _shared_roster(seed=5)[:6]
    info = {}
    results = simulate_fast_many(roster, budget_s=0.0, info=info)
    assert results[0] is not None  # >= 1 candidate always evaluated
    assert results[1:] == [None] * 5
    assert info["engines"][0] == "fast-batch"
    assert info["engines"][1:] == [None] * 5
    # and the same contract through simulate_many's serial batched path
    info2 = {}
    results2 = simulate_many(roster, workers=1, budget_s=0.0, info=info2)
    assert results2[0] is not None and results2[1:] == [None] * 5
    assert canon(results2[0]) == canon(results[0])


def test_batched_engine_fast_raises_on_nonqualifying():
    roster = _shared_roster(seed=9)[:2]
    traced = dataclasses.replace(roster[1], collect_trace=True)
    with pytest.raises(ValueError, match="does not qualify"):
        simulate_fast_many([roster[0], traced], engine="fast")


def test_sweep_cache_pins_identity_and_evicts():
    cache = SweepCache(max_entries=2)
    a = np.ones(10)
    pref_a, list_a = cache.pref(a)
    assert cache.pref(a)[0] is pref_a  # hit: same object back
    b, c = np.ones(5), np.ones(7)
    cache.pref(b)
    cache.pref(c)  # third entry: evicts the oldest, cache stays bounded
    assert len(cache._pref) == 2
    # identity keying holds the keyed array: a stale id can't alias
    for ref, _, _ in cache._pref.values():
        assert ref is b or ref is c
