"""repro.replay: capture, round-trip, calibration, prediction, auto, CLI.

The acceptance contract of the replay subsystem (DESIGN.md Sec. 9):

* traces round-trip byte-stably through JSONL and the ``TraceStore``;
* ``SessionReport`` persists (``to_json``/``from_json``, versioned);
* calibrate/predict are deterministic for a fixed trace + seed;
* a recorded sim trace, replayed through the calibrated DES, reproduces
  the native ``T_loop`` within a pinned percent error;
* ``dls.loop(..., technique="auto")`` selects the predicted-best
  technique (top of its own sweep) and records the decision;
* the ``python -m repro.replay`` CLI records/renders end to end.
"""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro import dls
from repro.core.chunk_calculus import TECHNIQUES
from repro.replay import (
    Trace,
    TraceStore,
    calibrate,
    choose_technique,
    gantt_ascii,
    gantt_svg,
    predict,
    sweep,
)

N, P, SEED = 2_000, 4, 0


def _workload(n=N, seed=SEED, mean=1e-3, cov=0.3):
    rng = np.random.default_rng(seed)
    sigma = np.sqrt(np.log(1 + cov * cov))
    return rng.lognormal(np.log(mean) - sigma**2 / 2, sigma, size=n)


def _het_speeds(p=P):
    s = np.ones(p)
    s[p // 2:] = 0.5
    return s


def _sim_trace(technique="fac2", runtime="one_sided", n=N, p=P, seed=SEED,
               **loop_kw):
    session = dls.loop(n, technique=technique, P=p, runtime=runtime,
                       **loop_kw)
    report = session.execute(None, executor="sim", costs=_workload(n),
                             speeds=_het_speeds(p), seed=seed,
                             collect_trace=True)
    return Trace.from_report(report, meta={"seed": seed}), report


# ---------------------------------------------------------------------------
# capture
# ---------------------------------------------------------------------------


def test_sim_executor_captures_chunk_times():
    trace, report = _sim_trace()
    assert report.chunk_times, "sim executor must emit chunk timing"
    assert trace.iters_covered() == N
    assert all(r.t1 >= r.t0 >= 0.0 for r in trace.records)
    assert max(r.t1 for r in trace.records) <= report.wall_time + 1e-9


@pytest.mark.parametrize("runtime,kw", [
    ("one_sided", {}),
    ("two_sided", {}),
    ("hierarchical", {"nodes": 2, "inner_technique": "ss"}),
])
def test_capture_covers_loop_any_runtime(runtime, kw):
    trace, _ = _sim_trace(technique="gss", runtime=runtime, n=800, **kw)
    assert trace.iters_covered() == 800
    # every iteration exactly once
    seen = np.zeros(800, dtype=np.int64)
    for r in trace.records:
        seen[r.start:r.stop] += 1
    assert (seen == 1).all()


def test_serial_executor_captures_chunk_times():
    session = dls.loop(500, technique="fac2", P=4)
    report = session.execute(lambda a, b: None, executor="serial")
    assert report.chunk_times and len(report.chunk_times) == report.steps
    trace = Trace.from_report(report)
    assert trace.iters_covered() == 500


def test_threads_executor_captures_chunk_times():
    session = dls.loop(300, technique="gss", P=4)
    report = session.execute(
        lambda a, b: time.sleep(1e-4 * (b - a)), executor="threads")
    trace = Trace.from_report(report)
    assert trace.iters_covered() == 300
    assert all(r.seconds >= 0 for r in trace.records)


# ---------------------------------------------------------------------------
# round trips (byte-stable)
# ---------------------------------------------------------------------------


def test_trace_jsonl_round_trip_byte_stable():
    trace, _ = _sim_trace()
    text = trace.to_jsonl()
    again = Trace.from_jsonl(text)
    assert again.to_jsonl() == text
    assert again.technique == trace.technique
    assert len(again.records) == len(trace.records)
    assert again.records[0] == trace.records[0]


def test_trace_store_save_load(tmp_path):
    trace, _ = _sim_trace()
    store = TraceStore(tmp_path / "traces")
    p1 = store.save(trace)
    p2 = store.save(trace)  # no overwrite: suffixed
    assert p1 != p2 and p1.exists() and p2.exists()
    assert store.load(p1.name).to_jsonl() == trace.to_jsonl()
    assert len(store.list()) == 2


def test_trace_version_gate():
    trace, _ = _sim_trace(n=200)
    bad = trace.to_jsonl().splitlines()
    header = json.loads(bad[0])
    header["version"] = 999
    bad[0] = json.dumps(header)
    with pytest.raises(ValueError, match="version"):
        Trace.from_jsonl("\n".join(bad))


def test_session_report_json_round_trip():
    _, report = _sim_trace(technique="awf_b")  # exercises adaptation field
    text = report.to_json()
    again = dls.SessionReport.from_json(text)
    assert again.to_json() == text
    assert again.technique == report.technique
    assert again.steps == report.steps
    assert (again.per_pe_iters == report.per_pe_iters).all()
    np.testing.assert_allclose(again.busy_time, report.busy_time)
    assert json.loads(text)["schema_version"] == 1


def test_session_report_json_round_trip_with_claims():
    session = dls.loop(400, technique="tss", P=4)
    report = session.execute(lambda a, b: None, executor="serial")
    again = dls.SessionReport.from_json(report.to_json())
    assert again.chunk_sizes == report.chunk_sizes
    assert [c.step for c in again.claims] == [c.step for c in report.claims]


def test_session_report_version_gate():
    _, report = _sim_trace(n=200)
    d = report.to_dict()
    d["schema_version"] = 999
    with pytest.raises(ValueError, match="schema_version"):
        dls.SessionReport.from_dict(d)


# ---------------------------------------------------------------------------
# calibration: the percent-error regression bound
# ---------------------------------------------------------------------------


def test_calibration_recovers_speeds_and_costs():
    trace, _ = _sim_trace(technique="fac2")
    calib = calibrate(trace)
    # 2:1 speed mix: fastest == 1.0, slow half ~0.5
    assert calib.speeds.max() == pytest.approx(1.0)
    assert calib.speeds[P // 2:].mean() == pytest.approx(0.5, rel=0.05)
    assert calib.cost_mean == pytest.approx(1e-3, rel=0.15)
    assert len(calib.costs) == N


@pytest.mark.parametrize("technique,runtime,bound", [
    ("fac2", "one_sided", 5.0),
    ("gss", "one_sided", 5.0),
    ("ss", "one_sided", 5.0),
    ("gss", "two_sided", 8.0),
])
def test_percent_error_regression(technique, runtime, bound):
    """A recorded sim trace replays within the documented percent error
    (EXPERIMENTS.md Sec. 4; seeded, so this is a regression pin)."""
    trace, _ = _sim_trace(technique=technique, runtime=runtime)
    err = calibrate(trace, seed=SEED).percent_error()
    assert err < bound, f"{technique}/{runtime} percent error {err:.2f}%"


def test_calibration_carries_chunk_bounds_and_seed():
    """min_chunk/max_chunk and the recorded seed survive capture ->
    serialization -> calibration, so replay schedules with the native
    bounds and noise stream (not silent defaults)."""
    trace, _ = _sim_trace(technique="ss", n=800, seed=5,
                          min_chunk=25, max_chunk=200)
    again = Trace.from_jsonl(trace.to_jsonl())
    assert (again.min_chunk, again.max_chunk) == (25, 200)
    calib = calibrate(again)
    assert (calib.min_chunk, calib.max_chunk) == (25, 200)
    assert calib.seed == 5  # from meta, not the default
    # SS with min_chunk=25: every replayed chunk must honor the bound
    cf = calib.sim_config()
    assert cf.spec.min_chunk == 25 and cf.spec.max_chunk == 200
    assert calib.percent_error() < 5.0


def test_calibrate_measured_constant_overrides():
    """Directly measured service times (repro.pt.latency) must win over
    the latency fit -- only the un-overridden params get fitted."""
    trace, _ = _sim_trace(technique="fac2")
    fitted = calibrate(trace)
    cal = calibrate(trace, o_rma=3.3e-6, o_serve=7.7e-6)
    assert cal.o_rma == 3.3e-6
    assert cal.o_serve == 7.7e-6
    assert cal.o_rma_local == fitted.o_rma_local  # still fitted
    cf = cal.sim_config()
    assert cf.o_rma == 3.3e-6  # flows into the replayed DES


def test_empty_costs_hint_rejected():
    with pytest.raises(ValueError, match="empty"):
        dls.loop(100, technique="auto", P=2, costs=[])


def test_percent_error_hierarchical():
    trace, _ = _sim_trace(technique="gss", runtime="hierarchical",
                          nodes=2, inner_technique="ss")
    err = calibrate(trace, nodes=2, inner_technique="ss",
                    seed=SEED).percent_error()
    assert err < 10.0, f"hierarchical percent error {err:.2f}%"


# ---------------------------------------------------------------------------
# prediction: determinism + ranking sanity
# ---------------------------------------------------------------------------


def test_calibrate_predict_deterministic():
    trace, _ = _sim_trace()
    a = predict(trace, seed=7, budget_s=None)
    b = predict(trace, seed=7, budget_s=None)
    assert a["percent_error"] == b["percent_error"]
    assert [p.to_dict() for p in a["ranking"]] == \
        [p.to_dict() for p in b["ranking"]]
    assert len(a["ranking"]) == len(TECHNIQUES)
    np.testing.assert_array_equal(a["calibration"].costs,
                                  b["calibration"].costs)


def test_sweep_ranks_static_last_on_heterogeneous():
    """On a 2:1 cluster with no weights, static chunking must rank badly
    (the slow half drags T_loop ~2x) -- the sweep must see that."""
    trace, _ = _sim_trace(technique="fac2")
    calib = calibrate(trace)
    ranking = sweep(calib, seed=SEED)
    techs = [p.technique for p in ranking]
    assert techs.index("static") >= len(techs) - 2
    t = {p.technique: p.T_loop for p in ranking}
    assert t["static"] > 1.4 * t["fac2"]


def test_sweep_budget_keeps_prefix():
    trace, _ = _sim_trace(n=500)
    calib = calibrate(trace)
    ranking = sweep(calib, seed=SEED, budget_s=0.0)
    assert len(ranking) >= 1  # at least one candidate always evaluated


# ---------------------------------------------------------------------------
# technique="auto" facade path
# ---------------------------------------------------------------------------


def test_auto_selects_and_runs():
    session = dls.loop(N, technique="auto", P=P, auto_seed=SEED,
                       auto_budget_s=None)
    d = session.auto_decision
    assert d is not None and session.spec.technique == d["chosen"]
    assert session.spec.technique in TECHNIQUES
    # chosen is within the top-2 of its own sweep (acceptance criterion)
    top2 = [r["technique"] for r in d["ranking"][:2]]
    assert d["chosen"] in top2
    report = session.execute(lambda a, b: None, executor="serial")
    assert report.total_iters == N
    assert report.auto_decision == d
    # the decision survives report persistence
    again = dls.SessionReport.from_json(report.to_json())
    assert again.auto_decision["chosen"] == d["chosen"]


def test_auto_deterministic_for_seed():
    d1 = dls.loop(N, technique="auto", P=P, auto_seed=3,
                  auto_budget_s=None).auto_decision
    d2 = dls.loop(N, technique="auto", P=P, auto_seed=3,
                  auto_budget_s=None).auto_decision
    assert d1["ranking"] == d2["ranking"]
    assert d1["chosen"] == d2["chosen"]


def test_auto_from_trace_beats_bad_static():
    """Calibrated auto on a heterogeneous trace picks a technique whose
    *native* T_loop beats the deliberately bad static choice."""
    trace, _ = _sim_trace(technique="fac2")
    d = choose_technique(N=N, P=P, runtime="one_sided", trace=trace,
                         seed=SEED, budget_s=None, max_sim_iters=N)
    assert d["source"] == "trace"
    costs, speeds = _workload(), _het_speeds()

    def native(tech):
        return dls.loop(N, technique=tech, P=P).execute(
            None, executor="sim", costs=costs, speeds=speeds,
            seed=SEED).wall_time

    assert native(d["chosen"]) < native("static")


def test_auto_accepts_cost_hints():
    session = dls.loop(1_000, technique="auto", P=4,
                       costs=np.linspace(1.0, 5.0, 100), auto_seed=SEED)
    assert session.auto_decision["source"] == "hints"
    assert session.spec.technique in TECHNIQUES


def test_hints_warn_without_auto():
    with pytest.warns(UserWarning, match="selection hints"):
        dls.loop(100, technique="fac2", P=2, costs=np.ones(10))


def test_auto_in_continuous_batcher():
    from repro.serve.engine import ContinuousBatcher, Request

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, 4).astype(np.int32),
                    max_new=int(l))
            for i, l in enumerate(rng.integers(2, 64, size=32))]
    cb = ContinuousBatcher(n_workers=4, technique="auto")
    done = cb.schedule(reqs, lambda chunk, w: 1e-3 * sum(
        r.max_new for r in chunk))
    assert done.shape == (32,) and (done > 0).all()
    d = cb.last_report.auto_decision
    assert d is not None and d["source"] == "hints"
    assert cb.last_report.technique == d["chosen"]


# ---------------------------------------------------------------------------
# gantt
# ---------------------------------------------------------------------------


def test_gantt_renders():
    trace, _ = _sim_trace(n=400)
    txt = gantt_ascii(trace, width=40)
    assert txt.count("\n") >= P  # one row per PE + header/footer
    assert "pe  0" in txt
    svg = gantt_svg(trace)
    assert svg.startswith("<svg") and svg.rstrip().endswith("</svg>")
    assert svg.count("<rect") >= len(trace.records)


# ---------------------------------------------------------------------------
# CLI smoke
# ---------------------------------------------------------------------------


def _cli(args, cwd):
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    return subprocess.run([sys.executable, "-m", "repro.replay"] + args,
                          capture_output=True, text=True, cwd=cwd, env=env,
                          timeout=120)


def test_cli_record_calibrate_predict_gantt(tmp_path):
    r = _cli(["record", "--n", "400", "--p", "4", "--technique", "fac2",
              "--executor", "sim", "--het", "--store", "traces",
              "--name", "smoke"], cwd=tmp_path)
    assert r.returncode == 0, r.stderr
    trace_path = tmp_path / "traces" / "smoke.jsonl"
    assert trace_path.exists()

    r = _cli(["calibrate", "--trace", str(trace_path)], cwd=tmp_path)
    assert r.returncode == 0, r.stderr
    assert "percent error" in r.stdout

    r = _cli(["predict", "--trace", str(trace_path),
              "--max-sim-iters", "400"], cwd=tmp_path)
    assert r.returncode == 0, r.stderr
    assert "rank" in r.stdout

    r = _cli(["gantt", "--trace", str(trace_path), "--svg", "g.svg",
              "--width", "50"], cwd=tmp_path)
    assert r.returncode == 0, r.stderr
    assert "pe  0" in r.stdout
    assert (tmp_path / "g.svg").read_text().startswith("<svg")
