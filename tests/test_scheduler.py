"""Concurrency tests for the One_Sided / Two_Sided runtimes (paper Sec. 3),
driven through the ``repro.dls`` session facade."""
import threading

import numpy as np
import pytest

from repro import dls
from repro.core import ThreadWindow, weights_from_speeds

TECHS = ["ss", "gss", "tss", "fac2", "wf", "static", "tfss"]


@pytest.mark.parametrize("tech", TECHS)
def test_one_sided_partition_under_concurrency(tech):
    """Every iteration executed exactly once, no matter the interleaving."""
    N, P = 20_000, 16
    w = tuple(weights_from_speeds(np.linspace(0.5, 2.0, P))) if tech == "wf" else None
    hits = np.zeros(N, dtype=np.int64)
    lock = threading.Lock()

    def work(a, b):
        with lock:
            hits[a:b] += 1

    report = dls.loop(N, technique=tech, P=P, weights=w).execute(
        work, executor="threads")
    assert (hits == 1).all()
    # claims partition [0, N)
    ivals = sorted((c.start, c.stop) for c in report.claims)
    assert ivals[0][0] == 0 and ivals[-1][1] == N
    for (a0, b0), (a1, b1) in zip(ivals, ivals[1:]):
        assert b0 == a1, "gap or overlap in claimed intervals"


@pytest.mark.parametrize("tech", ["ss", "gss", "fac2"])
def test_two_sided_partition_under_concurrency(tech):
    N, P = 20_000, 8
    hits = np.zeros(N, dtype=np.int64)
    lock = threading.Lock()

    def work(a, b):
        with lock:
            hits[a:b] += 1

    report = dls.loop(N, technique=tech, P=P, runtime="two_sided").execute(
        work, executor="threads")
    assert (hits == 1).all()
    assert sum(c.size for c in report.claims) == N


def test_one_sided_step_indices_unique():
    """Step 1's fetch-add must hand out unique i values (paper's atomicity)."""
    # widen the race window with a slow RMW
    session = dls.loop(50_000, technique="fac2", P=32,
                       window=ThreadWindow(rmw_latency=1e-5))
    seen = []
    lock = threading.Lock()

    def worker(pe):
        for c in session.claims(pe):
            with lock:
                seen.append(c.step)

    ts = [threading.Thread(target=worker, args=(j,)) for j in range(8)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert len(seen) == len(set(seen)), "duplicate scheduling step index"


def test_one_sided_namespacing_allows_multiple_loops():
    """Monotonic KV backends need per-loop counters; two loops must not clash."""
    win = ThreadWindow()
    s1 = dls.loop(1000, technique="gss", P=4, window=win)
    s2 = dls.loop(1000, technique="gss", P=4, window=win)
    tot1 = sum(c.size for c in s1.claims(0))
    tot2 = sum(c.size for c in s2.claims(0))
    assert tot1 == 1000 and tot2 == 1000


def test_session_reset_opens_fresh_namespace():
    """reset() rewinds a drained session without disturbing the old counters."""
    win = ThreadWindow()
    s = dls.loop(500, technique="fac2", P=2, window=win)
    assert sum(c.size for c in s.claims(0)) == 500
    assert s.drained()
    s.reset()
    assert s.remaining() == 500
    assert sum(c.size for c in s.claims(1)) == 500


def test_two_sided_master_recurrence_matches_series():
    from repro.core import chunk_series_recurrence

    session = dls.loop(5000, technique="gss", P=4, runtime="two_sided")
    got = []
    while True:
        c = session.claim(len(got) % 4)
        if c is None:
            break
        got.append(c.size)
    assert got == chunk_series_recurrence(dls.LoopSpec("gss", N=5000, P=4))


def test_awf_live_weight_changes_chunk():
    session = dls.loop(100_000, technique="awf", P=8,
                       weights=tuple([1.0] * 8))
    c_small = session.claim(0, weight=0.25)
    c_big = session.claim(1, weight=2.0)
    assert c_big.size > c_small.size
    assert c_big.size >= int(0.9 * 8 * c_small.size)  # ~8x modulo ceil/batch


def test_two_sided_queue_carries_live_weight():
    """The request/serve path must honor per-claim AWF weights end to end."""
    session = dls.loop(100_000, technique="awf", P=8, runtime="two_sided")
    rt = session.runtime
    # serve synchronously: request then serve_pending then read the replies
    r1 = rt.request(0, weight=0.25)
    r2 = rt.request(1, weight=2.0)
    rt.serve_pending()
    c_small, c_big = r1.get(), r2.get()
    assert c_big.size > c_small.size
