"""Concurrency tests for the One_Sided / Two_Sided runtimes (paper Sec. 3)."""
import threading

import numpy as np
import pytest

from repro.core import (
    LoopSpec,
    OneSidedRuntime,
    ThreadWindow,
    TwoSidedRuntime,
    run_threaded_one_sided,
    run_threaded_two_sided,
    weights_from_speeds,
)

TECHS = ["ss", "gss", "tss", "fac2", "wf", "static", "tfss"]


@pytest.mark.parametrize("tech", TECHS)
def test_one_sided_partition_under_concurrency(tech):
    """Every iteration executed exactly once, no matter the interleaving."""
    N, P = 20_000, 16
    w = tuple(weights_from_speeds(np.linspace(0.5, 2.0, P))) if tech == "wf" else None
    spec = LoopSpec(tech, N=N, P=P, weights=w)
    hits = np.zeros(N, dtype=np.int64)
    lock = threading.Lock()

    def work(a, b):
        with lock:
            hits[a:b] += 1

    claims = run_threaded_one_sided(spec, work, n_threads=P)
    assert (hits == 1).all()
    # claims partition [0, N)
    ivals = sorted((c.start, c.stop) for c in claims)
    assert ivals[0][0] == 0 and ivals[-1][1] == N
    for (a0, b0), (a1, b1) in zip(ivals, ivals[1:]):
        assert b0 == a1, "gap or overlap in claimed intervals"


@pytest.mark.parametrize("tech", ["ss", "gss", "fac2"])
def test_two_sided_partition_under_concurrency(tech):
    N, P = 20_000, 8
    spec = LoopSpec(tech, N=N, P=P)
    hits = np.zeros(N, dtype=np.int64)
    lock = threading.Lock()

    def work(a, b):
        with lock:
            hits[a:b] += 1

    claims = run_threaded_two_sided(spec, work, n_threads=P)
    assert (hits == 1).all()
    assert sum(c.size for c in claims) == N


def test_one_sided_step_indices_unique():
    """Step 1's fetch-add must hand out unique i values (paper's atomicity)."""
    spec = LoopSpec("fac2", N=50_000, P=32)
    # widen the race window with a slow RMW
    rt = OneSidedRuntime(spec, ThreadWindow(rmw_latency=1e-5))
    seen = []
    lock = threading.Lock()

    def worker(pe):
        while True:
            c = rt.claim(pe)
            if c is None:
                return
            with lock:
                seen.append(c.step)

    ts = [threading.Thread(target=worker, args=(j,)) for j in range(8)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert len(seen) == len(set(seen)), "duplicate scheduling step index"


def test_one_sided_namespacing_allows_multiple_loops():
    """Monotonic KV backends need per-loop counters; two loops must not clash."""
    win = ThreadWindow()
    spec = LoopSpec("gss", N=1000, P=4)
    r1 = OneSidedRuntime(spec, win)
    r2 = OneSidedRuntime(spec, win)
    tot1 = tot2 = 0
    while True:
        c = r1.claim(0)
        if c is None:
            break
        tot1 += c.size
    while True:
        c = r2.claim(0)
        if c is None:
            break
        tot2 += c.size
    assert tot1 == 1000 and tot2 == 1000


def test_two_sided_master_recurrence_matches_series():
    from repro.core import chunk_series_recurrence

    spec = LoopSpec("gss", N=5000, P=4)
    rt = TwoSidedRuntime(spec)
    got = []
    while True:
        c = rt._next_chunk(pe=len(got) % 4)
        if c is None:
            break
        got.append(c.size)
    assert got == chunk_series_recurrence(spec)


def test_awf_live_weight_changes_chunk():
    spec = LoopSpec("awf", N=100_000, P=8, weights=tuple([1.0] * 8))
    rt = OneSidedRuntime(spec)
    c_small = rt.claim(0, weight=0.25)
    c_big = rt.claim(1, weight=2.0)
    assert c_big.size > c_small.size
    assert c_big.size >= int(0.9 * 8 * c_small.size)  # ~8x modulo ceil/batch
