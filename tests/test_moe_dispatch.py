"""Property tests for the group-local MoE dispatch (the EP work scheduler).

The dispatch is itself a scheduling problem (assign token-jobs to expert-
workers under capacity) -- these invariants are its correctness contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.configs.base import ModelConfig
from repro.models.layers import moe_block, moe_init


def _cfg(E, K, ff=32, d=64, cf=1.25):
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=d,
                       n_heads=2, n_kv_heads=2, d_ff=ff, vocab=64,
                       n_experts=E, top_k=K, capacity_factor=cf,
                       dtype="float32")


@given(B=st.integers(1, 4), T=st.sampled_from([4, 16, 64]),
       E=st.sampled_from([2, 4, 8]), K=st.integers(1, 2), seed=st.integers(0, 5))
@settings(max_examples=12, deadline=None)
def test_moe_finite_and_shape(B, T, E, K, seed):
    cfg = _cfg(E, min(K, E))
    p = moe_init(jax.random.key(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(seed + 1), (B, T, cfg.d_model))
    y = moe_block(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_moe_dropless_when_capacity_covers():
    """With C >= n (the decode floor), every token's top-k contributes:
    output must equal the dense mixture computed by hand."""
    cfg = _cfg(E=4, K=2)
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))  # N=16<256
    y = moe_block(p, x, cfg)

    # hand-computed dense mixture
    N = 16
    xf = x.reshape(N, cfg.d_model)
    gates = jax.nn.softmax(xf @ p["router"], axis=-1)
    tw, te = jax.lax.top_k(gates, 2)
    tw = tw / tw.sum(-1, keepdims=True)

    def expert(e, v):
        h = jax.nn.silu(v @ p["wg"][e]) * (v @ p["wu"][e])
        return h @ p["wd"][e]

    ref = jnp.zeros_like(xf)
    for n in range(N):
        acc = jnp.zeros(cfg.d_model)
        for j in range(2):
            acc += tw[n, j] * expert(int(te[n, j]), xf[n])
        ref = ref.at[n].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(N, -1)), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg(E=4, K=2)
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    g = jax.grad(lambda p: jnp.sum(moe_block(p, x, cfg) ** 2))(p)
    for name in ("router", "wg", "wu", "wd"):
        assert float(jnp.abs(g[name]).max()) > 0, f"no grad into {name}"
        assert bool(jnp.isfinite(g[name]).all())


def test_moe_shared_expert_contributes():
    cfg = _cfg(E=4, K=1)
    import dataclasses

    cfg_sh = dataclasses.replace(cfg, n_shared_experts=1)
    p = moe_init(jax.random.key(0), cfg_sh, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    y_with = moe_block(p, x, cfg_sh)
    p_no = {k: v for k, v in p.items() if k != "shared"}
    y_without = moe_block(p_no, x, cfg)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-4


def test_moe_group_invariance_when_dropless():
    """Group-local dispatch must not change results vs single-group when no
    tokens are dropped (G only changes *where* slots live)."""
    from repro.shard.spec import ShardCtx

    cfg = _cfg(E=4, K=2, cf=8.0)  # generous capacity: dropless
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 2048, cfg.d_model))  # N=8192
    y1 = moe_block(p, x, cfg)  # ctx disabled -> G=1
    ctx4 = ShardCtx(batch_axes=None, model_axis=None, enabled=True,
                    batch_size_product=4, model_size=1)
    y4 = moe_block(p, x, cfg, ctx=ctx4)  # G=4 groups
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               atol=2e-4, rtol=2e-4)
