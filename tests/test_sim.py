"""DES reproduction tests: the paper's Fig. 4/5 claims, in simulation.

The quantitative band test (`test_psia_grid_within_band`) checks the
calibrated simulator against every T_p^loop the paper quotes numerically
(Sec. 5) to within 10%.  The qualitative tests assert the paper's headline
claims independent of calibration.

The 288k-iteration PSIA sims carry the ``slow`` marker (run them with
``pytest -m slow``); the same ordering invariants are locked at tier-1
scale in ``test_sim_regressions.py``.
"""
import numpy as np
import pytest

from repro.core import (
    LoopSpec,
    SimConfig,
    mandelbrot_iteration_counts,
    paper_cluster,
    psia_costs,
    simulate,
    weights_from_speeds,
)
from repro.core.sim import PSIA_MEAN_COST

N, P = 288_000, 288


@pytest.fixture(scope="module")
def psia():
    return psia_costs(N, mean=PSIA_MEAN_COST)


def run(tech, impl, ratio, coord_on, costs, seed=0):
    speeds, coord = paper_cluster(ratio, coord_on)
    w = tuple(weights_from_speeds(speeds)) if tech == "wf" else None
    spec = LoopSpec(tech, N=len(costs), P=len(speeds), weights=w)
    return simulate(
        SimConfig(spec, speeds, costs, impl=impl, coordinator=coord, seed=seed)
    )


# Every number the paper quotes in Sec. 5 (PSIA).
PAPER_GRID = [
    ("ss", "one_sided", "2:1", "knl", 109.0),
    ("ss", "one_sided", "1:2", "knl", 68.5),
    ("gss", "one_sided", "2:1", "knl", 185.0),
    ("tss", "one_sided", "2:1", "knl", 125.0),
    ("ss", "two_sided", "2:1", "knl", 233.0),
    ("gss", "two_sided", "2:1", "knl", 236.0),
    ("tss", "two_sided", "2:1", "knl", 136.0),
    ("ss", "one_sided", "2:1", "xeon", 108.0),
    ("gss", "one_sided", "2:1", "xeon", 177.0),
    ("tss", "one_sided", "2:1", "xeon", 125.0),
    ("fac2", "one_sided", "2:1", "xeon", 125.0),
    ("wf", "one_sided", "2:1", "xeon", 110.0),
    ("ss", "two_sided", "2:1", "xeon", 105.0),
    ("gss", "two_sided", "2:1", "xeon", 175.0),
    ("tss", "two_sided", "2:1", "xeon", 135.6),
    ("fac2", "two_sided", "2:1", "xeon", 125.0),
    ("wf", "two_sided", "2:1", "xeon", 106.45),
]


@pytest.mark.parametrize("tech,impl,ratio,coord,target", PAPER_GRID)
@pytest.mark.slow
def test_psia_grid_within_band(tech, impl, ratio, coord, target, psia):
    r = run(tech, impl, ratio, coord, psia)
    assert r.T_loop == pytest.approx(target, rel=0.10), (
        f"{tech}/{impl}/{ratio}/{coord}: sim {r.T_loop:.1f}s vs paper {target}s"
    )


# ---- qualitative claims (calibration-independent) ----


@pytest.mark.slow
def test_slow_master_hurts_two_sided_ss(psia):
    """Paper headline: SS 109s one-sided vs 233s two-sided with KNL master."""
    one = run("ss", "one_sided", "2:1", "knl", psia)
    two = run("ss", "two_sided", "2:1", "knl", psia)
    assert two.T_loop > 1.8 * one.T_loop


@pytest.mark.slow
def test_one_sided_insensitive_to_coordinator_placement(psia):
    """Fig. 4/5: One_Sided performs equally with coordinator on KNL or Xeon."""
    for tech in ["ss", "gss", "tss", "fac2", "wf"]:
        a = run(tech, "one_sided", "2:1", "knl", psia)
        b = run(tech, "one_sided", "2:1", "xeon", psia)
        assert a.T_loop == pytest.approx(b.T_loop, rel=0.05), tech


@pytest.mark.slow
def test_two_sided_sensitive_to_master_placement(psia):
    """Two_Sided SS degrades >50% moving the master from Xeon to KNL."""
    knl = run("ss", "two_sided", "2:1", "knl", psia)
    xeon = run("ss", "two_sided", "2:1", "xeon", psia)
    assert knl.T_loop > 1.5 * xeon.T_loop


@pytest.mark.slow
def test_wf_least_sensitive_among_techniques(psia):
    """Paper 2nd observation: factoring-based WF barely reacts to placement."""
    def sensitivity(tech):
        knl = run(tech, "two_sided", "2:1", "knl", psia)
        xeon = run(tech, "two_sided", "2:1", "xeon", psia)
        return knl.T_loop / xeon.T_loop

    assert sensitivity("wf") < sensitivity("ss")
    assert sensitivity("wf") < 1.25


@pytest.mark.slow
def test_more_xeons_help_one_sided(psia):
    """Paper: 1:2 ratio cuts One_Sided SS from 109s to 68.5s."""
    a = run("ss", "one_sided", "2:1", "knl", psia)
    b = run("ss", "one_sided", "1:2", "knl", psia)
    assert b.T_loop < 0.75 * a.T_loop


@pytest.mark.slow
def test_one_sided_claim_latency_much_lower(psia):
    one = run("ss", "one_sided", "2:1", "knl", psia)
    two = run("ss", "two_sided", "2:1", "knl", psia)
    assert one.mean_claim_latency < two.mean_claim_latency / 10


def test_partition_conserved_in_sim(psia):
    for impl in ["one_sided", "two_sided"]:
        r = run("fac2", impl, "2:1", "knl", psia)
        assert r.per_pe_iters.sum() == N


@pytest.mark.slow
def test_ss_best_balance_worst_overhead(psia):
    ss = run("ss", "one_sided", "2:1", "knl", psia)
    gss = run("gss", "one_sided", "2:1", "knl", psia)
    assert ss.cov < gss.cov  # finer chunks balance better
    assert ss.n_claims > 50 * gss.n_claims  # at far higher scheduling cost


# ---- Mandelbrot (paper Fig. 5, qualitative; z <- z^4 + c) ----


def test_mandelbrot_counts_sane():
    counts = mandelbrot_iteration_counts(width=96, ct=200)
    assert counts.shape == (96 * 96,)
    assert counts.max() == 200  # interior pixels hit CT
    assert counts.min() >= 1
    # imbalance is the point: spread is wide
    assert counts.std() / counts.mean() > 0.5


def test_mandelbrot_dls_beats_static_imbalance():
    """DLS exists to fix exactly this: static split of an imbalanced loop."""
    counts = mandelbrot_iteration_counts(width=192, ct=300).astype(np.float64)
    costs = counts * 1e-5
    speeds = np.ones(16)
    static = simulate(
        SimConfig(LoopSpec("static", N=len(costs), P=16), speeds, costs)
    )
    fac2 = simulate(
        SimConfig(LoopSpec("fac2", N=len(costs), P=16), speeds, costs)
    )
    assert fac2.T_loop < 0.75 * static.T_loop
    assert fac2.cov < static.cov
