"""Property + unit tests for the chunk calculus (paper Table 2 / Eq. 1-3)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (
    TECHNIQUES,
    WEIGHTED,
    LoopSpec,
    chunk_series_recurrence,
    chunk_size_closed,
    chunk_sizes_closed,
    plan,
    plan_jax,
    tss_constants,
    weights_from_speeds,
)

N_ST = st.integers(min_value=1, max_value=50_000)
P_ST = st.integers(min_value=1, max_value=512)


# ---------------------------------------------------------------------------
# Partition property: every schedule covers [0, N) exactly once.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tech", TECHNIQUES)
@given(N=N_ST, P=P_ST)
@settings(max_examples=30, deadline=None)
def test_plan_partitions_the_loop(tech, N, P):
    spec = LoopSpec(tech, N=N, P=P)
    sizes, starts = plan(spec)
    assert sizes.sum() == N
    assert (sizes > 0).all()
    assert starts[0] == 0
    np.testing.assert_array_equal(starts[1:], np.cumsum(sizes)[:-1])


@pytest.mark.parametrize("tech", TECHNIQUES)
@given(N=N_ST, P=P_ST)
@settings(max_examples=30, deadline=None)
def test_recurrence_partitions_the_loop(tech, N, P):
    spec = LoopSpec(tech, N=N, P=P)
    rec = chunk_series_recurrence(spec)
    assert sum(rec) == N
    assert all(k > 0 for k in rec)


# ---------------------------------------------------------------------------
# Closed form == recurrence (the paper's Eq. 1-3 vs Table 2).
# TSS is algebraically exact (paper Eq. 4-10); GSS/FAC2 match modulo
# ceil-accumulation on the remainder -- the paper adopts the closed forms
# from [5], which bound the drift; we assert exactness for TSS and a tight
# band + identical batch structure for the others.
# ---------------------------------------------------------------------------


@given(N=st.integers(10, 100_000), P=st.integers(1, 256))
@settings(max_examples=50, deadline=None)
def test_tss_closed_equals_recurrence(N, P):
    spec = LoopSpec("tss", N=N, P=P)
    rec = chunk_series_recurrence(spec)
    closed = [chunk_size_closed(spec, i) for i in range(len(rec))]
    # identical except the final truncated chunk
    assert closed[: len(rec) - 1] == rec[:-1]
    assert closed[-1] >= rec[-1]


@given(N=st.integers(10, 100_000), P=st.integers(2, 256))
@settings(max_examples=50, deadline=None)
def test_gss_closed_tracks_recurrence(N, P):
    spec = LoopSpec("gss", N=N, P=P)
    rec = chunk_series_recurrence(spec)
    # Compare the first half of the series (before ceil drift accumulates in
    # the tail of 1-iteration chunks): relative error <= 1/P + 1 iteration.
    m = max(len(rec) // 2, 1)
    for i in range(m):
        closed = chunk_size_closed(spec, i)
        assert abs(closed - rec[i]) <= max(1, rec[i] // P + 1), (i, closed, rec[i])


def test_gss_paper_example():
    # Paper Sec. 3: N=10, P=2 -> K_0 = 5, K_1 = 3.
    spec = LoopSpec("gss", N=10, P=2)
    assert chunk_size_closed(spec, 0) == 5
    assert chunk_size_closed(spec, 1) == 3


def test_fac2_batches_of_P_halve():
    spec = LoopSpec("fac2", N=100_000, P=8)
    sizes, _ = plan(spec)
    # First batch: ceil(N/2P) repeated P times.
    assert (sizes[:8] == 6250).all()
    # Second batch: half of that.
    assert (sizes[8:16] == 3125).all()


def test_tss_constants_match_table2():
    N, P = 10_000, 16
    K0, Klast, S, C = tss_constants(N, P)
    assert K0 == int(np.ceil(N / (2 * P)))
    assert Klast == 1
    assert S == int(np.ceil(2 * N / (K0 + Klast)))
    assert C == (K0 - Klast) // (S - 1)


# ---------------------------------------------------------------------------
# Monotonicity: GSS/TSS/FAC2/TFSS chunk sizes are non-increasing.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tech", ["gss", "tss", "fac2", "tfss"])
@given(N=st.integers(100, 100_000), P=st.integers(1, 128))
@settings(max_examples=30, deadline=None)
def test_decreasing_chunks(tech, N, P):
    spec = LoopSpec(tech, N=N, P=P)
    sizes, _ = plan(spec)
    assert (np.diff(sizes[:-1]) <= 0).all()  # last chunk may be truncated


# ---------------------------------------------------------------------------
# Weighted techniques.
# ---------------------------------------------------------------------------


@given(
    N=st.integers(1000, 50_000),
    fast=st.integers(1, 8),
    slow=st.integers(1, 8),
    ratio=st.floats(1.5, 8.0),
)
@settings(max_examples=30, deadline=None)
def test_wf_weights_scale_chunks(N, fast, slow, ratio):
    P = fast + slow
    w = weights_from_speeds([ratio] * fast + [1.0] * slow)
    spec = LoopSpec("wf", N=N, P=P, weights=tuple(w))
    k_fast = chunk_size_closed(spec, 0, pe=0)
    k_slow = chunk_size_closed(spec, 0, pe=P - 1)
    assert k_fast >= k_slow
    # ratio preserved within ceil rounding
    assert k_fast <= int(np.ceil(ratio * k_slow)) + 1


def test_weights_sum_to_P():
    w = weights_from_speeds([0.205] * 192 + [1.0] * 96)
    assert np.isclose(w.sum(), 288)


# ---------------------------------------------------------------------------
# jnp planner == numpy planner (on-device batched planning).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tech", ["static", "ss", "gss", "tss", "fac2", "tfss"])
def test_plan_jax_matches_numpy(tech):
    spec = LoopSpec(tech, N=12_345, P=24)
    sizes_np, starts_np = plan(spec)
    sizes_j, starts_j, n_valid = plan_jax(spec)
    n = int(n_valid)
    assert n == len(sizes_np)
    np.testing.assert_array_equal(np.asarray(sizes_j)[:n], sizes_np)
    np.testing.assert_array_equal(np.asarray(starts_j)[:n], starts_np)
    # padding is zero-sized
    assert (np.asarray(sizes_j)[n:] == 0).all()


@pytest.mark.parametrize("tech", ["gss", "tss", "fac2"])
def test_vectorized_matches_scalar(tech):
    spec = LoopSpec(tech, N=99_999, P=31)
    idx = np.arange(200)
    vec = chunk_sizes_closed(spec, idx)
    scal = np.array([chunk_size_closed(spec, int(i)) for i in idx])
    np.testing.assert_array_equal(vec, scal)
