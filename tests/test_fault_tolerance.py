"""Fault-tolerance & elasticity: the one-sided protocol's operational story.

The paper's protocol is *naturally elastic*: membership is implicit (a PE
participates by claiming), so host death means unclaimed work flows to
survivors, and a new host can join mid-epoch by simply starting to claim.
These tests exercise that story end-to-end at the data-pipeline layer, plus
crash-restart with the window counters restored from a checkpoint.
"""
import threading

import numpy as np

from repro import dls
from repro.core.rma import ThreadWindow
from repro.data import DLSSampler, EpochState
from repro.train.trainer import SimCluster


def test_late_joiner_picks_up_work():
    """Elastic scale-up: a host that joins mid-epoch claims real work."""
    win = ThreadWindow()
    N, H = 5000, 4
    early = [DLSSampler(N, H, h, window=win, technique="fac2") for h in range(3)]
    # three hosts drain ~half the epoch
    claimed_early = 0
    for _ in range(20):
        for s in early:
            idx = s.claim_batch(32)
            if idx is not None:
                claimed_early += len(idx)
    # host 3 joins late and still gets work
    late = DLSSampler(N, H, 3, window=win, technique="fac2")
    got = late.claim_batch(32)
    assert got is not None and len(got) == 32
    # and the global partition property still holds across all claimers
    seen = set(got.tolist())
    while True:
        idx = late.claim_batch(32)
        if idx is None:
            break
        assert not (set(idx.tolist()) & seen)
        seen.update(idx.tolist())


def test_dead_host_work_flows_to_survivors():
    cl = SimCluster(4, 3000, technique="fac2")
    counts = cl.run_epoch(batch_size=8, work_time=lambda h: 0.0002,
                          kill_at={1: 2, 3: 2})
    # two hosts die after 2 batches each; the epoch still (nearly) completes
    assert counts.sum() >= 3000 - 2 * (4 * 8) - 2 * 2 * 8 - 4 * 8
    assert counts[0] + counts[2] > 0.75 * counts.sum()


def test_window_crash_restart_no_duplicates():
    """Counters restored from a checkpoint: no sample re-served, none lost
    beyond the in-flight buffer (which the checkpoint also carries)."""
    win = ThreadWindow()
    s = DLSSampler(2000, 2, 0, window=win, technique="gss")
    served = []
    for _ in range(5):
        served.extend(s.claim_batch(16).tolist())
    st = s.state()
    # crash: new process, fresh window, restore
    s2 = DLSSampler(2000, 2, 0, window=ThreadWindow(), technique="gss")
    s2.restore(EpochState(**{
        "epoch": st.epoch, "next_step_i": st.next_step_i,
        "next_lp": st.next_lp, "leftover": st.leftover}))
    after = []
    while True:
        idx = s2.claim_batch(16)
        if idx is None:
            break
        after.extend(idx.tolist())
    assert not (set(served) & set(after)), "re-served after restart"
    assert len(served) + len(after) >= 2000 - 16  # tail smaller than a batch


def test_concurrent_claims_with_contention_partition():
    """Heavy contention (slow RMW) still yields an exact partition."""
    N = 8_000
    session = dls.loop(N, technique="gss", P=16,
                       window=ThreadWindow(rmw_latency=2e-5))
    hits = np.zeros(N, np.int32)
    lock = threading.Lock()

    def worker(pe):
        for c in session.claims(pe):
            with lock:
                hits[c.start:c.stop] += 1

    ts = [threading.Thread(target=worker, args=(j,)) for j in range(16)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert (hits == 1).all()


def test_real_process_killed_mid_chunk_survivors_reclaim():
    """A real OS worker dies (``os._exit``) mid-chunk: the parent salvages
    the executed prefix from the crash slot, orphans the remainder, and a
    survivor re-executes it -- conservation holds to exactly N."""
    import functools

    from repro.pt import SharedMemWindow, workloads

    if not SharedMemWindow.available():
        import pytest

        pytest.skip("SharedMemWindow unavailable: "
                    + SharedMemWindow.availability()[1])
    N, P = 400, 4
    shm, name = workloads.alloc_hits(N)
    try:
        session = dls.loop(N, technique="fac2", P=P, window="shm")
        # PE 1 dies on its 2nd sub-block: mid-chunk (batch-0 chunks span
        # several 16-iteration sub-blocks), so salvage AND orphaning run
        report = session.execute(
            functools.partial(workloads.die_at, name, 1, 1, 200.0),
            executor="processes", timeout=120.0, progress=16)
        hits = workloads.read_hits(name, N)
        missed = [i for i, h in enumerate(hits) if h != 1]
        assert not missed, f"not executed exactly once: {missed[:10]}"
        assert report.total_iters == N
        ps = report.process_stats
        assert ps["n_deaths"] == 1
        victim = next(e for e in ps["per_pe"] if e.get("died"))
        assert victim["pe"] == 1 and victim["exitcode"] == 77
        assert victim["salvaged_iters"] == 16  # exactly one sub-block ran
        assert victim["orphaned_iters"] > 0
        # the orphan log pairs the dead PE with a surviving executor
        assert sum(o["size"] for o in ps["orphans"]) == victim["orphaned_iters"]
        assert all(o["from_pe"] == 1 and o["by_pe"] != 1
                   for o in ps["orphans"])
        session.close()
    finally:
        shm.close()
        shm.unlink()


def test_awf_demotes_straggler_then_recovers():
    """A host that slows down gets smaller chunks; recovery restores them."""
    from repro.core.weights import WeightBoard

    board = WeightBoard(2, ema=0.7)
    for _ in range(10):
        board.record(0, 100, 1.0)  # 100 it/s
        board.record(1, 100, 1.0)
    w_before = board.weight(1)
    for _ in range(10):
        board.record(0, 100, 1.0)
        board.record(1, 100, 8.0)  # straggling: 12.5 it/s
    w_slow = board.weight(1)
    assert w_slow < 0.4 * w_before
    for _ in range(20):
        board.record(1, 100, 1.0)
    assert board.weight(1) > 0.8 * w_before
