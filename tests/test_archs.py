"""Per-architecture smoke tests: reduced config, one forward + train step +
decode step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only by the dry-run (ShapeDtypeStruct level).

The whole matrix jit-compiles ~2 minutes of models on CPU, so it lives in
the slow tier; the tier-1 suite covers the model plane through the dry-run
and the kernel/substrate tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import ARCHS, applicable_shapes, get_config
from repro.models import api

B, T = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab)}
    if cfg.is_encdec:
        batch["src_embeds"] = api.frontend_stub_embeds(cfg, B, T, ks[1])
    elif cfg.frontend:  # vlm: prefix patch embeddings
        batch["prefix_embeds"] = api.frontend_stub_embeds(
            cfg, B, cfg.n_prefix_tokens, ks[1])
    return batch


def _loss_fn(params, cfg, batch):
    logits = api.forward(params, cfg, batch)
    labels = batch["tokens"]
    logits = logits[:, -labels.shape[1]:]  # drop vlm prefix positions
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = api.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))

    logits = api.forward(params, cfg, batch)
    total_T = T + (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, total_T, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in forward logits"

    loss, grads = jax.jit(jax.value_and_grad(_loss_fn), static_argnums=1)(
        params, cfg, batch)
    assert bool(jnp.isfinite(loss)), "NaN loss"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), "NaN in grads"
    # a step must change the params
    new = jax.tree.map(lambda p, g: p - 1e-2 * g.astype(p.dtype), params, grads)
    loss2 = _loss_fn(new, cfg, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    params = api.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    src_len = T if cfg.is_encdec else None
    cache = api.init_cache(cfg, B, 2 * T, src_len=src_len)
    logits, cache = api.prefill(params, cfg, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = api.decode_step(params, cfg, tok, cache)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_consistent_with_forward(arch):
    """Greedy decode logits == teacher-forced forward logits (same prefix)."""
    cfg = get_config(arch).reduced()
    params = api.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    src_len = T if cfg.is_encdec else None
    cache = api.init_cache(cfg, B, 2 * T, src_len=src_len)

    pre = {k: (v[:, : T // 2] if k == "tokens" else v) for k, v in batch.items()}
    lg, cache = api.prefill(params, cfg, pre, cache)
    full = api.forward(params, cfg, pre)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full[:, -1]), atol=2e-3, rtol=2e-3)

    nxt = batch["tokens"][:, T // 2]
    lg2, cache = api.decode_step(params, cfg, nxt, cache)
    pre2 = {k: (batch["tokens"][:, : T // 2 + 1] if k == "tokens" else v)
            for k, v in batch.items()}
    full2 = api.forward(params, cfg, pre2)
    np.testing.assert_allclose(
        np.asarray(lg2), np.asarray(full2[:, -1]), atol=2e-3, rtol=2e-3)


def test_shape_cells_cover_assignment():
    """10 archs x shapes: long_500k only for sub-quadratic families."""
    total = sum(len(applicable_shapes(c)) for c in ARCHS.values())
    # 10 archs x 3 universal shapes + 3 sub-quadratic archs (danube/mamba2/
    # zamba2) x long_500k
    assert total == 33
    subq = {n for n, c in ARCHS.items() if "long_500k" in applicable_shapes(c)}
    assert subq == {"h2o-danube-3-4b", "mamba2-370m", "zamba2-2.7b"}


def test_param_counts_match_names():
    expect = {
        "qwen3-moe-235b-a22b": (232e9, 0.1), "deepseek-67b": (67e9, 0.05),
        "tinyllama-1.1b": (1.1e9, 0.05), "mamba2-370m": (0.37e9, 0.05),
        "zamba2-2.7b": (2.7e9, 0.15), "stablelm-12b": (12e9, 0.05),
        "h2o-danube-3-4b": (4e9, 0.05),
    }
    for name, (target, tol) in expect.items():
        n = get_config(name).param_count()
        assert abs(n - target) / target < tol + 0.05, (name, n)


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.12 * cfg.param_count()
