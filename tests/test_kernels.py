"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode on CPU).

Each kernel is swept over shapes (incl. non-block-aligned), dtypes, and its
semantic options (masking modes, GQA groups, chunk sizes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    attention_oracle,
    flash_attention,
    mandelbrot,
    mandelbrot_ref,
    spin_images,
    spin_images_oracle,
    ssd_scan,
    ssd_scan_oracle,
)

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# Mandelbrot (paper Algorithm 2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width,height,ct,bh,bw", [
    (64, 64, 100, 128, 128),    # smaller than one block (padding path)
    (200, 120, 150, 128, 128),  # non-aligned both dims
    (256, 256, 80, 128, 128),   # exact blocks
    (96, 96, 120, 32, 128),     # non-square small blocks
])
def test_mandelbrot_matches_ref(width, height, ct, bh, bw):
    """Escape counts match the oracle except on chaotic boundary pixels.

    The z<-z^4+c iteration is chaotic at the set boundary: XLA's FMA
    contraction may round ``zr*zr - zi*zi`` differently between the two
    program shapes, and a 1-ULP difference there flips the escape step.
    We bound the affected fraction rather than demand bit-exactness.
    """
    k = np.asarray(mandelbrot(width, height, ct=ct, block_h=bh, block_w=bw))
    r = np.asarray(mandelbrot_ref(width, height, ct=ct))
    assert (k != r).mean() < 0.005, f"{(k != r).sum()} mismatched pixels"


def test_mandelbrot_interior_hits_ct():
    k = np.asarray(mandelbrot(128, ct=60))
    assert k.max() == 60       # interior pixels never escape
    assert k.min() >= 1        # every pixel runs at least one iteration
    assert k.std() > 5         # the variable-cost profile DLS needs


def test_mandelbrot_close_to_float64_oracle():
    """f32 kernel vs f64 numpy oracle: escape-boundary pixels may differ."""
    from repro.core import mandelbrot_iteration_counts

    k = np.asarray(mandelbrot(96, ct=150))
    n = mandelbrot_iteration_counts(width=96, ct=150).reshape(96, 96)
    assert (k != n).mean() < 0.01  # <1% boundary pixels


# ---------------------------------------------------------------------------
# Spin image (paper Algorithm 1)
# ---------------------------------------------------------------------------


def _cloud(n, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, 3)).astype(np.float32)
    nrm = rng.normal(size=(n, 3)).astype(np.float32)
    nrm /= np.linalg.norm(nrm, axis=1, keepdims=True)
    return jnp.asarray(pts), jnp.asarray(nrm)


@pytest.mark.parametrize("n_points,n_images,W,bin_size,angle", [
    (256, 16, 5, 0.5, 2.0),     # paper's W=5
    (300, 20, 5, 0.25, 1.0),    # tighter support angle, non-aligned N
    (128, 8, 7, 0.4, 2.0),      # different image width
    (512, 50, 5, 0.6, 3.2),     # angle > pi: all normals pass
])
def test_spin_images_match_ref(n_points, n_images, W, bin_size, angle):
    pts, nrm = _cloud(n_points)
    k = spin_images(pts, nrm, n_images, img_width=W, bin_size=bin_size,
                    support_angle=angle)
    r = spin_images_oracle(pts, nrm, n_images, img_width=W, bin_size=bin_size,
                           support_angle=angle)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_spin_images_block_size_invariance():
    pts, nrm = _cloud(300, seed=7)
    a = spin_images(pts, nrm, 12, bin_size=0.5, block_m=8, block_p=128)
    b = spin_images(pts, nrm, 12, bin_size=0.5, block_m=16, block_p=64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


def _qkv(B, H, Hkv, Tq, Tk, D, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, Tq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Tk, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Tk, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,H,Hkv,Tq,Tk,D", [
    (1, 2, 2, 128, 128, 64),    # MHA aligned
    (2, 4, 2, 200, 200, 64),    # GQA 2x, ragged seq
    (1, 8, 2, 256, 256, 128),   # GQA 4x, d=128
    (2, 4, 1, 100, 300, 32),    # MQA, cross lengths
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 64)])
def test_flash_attention_matches_ref(B, H, Hkv, Tq, Tk, D, causal, window):
    if causal and Tq != Tk:
        pytest.skip("causal assumes aligned self-attention")
    q, k, v = _qkv(B, H, Hkv, Tq, Tk, D)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_oracle(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    q, k, v = _qkv(1, 2, 2, 128, 128, 64, dtype=jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_oracle(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2
    )


def test_flash_attention_block_invariance():
    q, k, v = _qkv(1, 2, 2, 256, 256, 64, seed=3)
    a = flash_attention(q, k, v, causal=True, blk_q=128, blk_k=128)
    b = flash_attention(q, k, v, causal=True, blk_q=64, blk_k=128)
    c = flash_attention(q, k, v, causal=True, blk_q=128, blk_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5)


def test_flash_attention_swa_equals_full_when_window_covers():
    q, k, v = _qkv(1, 2, 2, 128, 128, 64, seed=4)
    full = flash_attention(q, k, v, causal=True)
    swa = flash_attention(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(full), np.asarray(swa), atol=1e-6)


# ---------------------------------------------------------------------------
# SSD scan (Mamba2)
# ---------------------------------------------------------------------------


def _ssd_inputs(B, T, H, Dh, S, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(B, T, H, Dh)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, T, H)), dtype)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, S)), dtype)
    Cm = jnp.asarray(rng.normal(size=(B, T, S)), dtype)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("B,T,H,Dh,S,chunk", [
    (1, 128, 2, 32, 16, 64),    # aligned
    (2, 200, 4, 32, 16, 64),    # ragged T (padding path)
    (1, 256, 2, 64, 64, 128),   # bigger state
    (2, 96, 8, 16, 32, 32),     # many heads, small chunks
])
def test_ssd_scan_matches_ref(B, T, H, Dh, S, chunk):
    x, dt, A, Bm, Cm = _ssd_inputs(B, T, H, Dh, S)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    r = ssd_scan_oracle(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), atol=2e-4, rtol=2e-4)


def test_ssd_chunk_invariance():
    x, dt, A, Bm, Cm = _ssd_inputs(1, 192, 2, 32, 16, seed=9)
    a = ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    b = ssd_scan(x, dt, A, Bm, Cm, chunk=96)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_ssd_decay_limits():
    """A -> -inf forgets state (y_t ~ dt C.B x_t); dt -> 0 yields ~0 output."""
    x, dt, A, Bm, Cm = _ssd_inputs(1, 64, 2, 16, 8, seed=5)
    y_tiny_dt = ssd_scan(x, dt * 1e-8, A, Bm, Cm, chunk=32)
    assert float(jnp.abs(y_tiny_dt).max()) < 1e-5
    strong = jnp.full_like(A, -1e5)  # dt_min * |A| >> 1: full forgetting
    y_forget = np.asarray(ssd_scan(x, dt, strong, Bm, Cm, chunk=32))
    expect = np.asarray(
        jnp.einsum("bts,bts,bth,bthd->bthd",
                   Cm, Bm, dt, x)
    )
    np.testing.assert_allclose(y_forget, expect, atol=1e-4)


def test_ssd_chunked_xla_matches_sequential():
    """The production chunked-XLA SSD path == the sequential oracle."""
    from repro.kernels.ssd_scan.ref import ssd_scan_chunked_xla

    x, dt, A, Bm, Cm = _ssd_inputs(2, 200, 4, 32, 16, seed=11)
    y_ref = ssd_scan_oracle(x, dt, A, Bm, Cm)
    y_chk, h = ssd_scan_chunked_xla(x, dt, A, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    # final state must equal the state reached by stepping the recurrence
    import jax

    def seq_state(x, dt, A, Bm, Cm):
        B, T, H, Dh = x.shape
        S = Bm.shape[-1]
        h = jnp.zeros((B, H, S, Dh), jnp.float32)
        for t in range(T):
            decay = jnp.exp(dt[:, t] * A[None, :])
            h = decay[:, :, None, None] * h + (
                dt[:, t][:, :, None, None]
                * Bm[:, t][:, None, :, None] * x[:, t][:, :, None, :])
        return h

    np.testing.assert_allclose(np.asarray(h),
                               np.asarray(seq_state(x, dt, A, Bm, Cm)),
                               atol=2e-4, rtol=2e-3)


def test_ssd_chunked_xla_grads_finite_with_strong_decay():
    """Regression: upper-triangle decay exponents must not inf->NaN the VJP."""
    import jax
    from repro.kernels.ssd_scan.ref import ssd_scan_chunked_xla

    x, dt, A, Bm, Cm = _ssd_inputs(1, 96, 2, 16, 8, seed=3)
    A = A * 50.0  # strong decay: exp(+|acum|) overflows without the mask
    g = jax.grad(lambda x: jnp.sum(
        ssd_scan_chunked_xla(x, dt, A, Bm, Cm, chunk=32)[0] ** 2))(x)
    assert bool(jnp.isfinite(g).all())
