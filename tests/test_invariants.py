"""Property-based conservation harness (the paper's partition property).

The one invariant every layer of this repo rests on: for ANY technique x
runtime x window backend, the claims handed out over ``[0, N)`` exactly
partition it -- no gap, no overlap, sizes summing to N -- no matter how
claims interleave.

Two layers:

  * a deterministic seeded case grid that always runs (so the harness
    guards every environment, including ones without hypothesis), and
  * hypothesis fuzzing over the same properties when hypothesis is
    importable -- CI runs this file both with and without hypothesis to
    keep the degraded path collectable.

Threaded cases widen the race windows with ``ThreadWindow(rmw_latency=...)``
so lost-update bugs in the fetch-add protocol (or the hierarchical epoch
protocol) have a real chance to fire.  Deep cases carry the ``slow``
marker; the default tier keeps example counts inside the tier-1 budget.
"""
import random
import threading

import numpy as np
import pytest

from repro import dls
from repro.core import (
    HierarchicalWindow,
    LoopSpec,
    SimConfig,
    ThreadWindow,
    simulate,
)
from repro.sim import PEFailure, SpeedDrift, Straggler

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the no-hypothesis CI job
    HAVE_HYPOTHESIS = False

RUNTIMES = ("one_sided", "two_sided", "hierarchical")


# ---------------------------------------------------------------------------
# shared checkers
# ---------------------------------------------------------------------------


def assert_partition(claims, N):
    """Claims exactly partition [0, N): no gap, no overlap, sizes sum N."""
    assert claims, "no claims handed out"
    ivals = sorted((c.start, c.stop) for c in claims)
    assert ivals[0][0] == 0, f"first claim starts at {ivals[0][0]}"
    assert ivals[-1][1] == N, f"last claim stops at {ivals[-1][1]} != {N}"
    for (_, b0), (a1, _) in zip(ivals, ivals[1:]):
        assert b0 == a1, f"gap or overlap at {b0} vs {a1}"
    assert sum(c.size for c in claims) == N


def drain_serial(session):
    """Round-robin drain with per-PE retirement (hierarchical drains per node)."""
    P = session.spec.P
    claims = []
    done = [False] * P
    n_done = 0
    pe = 0
    while n_done < P:
        if not done[pe]:
            c = session.claim(pe)
            if c is None:
                done[pe] = True
                n_done += 1
            else:
                claims.append(c)
        pe = (pe + 1) % P
    return claims


def drain_threads(session, n_threads, hits):
    lock = threading.Lock()
    claims = []

    def worker(pe):
        while True:
            c = session.claim(pe)
            if c is None:
                return
            with lock:
                hits[c.start:c.stop] += 1
                claims.append(c)

    ts = [threading.Thread(target=worker, args=(j,)) for j in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return claims


def session_for(case, runtime, window=None):
    kw = dict(technique=case["technique"], P=case["P"],
              min_chunk=case["min_chunk"], max_chunk=case["max_chunk"],
              runtime=runtime, window=window)
    if runtime == "hierarchical":
        kw.update(nodes=case["nodes"], inner_technique=case["inner"])
    return dls.loop(case["N"], **kw)


def make_case(rng, max_n):
    P = rng.randint(1, 12)
    return dict(
        # dls.TECHNIQUES includes the adaptive family (af, awf_b..e): those
        # run their telemetry-less bootstrap here -- the live-telemetry
        # conservation path is covered by tests/test_adaptive.py.
        technique=rng.choice(dls.TECHNIQUES),
        N=rng.randint(1, max_n),
        P=P,
        min_chunk=rng.choice([1, 1, 1, 2, 7]),
        max_chunk=rng.choice([None, None, None, 64]),
        nodes=rng.randint(1, P),
        inner=rng.choice(["ss", "gss", "fac2", "tss", "af", "awf_c"]),
    )


# Deterministic grid: seeded draws + the degenerate corners that bite.
_rng = random.Random(20260801)
CASES = [make_case(_rng, 4_000) for _ in range(24)] + [
    dict(technique="gss", N=1, P=1, min_chunk=1, max_chunk=None,
         nodes=1, inner="ss"),
    dict(technique="fac2", N=7, P=12, min_chunk=1, max_chunk=None,
         nodes=12, inner="gss"),
    dict(technique="tss", N=97, P=8, min_chunk=3, max_chunk=5,
         nodes=3, inner="tss"),
    dict(technique="ss", N=500, P=6, min_chunk=2, max_chunk=None,
         nodes=2, inner="fac2"),
    # adaptive corners: AF at both levels; an overhead-timing AWF variant
    # with capped chunks; a degenerate single-PE AF
    dict(technique="af", N=777, P=5, min_chunk=1, max_chunk=None,
         nodes=2, inner="af"),
    dict(technique="awf_e", N=1234, P=7, min_chunk=2, max_chunk=32,
         nodes=3, inner="awf_c"),
    dict(technique="af", N=1, P=1, min_chunk=1, max_chunk=None,
         nodes=1, inner="awf_d"),
]


# ---------------------------------------------------------------------------
# always-on layer (no hypothesis needed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("runtime", RUNTIMES)
def test_serial_claims_partition_grid(runtime):
    for case in CASES:
        claims = drain_serial(session_for(case, runtime))
        assert_partition(claims, case["N"])


@pytest.mark.parametrize("runtime", ["one_sided", "hierarchical"])
def test_threaded_partition_widened_races_grid(runtime):
    """Concurrent claimers over latency-widened windows still partition."""
    rng = random.Random(7)
    for _ in range(6):
        case = make_case(rng, 600)
        if runtime == "hierarchical":
            window = HierarchicalWindow(
                case["nodes"], ThreadWindow(rmw_latency=2e-5),
                [ThreadWindow(rmw_latency=1e-5) for _ in range(case["nodes"])])
        else:
            window = ThreadWindow(rmw_latency=1e-5)
        session = session_for(case, runtime, window=window)
        hits = np.zeros(case["N"], np.int64)
        claims = drain_threads(session, case["P"], hits)
        assert (hits == 1).all(), np.flatnonzero(hits != 1)[:10]
        assert_partition(claims, case["N"])


@pytest.mark.parametrize("window", ["thread", "sim"])
def test_window_backends_conserve_grid(window):
    """The invariant is backend-independent (thread vs clocked sim window)."""
    for case in CASES[:12]:
        for runtime in ("one_sided", "hierarchical"):
            claims = drain_serial(session_for(case, runtime, window=window))
            assert_partition(claims, case["N"])


def test_hierarchical_state_restore_conserves_grid():
    """Checkpoint mid-loop, restore elsewhere: served + tail == N, disjoint."""
    rng = random.Random(11)
    for _ in range(8):
        case = make_case(rng, 4_000)
        cut = rng.randint(0, 30)
        src = session_for(case, "hierarchical")
        served = []
        for j in range(cut):
            c = src.claim(j % case["P"])
            if c is None:
                break
            served.append(c)
        dst = session_for(case, "hierarchical")
        dst.restore(src.state())
        assert_partition(served + drain_serial(dst), case["N"])


# ---------------------------------------------------------------------------
# hypothesis layer (fuzzing over the same properties)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    COMMON = dict(suppress_health_check=[HealthCheck.too_slow], deadline=None)

    @st.composite
    def loop_cases(draw, max_n=4_000):
        P = draw(st.integers(min_value=1, max_value=12))
        return dict(
            technique=draw(st.sampled_from(dls.TECHNIQUES)),
            N=draw(st.integers(min_value=1, max_value=max_n)),
            P=P,
            min_chunk=draw(st.sampled_from([1, 1, 1, 2, 7])),
            max_chunk=draw(st.sampled_from([None, None, None, 64])),
            nodes=draw(st.integers(min_value=1, max_value=P)),
            inner=draw(st.sampled_from(
                ["ss", "gss", "fac2", "tss", "af", "awf_c"])),
        )

    @pytest.mark.parametrize("runtime", RUNTIMES)
    @settings(max_examples=25, **COMMON)
    @given(case=loop_cases())
    def test_serial_claims_partition_fuzz(runtime, case):
        claims = drain_serial(session_for(case, runtime))
        assert_partition(claims, case["N"])

    @pytest.mark.parametrize("runtime", ["one_sided", "hierarchical"])
    @settings(max_examples=8, **COMMON)
    @given(case=loop_cases(max_n=500))
    def test_threaded_partition_widened_races_fuzz(runtime, case):
        if runtime == "hierarchical":
            window = HierarchicalWindow(
                case["nodes"], ThreadWindow(rmw_latency=2e-5),
                [ThreadWindow(rmw_latency=1e-5) for _ in range(case["nodes"])])
        else:
            window = ThreadWindow(rmw_latency=1e-5)
        session = session_for(case, runtime, window=window)
        hits = np.zeros(case["N"], np.int64)
        claims = drain_threads(session, case["P"], hits)
        assert (hits == 1).all(), np.flatnonzero(hits != 1)[:10]
        assert_partition(claims, case["N"])

    @settings(max_examples=20, **COMMON)
    @given(case=loop_cases(), cut=st.integers(min_value=0, max_value=30))
    def test_hierarchical_state_restore_conserves_fuzz(case, cut):
        src = session_for(case, "hierarchical")
        served = []
        for j in range(cut):
            c = src.claim(j % case["P"])
            if c is None:
                break
            served.append(c)
        dst = session_for(case, "hierarchical")
        dst.restore(src.state())
        assert_partition(served + drain_serial(dst), case["N"])

    @pytest.mark.slow
    @pytest.mark.parametrize("runtime", RUNTIMES)
    @settings(max_examples=200, **COMMON)
    @given(case=loop_cases(max_n=20_000))
    def test_serial_claims_partition_deep(runtime, case):
        """The same invariant, hammered (slow tier)."""
        claims = drain_serial(session_for(case, runtime))
        assert_partition(claims, case["N"])


# ---------------------------------------------------------------------------
# Perturbation layer (repro.sim.perturb): the same conservation invariant
# under PE failure/churn, straggler injection, and speed drift -- in every
# DES topology, through the unified kernel's shared re-claim path.
# ---------------------------------------------------------------------------

SIM_N, SIM_P = 2_400, 6


def _sim_costs(n=SIM_N, seed=17):
    rng = np.random.default_rng(seed)
    return rng.lognormal(np.log(1e-3), 0.4, size=n)


def _sim(technique, runtime, perturbations=None, n=SIM_N, seed=3, **kw):
    speeds = np.array([1.0, 0.5, 1.0, 0.5, 1.0, 0.5])[:SIM_P]
    if runtime == "hierarchical":
        kw.setdefault("nodes", 3)
    return simulate(SimConfig(
        LoopSpec(technique, N=n, P=SIM_P), speeds, _sim_costs(n),
        impl=runtime, seed=seed, collect_trace=True,
        perturbations=perturbations, **kw))


def _assert_exactly_once(r, n):
    """Every iteration executed exactly once (trace-level), sums conserve."""
    seen = np.zeros(n, np.int64)
    for rec in r.chunk_trace:
        seen[rec["start"]:rec["start"] + rec["size"]] += 1
    assert (seen == 1).all(), np.flatnonzero(seen != 1)[:10]
    assert r.per_pe_iters.sum() == n
    assert sum(rec["size"] for rec in r.chunk_trace) == n


@pytest.mark.parametrize("runtime", RUNTIMES)
@pytest.mark.parametrize("technique", ["ss", "gss", "fac2", "tss"])
def test_pe_death_reclaim_conserves_grid(runtime, technique):
    """PE churn: two PEs die mid-loop; their in-flight remainders are
    re-claimed by survivors, and the partition property still holds."""
    base = _sim(technique, runtime)
    for frac in (0.0, 0.3, 0.75):
        deaths = (PEFailure(pe=3, at=base.T_loop * frac),
                  PEFailure(pe=5, at=base.T_loop * max(frac, 0.1) * 0.8))
        r = _sim(technique, runtime, perturbations=deaths)
        _assert_exactly_once(r, SIM_N)
        # the dead PE stopped at (or before) its death time
        assert r.finish[3] <= deaths[0].at + 1e-12
        # ...and the loop still completed entirely
        assert r.T_loop > 0 and r.per_pe_iters[3] <= base.per_pe_iters[3] \
            or base.per_pe_iters[3] == 0


@pytest.mark.parametrize("runtime", RUNTIMES)
def test_pe_death_with_adaptive_technique_conserves(runtime):
    """Churn composes with live telemetry (adaptive chunk sizing)."""
    kw = dict(inner_technique="af") if runtime == "hierarchical" else {}
    tech = "gss" if runtime == "hierarchical" else "awf_b"
    base = _sim(tech, runtime, **kw)
    r = _sim(tech, runtime, perturbations=(
        PEFailure(pe=4, at=base.T_loop * 0.4),), **kw)
    _assert_exactly_once(r, SIM_N)


@pytest.mark.parametrize("runtime", RUNTIMES)
def test_straggler_and_drift_conserve_and_slow_down(runtime):
    base = _sim("fac2", runtime)
    r = _sim("fac2", runtime, perturbations=(
        Straggler(pe=2, at=0.0, factor=0.2),
        SpeedDrift(amplitude=0.3, period=base.T_loop / 2),
    ))
    _assert_exactly_once(r, SIM_N)
    # a 5x-slowed PE must lose iterations relative to the clean run
    assert r.per_pe_iters[2] < base.per_pe_iters[2]


@pytest.mark.parametrize("runtime", RUNTIMES)
def test_perturbed_runs_deterministic(runtime):
    perts = (PEFailure(pe=1, at=0.4), Straggler(pe=2, at=0.1, factor=0.5),
             SpeedDrift(amplitude=0.2, period=0.7))
    a = _sim("fac2", runtime, perturbations=perts)
    b = _sim("fac2", runtime, perturbations=perts)
    assert a.T_loop == b.T_loop
    assert (a.finish == b.finish).all()
    assert (a.per_pe_iters == b.per_pe_iters).all()
    assert a.chunk_trace == b.chunk_trace


@pytest.mark.parametrize("runtime", RUNTIMES)
def test_never_firing_perturbation_changes_nothing(runtime):
    """A death scheduled after the loop ends exercises the perturbed code
    path but must reproduce the clean run exactly (floats included)."""
    base = _sim("gss", runtime)
    r = _sim("gss", runtime,
             perturbations=(PEFailure(pe=1, at=base.T_loop * 10),))
    assert r.T_loop == base.T_loop
    assert (r.finish == base.finish).all()
    assert r.chunk_trace == base.chunk_trace
    assert r.n_claims == base.n_claims


def test_scenario_validation():
    spec = LoopSpec("ss", N=10, P=2)
    costs, speeds = np.full(10, 1e-3), np.ones(2)
    with pytest.raises(ValueError, match="survive"):
        simulate(SimConfig(spec, speeds, costs, perturbations=(
            PEFailure(0, 0.1), PEFailure(1, 0.2))))
    with pytest.raises(ValueError, match="master death"):
        simulate(SimConfig(spec, speeds, costs, impl="two_sided",
                           perturbations=(PEFailure(0, 0.1),)))
    with pytest.raises(ValueError, match="amplitude"):
        simulate(SimConfig(spec, speeds, costs,
                           perturbations=(SpeedDrift(amplitude=1.5),)))
    with pytest.raises(TypeError):
        simulate(SimConfig(spec, speeds, costs, perturbations=("boom",)))


def test_sim_executor_forwards_perturbations():
    """The facade path: dls sessions pass scenarios into the kernel."""
    session = dls.loop(SIM_N, technique="fac2", P=SIM_P)
    report = session.execute(
        None, executor="sim", costs=_sim_costs(), speeds=np.ones(SIM_P),
        collect_trace=True, perturbations=(PEFailure(pe=1, at=0.05),))
    assert report.total_iters == SIM_N
    seen = np.zeros(SIM_N, np.int64)
    for rec in report.chunk_times:
        seen[rec["start"]:rec["start"] + rec["size"]] += 1
    assert (seen == 1).all()


@pytest.mark.slow
@pytest.mark.parametrize("runtime", RUNTIMES)
def test_pe_churn_deep_grid(runtime):
    """Randomized churn scenarios, hammered (slow tier): any subset of
    non-coordinator PEs dying at any time conserves in any technique."""
    rng = random.Random(20260801 + RUNTIMES.index(runtime))
    for _ in range(40):
        tech = rng.choice(["ss", "gss", "fac2", "tss", "tfss", "wf",
                           "af", "awf_c"])
        base = _sim(tech, runtime, seed=rng.randrange(100))
        n_dead = rng.randint(1, SIM_P - 2)
        victims = rng.sample([p for p in range(SIM_P) if p != 0], n_dead)
        perts = tuple(PEFailure(pe=v, at=rng.random() * base.T_loop * 1.1)
                      for v in victims)
        if rng.random() < 0.5:
            perts += (SpeedDrift(amplitude=0.25, period=base.T_loop / 3),)
        r = _sim(tech, runtime, perturbations=perts,
                 seed=rng.randrange(100))
        _assert_exactly_once(r, SIM_N)
