"""Property-based conservation harness (the paper's partition property).

The one invariant every layer of this repo rests on: for ANY technique x
runtime x window backend, the claims handed out over ``[0, N)`` exactly
partition it -- no gap, no overlap, sizes summing to N -- no matter how
claims interleave.

Two layers:

  * a deterministic seeded case grid that always runs (so the harness
    guards every environment, including ones without hypothesis), and
  * hypothesis fuzzing over the same properties when hypothesis is
    importable -- CI runs this file both with and without hypothesis to
    keep the degraded path collectable.

Threaded cases widen the race windows with ``ThreadWindow(rmw_latency=...)``
so lost-update bugs in the fetch-add protocol (or the hierarchical epoch
protocol) have a real chance to fire.  Deep cases carry the ``slow``
marker; the default tier keeps example counts inside the tier-1 budget.
"""
import random
import threading

import numpy as np
import pytest

from repro import dls
from repro.core import HierarchicalWindow, ThreadWindow

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the no-hypothesis CI job
    HAVE_HYPOTHESIS = False

RUNTIMES = ("one_sided", "two_sided", "hierarchical")


# ---------------------------------------------------------------------------
# shared checkers
# ---------------------------------------------------------------------------


def assert_partition(claims, N):
    """Claims exactly partition [0, N): no gap, no overlap, sizes sum N."""
    assert claims, "no claims handed out"
    ivals = sorted((c.start, c.stop) for c in claims)
    assert ivals[0][0] == 0, f"first claim starts at {ivals[0][0]}"
    assert ivals[-1][1] == N, f"last claim stops at {ivals[-1][1]} != {N}"
    for (_, b0), (a1, _) in zip(ivals, ivals[1:]):
        assert b0 == a1, f"gap or overlap at {b0} vs {a1}"
    assert sum(c.size for c in claims) == N


def drain_serial(session):
    """Round-robin drain with per-PE retirement (hierarchical drains per node)."""
    P = session.spec.P
    claims = []
    done = [False] * P
    n_done = 0
    pe = 0
    while n_done < P:
        if not done[pe]:
            c = session.claim(pe)
            if c is None:
                done[pe] = True
                n_done += 1
            else:
                claims.append(c)
        pe = (pe + 1) % P
    return claims


def drain_threads(session, n_threads, hits):
    lock = threading.Lock()
    claims = []

    def worker(pe):
        while True:
            c = session.claim(pe)
            if c is None:
                return
            with lock:
                hits[c.start:c.stop] += 1
                claims.append(c)

    ts = [threading.Thread(target=worker, args=(j,)) for j in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return claims


def session_for(case, runtime, window=None):
    kw = dict(technique=case["technique"], P=case["P"],
              min_chunk=case["min_chunk"], max_chunk=case["max_chunk"],
              runtime=runtime, window=window)
    if runtime == "hierarchical":
        kw.update(nodes=case["nodes"], inner_technique=case["inner"])
    return dls.loop(case["N"], **kw)


def make_case(rng, max_n):
    P = rng.randint(1, 12)
    return dict(
        # dls.TECHNIQUES includes the adaptive family (af, awf_b..e): those
        # run their telemetry-less bootstrap here -- the live-telemetry
        # conservation path is covered by tests/test_adaptive.py.
        technique=rng.choice(dls.TECHNIQUES),
        N=rng.randint(1, max_n),
        P=P,
        min_chunk=rng.choice([1, 1, 1, 2, 7]),
        max_chunk=rng.choice([None, None, None, 64]),
        nodes=rng.randint(1, P),
        inner=rng.choice(["ss", "gss", "fac2", "tss", "af", "awf_c"]),
    )


# Deterministic grid: seeded draws + the degenerate corners that bite.
_rng = random.Random(20260801)
CASES = [make_case(_rng, 4_000) for _ in range(24)] + [
    dict(technique="gss", N=1, P=1, min_chunk=1, max_chunk=None,
         nodes=1, inner="ss"),
    dict(technique="fac2", N=7, P=12, min_chunk=1, max_chunk=None,
         nodes=12, inner="gss"),
    dict(technique="tss", N=97, P=8, min_chunk=3, max_chunk=5,
         nodes=3, inner="tss"),
    dict(technique="ss", N=500, P=6, min_chunk=2, max_chunk=None,
         nodes=2, inner="fac2"),
    # adaptive corners: AF at both levels; an overhead-timing AWF variant
    # with capped chunks; a degenerate single-PE AF
    dict(technique="af", N=777, P=5, min_chunk=1, max_chunk=None,
         nodes=2, inner="af"),
    dict(technique="awf_e", N=1234, P=7, min_chunk=2, max_chunk=32,
         nodes=3, inner="awf_c"),
    dict(technique="af", N=1, P=1, min_chunk=1, max_chunk=None,
         nodes=1, inner="awf_d"),
]


# ---------------------------------------------------------------------------
# always-on layer (no hypothesis needed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("runtime", RUNTIMES)
def test_serial_claims_partition_grid(runtime):
    for case in CASES:
        claims = drain_serial(session_for(case, runtime))
        assert_partition(claims, case["N"])


@pytest.mark.parametrize("runtime", ["one_sided", "hierarchical"])
def test_threaded_partition_widened_races_grid(runtime):
    """Concurrent claimers over latency-widened windows still partition."""
    rng = random.Random(7)
    for _ in range(6):
        case = make_case(rng, 600)
        if runtime == "hierarchical":
            window = HierarchicalWindow(
                case["nodes"], ThreadWindow(rmw_latency=2e-5),
                [ThreadWindow(rmw_latency=1e-5) for _ in range(case["nodes"])])
        else:
            window = ThreadWindow(rmw_latency=1e-5)
        session = session_for(case, runtime, window=window)
        hits = np.zeros(case["N"], np.int64)
        claims = drain_threads(session, case["P"], hits)
        assert (hits == 1).all(), np.flatnonzero(hits != 1)[:10]
        assert_partition(claims, case["N"])


@pytest.mark.parametrize("window", ["thread", "sim"])
def test_window_backends_conserve_grid(window):
    """The invariant is backend-independent (thread vs clocked sim window)."""
    for case in CASES[:12]:
        for runtime in ("one_sided", "hierarchical"):
            claims = drain_serial(session_for(case, runtime, window=window))
            assert_partition(claims, case["N"])


def test_hierarchical_state_restore_conserves_grid():
    """Checkpoint mid-loop, restore elsewhere: served + tail == N, disjoint."""
    rng = random.Random(11)
    for _ in range(8):
        case = make_case(rng, 4_000)
        cut = rng.randint(0, 30)
        src = session_for(case, "hierarchical")
        served = []
        for j in range(cut):
            c = src.claim(j % case["P"])
            if c is None:
                break
            served.append(c)
        dst = session_for(case, "hierarchical")
        dst.restore(src.state())
        assert_partition(served + drain_serial(dst), case["N"])


# ---------------------------------------------------------------------------
# hypothesis layer (fuzzing over the same properties)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    COMMON = dict(suppress_health_check=[HealthCheck.too_slow], deadline=None)

    @st.composite
    def loop_cases(draw, max_n=4_000):
        P = draw(st.integers(min_value=1, max_value=12))
        return dict(
            technique=draw(st.sampled_from(dls.TECHNIQUES)),
            N=draw(st.integers(min_value=1, max_value=max_n)),
            P=P,
            min_chunk=draw(st.sampled_from([1, 1, 1, 2, 7])),
            max_chunk=draw(st.sampled_from([None, None, None, 64])),
            nodes=draw(st.integers(min_value=1, max_value=P)),
            inner=draw(st.sampled_from(
                ["ss", "gss", "fac2", "tss", "af", "awf_c"])),
        )

    @pytest.mark.parametrize("runtime", RUNTIMES)
    @settings(max_examples=25, **COMMON)
    @given(case=loop_cases())
    def test_serial_claims_partition_fuzz(runtime, case):
        claims = drain_serial(session_for(case, runtime))
        assert_partition(claims, case["N"])

    @pytest.mark.parametrize("runtime", ["one_sided", "hierarchical"])
    @settings(max_examples=8, **COMMON)
    @given(case=loop_cases(max_n=500))
    def test_threaded_partition_widened_races_fuzz(runtime, case):
        if runtime == "hierarchical":
            window = HierarchicalWindow(
                case["nodes"], ThreadWindow(rmw_latency=2e-5),
                [ThreadWindow(rmw_latency=1e-5) for _ in range(case["nodes"])])
        else:
            window = ThreadWindow(rmw_latency=1e-5)
        session = session_for(case, runtime, window=window)
        hits = np.zeros(case["N"], np.int64)
        claims = drain_threads(session, case["P"], hits)
        assert (hits == 1).all(), np.flatnonzero(hits != 1)[:10]
        assert_partition(claims, case["N"])

    @settings(max_examples=20, **COMMON)
    @given(case=loop_cases(), cut=st.integers(min_value=0, max_value=30))
    def test_hierarchical_state_restore_conserves_fuzz(case, cut):
        src = session_for(case, "hierarchical")
        served = []
        for j in range(cut):
            c = src.claim(j % case["P"])
            if c is None:
                break
            served.append(c)
        dst = session_for(case, "hierarchical")
        dst.restore(src.state())
        assert_partition(served + drain_serial(dst), case["N"])

    @pytest.mark.slow
    @pytest.mark.parametrize("runtime", RUNTIMES)
    @settings(max_examples=200, **COMMON)
    @given(case=loop_cases(max_n=20_000))
    def test_serial_claims_partition_deep(runtime, case):
        """The same invariant, hammered (slow tier)."""
        claims = drain_serial(session_for(case, runtime))
        assert_partition(claims, case["N"])
