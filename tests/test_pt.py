"""repro.pt: the real cross-process window + processes executor.

Everything here runs real OS processes (never mocks): conservation must
hold to exactly N across process boundaries, deaths and all.  The full
technique x runtime grid is slow-marked; tier-1 keeps one fast
representative per runtime.
"""
import functools
import threading
import time

import pytest

from repro import dls
from repro.core.rma import SimWindow, ThreadWindow, Window, make_window
from repro.dls.report import SessionReport
from repro.pt import (
    SharedMemWindow,
    attach_hier,
    hier_descriptor,
    measure_contention,
    measure_rmw_latency,
    shm_hierarchical,
    workloads,
)

pytestmark = pytest.mark.skipif(
    not SharedMemWindow.available(),
    reason=f"SharedMemWindow unavailable: {SharedMemWindow.availability()[1]}")


# ---------------------------------------------------------------------------
# window unit behavior
# ---------------------------------------------------------------------------

def test_fetch_add_semantics():
    w = SharedMemWindow.create(capacity=32)
    try:
        assert w.fetch_add("k", 5) == 0  # returns the OLD value
        assert w.fetch_add("k", 3) == 5
        assert w.read("k") == 8
        w.reset("k", 41)
        assert w.read("k") == 41
        assert w.fetch_add("k", 1) == 41
        assert w.read("never-touched") == 0
        assert w.n_rmw == 3
    finally:
        w.close()


def test_read_many_matches_reads():
    w = SharedMemWindow.create(capacity=32)
    try:
        for j, key in enumerate(["a", "b", "c"]):
            w.fetch_add(key, j * 7)
        keys = ["c", "a", "unset", "b"]
        assert w.read_many(keys) == [w.read(k) for k in keys]
    finally:
        w.close()


def test_attach_by_name_and_descriptor():
    w = SharedMemWindow.create(capacity=32)
    try:
        w.fetch_add("x", 9)
        by_name = SharedMemWindow.attach(w.name)
        by_desc = SharedMemWindow.attach(w.descriptor())
        assert by_name.read("x") == 9
        assert by_desc.fetch_add("x", 1) == 9
        assert w.read("x") == 10  # one slab, three instances
        by_name.close(unlink=False)
        by_desc.close(unlink=False)
    finally:
        w.close()


def test_directory_full_and_key_too_long():
    w = SharedMemWindow.create(capacity=2)
    try:
        w.fetch_add("a", 1)
        w.fetch_add("b", 1)
        with pytest.raises(RuntimeError, match="directory full"):
            w.fetch_add("c", 1)
        with pytest.raises(ValueError, match="too long"):
            w.fetch_add("k" * 64, 1)
    finally:
        w.close()


def test_keys_directory():
    w = SharedMemWindow.create(capacity=8)
    try:
        for k in ("loop0/i", "loop0/lp", "tele/mu0"):
            w.fetch_add(k, 1)
        assert set(w.keys()) == {"loop0/i", "loop0/lp", "tele/mu0"}
    finally:
        w.close()


def test_attach_rejects_foreign_segment():
    from multiprocessing import shared_memory

    shm = shared_memory.SharedMemory(create=True, size=64)
    try:
        with pytest.raises(RuntimeError, match="not a pt window slab"):
            SharedMemWindow.attach(shm.name)
    finally:
        shm.close()
        shm.unlink()


def test_hier_descriptor_round_trip():
    hw = shm_hierarchical(2, capacity=64)
    try:
        hw2 = attach_hier(hier_descriptor(hw))
        hw2.local(0).fetch_add("x", 1)
        hw2.global_window.fetch_add("g", 2)
        assert hw.local_windows[0].read("x") == 1
        assert hw.global_window.read("g") == 2
        hw2.global_window.close(unlink=False)
        for lw in hw2.local_windows:
            lw.close(unlink=False)
    finally:
        hw.global_window.close()
        for lw in hw.local_windows:
            lw.close()


def test_make_window_shm_and_capability_routing():
    w = make_window("shm", capacity=32)
    try:
        assert isinstance(w, SharedMemWindow)
        assert w.fetch_add("k", 2) == 0
    finally:
        w.close()
    # every backend answers the same capability question
    for cls in (Window, ThreadWindow, SimWindow, SharedMemWindow):
        ok, reason = cls.availability()
        assert ok and reason == ""


def test_kvstore_unavailable_reason_routed():
    from repro.core.rma import KVStoreWindow

    ok, reason = KVStoreWindow.availability()
    if ok:
        pytest.skip("coordination client present: nothing to route")
    assert reason  # the skip/raise message carries the why
    with pytest.raises(RuntimeError, match="KVStoreWindow unavailable"):
        KVStoreWindow()


# ---------------------------------------------------------------------------
# cross-process atomicity
# ---------------------------------------------------------------------------

def test_cross_process_conservation_hammer():
    lat = measure_contention(p_list=(4,), ops=250)
    # measure_contention asserts hot-key conservation internally; the
    # numbers just have to be sane latencies
    assert 0 < lat.per_p[4] < 0.1
    assert lat.backend in ("atomics", "lockf")


def test_uncontended_latency_measurement():
    lat = measure_rmw_latency(ops=500, repeats=2)
    assert 0 < lat.o_rma_min <= lat.o_rma_mean < 0.01
    ov = lat.calibration_overrides()
    assert ov["o_rma"] == lat.o_rma_mean


# ---------------------------------------------------------------------------
# processes executor: conservation at real P
# ---------------------------------------------------------------------------

def _run_processes(technique, runtime, P=8, N=600, work=None, **kw):
    xkw = kw.pop("execute_kw", {})
    shm, name = workloads.alloc_hits(N)
    try:
        session = dls.loop(N, technique=technique, P=P, window="shm",
                           runtime=runtime, **kw)
        work_fn = work or functools.partial(workloads.mark_hits, name)
        report = session.execute(work_fn, executor="processes",
                                 timeout=120.0, **xkw)
        hits = workloads.read_hits(name, N)
        missed = [i for i, h in enumerate(hits) if h != 1]
        assert not missed, f"iterations not executed exactly once: {missed[:10]}"
        assert report.total_iters == N
        session.close()
        return report
    finally:
        shm.close()
        shm.unlink()


def test_processes_one_sided_fac2_p8():
    report = _run_processes("fac2", "one_sided")
    ps = report.process_stats
    assert ps["runtime"] == "one_sided"
    assert ps["n_deaths"] == 0
    assert ps["window_backend"] in ("atomics", "lockf")
    # every PE ran as a real process and reported its own RMW count
    pids = {e["pid"] for e in ps["per_pe"]}
    assert len(pids) == 8
    assert report.n_rmw_global == sum(e["rmw_global"] for e in ps["per_pe"])
    assert report.n_rmw_global >= 2 * report.steps  # two fetch-adds per claim


def test_processes_hierarchical_p8():
    report = _run_processes("fac2", "hierarchical", nodes=2,
                            inner_technique="gss")
    ps = report.process_stats
    assert ps["runtime"] == "hierarchical"
    assert report.n_rmw_local and report.n_rmw_local > 0
    assert report.n_rmw_global and report.n_rmw_global > 0
    # node-local claims must dominate (the hierarchical point)
    assert report.n_rmw_local > report.n_rmw_global


def test_processes_two_sided_master_in_parent():
    report = _run_processes("tss", "two_sided", P=4, N=400)
    ps = report.process_stats
    assert ps["runtime"] == "two_sided"
    # P-1 worker processes; the master executes in the parent
    assert len(ps["per_pe"]) == 3
    assert report.per_pe_iters[0] > 0  # master did real work too


def test_processes_adaptive_shared_telemetry():
    report = _run_processes(
        "awf_b", "one_sided",
        work=None, execute_kw={"progress": 32})
    ps = report.process_stats
    assert ps["policy"] == "awf_b"
    assert ps["shared_telemetry"] is True


def test_processes_report_round_trip():
    report = _run_processes("gss", "one_sided", P=4, N=300)
    clone = SessionReport.from_json(report.to_json())
    assert clone.process_stats == report.process_stats
    assert clone.wall_time == report.wall_time
    assert clone.summary() == report.summary()
    assert "procs[" in clone.summary()


def test_processes_wall_time_is_loop_not_teardown():
    report = _run_processes("fac2", "one_sided", P=4, N=200)
    t_last = max(c["t1"] for c in report.chunk_times)
    assert report.wall_time == pytest.approx(t_last)
    assert report.process_stats["teardown_s"] >= 0.0


def test_processes_requires_shm_window():
    session = dls.loop(100, technique="fac2", P=2)  # thread window
    with pytest.raises(ValueError, match='window="shm"'):
        session.execute(None, executor="processes")


@pytest.mark.slow
@pytest.mark.parametrize("technique", ["static", "ss", "gss", "tss", "fac2",
                                       "wf", "tfss", "awf", "af", "awf_b",
                                       "awf_c", "awf_d", "awf_e"])
@pytest.mark.parametrize("runtime", ["one_sided", "hierarchical"])
def test_processes_full_grid(technique, runtime):
    kw = {"nodes": 2} if runtime == "hierarchical" else {}
    if technique == "wf":
        kw["weights"] = [1.0] * 8
    report = _run_processes(technique, runtime, P=8, N=400, **kw)
    assert report.process_stats["n_deaths"] == 0


# ---------------------------------------------------------------------------
# ThreadWindow per-key locking (satellite: rmw_latency on distinct keys
# must not serialize; same key must)
# ---------------------------------------------------------------------------

def _timed_pair(win, keys):
    t0 = time.perf_counter()
    threads = [threading.Thread(target=win.fetch_add, args=(k, 1))
               for k in keys]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def test_thread_window_per_key_locks_overlap():
    lat = 0.15
    win = ThreadWindow(rmw_latency=lat)
    distinct = _timed_pair(win, ["a", "b"])
    same = _timed_pair(win, ["c", "c"])
    assert distinct < 1.7 * lat, "distinct keys serialized"
    assert same >= 2 * lat, "same-key RMWs overlapped (atomicity lost)"
    assert win.read("a") == win.read("b") == 1
    assert win.read("c") == 2


def test_sim_window_still_single_service_point():
    # SimWindow deliberately models ONE serialization point: RMWs on
    # distinct keys all advance the same virtual clock under one lock
    win = SimWindow(o_rma=0.5)
    threads = [threading.Thread(target=win.fetch_add, args=(k, 1))
               for k in ("a", "b", "c", "a")]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert win.n_rmw == 4
    assert win.clock == pytest.approx(4 * 0.5)
    assert win.read("a") == 2
