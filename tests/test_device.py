"""repro.device: the RMA window relocated to device memory (DESIGN.md 14).

Everything runs under the Pallas interpreter on CPU -- the same protocol
kernel an accelerator compiles.  The load-bearing pins: the on-device
chunk calculus and claim loop match the host closed forms *index for
index* (golden parity), claims partition [0, N) exactly (conservation),
and a device-made session report round-trips through the ordinary
replay plane (capture -> calibrate -> simulate -> gantt) unchanged.
"""
import numpy as np
import pytest

from repro import dls
from repro.core.chunk_calculus import chunk_sizes_closed, plan
from repro.core.rma import HierarchicalWindow, make_window
from repro.core.scheduler import Claim
from repro.device import (
    DEVICE_SPEC_TECHNIQUES,
    DEVICE_TECHNIQUES,
    DeviceRuntime,
    DeviceWindow,
    chunk_size_device,
    claim_schedule,
    host_spec,
    schedule_timeline,
)

pytestmark = pytest.mark.skipif(
    not DeviceWindow.available(),
    reason=f"DeviceWindow unavailable: {DeviceWindow.availability()[1]}")

# The seeded grid the golden parity pins.  (513, 3) is the canonical GSS
# f32-vs-f64 ceil-boundary case; the larger combos ride the slow tier
# (the `device` CI job runs them explicitly, tier-1 stays in budget).
PARITY_GRID = (
    (100, 4),
    (513, 3),
    pytest.param(1000, 7, marks=pytest.mark.slow),
    pytest.param(4096, 8, marks=pytest.mark.slow),
)


# ---------------------------------------------------------------------------
# golden parity: on-device closed forms vs core.chunk_calculus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("technique", DEVICE_TECHNIQUES)
@pytest.mark.parametrize("N,P", PARITY_GRID)
def test_chunk_size_device_matches_host(technique, N, P):
    import jax.numpy as jnp

    chunk = 3 if technique in ("ss", "fsc", "tss") else 1
    spec = host_spec(technique, N, P, chunk=chunk)
    from repro.core.chunk_calculus import max_steps_bound
    S = max_steps_bound(spec)
    idx = np.arange(S, dtype=np.int64)
    want = chunk_sizes_closed(spec, idx, np).astype(np.int64)
    got = np.asarray(
        chunk_size_device(technique, jnp.arange(S, dtype=jnp.int32),
                          N=N, P=P, chunk=chunk), np.int64)
    assert np.array_equal(got, want), (
        f"{technique} N={N} P={P}: first mismatch at "
        f"i={int(np.argmax(got != want))}")


@pytest.mark.parametrize("technique", DEVICE_SPEC_TECHNIQUES)
@pytest.mark.parametrize("N,P", PARITY_GRID)
def test_claim_schedule_matches_host_plan(technique, N, P):
    sched = claim_schedule(technique, N, P)
    sizes, starts = plan(host_spec(technique, N, P))
    assert sched.n_steps == len(sizes)
    assert np.array_equal(sched.sizes, sizes)
    assert np.array_equal(sched.starts, starts)
    assert np.array_equal(sched.steps, np.arange(sched.n_steps))
    # conservation: the device-made claims partition [0, N) exactly
    assert int(sched.sizes.sum()) == N
    cov = np.zeros(N, np.int64)
    for st, sz in zip(sched.starts, sched.sizes):
        cov[st:st + sz] += 1
    assert (cov == 1).all()
    # every worker's claim count is accounted
    assert int(sched.counts.sum()) == sched.n_steps
    assert sched.n_rmw == 2 * sched.n_steps


def test_claim_schedule_max_chunk_and_min_chunk():
    sched = claim_schedule("gss", 200, 4, chunk=2, max_chunk=30)
    sizes, starts = plan(host_spec("gss", 200, 4, chunk=2, max_chunk=30))
    assert np.array_equal(sched.sizes, sizes)
    assert sched.sizes.max() <= 30
    assert int(sched.sizes.sum()) == 200


def test_claim_schedule_resumes_from_nonzero_counters():
    """Nonzero window counters resume a partially-drained loop."""
    import jax.numpy as jnp

    full = claim_schedule("fac2", 150, 3)
    k = 4  # pretend the first k claims already happened
    slab = jnp.zeros(2, jnp.int32)
    slab = slab.at[0].set(k)
    slab = slab.at[1].set(int(full.starts[k]))
    rest = claim_schedule("fac2", 150, 3, slab=slab)
    assert np.array_equal(rest.sizes, full.sizes[k:])
    assert np.array_equal(rest.starts, full.starts[k:])
    assert np.array_equal(rest.steps, full.steps[k:])


def test_schedule_timeline_consistency():
    costs = np.linspace(1.0, 3.0, 400)
    sched = claim_schedule("tss", 400, 5, costs=costs)
    t0s, t1s = schedule_timeline(sched, costs=costs)
    assert np.isclose(max(t1s), sched.makespan(), rtol=1e-6)
    # per-worker intervals are back-to-back and non-overlapping
    for w in range(5):
        rows = [r for r in range(sched.n_steps) if sched.workers[r] == w]
        for a, b in zip(rows, rows[1:]):
            assert t1s[a] <= t0s[b] + 1e-9


# ---------------------------------------------------------------------------
# DeviceWindow: the Window contract over a device-array slab
# ---------------------------------------------------------------------------

def test_window_contract_semantics():
    w = DeviceWindow(capacity=16)
    assert w.fetch_add("k", 5) == 0  # returns the OLD value
    assert w.fetch_add("k", 3) == 5
    assert w.read("k") == 8
    w.reset("k", 41)
    assert w.read("k") == 41
    assert w.fetch_add("k", 1) == 41
    assert w.read("never-touched") == 0
    assert w.n_rmw == 3
    keys = ["k", "never-touched", "k"]
    assert w.read_many(keys) == [w.read(x) for x in keys]


def test_window_directory_is_append_only_and_bounded():
    w = DeviceWindow(capacity=2)
    assert w.slot("a") == 0
    assert w.slot("b") == 1
    assert w.slot("a") == 0  # published slots never move
    with pytest.raises(RuntimeError, match="directory full"):
        w.slot("c")


def test_window_adopt_validates_shape():
    import jax.numpy as jnp

    w = DeviceWindow(capacity=8)
    with pytest.raises(ValueError, match="adopted slab"):
        w.adopt(jnp.zeros(4, jnp.int32))
    w.adopt(jnp.arange(8, dtype=jnp.int32), n_rmw=6)
    assert w.read(w.keys()[0]) if w.keys() else True
    assert w.n_rmw == 6


def test_make_window_device_routes_through_availability():
    w = make_window("device", capacity=32)
    assert isinstance(w, DeviceWindow)
    assert w.capacity == 32
    assert w.capability_tier() in ("atomics", "aliased", "interpret")


def test_fetch_add_traced_shim_matches_host_path():
    import jax
    import jax.numpy as jnp

    w = DeviceWindow(capacity=8)

    @jax.jit
    def bump(d):
        return w.fetch_add_traced("ctr", d)

    olds = [int(bump(jnp.int32(2))) for _ in range(4)]
    assert olds == [0, 2, 4, 6]
    assert w.read("ctr") == 8  # same counter the host path sees
    assert w.fetch_add("ctr", 1) == 8


# ---------------------------------------------------------------------------
# DeviceRuntime: the one-sided protocol over the device window
# ---------------------------------------------------------------------------

def test_runtime_host_claims_match_plan():
    spec = host_spec("gss", 200, 4)
    rt = DeviceRuntime(spec)
    sizes, starts = plan(spec)
    got = []
    while True:
        c = rt.claim(0)
        if c is None:
            break
        got.append((c.start, c.size))
    assert got == list(zip(starts.tolist(), sizes.tolist()))
    assert rt.drained()


def test_runtime_rejects_adaptive_and_weighted():
    from repro.core.chunk_calculus import LoopSpec

    with pytest.raises(ValueError, match="no device closed form"):
        DeviceRuntime(LoopSpec("awf", N=100, P=2))
    with pytest.raises(ValueError, match="unweighted"):
        DeviceRuntime(LoopSpec("gss", N=100, P=2, weights=(1.0, 2.0)))


def test_runtime_rejects_foreign_window():
    from repro.core.rma import ThreadWindow

    with pytest.raises(TypeError, match="DeviceWindow"):
        DeviceRuntime(host_spec("gss", 100, 2), ThreadWindow())


# ---------------------------------------------------------------------------
# facade: dls.loop(runtime="device") + executor="device"
# ---------------------------------------------------------------------------

def test_device_session_end_to_end_and_replay_roundtrip():
    from repro.core.sim import simulate
    from repro.replay import Trace, calibrate, gantt_ascii

    N, P = 300, 4
    costs = np.linspace(1.0, 2.0, N)
    executed = []
    s = dls.loop(N, "gss", P=P, runtime="device")
    rep = dls.execute(s, lambda a, b: executed.append((a, b)),
                      executor="device", costs=costs)
    # coverage: the work_fn saw a partition of [0, N)
    cov = np.zeros(N, np.int64)
    for a, b in executed:
        cov[a:b] += 1
    assert (cov == 1).all()
    assert int(rep.per_pe_iters.sum()) == N
    assert s.runtime.drained()
    # protocol accounting: two RMWs per granted step (and the fast-path
    # reads are free -- they're device loads, not RMWs)
    assert rep.n_rmw_global == 2 * rep.steps
    assert rep.runtime == "one_sided"  # calibrates with the one-sided DES
    assert rep.executor == "device"
    assert rep.wall_time > 0
    # the capture plane round-trips unchanged
    tr = Trace.from_report(rep)
    assert tr.iters_covered() == N
    cal = calibrate(tr)
    r = simulate(cal.sim_config(seed=0))
    assert r.T_loop > 0
    assert "device" in gantt_ascii(tr) or tr.chunks  # renders without error


def test_device_session_serial_executor_interop():
    """Host-side claiming against the same device window still drains."""
    s = dls.loop(120, "tss", P=3, runtime="device", min_chunk=2)
    rep = dls.execute(s, None, executor="serial")
    assert int(rep.per_pe_iters.sum()) == 120
    assert s.runtime.drained()


def test_device_executor_requires_device_runtime():
    s = dls.loop(50, "ss", P=2)  # plain one-sided session
    with pytest.raises(ValueError, match='runtime="device"'):
        dls.execute(s, None, executor="device")


def test_loop_rejects_non_device_window_for_device_runtime():
    with pytest.raises(TypeError, match="DeviceWindow"):
        dls.loop(50, "ss", P=2, runtime="device", window="thread")


def test_device_hierarchy_composes():
    from repro.launch.mesh import make_device_hierarchy

    hw = make_device_hierarchy(capacity=64)
    assert isinstance(hw, HierarchicalWindow)
    s = dls.loop(90, "fac2", P=2, runtime="hierarchical", nodes=1, window=hw)
    rep = dls.execute(s, None, executor="serial")
    assert int(rep.per_pe_iters.sum()) == 90


# ---------------------------------------------------------------------------
# persistent compute kernels: self-scheduled == static, exactly
# ---------------------------------------------------------------------------

def test_mandelbrot_persistent_matches_static():
    from repro.kernels import mandelbrot, mandelbrot_persistent
    from repro.kernels.mandelbrot.persistent import mandelbrot_tile_costs

    ref = np.asarray(mandelbrot(64, 48, ct=30, block_h=16, block_w=16))
    out, sched = mandelbrot_persistent(
        64, 48, ct=30, block_h=16, block_w=16, technique="gss", workers=3)
    assert np.array_equal(np.asarray(out), ref)
    assert int(sched.sizes.sum()) == sched.N
    # the real per-tile cost model shapes the assignment, output unchanged
    costs = mandelbrot_tile_costs(ref, 16, 16)
    out2, sched2 = mandelbrot_persistent(
        64, 48, ct=30, block_h=16, block_w=16, technique="gss", workers=3,
        costs=costs)
    assert np.array_equal(np.asarray(out2), ref)
    # reusing a schedule skips the claim pass and stays exact
    out3, sched3 = mandelbrot_persistent(
        64, 48, ct=30, block_h=16, block_w=16, technique="gss", workers=3,
        schedule=sched2)
    assert sched3 is sched2
    assert np.array_equal(np.asarray(out3), ref)


@pytest.mark.slow
def test_mandelbrot_persistent_other_techniques():
    from repro.kernels import mandelbrot, mandelbrot_persistent

    ref = np.asarray(mandelbrot(96, 80, ct=60, block_h=32, block_w=32))
    for tech in ("fac2", "tss", "ss"):
        out, sched = mandelbrot_persistent(
            96, 80, ct=60, block_h=32, block_w=32, technique=tech, workers=3)
        assert np.array_equal(np.asarray(out), ref)
        assert int(sched.sizes.sum()) == sched.N


@pytest.mark.slow  # pallas compile-bound; the CI device job runs slow tier
def test_flash_attention_persistent_matches_static_causal():
    import jax
    import jax.numpy as jnp
    from repro.kernels import flash_attention, flash_attention_persistent

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, Hkv, T, D = 1, 2, 1, 32, 8
    q = jax.random.normal(kq, (B, H, T, D), jnp.float32)
    k = jax.random.normal(kk, (B, Hkv, T, D), jnp.float32)
    v = jax.random.normal(kv, (B, Hkv, T, D), jnp.float32)
    ref = np.asarray(flash_attention(q, k, v, causal=True, blk_q=16, blk_k=16))
    out, _ = flash_attention_persistent(
        q, k, v, causal=True, blk_q=16, blk_k=16, technique="gss", workers=3)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)


@pytest.mark.slow  # pallas compile-bound; the CI device job runs slow tier
def test_flash_attention_persistent_varlen_matches_oracle():
    import jax
    import jax.numpy as jnp
    from repro.kernels import attention_oracle, flash_attention_persistent

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, T, D = 2, 2, 32, 8
    q = jax.random.normal(kq, (B, H, T, D), jnp.float32)
    k = jax.random.normal(kk, (B, H, T, D), jnp.float32)
    v = jax.random.normal(kv, (B, H, T, D), jnp.float32)
    lengths = np.array([32, 19], np.int32)
    out, sched = flash_attention_persistent(
        q, k, v, causal=False, lengths=lengths, blk_q=16, blk_k=16,
        technique="fac2", workers=4)
    out = np.asarray(out)
    for b, L in enumerate(lengths):
        ref = np.asarray(attention_oracle(
            q[b:b + 1], k[b:b + 1, :, :L], v[b:b + 1, :, :L], causal=False))
        np.testing.assert_allclose(out[b], ref[0], atol=1e-5)
    # the cost model made short-batch tiles cheap: conservation still holds
    assert int(sched.sizes.sum()) == sched.N


def test_varlen_costs_reflect_lengths():
    from repro.kernels.flash_attention.persistent import varlen_tile_costs

    costs = varlen_tile_costs([64, 16], H=2, nq=4, blk_q=16, blk_k=16,
                              causal=True)
    assert costs.shape == (16,)
    # batch 0 (length 64): causal staircase 1,2,3,4 kv blocks per q block
    assert costs[:4].tolist() == [1, 2, 3, 4]
    # batch 1 (length 16): capped at one kv block everywhere
    assert costs[8:12].tolist() == [1, 1, 1, 1]
