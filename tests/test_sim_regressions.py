"""Fast DES regression tests: the paper's ordering invariants at small N.

``test_sim.py`` reproduces the paper's numbers at full scale (288k
iterations -- slow tier).  This module locks the *ordering* claims the
repo must never regress on, at a scale that stays inside the tier-1
budget: a 288-core mix with 100 iterations per PE preserves every
qualitative relationship (the protocols' serialization points, not the
loop length, produce them).
"""
import numpy as np
import pytest

from repro.core import (
    LoopSpec,
    SimConfig,
    paper_cluster,
    psia_costs,
    simulate,
)
from repro.core.sim import PSIA_MEAN_COST

N = 28_800  # 100 iterations per PE on the 288-core mixes


@pytest.fixture(scope="module")
def costs():
    return psia_costs(N, mean=PSIA_MEAN_COST)


def run(tech, impl, coord_on, costs, **kw):
    speeds, coord = paper_cluster("2:1", coord_on)
    spec = LoopSpec(tech, N=len(costs), P=len(speeds))
    return simulate(SimConfig(spec, speeds, costs, impl=impl,
                              coordinator=coord, **kw))


def test_one_sided_beats_two_sided_with_slow_coordinator(costs):
    """The paper's headline ordering: passive-target RMA does not care that
    the coordinator sits on a slow KNL; the master-worker baseline does."""
    one = run("ss", "one_sided", "knl", costs)
    two = run("ss", "two_sided", "knl", costs)
    assert one.T_loop < two.T_loop


def test_slow_master_gss_catastrophe(costs):
    """Fig. 4a: with GSS the master self-claims K_0 (the largest chunk) at
    t=0 -- on a slow master that single chunk dominates T_loop."""
    _, knl_coord = paper_cluster("2:1", "knl")
    slow = run("gss", "two_sided", "knl", costs)
    fast = run("gss", "two_sided", "xeon", costs)
    assert slow.T_loop > 1.2 * fast.T_loop
    # the catastrophe is the master's own K_0 chunk: the slow master is
    # *the* straggler, while a fast master finishes well before the loop
    assert slow.finish.argmax() == knl_coord
    assert slow.finish[knl_coord] == slow.T_loop
    _, xeon_coord = paper_cluster("2:1", "xeon")
    assert fast.finish[xeon_coord] < fast.T_loop


@pytest.mark.parametrize("impl", ["one_sided", "two_sided", "hierarchical"])
def test_simulate_deterministic_for_fixed_seed(impl, costs):
    kw = dict(nodes=8) if impl == "hierarchical" else {}
    a = run("gss", impl, "knl", costs, seed=5, **kw)
    b = run("gss", impl, "knl", costs, seed=5, **kw)
    assert a.T_loop == b.T_loop
    assert (a.finish == b.finish).all()
    assert (a.per_pe_iters == b.per_pe_iters).all()
    assert a.n_claims == b.n_claims
    assert (a.n_rmw_global, a.n_rmw_local) == (b.n_rmw_global, b.n_rmw_local)


def test_hierarchical_cuts_global_rmws_at_least_2x(costs):
    """Acceptance: the two-level scheme must pay the global serialization
    point at least 2x less often than flat one-sided for the same spec
    (in practice the reduction is orders of magnitude)."""
    flat = run("gss", "one_sided", "knl", costs)
    hier = run("gss", "hierarchical", "knl", costs, nodes=8,
               inner_technique="ss")
    assert flat.per_pe_iters.sum() == N
    assert hier.per_pe_iters.sum() == N
    assert hier.n_rmw_global * 2 <= flat.n_rmw_global
    assert hier.n_rmw_local > 0


def test_hierarchical_conserves_on_heterogeneous_mix(costs):
    for nodes in (1, 4, 8):
        r = run("gss", "hierarchical", "knl", costs, nodes=nodes)
        assert r.per_pe_iters.sum() == N, nodes
        assert r.T_loop > 0


def test_hierarchical_local_claims_cheaper_than_flat_global(costs):
    """Mean claim latency drops when most claims are node-local RMWs."""
    flat = run("ss", "one_sided", "knl", costs)
    hier = run("gss", "hierarchical", "knl", costs, nodes=8,
               inner_technique="ss")
    assert hier.mean_claim_latency < flat.mean_claim_latency


# ---------------------------------------------------------------------------
# Adaptive techniques (af / awf_b..e): EXPERIMENTS.md Sec. 3 ordering locks.
# ---------------------------------------------------------------------------

ADAPTIVE_N, ADAPTIVE_P = 8_000, 16


def run_small(tech, impl="one_sided", weights=None, seed=7, **kw):
    """A 16-PE mix with 4 PEs at half speed (the 2x-slow straggler set)."""
    speeds = np.ones(ADAPTIVE_P)
    speeds[-4:] = 0.5
    spec = LoopSpec(tech, N=ADAPTIVE_N, P=ADAPTIVE_P, weights=weights)
    return simulate(SimConfig(spec, speeds, np.full(ADAPTIVE_N, 2e-3),
                              impl=impl, seed=seed, **kw))


@pytest.mark.parametrize("tech", ["af", "awf_b", "awf_c", "awf_d", "awf_e"])
@pytest.mark.parametrize("impl", ["one_sided", "two_sided"])
def test_adaptive_conserves_and_is_deterministic(tech, impl):
    a = run_small(tech, impl)
    b = run_small(tech, impl)
    assert a.per_pe_iters.sum() == ADAPTIVE_N
    assert a.T_loop == b.T_loop
    assert (a.per_pe_iters == b.per_pe_iters).all()
    assert a.n_claims == b.n_claims


@pytest.mark.parametrize("tech", ["af", "awf_b", "awf_c", "awf_d", "awf_e"])
def test_adaptive_schedule_distinct_from_static_parent(tech):
    """fac2 -> af and awf -> awf_b..e must *change* the schedule once
    telemetry exists (the adaptive rows of arXiv:1804.11115 are new rows,
    not aliases)."""
    parent = run_small("fac2" if tech == "af" else "awf")
    adaptive = run_small(tech)
    assert (parent.n_claims != adaptive.n_claims
            or (parent.per_pe_iters != adaptive.per_pe_iters).any())


@pytest.mark.parametrize("tech", ["af", "awf_b", "awf_c"])
def test_adaptive_not_worse_than_stale_static_wf(tech):
    """The reason the family exists: static WF with stale weights (favoring
    the now-slow PEs) loses to online measurement on a 2x-slow-PE mix."""
    stale = np.ones(ADAPTIVE_P)
    stale[-4:] = 2.0  # yesterday's fast PEs are today's slow ones
    stale = tuple(ADAPTIVE_P * stale / stale.sum())
    wf = run_small("wf", weights=stale)
    adaptive = run_small(tech)
    assert adaptive.T_loop < wf.T_loop


def test_adaptive_hierarchical_conserves_with_adaptive_inner():
    r = run_small("gss", impl="hierarchical", nodes=4, inner_technique="af")
    assert r.per_pe_iters.sum() == ADAPTIVE_N
    r = run_small("awf_b", impl="hierarchical", nodes=4,
                  inner_technique="awf_c")
    assert r.per_pe_iters.sum() == ADAPTIVE_N
