"""Golden-fixture pins: the unified kernel vs the pre-refactor DES.

ISSUE 5's byte-identity contract: collapsing the three hand-rolled
event loops into the ``repro.sim`` kernel must not move a single float
in any non-adaptive event stream.  ``tests/fixtures/sim_golden.json``
was captured from the *pre-refactor* ``core/sim.py`` (the triplicated
implementations of PR 2-4) by ``scripts/capture_sim_fixtures.py``;
every case here re-simulates through the unified kernel and compares
the canonical JSON encoding -- full ``SimResult`` plus the per-chunk
event trace -- byte for byte.

Also pinned here: ``simulate_many`` returns exactly what serial
``simulate`` returns at any worker count (the batch API may never
change results), and its budget semantics keep at least the first
candidate.
"""
import dataclasses
import json
import os
import pathlib

import pytest

import _sim_golden_cases as gc
from repro.core.sim import simulate, simulate_many
from repro.sim import fast_qualifies
from repro.sim.batch import (FAST_DISCOUNT, PARALLEL_MIN_ITERS,
                             POOL_STARTUP_S, estimate_batch_iters,
                             resolve_workers)

FIXTURE_PATH = pathlib.Path(__file__).parent / "fixtures" / gc.FIXTURE_NAME


@pytest.fixture(scope="module")
def golden():
    data = json.loads(FIXTURE_PATH.read_text())
    assert data["version"] == gc.FIXTURE_VERSION
    return {e["case"]["key"]: e for e in data["cases"]}


_KEYS = [c["key"] for c in gc.cases()]


def test_fixture_grid_is_current(golden):
    """The committed fixtures cover exactly the shared case grid (a case
    added to the grid without re-capturing must fail loudly)."""
    assert sorted(golden) == sorted(_KEYS)
    # grid sanity: every technique x runtime combination is pinned
    assert len(_KEYS) >= len(gc.NON_ADAPTIVE) * 3


@pytest.mark.parametrize("key", _KEYS)
def test_event_stream_byte_identical(key, golden):
    entry = golden[key]
    r = simulate(gc.build_config(entry["case"]))
    fresh = json.dumps(gc.encode_result(r), sort_keys=True)
    pinned = json.dumps(entry["result"], sort_keys=True)
    assert fresh == pinned, (
        f"{key}: unified kernel drifted from the pre-refactor event stream "
        "(if the change is intentional, re-capture with "
        "scripts/capture_sim_fixtures.py and say so in the PR)")


# ---------------------------------------------------------------------------
# simulate_many: parallel fan-out may never change results
# ---------------------------------------------------------------------------


def _batch_configs(n=6):
    return [gc.build_config(c) for c in gc.cases()[:n]]


def test_simulate_many_serial_matches_simulate():
    cfgs = _batch_configs()
    for r_many, cf in zip(simulate_many(cfgs, workers=1), cfgs):
        assert json.dumps(gc.encode_result(r_many), sort_keys=True) == \
            json.dumps(gc.encode_result(simulate(cf)), sort_keys=True)


def test_simulate_many_parallel_matches_serial():
    cfgs = _batch_configs()
    serial = simulate_many(cfgs, workers=1)
    par = simulate_many(cfgs, workers=2)
    for a, b in zip(serial, par):
        assert json.dumps(gc.encode_result(a), sort_keys=True) == \
            json.dumps(gc.encode_result(b), sort_keys=True)


def test_simulate_many_budget_keeps_first():
    cfgs = _batch_configs()
    for workers in (1, 2):
        out = simulate_many(cfgs, workers=workers, budget_s=0.0)
        assert out[0] is not None  # >= 1 candidate always evaluated
        assert len(out) == len(cfgs)


def test_simulate_many_empty_and_single():
    assert simulate_many([]) == []
    cf = _batch_configs(1)[0]
    (r,) = simulate_many([cf], workers="auto")
    assert json.dumps(gc.encode_result(r), sort_keys=True) == \
        json.dumps(gc.encode_result(simulate(cf)), sort_keys=True)


# ---------------------------------------------------------------------------
# engine routing: "auto" never changes what a non-qualifying config runs on
# ---------------------------------------------------------------------------


def test_auto_routes_non_qualifying_to_kernel(golden):
    """Golden cases collect traces, so none qualify for the fast path:
    ``engine="auto"`` must still reproduce the pinned stream exactly."""
    for key in _KEYS[::6]:  # a spread of the grid, not the whole rerun
        entry = golden[key]
        cf = gc.build_config(entry["case"])
        assert not fast_qualifies(cf)
        fresh = json.dumps(gc.encode_result(simulate(cf, engine="auto")),
                           sort_keys=True)
        assert fresh == json.dumps(entry["result"], sort_keys=True), key


def test_fast_qualifies_predicate():
    """Each disqualifier flips the routing predicate on its own."""
    base = dataclasses.replace(gc.build_config(gc.cases()[0]),
                               collect_trace=False)
    assert base.impl == "one_sided"
    assert fast_qualifies(base)
    assert fast_qualifies(dataclasses.replace(base, impl="two_sided"))
    assert not fast_qualifies(dataclasses.replace(base, collect_trace=True))
    assert not fast_qualifies(
        dataclasses.replace(base, perturbations=[("die", 0, 0.0)]))
    af = dataclasses.replace(
        base, spec=dataclasses.replace(base.spec, technique="af"))
    assert not fast_qualifies(af)
    hier = dataclasses.replace(base, impl="hierarchical", nodes=2,
                               inner_technique="ss")
    assert fast_qualifies(hier)
    assert not fast_qualifies(
        dataclasses.replace(hier, inner_technique="awf_b"))


# ---------------------------------------------------------------------------
# resolve_workers: the adaptive default's decision matrix
# ---------------------------------------------------------------------------


def test_resolve_workers_matrix():
    cores = os.cpu_count() or 1
    big = PARALLEL_MIN_ITERS  # at the threshold counts as big enough
    # adaptive default: serial below the iteration floor ...
    assert resolve_workers(None, 8, total_iters=big - 1) == 1
    # ... parallel at/above it (capped by tasks and cores) ...
    assert resolve_workers(None, 8, total_iters=big) == min(cores, 8)
    # ... but never when the budget can't amortize pool startup
    assert resolve_workers(None, 8, total_iters=big,
                           budget_s=POOL_STARTUP_S / 2) == 1
    assert resolve_workers(None, 8, total_iters=big,
                           budget_s=POOL_STARTUP_S) == min(cores, 8)
    # explicit requests bypass both adaptive guards
    assert resolve_workers("auto", 8, total_iters=0) == min(cores, 8)
    assert resolve_workers(6, 3, total_iters=0) == 3  # capped at tasks
    assert resolve_workers(2, 8, total_iters=0) == 2
    for serial in (0, 1, -3):
        assert resolve_workers(serial, 8, total_iters=10 ** 9) == 1


def test_estimate_batch_iters_discounts_fast_candidates():
    """The adaptive pool guard counts what the batch actually costs:
    fast-qualifying candidates at a fraction of their iteration count
    (a subsampled all-fast selection sweep must not spin up a pool)."""
    base = dataclasses.replace(gc.build_config(gc.cases()[0]),
                               collect_trace=False)
    n = len(base.costs)
    assert fast_qualifies(base)
    assert estimate_batch_iters([base]) == n // FAST_DISCOUNT
    # forced-kernel sweeps pay full price
    assert estimate_batch_iters([base], engine="kernel") == n
    # non-qualifying candidates pay full price under engine="auto" too
    traced = dataclasses.replace(base, collect_trace=True)
    assert estimate_batch_iters([traced]) == n
    assert estimate_batch_iters([base, traced]) == n // FAST_DISCOUNT + n
