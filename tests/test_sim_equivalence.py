"""Golden-fixture pins: the unified kernel vs the pre-refactor DES.

ISSUE 5's byte-identity contract: collapsing the three hand-rolled
event loops into the ``repro.sim`` kernel must not move a single float
in any non-adaptive event stream.  ``tests/fixtures/sim_golden.json``
was captured from the *pre-refactor* ``core/sim.py`` (the triplicated
implementations of PR 2-4) by ``scripts/capture_sim_fixtures.py``;
every case here re-simulates through the unified kernel and compares
the canonical JSON encoding -- full ``SimResult`` plus the per-chunk
event trace -- byte for byte.

Also pinned here: ``simulate_many`` returns exactly what serial
``simulate`` returns at any worker count (the batch API may never
change results), and its budget semantics keep at least the first
candidate.
"""
import json
import pathlib

import pytest

import _sim_golden_cases as gc
from repro.core.sim import simulate, simulate_many

FIXTURE_PATH = pathlib.Path(__file__).parent / "fixtures" / gc.FIXTURE_NAME


@pytest.fixture(scope="module")
def golden():
    data = json.loads(FIXTURE_PATH.read_text())
    assert data["version"] == gc.FIXTURE_VERSION
    return {e["case"]["key"]: e for e in data["cases"]}


_KEYS = [c["key"] for c in gc.cases()]


def test_fixture_grid_is_current(golden):
    """The committed fixtures cover exactly the shared case grid (a case
    added to the grid without re-capturing must fail loudly)."""
    assert sorted(golden) == sorted(_KEYS)
    # grid sanity: every technique x runtime combination is pinned
    assert len(_KEYS) >= len(gc.NON_ADAPTIVE) * 3


@pytest.mark.parametrize("key", _KEYS)
def test_event_stream_byte_identical(key, golden):
    entry = golden[key]
    r = simulate(gc.build_config(entry["case"]))
    fresh = json.dumps(gc.encode_result(r), sort_keys=True)
    pinned = json.dumps(entry["result"], sort_keys=True)
    assert fresh == pinned, (
        f"{key}: unified kernel drifted from the pre-refactor event stream "
        "(if the change is intentional, re-capture with "
        "scripts/capture_sim_fixtures.py and say so in the PR)")


# ---------------------------------------------------------------------------
# simulate_many: parallel fan-out may never change results
# ---------------------------------------------------------------------------


def _batch_configs(n=6):
    return [gc.build_config(c) for c in gc.cases()[:n]]


def test_simulate_many_serial_matches_simulate():
    cfgs = _batch_configs()
    for r_many, cf in zip(simulate_many(cfgs, workers=1), cfgs):
        assert json.dumps(gc.encode_result(r_many), sort_keys=True) == \
            json.dumps(gc.encode_result(simulate(cf)), sort_keys=True)


def test_simulate_many_parallel_matches_serial():
    cfgs = _batch_configs()
    serial = simulate_many(cfgs, workers=1)
    par = simulate_many(cfgs, workers=2)
    for a, b in zip(serial, par):
        assert json.dumps(gc.encode_result(a), sort_keys=True) == \
            json.dumps(gc.encode_result(b), sort_keys=True)


def test_simulate_many_budget_keeps_first():
    cfgs = _batch_configs()
    for workers in (1, 2):
        out = simulate_many(cfgs, workers=workers, budget_s=0.0)
        assert out[0] is not None  # >= 1 candidate always evaluated
        assert len(out) == len(cfgs)


def test_simulate_many_empty_and_single():
    assert simulate_many([]) == []
    cf = _batch_configs(1)[0]
    (r,) = simulate_many([cf], workers="auto")
    assert json.dumps(gc.encode_result(r), sort_keys=True) == \
        json.dumps(gc.encode_result(simulate(cf)), sort_keys=True)
