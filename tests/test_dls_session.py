"""Facade tests: repro.dls sessions across technique x runtime x executor.

The contract under test is the paper's partition property lifted to the
facade: whatever the technique, runtime, and executor, the claims handed
out by a session exactly partition [0, N) -- no gaps, no overlaps -- and
the ``SessionReport`` accounting sums to N.
"""
import math
import threading

import numpy as np
import pytest

from repro import dls
from repro.core import LoopSpec, ThreadWindow, chunk_size_closed, scheduling_steps
from repro.core.weights import WeightBoard

RUNTIMES = ["one_sided", "two_sided"]
EXECUTORS = ["serial", "threads"]


def _assert_partition(claims, N):
    ivals = sorted((c.start, c.stop) for c in claims)
    assert ivals, "no claims"
    assert ivals[0][0] == 0 and ivals[-1][1] == N
    for (a0, b0), (a1, b1) in zip(ivals, ivals[1:]):
        assert b0 == a1, f"gap or overlap at {b0} vs {a1}"


@pytest.mark.parametrize("tech", dls.TECHNIQUES)
@pytest.mark.parametrize("runtime", RUNTIMES)
@pytest.mark.parametrize("executor", EXECUTORS)
def test_partition_for_every_combination(tech, runtime, executor):
    N, P = 5_000, 5
    hits = np.zeros(N, np.int32)
    lock = threading.Lock()

    def work(a, b):
        with lock:
            hits[a:b] += 1

    session = dls.loop(N, technique=tech, P=P, runtime=runtime)
    report = session.execute(work, executor=executor)
    assert (hits == 1).all(), f"{tech}/{runtime}/{executor} not a partition"
    _assert_partition(report.claims, N)
    assert sum(report.chunk_sizes) == N
    assert report.total_iters == N
    assert report.steps == len(report.claims)
    assert session.drained() and session.remaining() == 0


@pytest.mark.parametrize("runtime", RUNTIMES)
def test_sim_executor_accounts_every_iteration(runtime):
    N, P = 2_000, 8
    session = dls.loop(N, technique="fac2", P=P, runtime=runtime)
    report = session.execute(
        None, executor="sim", costs=np.full(N, 1e-3),
        speeds=np.linspace(0.5, 2.0, P))
    assert report.total_iters == N
    assert report.wall_time > 0
    assert report.executor == "sim"


def test_report_busy_time_and_cov():
    N, P = 4_000, 4
    session = dls.loop(N, technique="gss", P=P)
    report = session.execute(lambda a, b: None, executor="serial")
    assert report.busy_time.shape == (P,)
    assert (report.busy_time >= 0).all()
    assert 0.0 <= report.cov or report.cov == 0.0  # finite, defined
    assert "gss" in report.summary()


def test_claims_iterator_is_pipeline_form():
    session = dls.loop(3_000, technique="tss", P=3)
    total = 0
    for c in session.claims(pe=1):
        total += c.size
    assert total == 3_000
    # all claims were logged against pe 1
    rep = session.report()
    assert rep.per_pe_iters[1] == 3_000
    assert rep.per_pe_iters[0] == 0


def test_weight_policy_adaptive_feeds_back():
    """AWF: a slow PE's recorded throughput shrinks its next chunks."""
    board = WeightBoard(2, ema=0.9)
    session = dls.loop(1_000_000, technique="awf", P=2, weights=board)
    c_fast_before = session.claim(0)
    c_slow_before = session.claim(1)
    assert c_fast_before.size == c_slow_before.size
    for _ in range(10):
        session.record(0, 1000, 0.1)   # 10k it/s
        session.record(1, 1000, 10.0)  # 100 it/s
    c_fast = session.claim(0)
    c_slow = session.claim(1)
    assert c_slow.size < c_fast.size


def test_window_backends_by_name():
    from repro.core.rma import SimWindow

    s = dls.loop(100, technique="ss", P=2, window="sim")
    assert isinstance(s.runtime.window, SimWindow)
    n = sum(c.size for c in s.claims(0))
    assert n == 100
    assert s.runtime.window.n_rmw > 0 and s.runtime.window.clock > 0

    s = dls.loop(100, technique="ss", P=2, window="thread")
    assert sum(c.size for c in s.claims(0)) == 100


def test_session_state_restore_roundtrip():
    """A restored session re-serves exactly the unclaimed tail."""
    win = ThreadWindow()
    s = dls.loop(2_000, technique="gss", P=2, window=win)
    served = 0
    for _ in range(3):
        served += s.claim(0).size
    st = s.state()
    # "crash": fresh window + session, restore counters
    s2 = dls.loop(2_000, technique="gss", P=2, window=ThreadWindow(),
                  loop_id=99)
    s2.restore(st)
    tail = sum(c.size for c in s2.claims(0))
    assert served + tail == 2_000


# ---------------------------------------------------------------------------
# AWF closed-form extraction (satellite): the weight= path of
# chunk_size_closed must match the math previously inlined in
# OneSidedRuntime.claim.
# ---------------------------------------------------------------------------


def _old_inline_awf(spec, i, weight):
    b = i // spec.P + 1
    base = 0.5 ** b * spec.N / spec.P
    return max(int(math.ceil(weight * base)), spec.min_chunk)


@pytest.mark.parametrize("tech", ["wf", "awf"])
def test_awf_closed_form_matches_old_inline(tech):
    for N in (1, 97, 10_000, 1_000_000):
        for P in (1, 7, 64, 288):
            spec = LoopSpec(tech, N=N, P=P)
            for i in (0, 1, P - 1, P, 3 * P + 1, 10 * P):
                for w in (0.05, 0.25, 1.0, 1.7, 4.0):
                    assert chunk_size_closed(spec, i, pe=0, weight=w) == \
                        _old_inline_awf(spec, i, w), (N, P, i, w)


def test_awf_weight_ignored_by_unweighted_techniques():
    spec = LoopSpec("gss", N=10_000, P=8)
    assert chunk_size_closed(spec, 3, weight=0.1) == chunk_size_closed(spec, 3)


def test_awf_weight_respects_max_chunk_cap():
    # The old inline path bypassed LoopSpec.max_chunk; the extracted form
    # applies it (FT refinement: bound the work lost when a PE dies).
    spec = LoopSpec("awf", N=100_000, P=4, max_chunk=50)
    assert chunk_size_closed(spec, 0, weight=4.0) == 50


# ---------------------------------------------------------------------------
# ContinuousBatcher drain contract (satellite): no probe claims burned.
# ---------------------------------------------------------------------------


def test_batcher_burns_no_probe_scheduling_steps():
    from repro.serve.engine import ContinuousBatcher, Request

    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32), max_new=4)
            for i in range(200)]
    cb = ContinuousBatcher(n_workers=4, technique="gss")
    done = cb.schedule(reqs, lambda chunk, w: 0.01 * len(chunk))
    assert done.shape == (200,)
    assert (done > 0).all()
    # sequential claiming must take exactly the closed-form number of steps:
    # the old drain check (claim-probe per worker) burned extra step indices.
    expected = scheduling_steps(LoopSpec("gss", N=200, P=4))
    assert cb.last_report.steps == expected
    assert sum(cb.last_report.chunk_sizes) == 200


def test_two_sided_restore_rebuilds_recurrence_state():
    """Restoring a mid-batch two-sided checkpoint must not crash or stall:
    the derived (_k_tss, _batch_base) state is re-derived, not left stale."""
    for tech in ("fac2", "wf", "awf", "tss", "tfss"):
        src = dls.loop(10_000, technique=tech, P=4, runtime="two_sided")
        served = sum(src.claim(i % 4).size for i in range(5))  # mid-batch
        st = src.state()
        dst = dls.loop(10_000, technique=tech, P=4, runtime="two_sided")
        dst.restore(st)
        tail = sum(c.size for c in dst.claims(0))
        assert served + tail == 10_000, tech


def test_two_sided_reset_replays_fresh_series():
    """reset() of a drained two-sided session must reproduce the original
    chunk series, not continue a stale TSS ramp from its floor."""
    from repro.core import chunk_series_recurrence

    s = dls.loop(2_000, technique="tss", P=4, runtime="two_sided")
    first = [c.size for c in s.claims(0)]
    s.reset()
    second = [c.size for c in s.claims(0)]
    assert first == second == chunk_series_recurrence(
        LoopSpec("tss", N=2_000, P=4))


def test_loop_warns_on_noop_weight_policy():
    """Weights supplied for a technique that ignores them is a silent no-op
    bug waiting to happen -- loop() must warn."""
    with pytest.warns(UserWarning, match="ignores weights"):
        dls.loop(1_000, technique="fac2", P=4, weights="awf")
    # weighted techniques and plain uniform stay silent
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error")
        dls.loop(1_000, technique="awf", P=4, weights="awf")
        dls.loop(1_000, technique="fac2", P=4)


def test_threaded_execution_via_facade():
    """The migration target of the removed ``run_threaded_*`` shims: the
    facade's threads executor covers both protocols (and the shim names
    are really gone from ``repro.core``)."""
    claims = dls.loop(1000, technique="fac2", P=4).execute(
        lambda a, b: None, executor="threads").claims
    assert sum(c.size for c in claims) == 1000
    claims = dls.loop(500, technique="ss", P=4, runtime="two_sided").execute(
        lambda a, b: None, executor="threads").claims
    assert sum(c.size for c in claims) == 500
    import repro.core
    assert not hasattr(repro.core, "run_threaded_one_sided")
    assert not hasattr(repro.core, "run_threaded_two_sided")
