"""Online PE telemetry + the adaptive technique family (DESIGN.md Sec. 8).

Covers the measurement plane (PerfModel / WapTracker / the adaptation
models), the AF closed form, the facade wiring (auto-policies, adaptation
trace, AF stats through every runtime), and the acceptance properties:
adaptive techniques produce schedules *distinct* from their static parents
once telemetry exists, while staying conservation-clean.
"""
import numpy as np
import pytest

from repro import dls
from repro.core import chunk_calculus as cc
from repro.core.rma import ThreadWindow
from repro.core.weights import (
    AdaptiveFactoringModel,
    AdaptiveWeightModel,
    PerfModel,
    WapTracker,
)

# ---------------------------------------------------------------------------
# PerfModel: window-backed telemetry
# ---------------------------------------------------------------------------


def test_perfmodel_rates_and_mu():
    m = PerfModel(3)
    m.record(0, 100, 0.1)          # 1000 it/s
    m.record(1, 100, 0.2)          # 500 it/s
    snap = m.snapshot()
    assert snap.iters[0] == 100 and snap.n[1] == 1 and snap.iters[2] == 0
    mu = m.mu(snap)
    assert mu[0] == pytest.approx(1e-3, rel=1e-3)
    assert mu[1] == pytest.approx(2e-3, rel=1e-3)
    assert np.isnan(mu[2])
    rates = m.rates(snap)
    assert rates[0] == pytest.approx(1000, rel=1e-3)


def test_perfmodel_sigma_needs_two_chunks_and_is_nonnegative():
    m = PerfModel(1)
    m.record(0, 10, 0.010)
    assert m.sigma2()[0] == 0.0  # one chunk: no spread yet
    m.record(0, 10, 0.030)  # means 1 ms vs 3 ms per iter
    s2 = m.sigma2()[0]
    assert s2 == pytest.approx(1e-6, rel=1e-2)  # var of {1ms, 3ms} = 1 ms^2


def test_perfmodel_survives_second_scale_iteration_times():
    """Regression: ns^2 sums of second-scale chunk means exceed int64;
    snapshot()/sigma2() must not overflow."""
    m = PerfModel(1)
    for _ in range(12):
        m.record(0, 10, 10.0)  # 1 s/iteration
    snap = m.snapshot()
    assert snap.n[0] == 12
    assert m.sigma2(snap)[0] == 0.0  # constant means: no spread
    assert m.mu(snap)[0] == pytest.approx(1.0, rel=1e-6)


def test_perfmodel_shared_window_aggregates_across_instances():
    """Two PerfModels over one window = one telemetry plane (multi-session
    sharing, the KV-store deployment shape)."""
    w = ThreadWindow()
    a, b = PerfModel(2, window=w), PerfModel(2, window=w)
    a.record(0, 50, 0.05)
    b.record(0, 50, 0.05)
    assert a.snapshot().iters[0] == 100
    assert b.snapshot().n[0] == 2


def test_perfmodel_node_weights_aggregate_rates():
    m = PerfModel(4)
    for pe, sec in ((0, 0.1), (1, 0.1), (2, 0.4), (3, 0.4)):
        m.record(pe, 100, sec)  # node 0 is 4x faster
    nw = m.node_weights([0, 2, 4])
    assert nw is not None and nw.sum() == pytest.approx(2.0)
    assert nw[0] == pytest.approx(1.6) and nw[1] == pytest.approx(0.4)
    assert PerfModel(4).node_weights([0, 2, 4]) is None  # blind -> None


# ---------------------------------------------------------------------------
# WapTracker + AdaptiveWeightModel (AWF-B/C/D/E)
# ---------------------------------------------------------------------------


def test_wap_tracker_normalizes_and_carries_forward():
    t = WapTracker(2)
    w = t.add(np.array([1e-3, 2e-3]))
    assert w.sum() == pytest.approx(2.0)
    assert w[0] > w[1]  # faster PE weighs more
    w2 = t.add(np.array([1e-3, np.nan]))  # PE 1 silent: carries 2e-3
    assert w2[0] > w2[1] and w2.sum() == pytest.approx(2.0)


def test_wap_tracker_weights_recent_intervals_more():
    t = WapTracker(2)
    t.add(np.array([1e-3, 1e-3]))        # s=1: equal
    w = t.add(np.array([1e-3, 4e-3]))    # s=2: PE1 got slow, weighted 2x
    # wap_1 = (1*1 + 2*4)/3 = 3 ms vs plain mean 2.5 ms: recency bites
    assert w[0] / w[1] == pytest.approx(3.0, rel=1e-6)


def test_awf_batch_vs_chunk_update_granularity():
    batch = AdaptiveWeightModel(4, update="batch")
    chunk = AdaptiveWeightModel(4, update="chunk")
    for j in range(3):  # 3 records < P=4
        batch.record(j, 10, 0.01)
        chunk.record(j, 10, 0.01)
    assert batch.weight(0) is None and batch.n_updates == 0
    assert chunk.weight(0) is not None and chunk.n_updates == 3
    batch.record(3, 10, 0.01)  # 4th record closes the batch
    assert batch.n_updates == 1 and batch.weight(0) is not None


def test_awf_overhead_variants_see_sched_seconds():
    plain = AdaptiveWeightModel(2, update="chunk", include_overhead=False)
    overhead = AdaptiveWeightModel(2, update="chunk", include_overhead=True)
    for m in (plain, overhead):
        m.record(0, 10, 0.010, sched_seconds=0.0)
        m.record(1, 10, 0.010, sched_seconds=0.010)  # PE1 pays 2x in sched
    assert plain.weight(0) == pytest.approx(plain.weight(1))
    assert overhead.weight(0) > overhead.weight(1)  # D/E punish overhead


def test_awf_model_traces_updates():
    m = AdaptiveWeightModel(2, update="chunk")
    m.record(0, 10, 0.01)
    m.record(1, 10, 0.02)
    assert len(m.trace) == 2
    assert m.trace[-1]["update"] == 2
    assert len(m.trace[-1]["weights"]) == 2


# ---------------------------------------------------------------------------
# AF: closed form + model
# ---------------------------------------------------------------------------


def test_af_chunk_zero_variance_is_speed_proportional_share():
    # D=0: K = T*R/mu -- each PE's speed share of 1/P of the remainder
    mu = np.array([1e-3, 1e-3, 1e-3, 2e-3])
    T = 1.0 / np.sum(1.0 / mu)
    R = 7000
    fast = cc.af_chunk_size(cc.AFStats(mu=1e-3, D=0.0, T=T), R)
    slow = cc.af_chunk_size(cc.AFStats(mu=2e-3, D=0.0, T=T), R)
    assert fast == 2 * slow
    assert fast == pytest.approx(T * R / 1e-3, abs=1)


def test_af_variance_shrinks_chunks():
    T = 2.5e-4
    calm = cc.af_chunk_size(cc.AFStats(mu=1e-3, D=0.0, T=T), 10_000)
    noisy = cc.af_chunk_size(cc.AFStats(mu=1e-3, D=1e-1, T=T), 10_000)
    assert noisy < calm


def test_af_model_bootstraps_then_measures():
    m = AdaptiveFactoringModel(2)
    assert m.af_stats(0) is None  # no telemetry: closed form bootstraps
    m.record(0, 100, 0.1)
    st = m.af_stats(0)
    assert st is not None
    assert st.mu == pytest.approx(1e-3, rel=1e-3)
    assert m.af_stats(1) is None  # PE1 itself still unmeasured
    assert st.T > 0


# ---------------------------------------------------------------------------
# Facade wiring
# ---------------------------------------------------------------------------


def test_loop_auto_adopts_adaptive_policies():
    for tech, cls in [("af", dls.AdaptiveFactoring),
                      ("awf_b", dls.AWFVariantWeights),
                      ("awf_e", dls.AWFVariantWeights)]:
        s = dls.loop(1000, technique=tech, P=4)
        assert isinstance(s.policy, cls), tech
    # non-adaptive techniques keep the uniform default
    assert isinstance(dls.loop(1000, technique="fac2", P=4).policy,
                      dls.UniformWeights)


def test_make_weight_policy_unknown_name_lists_adaptive_set():
    with pytest.raises(ValueError, match="awf_e"):
        dls.make_weight_policy("nope", 4)


def test_loop_accepts_policy_name_matching_every_adaptive_technique():
    for name in dls.ADAPTIVE:
        s = dls.loop(500, technique=name, P=3, weights=name)
        assert not isinstance(s.policy, dls.UniformWeights)


def _virtual_drain(session, speeds, cost=1e-3):
    """Round-robin drain recording synthetic per-PE timings (deterministic
    telemetry without wall-clock noise)."""
    P = len(speeds)
    done = [False] * P
    n_done = 0
    pe = 0
    claims = []
    while n_done < P:
        if not done[pe]:
            c = session.claim(pe)
            if c is None:
                done[pe] = True
                n_done += 1
            else:
                claims.append((pe, c))
                session.record(pe, c.size, c.size * cost / speeds[pe])
        pe = (pe + 1) % P
    return claims


@pytest.mark.parametrize("runtime", ["one_sided", "two_sided"])
@pytest.mark.parametrize("tech", dls.ADAPTIVE)
def test_adaptive_with_live_telemetry_conserves(tech, runtime):
    N, P = 3_000, 4
    speeds = [1.0, 1.0, 1.0, 0.5]
    session = dls.loop(N, technique=tech, P=P, runtime=runtime)
    claims = [c for _, c in _virtual_drain(session, speeds)]
    ivals = sorted((c.start, c.stop) for c in claims)
    assert ivals[0][0] == 0 and ivals[-1][1] == N
    assert all(b0 == a1 for (_, b0), (a1, _) in zip(ivals, ivals[1:]))
    assert sum(c.size for c in claims) == N


@pytest.mark.parametrize("tech", dls.ADAPTIVE)
def test_adaptive_schedule_differs_from_static_parent(tech):
    """fac2 -> af / awf -> awf_b..e: measured heterogeneity must change
    the chunk series (the whole point of adapting)."""
    N, P = 3_000, 4
    speeds = [1.0, 1.0, 1.0, 0.25]
    parent = "fac2" if tech == "af" else "awf"
    sizes = {}
    for t in (tech, parent):
        session = dls.loop(N, technique=t, P=P)
        sizes[t] = [(pe, c.size) for pe, c in _virtual_drain(session, speeds)]
    assert sizes[tech] != sizes[parent], tech


def test_adaptive_report_carries_adaptation_trace():
    session = dls.loop(2_000, technique="awf_c", P=4)
    _virtual_drain(session, [1.0, 1.0, 0.5, 0.5])
    report = session.report()
    assert report.n_weight_updates > 0
    fw = report.final_weights()
    assert fw is not None and len(fw) == 4
    # the measured-slow PEs ended with smaller weights
    assert fw[0] > fw[3]
    assert "adapt=" in report.summary()


def test_af_report_traces_mu_observations():
    session = dls.loop(2_000, technique="af", P=4)
    _virtual_drain(session, [1.0, 1.0, 1.0, 1.0])
    report = session.report()
    assert report.n_weight_updates > 0
    assert "mu" in report.adaptation[0]


def test_static_policy_report_has_no_adaptation():
    session = dls.loop(500, technique="fac2", P=2)
    _virtual_drain(session, [1.0, 1.0])
    assert session.report().adaptation is None


def test_legacy_three_arg_record_policy_still_works():
    class Legacy:
        def __init__(self):
            self.calls = []

        def weight(self, pe):
            return 1.0

        def record(self, pe, iters, seconds):  # no sched_seconds
            self.calls.append((pe, iters))

    pol = Legacy()
    session = dls.loop(500, technique="wf", P=2, weights=pol)
    session.execute(lambda a, b: None, executor="serial")
    assert pol.calls and sum(i for _, i in pol.calls) == 500


def test_keyword_only_sched_seconds_policy_works():
    """Regression: a keyword-only ``sched_seconds`` (or **kwargs) policy
    must receive the overhead by keyword, not a 5th positional arg."""
    seen = {"sched": 0, "iters": 0}

    class KwOnly:
        def weight(self, pe):
            return 1.0

        def record(self, pe, iters, seconds, *, sched_seconds=0.0):
            seen["iters"] += iters
            seen["sched"] += 1 if sched_seconds >= 0 else 0

    session = dls.loop(400, technique="wf", P=2, weights=KwOnly())
    session.execute(lambda a, b: None, executor="serial")
    assert seen["iters"] == 400 and seen["sched"] > 0


def test_two_sided_af_batch_boundary_after_stats_claim():
    """Regression: an AF claim landing on a batch boundary must still
    refresh the master's batch base for telemetry-less bootstrap PEs."""
    from repro.core.scheduler import TwoSidedRuntime

    rt = TwoSidedRuntime(cc.LoopSpec("af", N=1_000, P=4))
    st = cc.AFStats(mu=1e-3, D=0.0, T=2.5e-4)
    a = rt.claim(0, af=st)  # i=0: AF stats claim on the boundary
    b = rt.claim(1)  # bootstrap PE: must not see a None batch base
    assert a is not None and b is not None
    assert a.stop == b.start


def test_executor_threads_drains_adaptive(tech="awf_d"):
    N = 2_000
    hits = np.zeros(N, np.int32)
    import threading
    lock = threading.Lock()

    def work(a, b):
        with lock:
            hits[a:b] += 1

    report = dls.loop(N, technique=tech, P=4).execute(work, executor="threads")
    assert (hits == 1).all()
    assert report.n_weight_updates > 0


# ---------------------------------------------------------------------------
# Hierarchical: per-level aggregation
# ---------------------------------------------------------------------------


def test_hierarchical_outer_weights_wired_from_telemetry():
    session = dls.loop(4_000, technique="awf_b", P=8,
                       runtime="hierarchical", nodes=2,
                       inner_technique="awf_b")
    assert session.runtime.outer_weight_fn is not None
    claims = [c for _, c in _virtual_drain(
        session, [1.0] * 4 + [0.25] * 4)]
    assert sum(c.size for c in claims) == 4_000


def test_hierarchical_inner_af_conserves():
    session = dls.loop(3_000, technique="gss", P=6,
                       runtime="hierarchical", nodes=2, inner_technique="af")
    assert session._wants_af  # AF stats flow to the inner level
    claims = [c for _, c in _virtual_drain(session, [1.0] * 6)]
    assert sum(c.size for c in claims) == 3_000


def test_hierarchical_static_outer_not_wired():
    session = dls.loop(1_000, technique="gss", P=4,
                       runtime="hierarchical", nodes=2)
    assert session.runtime.outer_weight_fn is None


# ---------------------------------------------------------------------------
# Planner / recurrence stay total for the new roster
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tech", dls.ADAPTIVE)
def test_plan_and_recurrence_bootstrap_partition(tech):
    spec = cc.LoopSpec(tech, N=4_321, P=7)
    sizes, _ = cc.plan(spec)
    assert sizes.sum() == 4_321
    assert sum(cc.chunk_series_recurrence(spec)) == 4_321


def test_plan_grows_bound_for_tiny_weights():
    """Live weights can shrink chunks below the unweighted halving the
    steps bound assumes; plan() must extend, not truncate."""
    spec = cc.LoopSpec("awf_b", N=2_000, P=4)
    S = cc.max_steps_bound(spec)
    sizes, _ = cc.plan(spec, weights_per_step=np.full(S, 0.05))
    assert sizes.sum() == 2_000
    assert (sizes > 0).all()
