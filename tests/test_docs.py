"""Docs-consistency harness (CI's docs job).

Two guarantees:

  * the README quickstart actually runs: every fenced python block
    containing doctest prompts is executed via doctest;
  * the technique tables embedded in README.md and DESIGN.md (between
    ``<!-- technique-table-start/end -->`` markers) are byte-identical to
    ``chunk_calculus.technique_table()`` -- the roster's single source of
    truth -- so docs can never drift from the code
    (regenerate with ``scripts/gen_technique_table.py``).
"""
import doctest
import pathlib
import re

import pytest

from repro.core.chunk_calculus import (
    ADAPTIVE,
    POLICY_DRIVEN,
    TECHNIQUES,
    WEIGHTED,
    technique_table,
)

ROOT = pathlib.Path(__file__).resolve().parent.parent
TABLE_RE = re.compile(
    r"<!-- technique-table-start -->\n(.*?)\n<!-- technique-table-end -->",
    re.S)
FENCE_RE = re.compile(r"```python\n(.*?)```", re.S)


def _read(name: str) -> str:
    return (ROOT / name).read_text()


# ---------------------------------------------------------------------------
# README quickstart snippet
# ---------------------------------------------------------------------------


def test_readme_quickstart_doctests_pass():
    blocks = [b for b in FENCE_RE.findall(_read("README.md")) if ">>>" in b]
    assert blocks, "README has no doctest-able quickstart block"
    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(optionflags=doctest.ELLIPSIS)
    for i, block in enumerate(blocks):
        test = parser.get_doctest(block, {}, f"README-block-{i}", "README.md",
                                  0)
        runner.run(test)
    assert runner.failures == 0, (
        f"{runner.failures} README doctest failure(s) -- run the quickstart "
        "block and update README.md")


# ---------------------------------------------------------------------------
# Technique tables: generated, never hand-drifted
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("doc", ["README.md", "DESIGN.md"])
def test_technique_table_matches_code(doc):
    m = TABLE_RE.search(_read(doc))
    assert m, f"{doc} lost its technique-table markers"
    assert m.group(1).strip() == technique_table().strip(), (
        f"{doc} technique table drifted from chunk_calculus.TECHNIQUE_INFO; "
        "regenerate with: PYTHONPATH=src python scripts/gen_technique_table.py")


def test_roster_sets_are_consistent():
    """The derived sets the facade / docs rely on stay within the roster."""
    assert set(WEIGHTED) <= set(TECHNIQUES)
    assert set(ADAPTIVE) <= set(TECHNIQUES)
    assert set(POLICY_DRIVEN) == set(WEIGHTED) | set(ADAPTIVE)
    # every technique row appears exactly once in the generated table
    table = technique_table()
    for name in TECHNIQUES:
        assert table.count(f"| `{name}` |") == 1


def test_readme_mentions_all_top_level_docs():
    readme = _read("README.md")
    for doc in ("DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md", "PAPERS.md"):
        assert doc in readme, f"README architecture map lost its {doc} link"
