"""Workload-generator + SLO-metrics properties (the serving data plane).

Mirrors the ``test_invariants.py`` two-layer pattern: a deterministic
seeded case grid that always runs, plus hypothesis fuzzing over the same
properties when hypothesis is importable.  Properties:

  * seeded streams are reproducible and byte-stable (write -> read ->
    write identical);
  * arrival times are strictly ordered with non-negative inter-arrivals,
    for every arrival process;
  * priority-class proportions match the tenant shares within tolerance;
  * heavy-tail parameters are respected (``max_new`` within
    [min, cap], prompt lengths >= 1);
  * parameter validation rejects nonsense;
  * the SLO plane computes what it claims (hand-checked queue depth /
    goodput cases; canonical round trip).
"""
import json
import math

import numpy as np
import pytest

from repro.serve import (
    SLO,
    RequestStream,
    SLOReport,
    TenantClass,
    compute_slo,
    generate_stream,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the no-hypothesis CI job
    HAVE_HYPOTHESIS = False

ARRIVAL_CASES = ["poisson", "bursty", "diurnal"]


# ---------------------------------------------------------------------------
# shared checkers
# ---------------------------------------------------------------------------


def assert_stream_wellformed(stream, n, *, max_new_min=2, max_new_cap=256):
    assert stream.n == n
    t = stream.arrival_times()
    assert (np.diff(t) >= 0).all(), "arrival times must be non-decreasing"
    assert (stream.inter_arrivals() >= 0).all()
    assert (t > 0).all()
    for r in stream.requests:
        assert r.prompt_len >= 1
        assert max_new_min <= r.max_new <= max_new_cap
    assert [r.rid for r in stream.requests] == list(range(n))


def assert_byte_stable(stream):
    text = stream.to_jsonl()
    back = RequestStream.from_jsonl(text)
    assert back.to_jsonl() == text, "write -> read -> write not byte-stable"
    assert back.meta == stream.meta


# ---------------------------------------------------------------------------
# deterministic seeded grid (always runs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arrival", ARRIVAL_CASES)
@pytest.mark.parametrize("seed", [0, 7])
def test_stream_wellformed_and_stable(arrival, seed):
    s = generate_stream(150, arrival=arrival, rate=12.0, seed=seed)
    assert_stream_wellformed(s, 150)
    assert_byte_stable(s)
    again = generate_stream(150, arrival=arrival, rate=12.0, seed=seed)
    assert again.to_jsonl() == s.to_jsonl(), "same seed, different bytes"


def test_different_seeds_differ():
    a = generate_stream(50, seed=0).to_jsonl()
    b = generate_stream(50, seed=1).to_jsonl()
    assert a != b


def test_mean_rate_is_preserved_across_processes():
    """Bursty/diurnal redistribute load in time but keep the long-run
    mean rate; horizons agree with poisson within statistical slack."""
    n, rate = 4000, 20.0
    horizons = {a: generate_stream(n, arrival=a, rate=rate, seed=2).horizon
                for a in ARRIVAL_CASES}
    for a, h in horizons.items():
        assert h == pytest.approx(n / rate, rel=0.15), (a, h)


def test_bursty_concentrates_arrivals():
    """The on-window of a bursty stream holds disproportionate traffic."""
    s = generate_stream(3000, arrival="bursty", rate=10.0, seed=4,
                        burst_factor=8.0, burst_on_s=2.0, burst_off_s=6.0)
    t = s.arrival_times()
    in_burst = ((t % 8.0) < 2.0).mean()
    assert in_burst > 0.5  # 25% of the cycle carries most of the load


def test_tenant_shares_within_tolerance():
    tenants = [TenantClass("free", 0.6, 0), TenantClass("pro", 0.3, 1),
               TenantClass("batch", 0.1, -1)]
    s = generate_stream(3000, seed=9, tenants=tenants)
    counts = s.tenant_counts()
    for c in tenants:
        assert counts[c.name] / s.n == pytest.approx(c.share / 1.0, abs=0.05)
    by_name = {r.tenant: r.priority for r in s.requests}
    assert by_name == {"free": 0, "pro": 1, "batch": -1}


def test_heavy_tail_parameters_respected():
    s = generate_stream(4000, seed=1, max_new_min=4, max_new_cap=128,
                        max_new_tail=1.05, max_new_scale=10.0)
    gen = np.array([r.max_new for r in s.requests])
    assert gen.min() >= 4 and gen.max() <= 128
    # tail index ~1: the cap is actually hit, and the distribution is
    # right-skewed (mean well above median)
    assert (gen == 128).sum() > 0
    assert gen.mean() > 1.5 * np.median(gen)


@pytest.mark.parametrize("kw", [
    {"rate": 0.0}, {"rate": -1.0}, {"burst_factor": 0.5},
    {"burst_on_s": 0.0}, {"diurnal_amplitude": 1.0},
    {"diurnal_period_s": 0.0}, {"max_new_tail": 0.0},
    {"max_new_min": 0}, {"max_new_min": 300, "max_new_cap": 256},
    {"prompt_mean": 0.5}, {"prompt_cov": -0.1},
    {"arrival": "weekly"},
    {"tenants": [TenantClass("a", 0.0)]},
])
def test_generator_rejects_bad_params(kw):
    with pytest.raises(ValueError):
        generate_stream(10, **kw)


def test_stream_rejects_newer_schema():
    s = generate_stream(3, seed=0)
    lines = s.to_jsonl().splitlines()
    header = json.loads(lines[0])
    header["version"] = 999
    bad = "\n".join([json.dumps(header)] + lines[1:])
    with pytest.raises(ValueError):
        RequestStream.from_jsonl(bad)


# ---------------------------------------------------------------------------
# SLO metrics plane
# ---------------------------------------------------------------------------


def _row(rid, sub, first, done, tokens, tenant="default", requeues=0):
    return {"rid": rid, "t_submit": sub, "t_first": first, "t_done": done,
            "max_new": tokens, "tenant": tenant, "requeues": requeues}


def test_queue_depth_hand_case():
    """Two overlapping waits: depth 2 for 1 s, depth 1 for 2 s of a 4 s
    horizon -> time-weighted mean 1.0, max 2."""
    rows = [_row(0, 0.0, 3.0, 3.5, 10), _row(1, 1.0, 2.0, 2.5, 10)]
    rep = compute_slo(rows, horizon=4.0)
    assert rep.queue_depth["max"] == 2
    assert rep.queue_depth["mean"] == pytest.approx(1.0)


def test_goodput_counts_only_slo_met_tokens():
    slo = SLO(ttft_s=0.5)
    rows = [_row(0, 0.0, 0.1, 1.0, 30),  # TTFT 0.1 -> in SLO
            _row(1, 0.0, 2.0, 3.0, 70)]  # TTFT 2.0 -> violated
    rep = compute_slo(rows, slo=slo, horizon=10.0)
    assert rep.tokens_per_s == pytest.approx(10.0)
    assert rep.goodput_tokens_per_s == pytest.approx(3.0)
    assert rep.slo_attainment == pytest.approx(0.5)


def test_tpot_gate():
    slo = SLO(ttft_s=10.0, tpot_s=0.01)
    rows = [_row(0, 0.0, 0.1, 0.2, 100),  # 1 ms/token -> in SLO
            _row(1, 0.0, 0.1, 5.1, 100)]  # 50 ms/token -> violated
    rep = compute_slo(rows, slo=slo)
    assert rep.slo_attainment == pytest.approx(0.5)


def test_slo_report_roundtrip_and_version_gate():
    rows = [_row(i, 0.1 * i, 0.1 * i + 0.05, 0.1 * i + 0.2, 8,
                 tenant="t" + str(i % 2)) for i in range(20)]
    rep = compute_slo(rows, n_submitted=25, horizon=3.0)
    back = SLOReport.from_json(rep.to_json())
    assert back.to_json() == rep.to_json()
    assert back.n_submitted == 25 and back.n_completed == 20
    assert set(back.per_tenant) == {"t0", "t1"}
    d = rep.to_dict()
    d["schema_version"] = 999
    with pytest.raises(ValueError):
        SLOReport.from_dict(d)


def test_empty_slo_report():
    rep = compute_slo([], n_submitted=0)
    assert rep.slo_attainment == 0.0 and rep.ttft["p99"] == 0.0
    assert math.isfinite(rep.goodput_tokens_per_s)


# ---------------------------------------------------------------------------
# hypothesis fuzz layer (when available)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(n=st.integers(0, 120),
           arrival=st.sampled_from(ARRIVAL_CASES),
           rate=st.floats(0.5, 100.0),
           seed=st.integers(0, 2 ** 31 - 1),
           tail=st.floats(0.3, 3.0),
           cap=st.integers(8, 512))
    def test_fuzz_stream_properties(n, arrival, rate, seed, tail, cap):
        s = generate_stream(n, arrival=arrival, rate=rate, seed=seed,
                            max_new_tail=tail, max_new_cap=cap)
        assert_stream_wellformed(s, n, max_new_cap=cap)
        assert_byte_stable(s)

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(shares=st.lists(st.floats(0.05, 1.0), min_size=1, max_size=4),
           seed=st.integers(0, 2 ** 31 - 1))
    def test_fuzz_tenant_proportions(shares, seed):
        tenants = [TenantClass(f"t{i}", sh, i) for i, sh in enumerate(shares)]
        s = generate_stream(1500, seed=seed, tenants=tenants)
        counts = s.tenant_counts()
        total = sum(shares)
        for c in tenants:
            got = counts.get(c.name, 0) / s.n
            assert got == pytest.approx(c.share / total, abs=0.06)

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(
        st.tuples(st.floats(0.0, 10.0),  # t_submit
                  st.floats(0.0, 5.0),   # wait to first token
                  st.floats(0.0, 5.0),   # decode span
                  st.integers(1, 256)),  # tokens
        min_size=1, max_size=40))
    def test_fuzz_slo_report_consistency(items):
        rows = [_row(i, a, a + w, a + w + d, k)
                for i, (a, w, d, k) in enumerate(items)]
        rep = compute_slo(rows)
        assert 0.0 <= rep.slo_attainment <= 1.0
        assert rep.goodput_tokens_per_s <= rep.tokens_per_s + 1e-9
        assert rep.ttft["p50"] <= rep.ttft["p99"] <= rep.ttft["max"]
        assert rep.queue_depth["max"] <= len(rows)
        back = SLOReport.from_json(rep.to_json())
        assert back.to_json() == rep.to_json()
