"""Shared golden-case grid for the DES equivalence pins.

One module, two consumers:

  * ``scripts/capture_sim_fixtures.py`` ran this grid against the
    pre-refactor triplicated event loops (``core/sim.py`` as of PR 4)
    and froze the results into ``tests/fixtures/sim_golden.json``;
  * ``tests/test_sim_equivalence.py`` re-runs the *same* grid against
    the unified ``repro.sim`` event kernel and pins every case
    byte-identical (canonical-JSON comparison, shortest-round-trip
    float reprs) against those fixtures.

The grid covers every non-adaptive technique on all three runtimes
(adaptive techniques draw lognormal telemetry noise and are covered by
determinism tests instead -- the byte-identity contract of ISSUE 5 is
for non-adaptive event streams), plus the degenerate corners that
historically bite: P=1, chunk bounds, FIFO lock polling, every-PE-its-
own-node hierarchies, and both master placements.
"""
from __future__ import annotations

import numpy as np

from repro.core.chunk_calculus import ADAPTIVE, TECHNIQUES, LoopSpec
from repro.core.sim import SimConfig
from repro.core.weights import weights_from_speeds

FIXTURE_VERSION = 1
FIXTURE_NAME = "sim_golden.json"

#: The byte-identity roster: every technique whose DES run is free of
#: telemetry noise (the adaptive family consumes the shared RNG through
#: ``lognormvariate`` and is pinned by determinism tests instead).
NON_ADAPTIVE = tuple(t for t in TECHNIQUES if t not in ADAPTIVE)

_RUNTIMES = ("one_sided", "two_sided", "hierarchical")


def _speeds(P: int) -> np.ndarray:
    """Deterministic heterogeneous mix (fast / half / quarter cores)."""
    base = np.array([1.0, 0.5, 0.25])
    return np.tile(base, (P + 2) // 3)[:P].copy()


def _costs(N: int, seed: int) -> np.ndarray:
    """Seeded lognormal workload (c.o.v. 0.4 around 1 ms)."""
    rng = np.random.default_rng(seed)
    sigma = np.sqrt(np.log(1.0 + 0.4 * 0.4))
    return rng.lognormal(np.log(1e-3) - sigma ** 2 / 2.0, sigma, size=N)


def cases() -> list:
    """The full golden grid, each entry a plain-JSON-able descriptor."""
    out = []
    for runtime in _RUNTIMES:
        for tech in NON_ADAPTIVE:
            out.append(dict(
                key=f"{tech}-{runtime}", technique=tech, runtime=runtime,
                N=400, P=7, seed=3, min_chunk=1, max_chunk=None,
                nodes=3, inner="gss", coordinator=2, weighted=False,
                lock_polling_random=True, cost_seed=11))
    out += [
        # degenerate single-PE loop
        dict(key="gss-one_sided-P1", technique="gss", runtime="one_sided",
             N=37, P=1, seed=0, min_chunk=1, max_chunk=None, nodes=1,
             inner="ss", coordinator=0, weighted=False,
             lock_polling_random=True, cost_seed=1),
        # chunk bounds active on the TSS ramp
        dict(key="tss-one_sided-bounds", technique="tss", runtime="one_sided",
             N=400, P=7, seed=5, min_chunk=3, max_chunk=50, nodes=3,
             inner="ss", coordinator=0, weighted=False,
             lock_polling_random=True, cost_seed=11),
        # FIFO window grants (lock_polling_random=False draws no RNG)
        dict(key="ss-one_sided-fifo", technique="ss", runtime="one_sided",
             N=200, P=5, seed=2, min_chunk=2, max_chunk=None, nodes=1,
             inner="ss", coordinator=0, weighted=False,
             lock_polling_random=False, cost_seed=7),
        # static per-PE weights through the WF closed form
        dict(key="wf-one_sided-weighted", technique="wf", runtime="one_sided",
             N=400, P=6, seed=4, min_chunk=1, max_chunk=None, nodes=2,
             inner="ss", coordinator=0, weighted=True,
             lock_polling_random=True, cost_seed=13),
        # fast master (the two-sided grid above uses the slow 0.25x core)
        dict(key="gss-two_sided-fast-master", technique="gss",
             runtime="two_sided", N=400, P=7, seed=3, min_chunk=1,
             max_chunk=None, nodes=1, inner="ss", coordinator=0,
             weighted=False, lock_polling_random=True, cost_seed=11),
        # every PE its own node: outer level does all the scheduling
        dict(key="fac2-hierarchical-all-nodes", technique="fac2",
             runtime="hierarchical", N=400, P=7, seed=9, min_chunk=1,
             max_chunk=None, nodes=7, inner="ss", coordinator=0,
             weighted=False, lock_polling_random=True, cost_seed=11),
        # weighted outer technique over nodes, TSS inner
        dict(key="wf-hierarchical-tss-inner", technique="wf",
             runtime="hierarchical", N=400, P=6, seed=6, min_chunk=1,
             max_chunk=None, nodes=3, inner="tss", coordinator=0,
             weighted=True, lock_polling_random=True, cost_seed=13),
    ]
    return out


def build_config(case: dict) -> SimConfig:
    """Rebuild a case's exact ``SimConfig`` (collect_trace always on)."""
    speeds = _speeds(case["P"])
    weights = tuple(weights_from_speeds(speeds)) if case["weighted"] else None
    spec = LoopSpec(case["technique"], N=case["N"], P=case["P"],
                    weights=weights, min_chunk=case["min_chunk"],
                    max_chunk=case["max_chunk"])
    kw = dict(impl=case["runtime"], coordinator=case["coordinator"],
              seed=case["seed"],
              lock_polling_random=case["lock_polling_random"],
              collect_trace=True)
    if case["runtime"] == "hierarchical":
        kw["nodes"] = case["nodes"]
        kw["inner_technique"] = case["inner"]
    return SimConfig(spec, speeds, _costs(case["N"], case["cost_seed"]), **kw)


def _scalar(x):
    """numpy scalar -> exact python scalar (json float repr round-trips)."""
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, (np.integer,)):
        return int(x)
    return x


def encode_result(r) -> dict:
    """A ``SimResult`` as plain JSON types, every field the DES reports."""
    return {
        "T_loop": _scalar(r.T_loop),
        "finish": [float(x) for x in r.finish],
        "n_claims": int(r.n_claims),
        "cov": _scalar(r.cov),
        "per_pe_iters": [int(x) for x in r.per_pe_iters],
        "master_serve_time": _scalar(r.master_serve_time),
        "mean_claim_latency": _scalar(r.mean_claim_latency),
        "n_rmw_global": int(r.n_rmw_global),
        "n_rmw_local": int(r.n_rmw_local),
        "chunk_trace": [
            {k: _scalar(v) for k, v in rec.items()} for rec in r.chunk_trace
        ] if r.chunk_trace is not None else None,
    }
