"""Substrate tests: optimizer, data pipeline, checkpointing, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.core.rma import ThreadWindow
from repro.core.weights import WeightBoard
from repro.data import DLSSampler, HostDataIterator, synth_tokens
from repro.optim import AdamWConfig


def test_adamw_descends_quadratic():
    from repro.optim import adamw

    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None,
                      warmup_steps=0, schedule="constant")
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, g, state, params)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_compression_close():
    from repro.optim import adamw

    key = jax.random.key(0)
    params = {"w": jax.random.normal(key, (64, 64))}
    g = {"w": jax.random.normal(jax.random.key(1), (64, 64)) * 1e-2}
    base = AdamWConfig(lr=1e-2, warmup_steps=0, schedule="constant")
    comp = AdamWConfig(lr=1e-2, warmup_steps=0, schedule="constant", compress="bf16")
    p1, _, _ = adamw.update(base, g, adamw.init(params), params)
    p2, _, _ = adamw.update(comp, g, adamw.init(params), params)
    # bf16 gradient compression changes the update by < 1% relative
    rel = float(jnp.abs(p1["w"] - p2["w"]).max() / jnp.abs(p1["w"] - params["w"]).max())
    assert rel < 0.05


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_synth_tokens_deterministic():
    a = synth_tokens(7, np.array([3, 9]), 16, 100)
    b = synth_tokens(7, np.array([3, 9]), 16, 100)
    np.testing.assert_array_equal(a, b)
    c = synth_tokens(8, np.array([3, 9]), 16, 100)
    assert not np.array_equal(a, c)


def test_dls_sampler_partitions_epoch_across_hosts():
    win = ThreadWindow()
    H, N = 4, 1000
    samplers = [DLSSampler(N, H, h, window=win) for h in range(H)]
    seen = []
    done = [False] * H
    while not all(done):
        for h in range(H):
            if done[h]:
                continue
            idx = samplers[h].claim_batch(16)
            if idx is None:
                done[h] = True
            else:
                seen.append(idx)
    got = np.sort(np.concatenate(seen))
    # every sample claimed at most once; at least N - H*15 claimed (leftovers
    # smaller than one batch are dropped per epoch by design)
    assert len(got) == len(np.unique(got))
    assert len(got) >= N - H * 16


def test_dls_sampler_checkpoint_resume():
    win = ThreadWindow()
    s = DLSSampler(1000, 2, 0, window=win)
    first = s.claim_batch(32)
    st = s.state()
    more = s.claim_batch(32)
    # restore into a *fresh* window (crash-restart path)
    s2 = DLSSampler(1000, 2, 0, window=ThreadWindow())
    s2.restore(st)
    resumed = s2.claim_batch(32)
    # the resumed claim continues where the checkpoint was taken: it must not
    # re-serve anything from `first`
    assert len(np.intersect1d(first, resumed)) == 0
    # and with the same technique + counters, it reproduces `more`'s indices
    np.testing.assert_array_equal(np.sort(more), np.sort(resumed))


def test_awf_weights_shift_chunks_to_fast_host():
    from repro.train.trainer import SimCluster

    cl = SimCluster(2, 4000, technique="wf", speeds=[4.0, 1.0])
    counts = cl.run_epoch(batch_size=8, work_time=lambda h: [0.0005, 0.002][h])
    assert counts[0] > 1.8 * counts[1], counts


def test_host_failure_work_reclaimed():
    from repro.train.trainer import SimCluster

    cl = SimCluster(4, 2000, technique="fac2")
    counts = cl.run_epoch(batch_size=8, work_time=lambda h: 0.0002,
                          kill_at={2: 3})
    # epoch still (nearly) fully consumed despite host 2 dying after 3 batches
    total = counts.sum()
    assert total >= 2000 - 4 * 8 - 8 * 3
    assert counts[2] <= 3 * 8


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------


def test_ckpt_roundtrip_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    mgr.save(5, tree, extra={"step": 5, "data": {"epoch": 0, "next_step_i": 7,
                                                 "next_lp": 123}})
    mgr.save(10, jax.tree.map(lambda x: x * 2, tree), extra={"step": 10})
    assert mgr.latest_step() == 10
    restored, extra = mgr.restore(tree)
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]) * 2)
    assert extra["step"] == 10
    restored5, extra5 = mgr.restore(tree, step=5)
    assert extra5["data"]["next_lp"] == 123


def test_ckpt_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    tree = {"a": jnp.zeros((2,))}
    for s in [1, 2, 3, 4]:
        mgr.save(s, tree, extra={"step": s})
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2 and dirs[-1].endswith("000000004")


def test_ckpt_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3, async_save=True)
    tree = {"a": jnp.arange(10_000).astype(jnp.float32)}
    mgr.save(1, tree, extra={"step": 1})
    mgr.wait()
    restored, _ = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_ckpt_tmp_dir_never_published(tmp_path):
    """A tmp dir (simulated crash) must not be visible as latest."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"a": jnp.zeros((2,))}
    mgr.save(1, tree, extra={})
    os.makedirs(tmp_path / "step_000000002.tmp0")  # crashed half-write
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# trainer end-to-end (tiny): loss goes down, resume is exact
# ---------------------------------------------------------------------------


def _tiny_cfg():
    from repro.configs.base import ModelConfig

    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=2, n_kv_heads=2, d_ff=128, vocab=64,
                       dtype="float32")


def test_trainer_loss_decreases(tmp_path):
    from repro.train import TrainConfig, Trainer

    tcfg = TrainConfig(steps=30, per_host_batch=4, seq_len=32, n_samples=500,
                       log_every=1000)
    tr = Trainer(_tiny_cfg(), tcfg, log=lambda s: None)
    tr.run()
    first5 = np.mean(tr.history[:5])
    last5 = np.mean(tr.history[-5:])
    assert last5 < first5


@pytest.mark.slow
def test_trainer_checkpoint_resume_exact(tmp_path):
    from repro.train import TrainConfig, Trainer

    kw = dict(per_host_batch=4, seq_len=32, n_samples=500,
              ckpt_dir=str(tmp_path), ckpt_every=10, log_every=1000)
    # run 20 steps straight
    t1 = Trainer(_tiny_cfg(), TrainConfig(steps=20, **kw), log=lambda s: None)
    p1, _ = t1.run()
    # run 10, "crash", resume to 20 from the checkpoint
    kw2 = dict(kw, ckpt_dir=str(tmp_path / "b"))
    t2 = Trainer(_tiny_cfg(), TrainConfig(steps=10, **kw2), log=lambda s: None)
    t2.run()
    t3 = Trainer(_tiny_cfg(), TrainConfig(steps=20, **kw2), log=lambda s: None)
    p3, _ = t3.run()
    assert t3.state_step == 20
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def test_engine_greedy_generation():
    from repro.serve import Engine
    from repro.models import api

    cfg = _tiny_cfg()
    params = api.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params, max_len=64, batch_size=2)
    prompts = np.random.default_rng(0).integers(0, 64, size=(2, 8)).astype(np.int32)
    out = eng.generate(prompts, max_new=5)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < 64).all()


def test_continuous_batcher_beats_static_tail():
    from repro.serve import ContinuousBatcher, Request

    rng = np.random.default_rng(0)
    # heavy-tailed request costs (generation lengths)
    costs = rng.pareto(1.5, size=400) + 0.1
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32)) for i in range(400)]

    def process(chunk, worker):
        return float(sum(costs[r.rid] for r in chunk))

    cb = ContinuousBatcher(n_workers=8, technique="gss")
    t_dls = cb.schedule(reqs, process)
    t_static = cb.schedule(reqs, process, static=True)
    assert t_dls.max() < t_static.max()  # makespan
