"""Capture (or verify) the DES golden fixtures for the equivalence pins.

Usage (repo root):

    PYTHONPATH=src python scripts/capture_sim_fixtures.py          # write
    PYTHONPATH=src python scripts/capture_sim_fixtures.py --check  # verify

The fixtures under ``tests/fixtures/sim_golden.json`` were captured
from the pre-refactor triplicated event loops (``core/sim.py`` before
the ``repro.sim`` unification) and are the byte-identity contract the
unified kernel is pinned against (``tests/test_sim_equivalence.py``).
Re-running this script must therefore be a **no-op** on a healthy tree:
``--check`` (also run by the CI ``sim-equivalence`` and ``sim-fast``
jobs) fails if the current simulator drifts from the frozen streams.
``--check`` also re-runs every golden case that qualifies for the
vectorized fast path (trace collection off) through ``repro.sim.fast``
and demands byte-identity with the kernel -- the fixture file pins the
kernel, and this leg transitively pins the fast path to it.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT / "tests"))

import _sim_golden_cases as gc  # noqa: E402
from repro.core.sim import simulate  # noqa: E402
from repro.sim import fast_qualifies, simulate_fast  # noqa: E402

FIXTURE_PATH = ROOT / "tests" / "fixtures" / gc.FIXTURE_NAME


def capture() -> dict:
    entries = []
    for case in gc.cases():
        r = simulate(gc.build_config(case))
        entries.append({"case": case, "result": gc.encode_result(r)})
    return {"version": gc.FIXTURE_VERSION, "cases": entries}


def check_fast() -> list:
    """Differential leg: fast path vs kernel on the qualifying grid."""
    bad = []
    n = 0
    for case in gc.cases():
        cf = dataclasses.replace(gc.build_config(case), collect_trace=False)
        if not fast_qualifies(cf):
            continue
        n += 1
        rk = json.dumps(gc.encode_result(simulate(cf, engine="kernel")),
                        sort_keys=True)
        rf = json.dumps(gc.encode_result(simulate_fast(cf)), sort_keys=True)
        if rk != rf:
            bad.append(case["key"])
    print(f"fast-path differential: {n - len(bad)}/{n} qualifying "
          "cases byte-identical")
    return bad


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="verify the committed fixtures instead of writing")
    args = ap.parse_args()
    data = capture()
    text = json.dumps(data, sort_keys=True, indent=1)
    if args.check:
        committed = json.loads(FIXTURE_PATH.read_text())
        fresh = json.loads(text)
        if committed != fresh:
            keys = [e["case"]["key"] for e in fresh["cases"]]
            bad = [k for k, a, b in zip(keys, committed["cases"],
                                        fresh["cases"]) if a != b]
            print(f"DRIFT in {len(bad)} golden case(s): {bad}")
            return 1
        print(f"{len(data['cases'])} golden cases match {FIXTURE_PATH}")
        bad_fast = check_fast()
        if bad_fast:
            print(f"FAST-PATH DRIFT in {len(bad_fast)} case(s): {bad_fast}")
            return 1
        return 0
    FIXTURE_PATH.parent.mkdir(parents=True, exist_ok=True)
    FIXTURE_PATH.write_text(text + "\n")
    print(f"wrote {len(data['cases'])} cases -> {FIXTURE_PATH}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
