"""Capture (or verify) the DES golden fixtures for the equivalence pins.

Usage (repo root):

    PYTHONPATH=src python scripts/capture_sim_fixtures.py          # write
    PYTHONPATH=src python scripts/capture_sim_fixtures.py --check  # verify

The fixtures under ``tests/fixtures/sim_golden.json`` were captured
from the pre-refactor triplicated event loops (``core/sim.py`` before
the ``repro.sim`` unification) and are the byte-identity contract the
unified kernel is pinned against (``tests/test_sim_equivalence.py``).
Re-running this script must therefore be a **no-op** on a healthy tree:
``--check`` (also run by the CI ``sim-equivalence`` job) fails if the
current simulator drifts from the frozen streams.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT / "tests"))

import _sim_golden_cases as gc  # noqa: E402
from repro.core.sim import simulate  # noqa: E402

FIXTURE_PATH = ROOT / "tests" / "fixtures" / gc.FIXTURE_NAME


def capture() -> dict:
    entries = []
    for case in gc.cases():
        r = simulate(gc.build_config(case))
        entries.append({"case": case, "result": gc.encode_result(r)})
    return {"version": gc.FIXTURE_VERSION, "cases": entries}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="verify the committed fixtures instead of writing")
    args = ap.parse_args()
    data = capture()
    text = json.dumps(data, sort_keys=True, indent=1)
    if args.check:
        committed = json.loads(FIXTURE_PATH.read_text())
        fresh = json.loads(text)
        if committed != fresh:
            keys = [e["case"]["key"] for e in fresh["cases"]]
            bad = [k for k, a, b in zip(keys, committed["cases"],
                                        fresh["cases"]) if a != b]
            print(f"DRIFT in {len(bad)} golden case(s): {bad}")
            return 1
        print(f"{len(data['cases'])} golden cases match {FIXTURE_PATH}")
        return 0
    FIXTURE_PATH.parent.mkdir(parents=True, exist_ok=True)
    FIXTURE_PATH.write_text(text + "\n")
    print(f"wrote {len(data['cases'])} cases -> {FIXTURE_PATH}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
