#!/usr/bin/env python
"""Print the markdown technique table README.md / DESIGN.md embed.

The table is generated from ``chunk_calculus.TECHNIQUE_INFO`` -- the single
source of truth for the technique roster -- and drift-checked by
``tests/test_docs.py`` (CI's docs-consistency job).  To update the docs:

    PYTHONPATH=src python scripts/gen_technique_table.py

and paste the output between the ``<!-- technique-table-start/end -->``
markers in README.md and DESIGN.md.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.chunk_calculus import technique_table  # noqa: E402

if __name__ == "__main__":
    print(technique_table())
