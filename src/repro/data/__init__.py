"""Self-scheduled data pipeline."""
from .pipeline import DLSSampler, EpochState, HostDataIterator, synth_tokens  # noqa: F401
