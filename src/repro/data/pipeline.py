"""Self-scheduled data pipeline: hosts claim sample-index chunks via the
paper's one-sided protocol instead of a fixed striped split.

Why this matters at 1000+-node scale: with a *static* split (host h gets
indices h::H), one slow or restarted host stalls the whole data-parallel
step.  With DLS claiming, hosts pull variable-size chunks of the global
index space through two atomic fetch-adds (a one-sided ``repro.dls``
session); slow hosts
simply claim less, dead hosts claim nothing, and restarted hosts resume from
the *current* loop pointer -- the window counters (i, lp_start) are part of
the checkpoint, so a restart continues the epoch exactly where it stopped.

AWF weights (from per-host step timings) make the chunk sizes adapt to
measured throughput: the paper's WF with live weights = its cited AWF
future-work direction, used here as straggler mitigation.

Data itself is synthetic-deterministic: token content is a pure function of
(seed, global_index), so any host can materialize any sample -- which is
what makes work-stealing across hosts free.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterator, Optional

import numpy as np

from repro import dls
from repro.core import ThreadWindow, Window
from repro.core.weights import WeightBoard


def synth_tokens(seed: int, global_idx: np.ndarray, seq_len: int, vocab: int):
    """Deterministic per-sample token content: counter-based RNG per index."""
    out = np.empty((len(global_idx), seq_len), dtype=np.int32)
    for row, gi in enumerate(np.asarray(global_idx)):
        rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003) + np.uint64(gi))
        out[row] = rng.integers(0, vocab, size=seq_len, dtype=np.int32)
    return out


@dataclasses.dataclass
class EpochState:
    epoch: int
    next_step_i: int  # window counter i (for checkpoint/restore)
    next_lp: int  # window counter lp_start
    # claimed-but-unconsumed index ranges [(start, stop), ...] -- part of the
    # checkpoint so a restart re-serves exactly the un-trained samples
    leftover: list = dataclasses.field(default_factory=list)


class DLSSampler:
    """Per-host sampler that claims chunks of [0, n_samples) via DLS.

    One instance per host process.  ``claim_batch(batch_size)`` returns
    ``batch_size`` global indices, carrying leftovers between calls; returns
    None when the epoch is exhausted (the host should then enter the
    end-of-epoch barrier).
    """

    def __init__(
        self,
        n_samples: int,
        n_hosts: int,
        host_id: int,
        *,
        technique: str = "fac2",
        window: Optional[Window] = None,
        weight_board: Optional[WeightBoard] = None,
        epoch: int = 0,
        min_chunk: int = 1,
        max_chunk: Optional[int] = None,
    ):
        self.n_samples = n_samples
        self.n_hosts = n_hosts
        self.host_id = host_id
        self.technique = technique
        self.window = window if window is not None else ThreadWindow()
        self.board = weight_board
        self.min_chunk = min_chunk
        self.max_chunk = max_chunk
        self._lock = threading.Lock()
        # claimed-but-unconsumed ranges [(start, stop), ...] FIFO
        self._ranges: list = []
        self._buffered = 0
        self._epoch = epoch
        self._new_epoch_session()

    def _new_epoch_session(self):
        # namespace by epoch so monotonic KV windows work across epochs
        # (the weight board only acts for the weighted family -- don't
        # attach a no-op policy, and don't warn, for unweighted techniques;
        # adaptive techniques with no board auto-adopt their telemetry
        # policy inside dls.loop)
        board = self.board if self.technique in dls.WEIGHTED else None
        self.session = dls.loop(
            self.n_samples, technique=self.technique, P=self.n_hosts,
            window=self.window, min_chunk=self.min_chunk,
            max_chunk=self.max_chunk, weights=board,
            loop_id=hash(("epoch", self._epoch)) & 0x7FFFFFFF)

    @property
    def epoch(self) -> int:
        return self._epoch

    def next_epoch(self):
        with self._lock:
            self._epoch += 1
            self._ranges = []
            self._buffered = 0
            self._new_epoch_session()

    def claim_batch(self, batch_size: int) -> Optional[np.ndarray]:
        """Claim until ``batch_size`` indices are buffered; None = exhausted."""
        with self._lock:
            while self._buffered < batch_size:
                c = self.session.claim(self.host_id)
                if c is None:
                    return None  # epoch drained (leftovers < batch: dropped)
                self._ranges.append((c.start, c.stop))
                self._buffered += c.size
            out = []
            need = batch_size
            while need:
                s, e = self._ranges[0]
                take = min(need, e - s)
                out.append(np.arange(s, s + take, dtype=np.int64))
                if take == e - s:
                    self._ranges.pop(0)
                else:
                    self._ranges[0] = (s + take, e)
                need -= take
                self._buffered -= take
            return np.concatenate(out)

    # ---- checkpointable state ----
    def state(self) -> EpochState:
        with self._lock:
            counters = self.session.state()
            return EpochState(
                epoch=self._epoch,
                next_step_i=counters["i"],
                next_lp=counters["lp"],
                leftover=[list(r) for r in self._ranges],
            )

    def restore(self, st: EpochState):
        with self._lock:
            self._epoch = st.epoch
            self._ranges = [tuple(r) for r in st.leftover]
            self._buffered = sum(e - s for s, e in self._ranges)
            self._new_epoch_session()
            self.session.restore({"i": st.next_step_i, "lp": st.next_lp})


class HostDataIterator:
    """Batches for one host: DLS-claimed indices -> synthetic token arrays."""

    def __init__(self, sampler: DLSSampler, *, seq_len: int, vocab: int,
                 per_host_batch: int, seed: int = 0, epochs: Optional[int] = None):
        self.sampler = sampler
        self.seq_len = seq_len
        self.vocab = vocab
        self.per_host_batch = per_host_batch
        self.seed = seed
        self.epochs = epochs

    def __iter__(self) -> Iterator[dict]:
        done_epochs = 0
        while self.epochs is None or done_epochs < self.epochs:
            idx = self.sampler.claim_batch(self.per_host_batch)
            if idx is None:
                done_epochs += 1
                self.sampler.next_epoch()
                continue
            toks = synth_tokens(self.seed + self.sampler.epoch, idx, self.seq_len,
                                self.vocab)
            yield {"tokens": toks, "indices": idx}
