"""Serving: engine + DLS continuous batching + open-loop scenarios."""
from .engine import ContinuousBatcher, Engine, Request  # noqa: F401
from .metrics import (  # noqa: F401
    SLO, SLO_SCHEMA_VERSION, SLOReport, compute_slo)
from .scenarios import (  # noqa: F401
    RESELECT_ROSTER, SCENARIO_SCHEMA_VERSION, ScenarioReport, ServeCostModel,
    run_scenario)
from .workload import (  # noqa: F401
    ARRIVALS, STREAM_SCHEMA_VERSION, RequestStream, ServeRequest, TenantClass,
    generate_stream)
