"""Serving: engine + DLS continuous batching."""
from .engine import ContinuousBatcher, Engine, Request  # noqa: F401
