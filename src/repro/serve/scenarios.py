"""Open-loop serving scenarios: traffic meets the batcher, in SLO terms.

``run_scenario`` drives a ``RequestStream`` (``serve.workload``) through
DLS admission control on a simulated clock, epoch by epoch: every time a
worker frees up with requests waiting, the accumulated backlog (sorted by
priority class, then arrival) becomes one ``dls.loop`` session and the
workers claim request chunks through the paper's one-sided protocol.
Requests that arrive while an epoch is draining wait for the next one --
the open-loop property: traffic never waits for the system, so overload
shows up as queue growth and TTFT blowup instead of a longer makespan.

Three layers ride on the same clock:

* **SLO metrics** (``serve.metrics``): per-request ``t_submit`` /
  ``t_first`` / ``t_done`` from the cost model's first-token and
  completion offsets -- TTFT is the request's first token, not its
  chunk's completion.
* **Online re-selection**: every ``reselect_every_s`` simulated seconds
  the controller calibrates the DES from a sliding window of its *live*
  chunk trace (``Trace.window`` -> ``replay.calibrate``) and re-runs the
  ``choose_technique`` sweep (cheap in-loop thanks to the vectorized
  fast path, DESIGN.md Sec. 12).  When the predicted winner changes, the
  next epoch switches technique; every decision -- full predicted
  ranking included -- lands in ``ScenarioReport.reselections`` and on
  the epoch's ``SessionReport.reselections``.
* **Chaos**: the ``repro.sim`` perturbation layer (``PEFailure`` /
  ``Straggler`` / ``SpeedDrift``) reinterpreted on serving workers.  A
  dead worker's in-flight requests past its death time are re-queued
  (``requeues`` per request, conservation still exactly-once); slow
  factors stretch chunk timing -- all *measured in SLO terms* rather
  than loop-time terms.

Determinism: given a stream and ``seed``, the whole scenario -- clock,
decisions, chaos salvage, report JSON bytes -- is reproducible;
``tests/test_serving.py`` pins it.  Re-selection sweeps run with
``budget_s=None`` (never wall-clock-truncated) for exactly that reason.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import dls
from repro.core.chunk_calculus import ADAPTIVE, TECHNIQUES
from repro.sim.perturb import Perturbation, compile_plan

from .metrics import SLO, SLOReport, compute_slo
from .workload import RequestStream, ServeRequest

#: Version of the serialized scenario-report schema.
SCENARIO_SCHEMA_VERSION = 1

#: Default candidate roster for in-loop re-selection: the non-adaptive
#: techniques (they route through the vectorized DES fast path, so a
#: full sweep costs milliseconds -- cheap enough to run mid-stream).
#: ``awf`` is excluded with the adaptive family: it needs an external
#: weight policy the sweep cannot fit from a serving trace.
RESELECT_ROSTER: Tuple[str, ...] = tuple(
    t for t in TECHNIQUES if t not in ADAPTIVE and t != "awf")


@dataclasses.dataclass(frozen=True)
class ServeCostModel:
    """Deterministic chunk timing: sequential prefill + grouped decode.

    A worker that claims a chunk pays ``sched_overhead`` (the admission
    claim), prefills the chunk's requests back to back (each request's
    first token arrives at *its* prefill end -- the TTFT instant), then
    decodes the group one token step at a time: request ``i`` finishes
    after its own ``max_new`` steps, but the worker is busy until the
    *longest* request finishes.  That last term is head-of-line
    blocking: under heavy-tailed lengths, one straggler request stalls
    its whole decode group, which is exactly why decreasing-chunk
    admission (GSS/FAC2) beats static splits on tail latency.
    """

    prefill_per_token: float = 5e-5  # s/prompt-token, serial within chunk
    tok_seconds: float = 2e-3  # s per decode step (group-granular)
    sched_overhead: float = 4e-3  # s per claim (admission overhead)

    def chunk_timing(self, chunk: Sequence[ServeRequest], t0: float,
                     speed: float = 1.0):
        """(t_first[], t_done[], t_end) for a chunk starting at ``t0``.

        ``speed`` is the worker's multiplicative speed factor (chaos
        stragglers/drift run at < 1); durations scale by ``1/speed``.
        """
        pf = np.array([r.prompt_len for r in chunk], dtype=np.float64) \
            * self.prefill_per_token / speed
        first = t0 + np.cumsum(pf)
        decode0 = t0 + pf.sum()
        gen = np.array([r.max_new for r in chunk], dtype=np.float64) \
            * self.tok_seconds / speed
        done = decode0 + gen
        return first, done, float(decode0 + gen.max())

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Live:
    """A request in flight: stream record + serving-side mutable state."""

    req: ServeRequest
    t_first: Optional[float] = None  # first token ever emitted (survives
    # a requeue: TTFT counts the first token, not the restarted one)
    requeues: int = 0


class _PlanShim:
    """Adapter so ``repro.sim.perturb.compile_plan`` validates serving
    scenarios: workers are the PEs, there is no two-sided master."""

    class _Spec:
        def __init__(self, P):
            self.P = P

    def __init__(self, P: int, perturbations):
        self.spec = self._Spec(P)
        self.perturbations = tuple(perturbations)
        self.impl = "one_sided"
        self.coordinator = 0


@dataclasses.dataclass
class ScenarioReport:
    """One scenario run: SLO plane + decisions + chaos log, serializable."""

    stream_meta: dict
    n_workers: int
    technique: str  # as requested ("auto" = online controller)
    final_technique: str  # what the last epoch actually ran
    slo: SLOReport
    reselections: List[dict]  # every decision, full ranking included
    epochs: List[dict]  # {"epoch", "t", "batch", "technique", "steps"}
    chaos: List[dict]  # worker deaths with salvage/requeue accounting
    horizon: float
    n_requeued: int
    requests: Optional[List[dict]] = None  # per-request timing rows
    epoch_reports: Optional[List[dict]] = None  # SessionReport dicts
    version: int = SCENARIO_SCHEMA_VERSION

    @property
    def n_switches(self) -> int:
        """Technique changes after the bootstrap decision."""
        return sum(1 for d in self.reselections
                   if d["switched"] and d["from"] != "auto")

    def technique_timeline(self) -> List[Tuple[float, str]]:
        """[(sim time, technique adopted)] including the bootstrap."""
        return [(d["t"], d["to"]) for d in self.reselections if d["switched"]]

    def summary(self) -> str:
        sw = ""
        if self.reselections:
            path = "->".join([self.reselections[0]["from"]]
                             + [d["to"] for d in self.reselections
                                if d["switched"]])
            sw = f" reselect[{path}]"
        ch = f" deaths={len(self.chaos)}" if self.chaos else ""
        return (f"scenario {self.technique} W={self.n_workers} "
                f"{self.slo.summary()}{sw}{ch}")

    # ------------------------------------------------------------------
    # persistence (schema-versioned, canonical -- determinism pins use it)
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema_version": self.version,
                "stream_meta": self.stream_meta,
                "n_workers": self.n_workers,
                "technique": self.technique,
                "final_technique": self.final_technique,
                "slo": self.slo.to_dict(),
                "reselections": self.reselections,
                "epochs": self.epochs,
                "chaos": self.chaos,
                "horizon": self.horizon,
                "n_requeued": self.n_requeued,
                "requests": self.requests,
                "epoch_reports": self.epoch_reports}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent,
                          separators=(",", ":") if indent is None else None)

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioReport":
        ver = d.get("schema_version")
        if ver is None or ver > SCENARIO_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported ScenarioReport schema_version {ver!r} "
                f"(this build reads <= {SCENARIO_SCHEMA_VERSION})")
        return cls(stream_meta=d["stream_meta"],
                   n_workers=int(d["n_workers"]),
                   technique=d["technique"],
                   final_technique=d["final_technique"],
                   slo=SLOReport.from_dict(d["slo"]),
                   reselections=d["reselections"], epochs=d["epochs"],
                   chaos=d["chaos"], horizon=float(d["horizon"]),
                   n_requeued=int(d["n_requeued"]),
                   requests=d.get("requests"),
                   epoch_reports=d.get("epoch_reports"), version=ver)

    @classmethod
    def from_json(cls, text: str) -> "ScenarioReport":
        return cls.from_dict(json.loads(text))


def _reselect(live_records: list, t_now: float, window_s: float,
              *, technique: str, n_workers: int, n_admitted: int,
              n_hint: int, roster, max_sim_iters: int, seed: int,
              min_chunk: int, max_chunk, cache=None,
              calib_overrides=None) -> Optional[dict]:
    """Windowed live-trace calibration + selection sweep (None = too
    little signal in the window to calibrate from).

    ``live_records`` is already a ``ChunkRecord`` list (built
    incrementally by the epoch loop -- a reselect tick re-ranks, it does
    not re-parse the whole trace); ``cache`` is the scenario's
    persistent ``SweepCache`` and ``calib_overrides`` the prior
    window's fitted overhead constants, both warm-start handles that
    make the tick a re-rank rather than a rebuild.
    """
    from repro.replay import Trace, choose_technique

    trace = Trace(technique=technique, N=max(n_admitted, 1), P=n_workers,
                  runtime="one_sided", executor="serve", wall_time=t_now,
                  records=live_records, min_chunk=min_chunk,
                  max_chunk=max_chunk, meta={"seed": seed})
    windowed = trace.window(max(0.0, t_now - window_s))
    if len(windowed.records) < 2:
        return None
    return choose_technique(
        N=max(n_hint, 1), P=n_workers, trace=windowed, seed=seed,
        budget_s=None,  # wall-clock truncation would break determinism
        max_sim_iters=max_sim_iters, techniques=roster,
        min_chunk=min_chunk, max_chunk=max_chunk, engine="auto",
        cache=cache, calib_overrides=calib_overrides)


def run_scenario(
    stream: RequestStream,
    *,
    n_workers: int = 4,
    technique: str = "gss",
    cost_model: Optional[ServeCostModel] = None,
    perturbations: Sequence[Perturbation] = (),
    slo: Optional[SLO] = None,
    reselect_every_s: Optional[float] = None,
    reselect_window_s: Optional[float] = None,
    reselect_techniques: Sequence[str] = RESELECT_ROSTER,
    reselect_max_sim_iters: int = 512,
    seed: int = 0,
    min_chunk: int = 1,
    max_chunk: Optional[int] = None,
    keep_requests: bool = True,
    keep_epoch_reports: bool = False,
) -> ScenarioReport:
    """Run one open-loop serving scenario (see module docstring).

    ``technique="auto"`` bootstraps from a ``choose_technique`` sweep
    over the first batch's shape (``max_new`` cost hints -- no live
    trace exists yet) and, when ``reselect_every_s`` is set, keeps
    re-selecting from the live windowed trace.  Fixed techniques accept
    ``reselect_every_s`` too: they bootstrap as themselves and hand
    control to the online controller afterwards.

    Chaos scenarios reuse ``repro.sim.perturb`` verbatim: ``pe`` means
    worker index, and validation (some worker must survive, bounds,
    positive factors) is the DES's own ``compile_plan``.
    """
    from repro.replay import ChunkRecord
    from repro.sim import SweepCache

    cm = cost_model or ServeCostModel()
    slo = slo or SLO()
    plan = compile_plan(_PlanShim(n_workers, perturbations))
    death = plan.death if plan is not None else None

    reqs = stream.requests
    n = len(reqs)
    free = [0.0] * n_workers
    alive = set(range(n_workers))
    backlog: List[_Live] = []
    rows: List[dict] = []
    live_records: List[ChunkRecord] = []
    sweep_cache = SweepCache()  # persists across re-selection ticks
    warm_fit: Optional[dict] = None  # prior window's fitted constants
    reselections: List[dict] = []
    chaos_events: List[dict] = []
    epoch_summaries: List[dict] = []
    epoch_reports: List[dict] = []
    cur_tech = technique
    n_admitted = 0
    n_requeued = 0
    arr = 0
    t = 0.0
    epoch = 0
    last_resel = 0.0
    window_s = reselect_window_s if reselect_window_s is not None else (
        2.0 * reselect_every_s if reselect_every_s else 0.0)

    def _decide(decision: dict, origin: str) -> None:
        nonlocal cur_tech, warm_fit
        chosen = decision["chosen"]
        reselections.append({"t": t, "epoch": epoch, "from": origin,
                             "to": chosen, "switched": chosen != origin,
                             "sweep_s": decision.get("sweep_s"),
                             "decision": decision})
        cur_tech = chosen
        if decision.get("source") == "trace" and decision.get("fitted"):
            # Warm-start the next tick's calibration with this window's
            # fitted constants (never the hints/default bootstrap's --
            # those are paper defaults, not measurements).
            warm_fit = decision["fitted"]

    while len(rows) < n:
        while arr < n and reqs[arr].t_arrival <= t + 1e-12:
            backlog.append(_Live(req=reqs[arr]))
            arr += 1
        if not backlog:
            if arr >= n:  # pragma: no cover - every admitted request either
                # completed or re-entered the backlog; nothing can be lost
                raise RuntimeError("open-loop accounting hole")
            t = max(t, reqs[arr].t_arrival)
            continue

        # -- controller: bootstrap, then windowed live re-selection ----
        if epoch == 0 and technique == "auto":
            from repro.replay import choose_technique

            hints = np.array([lv.req.max_new for lv in backlog],
                             dtype=np.float64)
            _decide(choose_technique(
                N=len(backlog), P=n_workers, costs=hints, seed=seed,
                budget_s=None, max_sim_iters=reselect_max_sim_iters,
                techniques=tuple(reselect_techniques), min_chunk=min_chunk,
                max_chunk=max_chunk, engine="auto",
                cache=sweep_cache), "auto")
            last_resel = t
        elif (reselect_every_s is not None and live_records
                and t - last_resel >= reselect_every_s):
            decision = _reselect(
                live_records, t, window_s, technique=cur_tech,
                n_workers=n_workers, n_admitted=n_admitted,
                n_hint=len(backlog), roster=tuple(reselect_techniques),
                max_sim_iters=reselect_max_sim_iters, seed=seed,
                min_chunk=min_chunk, max_chunk=max_chunk,
                cache=sweep_cache, calib_overrides=warm_fit)
            if decision is not None:
                _decide(decision, cur_tech)
            last_resel = t

        # -- one epoch: the backlog becomes a DLS session ---------------
        batch = sorted(backlog, key=lambda lv: (-lv.req.priority,
                                                lv.req.t_arrival,
                                                lv.req.rid))
        backlog = []
        offset = n_admitted
        n_admitted += len(batch)
        session = dls.loop(len(batch), technique=cur_tech, P=n_workers,
                           min_chunk=min_chunk, max_chunk=max_chunk)
        t_epoch = t
        n_steps = 0
        epoch_rows: List[dict] = []

        def _complete(lv: _Live, t_first: float, t_done: float,
                      worker: int) -> None:
            if lv.t_first is None:
                lv.t_first = float(t_first)
            row = {"rid": lv.req.rid, "tenant": lv.req.tenant,
                   "priority": lv.req.priority,
                   "t_submit": lv.req.t_arrival, "t_first": lv.t_first,
                   "t_done": float(t_done), "max_new": lv.req.max_new,
                   "worker": worker, "requeues": lv.requeues}
            rows.append(row)
            epoch_rows.append(row)

        while True:
            w = min(alive, key=lambda j: (max(free[j], t_epoch), j))
            t0 = max(free[w], t_epoch)
            if death is not None and t0 >= death[w]:
                # died idle, between chunks: no in-flight work to salvage
                alive.discard(w)
                chaos_events.append({"kind": "death", "worker": w,
                                     "t": float(death[w]), "salvaged": 0,
                                     "requeued": 0})
                continue
            c = session.claim(w)
            if c is None:
                break
            n_steps += 1
            chunk = batch[c.start:c.stop]
            speed = plan.speed_factor(w, t0) if plan is not None else 1.0
            lat = cm.sched_overhead / speed
            t_exec = t0 + lat
            first, done, t_end = cm.chunk_timing(
                [lv.req for lv in chunk], t_exec, speed)
            d_w = death[w] if death is not None else math.inf
            if t_end > d_w:
                # worker dies mid-chunk: salvage the finished prefix of
                # the group, re-queue the rest for surviving workers
                salvaged = 0
                for i, lv in enumerate(chunk):
                    if done[i] <= d_w:
                        _complete(lv, first[i], done[i], w)
                        salvaged += 1
                    else:
                        if lv.t_first is None and first[i] <= d_w:
                            lv.t_first = float(first[i])  # token got out
                        lv.requeues += 1
                        n_requeued += 1
                        backlog.append(lv)
                alive.discard(w)
                free[w] = math.inf
                chaos_events.append({"kind": "death", "worker": w,
                                     "t": float(d_w), "salvaged": salvaged,
                                     "requeued": len(chunk) - salvaged})
                if salvaged:
                    session.record(w, salvaged, d_w - t_exec, lat, claim=c,
                                   t_start=t_exec, t_end=d_w)
                    live_records.append(ChunkRecord(
                        pe=w, step=c.step, start=offset + c.start,
                        size=salvaged, t0=t_exec, t1=float(d_w), lat=lat))
            else:
                for i, lv in enumerate(chunk):
                    _complete(lv, first[i], done[i], w)
                free[w] = t_end
                session.record(w, c.size, t_end - t_exec, lat, claim=c,
                               t_start=t_exec, t_end=t_end)
                live_records.append(ChunkRecord(
                    pe=w, step=c.step, start=offset + c.start,
                    size=c.size, t0=t_exec, t1=t_end, lat=lat))

        epoch_summaries.append({"epoch": epoch, "t": t_epoch,
                                "batch": len(batch),
                                "technique": cur_tech, "steps": n_steps})
        if keep_epoch_reports:
            rep = session.report(executor="serve")
            rep.reselections = [d for d in reselections
                                if d["epoch"] == epoch] or None
            if epoch_rows:
                rep.slo = compute_slo(
                    epoch_rows, slo=slo,
                    horizon=max(r["t_done"] for r in epoch_rows)).to_dict()
            epoch_reports.append(rep.to_dict())
        epoch += 1
        t = max(t_epoch, min(free[j] for j in alive))

    horizon = max((r["t_done"] for r in rows), default=0.0)
    return ScenarioReport(
        stream_meta=dict(stream.meta),
        n_workers=n_workers,
        technique=technique,
        final_technique=cur_tech,
        slo=compute_slo(rows, slo=slo, n_submitted=n, horizon=horizon),
        reselections=reselections,
        epochs=epoch_summaries,
        chaos=chaos_events,
        horizon=float(horizon),
        n_requeued=n_requeued,
        requests=sorted(rows, key=lambda r: r["rid"]) if keep_requests
        else None,
        epoch_reports=epoch_reports if keep_epoch_reports else None,
    )
