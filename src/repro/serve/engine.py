"""Serving engine: batched prefill/decode + DLS continuous batching.

The paper's self-scheduling maps onto inference serving directly: requests
are the loop iterations (highly variable cost -- prompt and generation
lengths vary by orders of magnitude), decode "workers" are batch slots, and
the shared work queue is claimed through the same one-sided protocol (a
``repro.dls`` session) -- no scheduler master thread serializing admissions.

``ContinuousBatcher`` keeps a fixed-size decode batch full: whenever a slot
finishes (EOS / max_len), it claims the next chunk of requests from the
queue.  GSS chunking admits large request groups early (deep queue) and
small ones late (tail latency), which is the decreasing-chunk insight of
the paper applied to admission control.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import dls
from repro.models import api
from repro.shard.spec import NO_SHARD


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (Tp,) int32
    max_new: int = 32
    # filled by the engine:
    output: Optional[list] = None
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    """Single-model batched engine (greedy decoding)."""

    def __init__(self, cfg, params, *, max_len=512, batch_size=8, ctx=NO_SHARD):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self.ctx = ctx
        self._prefill = jax.jit(
            lambda p, b, c: api.prefill(p, cfg, b, c, ctx=ctx))
        self._decode = jax.jit(
            lambda p, t, c: api.decode_step(p, cfg, t, c, ctx=ctx))

    def generate(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """prompts (B, Tp) -> tokens (B, max_new), greedy."""
        B, Tp = prompts.shape
        cache = api.init_cache(self.cfg, B, Tp + max_new,
                               src_len=Tp if self.cfg.is_encdec else None)
        batch = {"tokens": jnp.asarray(prompts)}
        if self.cfg.is_encdec:
            batch["src_embeds"] = api.frontend_stub_embeds(self.cfg, B, Tp)
        logits, cache = self._prefill(self.params, batch, cache)
        out = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(max_new):
            out.append(tok)
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return np.stack([np.asarray(t) for t in out], axis=1)


class ContinuousBatcher:
    """DLS admission control over a request queue (simulation-friendly).

    ``schedule(requests)`` processes the queue with ``n_workers`` decode
    groups; each group claims its next chunk of requests through the
    one-sided protocol.  Per-request cost = prefill + new tokens (supplied by
    ``cost_model`` or real engine calls).  Returns per-request latencies.

    ``technique="auto"`` runs the ``repro.replay`` selection sweep per
    queue: each request's ``max_new`` (when present) becomes the
    per-iteration cost hint, so admission control picks the technique the
    calibrated DES predicts fastest for *this* queue shape.  The decision
    lands in ``last_report.auto_decision``.
    """

    def __init__(self, n_workers: int = 4, technique: str = "gss",
                 min_chunk: int = 1, auto_seed: int = 0):
        self.n_workers = n_workers
        self.technique = technique
        self.min_chunk = min_chunk
        self.auto_seed = auto_seed
        self.last_report: Optional[dls.SessionReport] = None  # of last schedule()

    def schedule(
        self,
        requests: List[Request],
        process: Callable[[List[Request], int], float],
        *,
        static: bool = False,
    ) -> np.ndarray:
        """Simulated clock schedule; ``process(chunk, worker)`` -> seconds.

        static=True replays the STATIC baseline (fixed equal split).
        """
        N = len(requests)
        technique = "static" if static else self.technique
        auto_kw = {}
        if technique == "auto":
            # Selection hint: generation length dominates per-request cost.
            if requests and hasattr(requests[0], "max_new"):
                auto_kw["costs"] = np.array(
                    [float(r.max_new) for r in requests])
            auto_kw["auto_seed"] = self.auto_seed
        session = dls.loop(N, technique=technique, P=self.n_workers,
                           min_chunk=self.min_chunk, **auto_kw)
        t_worker = np.zeros(self.n_workers)
        done_at = np.zeros(N)
        while not session.drained():
            w = int(np.argmin(t_worker))
            c = session.claim(w)
            if c is None:
                # drained() is authoritative under the Runtime contract --
                # no probe claims that burn scheduling steps per worker.
                break
            chunk = requests[c.start : c.stop]
            t_start = float(t_worker[w])
            dt = process(chunk, w)
            t_worker[w] += dt
            session.record(w, c.size, dt, claim=c, t_start=t_start,
                           t_end=t_start + dt)
            done_at[c.start : c.stop] = t_worker[w]
            for r in chunk:
                # Closed-loop queue: every request is present at t=0.
                # TTFT = the chunk's first token (its execution start),
                # not chunk completion; the group finishes together.
                r.t_submit = 0.0
                r.t_first = t_start
                r.t_done = t_start + dt
        self.last_report = session.report(executor="admission")
        return done_at
