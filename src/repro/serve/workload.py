"""Open-loop serving workload generator: deterministic request streams.

Closed-loop scheduling (every request present at t=0) hides the dynamics
that make serving hard: arrival bursts, heavy-tailed generation lengths,
and tenants with different priorities.  This module generates **open-loop**
request streams -- the load does not wait for the system -- as seeded,
byte-stable artifacts:

* **arrival processes** -- ``poisson`` (memoryless), ``bursty`` (on/off
  rate modulation: rate spikes of ``burst_factor`` for ``burst_on_s``
  out of every on+off cycle), and ``diurnal`` (sinusoidal rate over
  ``diurnal_period_s``).  The non-homogeneous processes are sampled by
  Lewis thinning against the peak rate, so inter-arrivals are exact
  draws from the modulated intensity, not a stepwise approximation.
* **length distributions** -- lognormal prompt lengths (moment-matched
  from ``prompt_mean``/``prompt_cov``) and Pareto generation lengths
  (``max_new_tail`` is the tail index: < 2 means infinite variance --
  the serving regime where one request can stall a whole decode group).
* **multi-tenant priority classes** -- ``TenantClass(name, share,
  priority)`` rows; requests are assigned by share and carry the class
  priority into admission control.

Streams serialize as canonical JSONL (sorted keys, compact separators,
header line first) under ``STREAM_SCHEMA_VERSION``; ``write -> read ->
write`` is byte-stable, mirroring the ``repro.replay`` trace contract.
The same seed always yields the same bytes -- scenario regressions pin
on that (``tests/test_workload.py``).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import List, Optional, Sequence

import numpy as np

#: Stream schema version.  Bump on any backward-incompatible record or
#: header change; ``RequestStream.from_jsonl`` rejects newer majors.
STREAM_SCHEMA_VERSION = 1

ARRIVALS = ("poisson", "bursty", "diurnal")


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One request of an open-loop stream (arrival time on the sim clock)."""

    rid: int
    t_arrival: float
    prompt_len: int
    max_new: int
    tenant: str = "default"
    priority: int = 0

    def to_dict(self) -> dict:
        return {"kind": "request", "rid": self.rid,
                "t_arrival": self.t_arrival, "prompt_len": self.prompt_len,
                "max_new": self.max_new, "tenant": self.tenant,
                "priority": self.priority}

    @classmethod
    def from_dict(cls, d: dict) -> "ServeRequest":
        return cls(rid=int(d["rid"]), t_arrival=float(d["t_arrival"]),
                   prompt_len=int(d["prompt_len"]), max_new=int(d["max_new"]),
                   tenant=str(d.get("tenant", "default")),
                   priority=int(d.get("priority", 0)))


@dataclasses.dataclass(frozen=True)
class TenantClass:
    """A priority class: ``share`` of the traffic at ``priority`` (higher
    admits first)."""

    name: str
    share: float
    priority: int = 0

    def to_dict(self) -> dict:
        return {"name": self.name, "share": self.share,
                "priority": self.priority}

    @classmethod
    def from_dict(cls, d: dict) -> "TenantClass":
        return cls(name=str(d["name"]), share=float(d["share"]),
                   priority=int(d.get("priority", 0)))


def _canon(obj: dict) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


@dataclasses.dataclass
class RequestStream:
    """A generated open-loop request stream (header + request records)."""

    requests: List[ServeRequest]
    meta: dict = dataclasses.field(default_factory=dict)
    version: int = STREAM_SCHEMA_VERSION

    # ------------------------------------------------------------------
    # inspection
    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.requests)

    @property
    def horizon(self) -> float:
        """Arrival span: the last request's arrival time."""
        return self.requests[-1].t_arrival if self.requests else 0.0

    def arrival_times(self) -> np.ndarray:
        return np.array([r.t_arrival for r in self.requests],
                        dtype=np.float64)

    def inter_arrivals(self) -> np.ndarray:
        t = self.arrival_times()
        return np.diff(t, prepend=0.0)

    def tenant_counts(self) -> dict:
        out: dict = {}
        for r in self.requests:
            out[r.tenant] = out.get(r.tenant, 0) + 1
        return out

    def total_tokens(self) -> int:
        return sum(r.max_new for r in self.requests)

    def summary(self) -> str:
        gen = np.array([r.max_new for r in self.requests]) if self.requests \
            else np.zeros(1)
        return (f"stream n={self.n} arrival={self.meta.get('arrival', '?')} "
                f"horizon={self.horizon:.2f}s "
                f"max_new p50={np.percentile(gen, 50):.0f} "
                f"p99={np.percentile(gen, 99):.0f} max={gen.max():.0f} "
                f"tenants={self.tenant_counts()}")

    # ------------------------------------------------------------------
    # canonical JSONL serialization (byte-stable round trip)
    # ------------------------------------------------------------------
    def to_jsonl(self) -> str:
        header = {"kind": "stream_header", "version": self.version,
                  "n": self.n, "meta": self.meta}
        lines = [_canon(header)]
        lines += [_canon(r.to_dict()) for r in self.requests]
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "RequestStream":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty stream")
        header = json.loads(lines[0])
        if header.get("kind") != "stream_header":
            raise ValueError("first JSONL line must be the stream_header")
        ver = header.get("version")
        if ver is None or ver > STREAM_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported stream version {ver!r} "
                f"(this build reads <= {STREAM_SCHEMA_VERSION})")
        reqs = [ServeRequest.from_dict(json.loads(ln)) for ln in lines[1:]
                if json.loads(ln).get("kind") == "request"]
        return cls(requests=reqs, meta=header.get("meta", {}), version=ver)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------


def _arrivals_poisson(rng: np.random.Generator, n: int,
                      rate: float) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _arrivals_thinned(rng: np.random.Generator, n: int, rate_max: float,
                      intensity) -> np.ndarray:
    """Lewis thinning: exact draws from a time-varying intensity."""
    out = np.empty(n)
    t = 0.0
    k = 0
    while k < n:
        t += rng.exponential(1.0 / rate_max)
        if rng.random() * rate_max <= intensity(t):
            out[k] = t
            k += 1
    return out


def _arrivals(rng, n, arrival, rate, burst_factor, burst_on_s, burst_off_s,
              diurnal_period_s, diurnal_amplitude) -> np.ndarray:
    if arrival == "poisson":
        return _arrivals_poisson(rng, n, rate)
    if arrival == "bursty":
        cycle = burst_on_s + burst_off_s
        # Rates chosen so the cycle-average intensity stays ``rate``:
        # bursts concentrate, they don't add load.
        hi = rate * burst_factor * cycle / (burst_factor * burst_on_s
                                            + burst_off_s)
        lo = hi / burst_factor

        def intensity(t):
            return hi if (t % cycle) < burst_on_s else lo

        return _arrivals_thinned(rng, n, hi, intensity)
    if arrival == "diurnal":
        hi = rate * (1.0 + diurnal_amplitude)

        def intensity(t):
            return rate * (1.0 + diurnal_amplitude * math.sin(
                2.0 * math.pi * t / diurnal_period_s))

        return _arrivals_thinned(rng, n, hi, intensity)
    raise ValueError(f"unknown arrival process {arrival!r}; "
                     f"pick from {ARRIVALS}")


# ---------------------------------------------------------------------------
# the generator
# ---------------------------------------------------------------------------


def generate_stream(
    n_requests: int,
    *,
    arrival: str = "poisson",
    rate: float = 8.0,
    seed: int = 0,
    burst_factor: float = 4.0,
    burst_on_s: float = 2.0,
    burst_off_s: float = 6.0,
    diurnal_period_s: float = 60.0,
    diurnal_amplitude: float = 0.8,
    prompt_mean: float = 64.0,
    prompt_cov: float = 0.75,
    max_new_min: int = 2,
    max_new_cap: int = 256,
    max_new_tail: float = 1.1,
    max_new_scale: float = 12.0,
    tenants: Optional[Sequence[TenantClass]] = None,
) -> RequestStream:
    """Generate a seeded open-loop request stream (see module docstring).

    ``rate`` is the long-run mean arrival rate [requests/s] for every
    arrival process -- bursty/diurnal redistribute the same load in
    time.  ``max_new`` is drawn ``min(cap, min + floor(scale *
    Pareto(tail)))``: ``max_new_tail`` < 2 gives the heavy-tailed
    generation lengths that dominate serving-tail behavior.
    """
    if n_requests < 0:
        raise ValueError("n_requests must be >= 0")
    if rate <= 0:
        raise ValueError("rate must be > 0")
    if burst_factor < 1 or burst_on_s <= 0 or burst_off_s < 0:
        raise ValueError("bursty parameters: factor >= 1, on_s > 0, "
                         "off_s >= 0")
    if not 0.0 <= diurnal_amplitude < 1.0:
        raise ValueError("diurnal_amplitude must be in [0, 1)")
    if diurnal_period_s <= 0:
        raise ValueError("diurnal_period_s must be > 0")
    if max_new_tail <= 0 or max_new_scale <= 0:
        raise ValueError("max_new_tail and max_new_scale must be > 0")
    if not 1 <= max_new_min <= max_new_cap:
        raise ValueError("need 1 <= max_new_min <= max_new_cap")
    if prompt_mean < 1 or prompt_cov < 0:
        raise ValueError("prompt_mean must be >= 1, prompt_cov >= 0")
    classes = list(tenants) if tenants else [TenantClass("default", 1.0, 0)]
    shares = np.array([c.share for c in classes], dtype=np.float64)
    if (shares <= 0).any():
        raise ValueError("tenant shares must be > 0")
    shares = shares / shares.sum()

    rng = np.random.default_rng(seed)
    t_arr = _arrivals(rng, n_requests, arrival, rate, burst_factor,
                      burst_on_s, burst_off_s, diurnal_period_s,
                      diurnal_amplitude)
    # lognormal prompt lengths, moment-matched to (mean, cov)
    sigma = math.sqrt(math.log(1.0 + prompt_cov ** 2))
    mu = math.log(prompt_mean) - sigma ** 2 / 2.0
    prompts = np.maximum(1, rng.lognormal(mu, sigma,
                                          size=n_requests).astype(np.int64))
    # Pareto generation lengths (heavy tail)
    gen = max_new_min + np.floor(
        max_new_scale * rng.pareto(max_new_tail, size=n_requests)
    ).astype(np.int64)
    gen = np.clip(gen, max_new_min, max_new_cap)
    tix = rng.choice(len(classes), size=n_requests, p=shares)

    reqs = [ServeRequest(rid=i, t_arrival=float(t_arr[i]),
                         prompt_len=int(prompts[i]), max_new=int(gen[i]),
                         tenant=classes[tix[i]].name,
                         priority=classes[tix[i]].priority)
            for i in range(n_requests)]
    meta = {"arrival": arrival, "rate": rate, "seed": seed,
            "burst_factor": burst_factor, "burst_on_s": burst_on_s,
            "burst_off_s": burst_off_s,
            "diurnal_period_s": diurnal_period_s,
            "diurnal_amplitude": diurnal_amplitude,
            "prompt_mean": prompt_mean, "prompt_cov": prompt_cov,
            "max_new_min": max_new_min, "max_new_cap": max_new_cap,
            "max_new_tail": max_new_tail, "max_new_scale": max_new_scale,
            "tenants": [c.to_dict() for c in classes]}
    return RequestStream(requests=reqs, meta=meta)
