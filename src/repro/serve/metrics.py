"""SLO metrics plane: serving-grade quantities on the simulated clock.

The scheduling layer reports loop-shaped metrics (``SessionReport``:
steps, chunk sizes, c.o.v.); serving is judged on request-shaped ones.
This module turns per-request timing rows -- ``t_submit`` (arrival),
``t_first`` (first token), ``t_done`` (last token), all on the same
simulated clock the batcher runs on -- into:

* **TTFT** (time to first token) and **TPOT** (per-output-token
  latency) percentiles: p50/p90/p99/mean/max;
* **queue depth** over time (time-weighted mean + max), integrated from
  the arrival(+1)/first-token(-1) event train;
* **goodput under overload** -- generated tokens of requests whose TTFT
  met the SLO, per second of horizon.  Under overload raw throughput
  stays flat while goodput collapses: that divergence is the overload
  signature (EXPERIMENTS.md Sec. 5);
* per-tenant slices of the above (multi-tenant priority classes).

``SLOReport`` serializes canonically under ``SLO_SCHEMA_VERSION``, the
same versioned-schema convention as ``SessionReport`` -- scenario
regressions pin its JSON bytes.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import numpy as np

#: Version of the serialized SLO-report schema.  Bump on any
#: backward-incompatible field change; ``from_json`` rejects newer majors.
SLO_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objectives a request must meet to count as goodput."""

    ttft_s: float = 0.5
    tpot_s: Optional[float] = None  # optional per-output-token gate

    def to_dict(self) -> dict:
        return {"ttft_s": self.ttft_s, "tpot_s": self.tpot_s}

    @classmethod
    def from_dict(cls, d: dict) -> "SLO":
        return cls(ttft_s=float(d["ttft_s"]), tpot_s=d.get("tpot_s"))

    def met(self, ttft: float, tpot: float) -> bool:
        if ttft > self.ttft_s:
            return False
        return self.tpot_s is None or tpot <= self.tpot_s


def _pct(a: np.ndarray) -> dict:
    """p50/p90/p99/mean/max of a latency sample (zeros when empty)."""
    if len(a) == 0:
        return {"p50": 0.0, "p90": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    return {"p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()), "max": float(a.max())}


def _queue_depth(t_submit: np.ndarray, t_first: np.ndarray,
                 horizon: float) -> dict:
    """Time-weighted mean + max of |arrived but first token not emitted|."""
    if len(t_submit) == 0 or horizon <= 0:
        return {"mean": 0.0, "max": 0}
    events = [(float(t), +1) for t in t_submit] + \
             [(float(t), -1) for t in t_first]
    events.sort()  # (-1 sorts before +1 at equal t: no phantom spike)
    depth = 0
    max_depth = 0
    area = 0.0
    t_prev = 0.0
    for t, d in events:
        area += depth * (min(t, horizon) - t_prev)
        t_prev = min(t, horizon)
        depth += d
        max_depth = max(max_depth, depth)
    area += depth * max(0.0, horizon - t_prev)
    return {"mean": float(area / horizon), "max": int(max_depth)}


@dataclasses.dataclass
class SLOReport:
    """Aggregated serving metrics for one scenario (or one slice of it)."""

    n_submitted: int
    n_completed: int
    horizon: float  # simulated makespan the rates are normalized by [s]
    slo: SLO
    ttft: dict  # percentiles [s]
    tpot: dict  # percentiles [s/token]
    e2e: dict  # percentiles [s]
    queue_depth: dict  # {"mean": time-weighted, "max": peak}
    throughput_rps: float  # completed requests / horizon
    tokens_per_s: float  # all generated tokens / horizon
    goodput_tokens_per_s: float  # SLO-met tokens / horizon
    slo_attainment: float  # fraction of completed requests meeting the SLO
    per_tenant: Dict[str, dict]
    n_requeued: int = 0  # chaos: requests re-queued by worker death

    def summary(self) -> str:
        return (f"slo[{self.n_completed}/{self.n_submitted} over "
                f"{self.horizon:.2f}s] ttft p50={self.ttft['p50']*1e3:.0f}ms "
                f"p99={self.ttft['p99']*1e3:.0f}ms "
                f"depth max={self.queue_depth['max']} "
                f"goodput={self.goodput_tokens_per_s:.1f}tok/s "
                f"({100 * self.slo_attainment:.0f}% in SLO)")

    # ------------------------------------------------------------------
    # persistence (schema-versioned, canonical)
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema_version": SLO_SCHEMA_VERSION,
                "n_submitted": self.n_submitted,
                "n_completed": self.n_completed,
                "horizon": self.horizon, "slo": self.slo.to_dict(),
                "ttft": self.ttft, "tpot": self.tpot, "e2e": self.e2e,
                "queue_depth": self.queue_depth,
                "throughput_rps": self.throughput_rps,
                "tokens_per_s": self.tokens_per_s,
                "goodput_tokens_per_s": self.goodput_tokens_per_s,
                "slo_attainment": self.slo_attainment,
                "per_tenant": self.per_tenant,
                "n_requeued": self.n_requeued}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent,
                          separators=(",", ":") if indent is None else None)

    @classmethod
    def from_dict(cls, d: dict) -> "SLOReport":
        ver = d.get("schema_version")
        if ver is None or ver > SLO_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported SLOReport schema_version {ver!r} "
                f"(this build reads <= {SLO_SCHEMA_VERSION})")
        return cls(n_submitted=int(d["n_submitted"]),
                   n_completed=int(d["n_completed"]),
                   horizon=float(d["horizon"]),
                   slo=SLO.from_dict(d["slo"]), ttft=d["ttft"],
                   tpot=d["tpot"], e2e=d["e2e"],
                   queue_depth=d["queue_depth"],
                   throughput_rps=float(d["throughput_rps"]),
                   tokens_per_s=float(d["tokens_per_s"]),
                   goodput_tokens_per_s=float(d["goodput_tokens_per_s"]),
                   slo_attainment=float(d["slo_attainment"]),
                   per_tenant=d["per_tenant"],
                   n_requeued=int(d.get("n_requeued", 0)))

    @classmethod
    def from_json(cls, text: str) -> "SLOReport":
        return cls.from_dict(json.loads(text))


def compute_slo(rows: Sequence[dict], *, slo: Optional[SLO] = None,
                n_submitted: Optional[int] = None,
                horizon: Optional[float] = None) -> SLOReport:
    """Aggregate per-request timing rows into an ``SLOReport``.

    Each row carries ``t_submit``/``t_first``/``t_done`` (same clock),
    ``max_new``, and optionally ``tenant``/``requeues``.  ``horizon``
    defaults to the makespan (latest ``t_done``); pass the scenario's
    wall horizon to normalize rates across configurations.
    """
    slo = slo or SLO()
    rows = list(rows)
    n_completed = len(rows)
    t_submit = np.array([r["t_submit"] for r in rows], dtype=np.float64)
    t_first = np.array([r["t_first"] for r in rows], dtype=np.float64)
    t_done = np.array([r["t_done"] for r in rows], dtype=np.float64)
    tokens = np.array([r["max_new"] for r in rows], dtype=np.float64)
    ttft = t_first - t_submit
    tpot = np.divide(t_done - t_first, np.maximum(tokens, 1.0))
    e2e = t_done - t_submit
    if horizon is None:
        horizon = float(t_done.max()) if n_completed else 0.0
    met = np.array([slo.met(float(f), float(p))
                    for f, p in zip(ttft, tpot)], dtype=bool) \
        if n_completed else np.zeros(0, dtype=bool)

    per_tenant: Dict[str, dict] = {}
    tenants = [r.get("tenant", "default") for r in rows]
    for name in sorted(set(tenants)):
        ix = np.array([i for i, t in enumerate(tenants) if t == name])
        per_tenant[name] = {
            "n": int(len(ix)),
            "ttft_p50": float(np.percentile(ttft[ix], 50)),
            "ttft_p99": float(np.percentile(ttft[ix], 99)),
            "attainment": float(met[ix].mean()),
        }

    safe_h = horizon if horizon > 0 else 1.0
    return SLOReport(
        n_submitted=int(n_submitted if n_submitted is not None
                        else n_completed),
        n_completed=n_completed,
        horizon=float(horizon),
        slo=slo,
        ttft=_pct(ttft),
        tpot=_pct(tpot),
        e2e=_pct(e2e),
        queue_depth=_queue_depth(t_submit, t_first, float(horizon)),
        throughput_rps=float(n_completed / safe_h),
        tokens_per_s=float(tokens.sum() / safe_h),
        goodput_tokens_per_s=float(tokens[met].sum() / safe_h)
        if n_completed else 0.0,
        slo_attainment=float(met.mean()) if n_completed else 0.0,
        per_tenant=per_tenant,
        n_requeued=int(sum(r.get("requeues", 0) for r in rows)),
    )
