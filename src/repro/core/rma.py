"""Passive-target RMA window abstraction.

The paper's mechanism: a *non-dedicated coordinator* exposes two integers
(``i`` -- the scheduling-step counter, and ``lp_start`` -- the loop pointer)
through an MPI-3 window; every PE claims work with atomic
``MPI_Get_accumulate`` under ``MPI_Win_lock(MPI_LOCK_SHARED)`` -- i.e. an
atomic **fetch-and-add** that involves no CPU cycles on any worker (passive
target).

On a TPU cluster there is no MPI, but the same semantics exist at the
host-coordination plane.  ``Window`` is the abstraction; four backends:

  * ``ThreadWindow``   -- in-process, lock-based.  Used by tests, the
    single-host data pipeline, and the threaded examples.  Models exactly
    the atomicity of the RMA window with one lock *per counter*, so
    independent counters (telemetry vs the scheduling pointer) never
    contend; ``rmw_latency`` optionally models per-counter serialization.
  * ``SharedMemWindow`` (``repro.pt.window``) -- the real cross-process
    single-host backend: an int64 slab in ``multiprocessing.shared_memory``
    with a fixed key directory, attachable by name from any OS process;
    RMWs are lock-free (``atomics``) or per-slot record-locked
    (``fcntl``).  This is the window the ``processes`` executor schedules
    through -- see DESIGN.md Sec. 11.
  * ``KVStoreWindow``  -- the real-cluster backend: JAX's distributed
    coordination service (``jax.distributed``) exposes
    ``key_value_increment`` -- an atomic fetch-and-add served by the
    coordination server, with **no involvement of any worker process**:
    precisely the paper's passive-target property.  (The coordination server
    plays the coordinator; like the paper's coordinator it does not execute
    chunk calculations -- those happen on the claiming host via the closed
    forms.)
  * ``SimWindow``      -- a simulated-clock window used by the discrete-event
    simulator (``core/sim.py``); claims advance a virtual clock and model the
    contention/fairness of Lock-Polling (the paper's first observation in
    Sec. 5).  It keeps the *single* lock on purpose: the window as one
    serialization point is the thing being modeled.

All backends implement ``fetch_add(key, delta) -> old_value``, ``read(key)``
and ``read_many(keys)``; backends that may be unavailable in a given
environment (KV store, shared memory) answer ``availability()`` with a
machine-checkable reason, so callers (and test skips) never invent their
own.

``HierarchicalWindow`` composes a global window with per-node local windows
(the paper's listed shared-memory window creation; the follow-up's MPI+MPI
two-level scheme) and accounts RMWs per level -- see
``scheduler.HierarchicalRuntime``.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence


class Window:
    """Abstract passive-target window over named int64 counters."""

    def fetch_add(self, key: str, delta: int) -> int:  # returns the OLD value
        raise NotImplementedError

    def read(self, key: str) -> int:
        raise NotImplementedError

    def reset(self, key: str, value: int = 0) -> None:
        raise NotImplementedError

    def read_many(self, keys: Sequence[str]) -> List[int]:
        """Batch read.  The default loops ``read`` (one RMW / lock round per
        key); backends with cheaper batch paths (one lock round, one slab
        pass) override.  No cross-key snapshot atomicity is promised --
        exactly like issuing the reads back-to-back."""
        return [self.read(k) for k in keys]

    @classmethod
    def availability(cls) -> "tuple[bool, str]":
        """(usable, reason).  The single source of truth for "can this
        backend work in this environment" -- test skips and ``make_window``
        route through it so the reason can never go stale relative to the
        constructor's actual requirements.  Base windows are always usable."""
        return True, ""

    @classmethod
    def available(cls) -> bool:
        """Convenience boolean over :meth:`availability`."""
        return cls.availability()[0]


class ThreadWindow(Window):
    """In-process window: a dict of counters, one lock *per counter*.

    A real RMA window serializes per address, not per window: fetch-adds on
    ``loop0/i`` and on a telemetry counter proceed independently.  The
    per-key locks reproduce that -- the ``threads`` executor's PerfModel
    traffic no longer queues behind the scheduling pointer.

    ``rmw_latency`` (seconds) optionally sleeps while *holding* the key's
    lock to model the serialization of window RMWs -- used by concurrency
    tests to widen race windows, never in production paths.
    """

    def __init__(self, initial: Optional[Dict[str, int]] = None, rmw_latency: float = 0.0):
        self._meta = threading.Lock()  # guards per-key lock creation only
        self._v: Dict[str, int] = dict(initial or {})
        self._key_locks: Dict[str, threading.Lock] = {
            k: threading.Lock() for k in self._v}
        self._rmw_latency = rmw_latency

    def _cell(self, key: str) -> threading.Lock:
        lk = self._key_locks.get(key)
        if lk is None:
            with self._meta:
                lk = self._key_locks.setdefault(key, threading.Lock())
        return lk

    def fetch_add(self, key: str, delta: int) -> int:
        with self._cell(key):
            old = self._v.get(key, 0)
            self._v[key] = old + delta
            if self._rmw_latency:
                # Sleep *inside* the key's lock on purpose: the latency
                # models the serialization of RMWs *on that counter*.
                time.sleep(self._rmw_latency)
            return old

    def read(self, key: str) -> int:
        with self._cell(key):
            return self._v.get(key, 0)

    def reset(self, key: str, value: int = 0) -> None:
        with self._cell(key):
            self._v[key] = value

    def read_many(self, keys: Sequence[str]) -> List[int]:
        # dict reads are atomic under the GIL; a batch snapshot needs no
        # locks at all (same guarantee as back-to-back read() calls).
        v = self._v
        return [v.get(k, 0) for k in keys]


class SimWindow(ThreadWindow):
    """Clocked window for deterministic overhead accounting.

    Functionally a ``ThreadWindow``, but every RMW advances a virtual clock
    by ``o_rma`` seconds and is counted -- behind ONE window-wide lock,
    because "the window is a single serialization point" is precisely the
    paper's Sec. 5 Lock-Polling observation this backend exists to model.
    Lets sessions report modeled coordination cost (``clock``) without
    wall-clock noise; the full contention/fairness model lives in
    ``core/sim.py``.
    """

    def __init__(self, initial: Optional[Dict[str, int]] = None,
                 o_rma: float = 2e-6):
        super().__init__(initial)
        self._lock = threading.Lock()  # the modeled serialization point
        self.o_rma = o_rma
        self.clock = 0.0
        self.n_rmw = 0

    def fetch_add(self, key: str, delta: int) -> int:
        with self._lock:
            old = self._v.get(key, 0)
            self._v[key] = old + delta
            self.n_rmw += 1
            self.clock += self.o_rma
            return old

    def read(self, key: str) -> int:
        with self._lock:
            return self._v.get(key, 0)

    def reset(self, key: str, value: int = 0) -> None:
        with self._lock:
            self._v[key] = value

    def read_many(self, keys: Sequence[str]) -> List[int]:
        with self._lock:
            v = self._v
            return [v.get(k, 0) for k in keys]

    def reset_clock(self) -> None:
        """Zero the clock/RMW accounting so one window can serve many loops
        without the next session inheriting stale overhead totals."""
        with self._lock:
            self.clock = 0.0
            self.n_rmw = 0


class HierarchicalWindow(Window):
    """Two-level window: one *global* window + one *node-local* window per node.

    The composition behind hierarchical DLS (arXiv:1903.09510, MPI+MPI):
    node-level super-chunks are claimed through the global window (expensive
    inter-node RMWs -- RDMA / coordination-service round trips) and
    sub-divided through the claiming node's local window (cheap shared-memory
    atomics).  ``fetch_add``/``read``/``reset`` address the *global* level,
    so a ``HierarchicalWindow`` is a drop-in ``Window``; ``local(node)``
    returns the node's local level.

    Per-level RMW accounting (``n_rmw_global``/``n_rmw_local``) is kept here,
    independent of the backends, so sessions can report the follow-up paper's
    headline metric -- how many claims actually paid the global serialization
    point -- for any backend mix.  ``SimWindow`` backends additionally carry
    per-level virtual clocks (``clocks()``).
    """

    def __init__(self, nodes: int,
                 global_window: Optional[Window] = None,
                 local_windows: Optional[Sequence[Window]] = None):
        if nodes <= 0:
            raise ValueError("nodes must be positive")
        self.nodes = nodes
        self.global_window = global_window if global_window is not None \
            else ThreadWindow()
        self.local_windows: List[Window] = (
            list(local_windows) if local_windows is not None
            else [ThreadWindow() for _ in range(nodes)])
        if len(self.local_windows) != nodes:
            raise ValueError("need exactly one local window per node")
        self._acct_lock = threading.Lock()  # global level only: local
        # levels each count behind their own lock, so accounting never
        # serializes across nodes (that is the contention the two-level
        # design exists to remove).
        self._n_rmw_global = 0
        self._locals = [_LevelWindow(w) for w in self.local_windows]

    @classmethod
    def sim(cls, nodes: int, o_rma_global: float = 2e-6,
            o_rma_local: float = 1e-7) -> "HierarchicalWindow":
        """All-SimWindow composition with distinct per-level RMW costs."""
        return cls(nodes, SimWindow(o_rma=o_rma_global),
                   [SimWindow(o_rma=o_rma_local) for _ in range(nodes)])

    # -- global level (the Window interface) ------------------------------
    def fetch_add(self, key: str, delta: int) -> int:
        old = self.global_window.fetch_add(key, delta)
        with self._acct_lock:
            self._n_rmw_global += 1
        return old

    def read(self, key: str) -> int:
        return self.global_window.read(key)

    def reset(self, key: str, value: int = 0) -> None:
        self.global_window.reset(key, value)

    # -- local level ------------------------------------------------------
    def local(self, node: int) -> Window:
        """The node-local window (RMWs counted against the local level)."""
        return self._locals[node]

    # -- per-level accounting ---------------------------------------------
    @property
    def n_rmw_global(self) -> int:
        return self._n_rmw_global

    @property
    def n_rmw_local(self) -> int:
        return sum(v.n_rmw for v in self._locals)

    def clocks(self) -> Dict[str, float]:
        """Per-level virtual clocks (SimWindow backends; 0.0 otherwise).

        ``local`` is the *max* over node windows: local windows serialize
        per node, so their costs overlap across nodes.
        """
        g = getattr(self.global_window, "clock", 0.0)
        loc = [getattr(w, "clock", 0.0) for w in self.local_windows]
        return {"global": g, "local": max(loc) if loc else 0.0}

    def reset_clock(self) -> None:
        with self._acct_lock:
            self._n_rmw_global = 0
        for v in self._locals:
            v.reset_count()
        for w in [self.global_window, *self.local_windows]:
            if isinstance(w, SimWindow):
                w.reset_clock()


class _LevelWindow(Window):
    """Window proxy counting its own RMWs (per node: no cross-node lock)."""

    def __init__(self, inner: Window):
        self._inner = inner
        self._lock = threading.Lock()
        self.n_rmw = 0

    def fetch_add(self, key: str, delta: int) -> int:
        old = self._inner.fetch_add(key, delta)
        with self._lock:
            self.n_rmw += 1
        return old

    def read(self, key: str) -> int:
        return self._inner.read(key)

    def reset(self, key: str, value: int = 0) -> None:
        self._inner.reset(key, value)

    def reset_count(self) -> None:
        with self._lock:
            self.n_rmw = 0


class KVStoreWindow(Window):
    """Multi-host window over the JAX coordination service.

    Requires ``jax.distributed.initialize()`` to have been called (i.e. a
    real multi-host run).  ``key_value_increment`` is an atomic RMW executed
    by the coordination server; it returns the *new* value, so the fetched
    (old) value is ``new - delta`` -- the same value ``MPI_Get_accumulate``
    would have returned.
    """

    def __init__(self, namespace: str = "repro/dls"):
        from jax._src import distributed

        ok, reason = self.availability()
        if not ok:
            raise RuntimeError(f"KVStoreWindow unavailable: {reason}")
        state = distributed.global_state
        if state.client is None:
            raise RuntimeError(
                "KVStoreWindow requires jax.distributed.initialize(); "
                "use ThreadWindow for single-host runs."
            )
        self._client = state.client
        self._ns = namespace

    @classmethod
    def availability(cls) -> "tuple[bool, str]":
        """Usable iff the running jax exposes the atomic-increment primitive.

        Older jaxlib coordination clients expose only get/set -- there is no
        atomic RMW to build a correct window on.
        """
        try:
            from jax._src.lib import xla_extension

            if hasattr(xla_extension.DistributedRuntimeClient,
                       "key_value_increment"):
                return True, ""
            return False, ("this jax version's coordination client has no "
                           "key_value_increment (atomic fetch-add); use "
                           "ThreadWindow/SharedMemWindow or upgrade jax")
        except Exception as e:  # no jaxlib at all
            return False, f"jax coordination client not importable ({e!r})"

    def _k(self, key: str) -> str:
        return f"{self._ns}/{key}"

    def fetch_add(self, key: str, delta: int) -> int:
        new = self._client.key_value_increment(self._k(key), delta)
        return int(new) - delta

    def read(self, key: str) -> int:
        # increment-by-0 is the cheapest consistent read the service offers
        return int(self._client.key_value_increment(self._k(key), 0))

    def reset(self, key: str, value: int = 0) -> None:
        # KV keys are write-once per key; emulate reset with a versioned key.
        raise NotImplementedError(
            "KVStoreWindow counters are monotonic; create a new namespace per loop "
            "(see scheduler.OneSidedRuntime which namespaces by loop id)."
        )


def make_window(backend: str = "auto", **kw) -> Window:
    """Pick a window backend. 'auto' prefers the KV store on multi-host runs.

    ``"shm"`` builds a :class:`repro.pt.window.SharedMemWindow` -- the real
    cross-process backend the ``processes`` executor schedules through
    (imported lazily; ``repro.pt`` is stdlib-only).
    """
    if backend == "thread":
        return ThreadWindow(**kw)
    if backend == "kvstore":
        return KVStoreWindow(**kw)
    if backend == "shm":
        from repro.pt.window import SharedMemWindow

        ok, reason = SharedMemWindow.availability()
        if not ok:
            raise RuntimeError(f"SharedMemWindow unavailable: {reason}")
        return SharedMemWindow.create(**kw)
    if backend == "sim":
        return SimWindow(**kw)
    if backend == "device":
        # counters in accelerator memory (jax device array slab); the
        # backend the persistent-kernel protocol claims through
        from repro.device.window import DeviceWindow

        ok, reason = DeviceWindow.availability()
        if not ok:
            raise RuntimeError(f"DeviceWindow unavailable: {reason}")
        return DeviceWindow(**kw)
    if backend == "auto":
        try:
            return KVStoreWindow(**kw)
        except Exception:
            return ThreadWindow()
    raise ValueError(f"unknown window backend {backend!r}")
