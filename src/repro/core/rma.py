"""Passive-target RMA window abstraction.

The paper's mechanism: a *non-dedicated coordinator* exposes two integers
(``i`` -- the scheduling-step counter, and ``lp_start`` -- the loop pointer)
through an MPI-3 window; every PE claims work with atomic
``MPI_Get_accumulate`` under ``MPI_Win_lock(MPI_LOCK_SHARED)`` -- i.e. an
atomic **fetch-and-add** that involves no CPU cycles on any worker (passive
target).

On a TPU cluster there is no MPI, but the same semantics exist at the
host-coordination plane.  ``Window`` is the abstraction; three backends:

  * ``ThreadWindow``   -- in-process, lock-based.  Used by tests, the
    single-host data pipeline, and the threaded examples.  Models exactly
    the atomicity (and, optionally, the serialization latency) of the RMA
    window.
  * ``KVStoreWindow``  -- the real-cluster backend: JAX's distributed
    coordination service (``jax.distributed``) exposes
    ``key_value_increment`` -- an atomic fetch-and-add served by the
    coordination server, with **no involvement of any worker process**:
    precisely the paper's passive-target property.  (The coordination server
    plays the coordinator; like the paper's coordinator it does not execute
    chunk calculations -- those happen on the claiming host via the closed
    forms.)
  * ``SimWindow``      -- a simulated-clock window used by the discrete-event
    simulator (``core/sim.py``); claims advance a virtual clock and model the
    contention/fairness of Lock-Polling (the paper's first observation in
    Sec. 5).

All backends implement ``fetch_add(key, delta) -> old_value`` and
``read(key)``.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional


class Window:
    """Abstract passive-target window over named int64 counters."""

    def fetch_add(self, key: str, delta: int) -> int:  # returns the OLD value
        raise NotImplementedError

    def read(self, key: str) -> int:
        raise NotImplementedError

    def reset(self, key: str, value: int = 0) -> None:
        raise NotImplementedError


class ThreadWindow(Window):
    """In-process window: a dict of counters behind a lock.

    ``rmw_latency`` (seconds) optionally sleeps while *holding* the lock to
    model the serialization of window RMWs -- used by concurrency tests to
    widen race windows, never in production paths.
    """

    def __init__(self, initial: Optional[Dict[str, int]] = None, rmw_latency: float = 0.0):
        self._lock = threading.Lock()
        self._v: Dict[str, int] = dict(initial or {})
        self._rmw_latency = rmw_latency

    def fetch_add(self, key: str, delta: int) -> int:
        with self._lock:
            old = self._v.get(key, 0)
            self._v[key] = old + delta
            if self._rmw_latency:
                import time

                time.sleep(self._rmw_latency)
            return old

    def read(self, key: str) -> int:
        with self._lock:
            return self._v.get(key, 0)

    def reset(self, key: str, value: int = 0) -> None:
        with self._lock:
            self._v[key] = value


class SimWindow(ThreadWindow):
    """Clocked window for deterministic overhead accounting.

    Functionally a ``ThreadWindow``, but every RMW advances a virtual clock
    by ``o_rma`` seconds (the window is the serialization point, as in the
    paper's Sec. 5 Lock-Polling observation) and is counted.  Lets sessions
    report modeled coordination cost (``clock``) without wall-clock noise;
    the full contention/fairness model lives in ``core/sim.py``.
    """

    def __init__(self, initial: Optional[Dict[str, int]] = None,
                 o_rma: float = 2e-6):
        super().__init__(initial)
        self.o_rma = o_rma
        self.clock = 0.0
        self.n_rmw = 0

    def fetch_add(self, key: str, delta: int) -> int:
        with self._lock:
            old = self._v.get(key, 0)
            self._v[key] = old + delta
            self.n_rmw += 1
            self.clock += self.o_rma
            return old


class KVStoreWindow(Window):
    """Multi-host window over the JAX coordination service.

    Requires ``jax.distributed.initialize()`` to have been called (i.e. a
    real multi-host run).  ``key_value_increment`` is an atomic RMW executed
    by the coordination server; it returns the *new* value, so the fetched
    (old) value is ``new - delta`` -- the same value ``MPI_Get_accumulate``
    would have returned.
    """

    def __init__(self, namespace: str = "repro/dls"):
        from jax._src import distributed

        state = distributed.global_state
        if state.client is None:
            raise RuntimeError(
                "KVStoreWindow requires jax.distributed.initialize(); "
                "use ThreadWindow for single-host runs."
            )
        if not hasattr(state.client, "key_value_increment"):
            # Older jaxlib coordination clients expose only get/set -- there
            # is no atomic RMW to build a correct window on.
            raise RuntimeError(
                "this jax version's coordination client has no "
                "key_value_increment (atomic fetch-add); KVStoreWindow is "
                "unavailable -- use ThreadWindow or upgrade jax."
            )
        self._client = state.client
        self._ns = namespace

    @staticmethod
    def available() -> bool:
        """True if the running jax exposes the atomic-increment primitive."""
        try:
            from jax._src.lib import xla_extension

            return hasattr(xla_extension.DistributedRuntimeClient,
                           "key_value_increment")
        except Exception:
            return False

    def _k(self, key: str) -> str:
        return f"{self._ns}/{key}"

    def fetch_add(self, key: str, delta: int) -> int:
        new = self._client.key_value_increment(self._k(key), delta)
        return int(new) - delta

    def read(self, key: str) -> int:
        # increment-by-0 is the cheapest consistent read the service offers
        return int(self._client.key_value_increment(self._k(key), 0))

    def reset(self, key: str, value: int = 0) -> None:
        # KV keys are write-once per key; emulate reset with a versioned key.
        raise NotImplementedError(
            "KVStoreWindow counters are monotonic; create a new namespace per loop "
            "(see scheduler.OneSidedRuntime which namespaces by loop id)."
        )


def make_window(backend: str = "auto", **kw) -> Window:
    """Pick a window backend. 'auto' prefers the KV store on multi-host runs."""
    if backend == "thread":
        return ThreadWindow(**kw)
    if backend == "kvstore":
        return KVStoreWindow(**kw)
    if backend == "sim":
        return SimWindow(**kw)
    if backend == "auto":
        try:
            return KVStoreWindow(**kw)
        except Exception:
            return ThreadWindow()
    raise ValueError(f"unknown window backend {backend!r}")
