"""Core of the paper's contribution: distributed chunk-calculation DLS.

Layers:
  chunk_calculus -- Table-2 recurrences + Eq.1-3 closed forms + batched planner
  rma            -- passive-target window (fetch_add) backends
  scheduler      -- One_Sided / Two_Sided runtimes over threads or hosts
  weights        -- WF static weights + AWF adaptive reweighting (stragglers)
  sim            -- discrete-event simulator (paper Fig. 4/5 reproduction)

Consumers should go through the ``repro.dls`` session facade (DESIGN.md);
this package is the implementation layer.  The DES event kernel behind
``sim`` lives in ``repro.sim`` (one kernel, three runtime topologies).
"""
from .chunk_calculus import (  # noqa: F401
    ADAPTIVE,
    AWF_VARIANTS,
    TECHNIQUE_INFO,
    TECHNIQUES,
    WEIGHTED,
    AFStats,
    LoopSpec,
    af_chunk_size,
    chunk_series_recurrence,
    chunk_size_closed,
    chunk_sizes_closed,
    max_steps_bound,
    plan,
    plan_jax,
    scheduling_steps,
    technique_table,
    tss_constants,
)
from .rma import (  # noqa: F401
    HierarchicalWindow,
    KVStoreWindow,
    SimWindow,
    ThreadWindow,
    Window,
    make_window,
)
from .scheduler import (  # noqa: F401
    Claim,
    HierarchicalRuntime,
    OneSidedRuntime,
    TwoSidedRuntime,
)
from .sim import (  # noqa: F401
    KNL_SPEED,
    XEON_SPEED,
    SimConfig,
    SimResult,
    mandelbrot_costs,
    mandelbrot_iteration_counts,
    paper_cluster,
    psia_costs,
    simulate,
    simulate_many,
)
from .weights import (  # noqa: F401
    AdaptiveFactoringModel,
    AdaptiveWeightModel,
    PerfModel,
    WapTracker,
    WeightBoard,
    coefficient_of_variation,
    weights_from_speeds,
)
