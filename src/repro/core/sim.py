"""Discrete-event simulation of DLS on heterogeneous distributed-memory
clusters -- the stable public API.

This is the faithful-reproduction engine for the paper's experiments
(Sec. 4-5): it executes the One_Sided (distributed chunk-calculation via
passive-target RMA), Two_Sided (master-worker), and Hierarchical
(two-level MPI+MPI) protocols over a virtual cluster of heterogeneous
PEs and reports the parallel loop time ``T_p^loop``, per-PE finish
times, and load-imbalance metrics.

Fidelity notes (matching the paper's observations):

* One_Sided claims are two *serialized* window RMWs (the coordinator's NIC
  is the serialization point), with the chunk calculation *in between*
  executed locally by the claiming PE -- so chunk calculations of different
  PEs overlap in time (paper Fig. 3), and the RMW service time does **not**
  depend on the coordinator core's speed (passive target: no coordinator CPU
  involved).  Lock-Polling fairness (Intel MPI) is modeled by granting the
  window to a *random* waiter (paper Sec. 5, first observation).
* Two_Sided claims queue at the master, which serves them **smallest rank
  first** (Intel MPI ``MPI_Iprobe`` behaviour per the paper) and whose
  service time scales with the *master's* core speed; the master is
  non-dedicated -- it interleaves serving with executing its own iterations.
* Hierarchical claims (the follow-up paper's MPI+MPI two-level scheme)
  split into rare super-chunk claims through the global window
  (``o_rma_global``) and frequent local claims through per-node
  shared-memory windows (``o_rma_local``), each window a separate
  serialization point -- see EXPERIMENTS.md Sec. 2.

The DES has no wall-clock dependence; it is deterministic given a seed.
Overhead constants are calibrated against the paper's published numbers
-- derivations in EXPERIMENTS.md ("DES calibration").

Since ISSUE 5 the three protocol implementations are **topology
descriptions over one event kernel** (``repro.sim``: ``EventQueue``,
``Resource`` serialization points, a shared PE process model, the
perturbation scenario layer, and ``simulate_many`` batched sweeps).
This module keeps the stable surface -- ``SimConfig``, ``SimResult``,
``simulate`` -- plus the paper's cluster/workload calibration helpers;
non-adaptive event streams are pinned byte-identical to the
pre-refactor implementations by ``tests/test_sim_equivalence.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from . import chunk_calculus as cc

# ---------------------------------------------------------------------------
# Cluster + overhead model
# ---------------------------------------------------------------------------


@dataclass
class SimConfig:
    spec: cc.LoopSpec
    speeds: np.ndarray  # per-PE relative speed (1.0 = reference core)
    costs: np.ndarray  # per-iteration execution cost at speed 1.0 [seconds]
    impl: str = "one_sided"  # "one_sided" | "two_sided" | "hierarchical"
    coordinator: int = 0  # PE hosting the window / playing the master
    # -- One_Sided overheads --
    o_rma: float = 2e-6  # window service time per atomic RMW [s]
    o_claim_net: float = 1e-6  # origin-side wire latency per RMW
    t_calc: float = 5e-7  # closed-form chunk-size computation [s] at speed 1
    # Origin-side CPU time to *issue* a claim (MPI software stack), scaled by
    # the origin core's speed.  On heterogeneous systems this skews the very
    # first scheduling steps toward the fast cores -- which is what keeps the
    # largest GSS/FAC2 chunks off the slow cores in the paper's Fig. 4/5.
    o_issue: float = 5e-4
    lock_polling_random: bool = True  # Intel MPI Lock-Polling fairness
    # -- Two_Sided overheads --
    o_serve: float = 1.66e-4  # master CPU time per request [s] at speed 1
    o_req_net: float = 2e-6  # request+reply wire latency (total)
    # The master interleaves serving with its own chunk in time slices of
    # this many seconds (MPI_Iprobe polling granularity) -- a fine quantum
    # matches the paper's observation that a *fast* master shows no
    # master-worker penalty (Fig. 4b), while a slow master saturates on
    # service time alone.
    master_quantum: float = 2e-3
    seed: int = 0
    # -- Hierarchical (impl="hierarchical") overheads --
    # Outer level: node super-chunks through the global window at
    # ``o_rma_global`` per RMW (defaults to ``o_rma``); inner level: local
    # sub-scheduling through the node's shared-memory window at
    # ``o_rma_local`` per RMW (an intra-node atomic is ~an order of magnitude
    # cheaper than an inter-node RDMA -- see EXPERIMENTS.md).
    nodes: int = 1
    inner_technique: str = "ss"
    o_rma_global: Optional[float] = None  # None -> o_rma
    o_rma_local: float = 1e-7
    o_issue_local: float = 1e-5  # CPU time to issue a *local* claim
    # -- Adaptive techniques (af / awf_b..e) --
    # Chunk timings feeding the online PerfModel are perturbed by
    # multiplicative lognormal noise with this c.o.v. (timer granularity +
    # OS jitter on the measured chunk), and become *visible* to claimers
    # only o_adapt_lag seconds after chunk completion (the telemetry RMWs
    # must traverse the window before another PE's read can see them).
    # Calibration derivations: EXPERIMENTS.md "Adaptive-technique
    # calibration".
    o_meas_cov: float = 0.05
    o_adapt_lag: float = 1e-3
    # Collect a per-chunk event trace (``SimResult.chunk_trace``): one dict
    # per executed chunk with the claiming PE, grant-order step, iteration
    # range, virtual start/end timestamps, and claim latency -- the DES leg
    # of the ``repro.replay`` data plane (EXPERIMENTS.md Sec. 4).  Off by
    # default: paper-scale grids take millions of chunks.
    collect_trace: bool = False
    # Scenario layer (``repro.sim.perturb``): a sequence of ``Perturbation``
    # objects -- PE failure/churn with in-flight chunk re-claim, straggler
    # injection, time-varying speed drift -- applied by the shared event
    # kernel, so every topology supports every scenario.  None (default)
    # compiles to nothing: event streams stay byte-identical to the
    # unperturbed simulator.
    perturbations: Optional[Sequence] = None

    def __post_init__(self):
        self.speeds = np.asarray(self.speeds, dtype=np.float64)
        self.costs = np.asarray(self.costs, dtype=np.float64)
        if len(self.speeds) != self.spec.P:
            raise ValueError("speeds length must equal spec.P")
        if len(self.costs) != self.spec.N:
            raise ValueError("costs length must equal spec.N")
        if self.o_rma_global is None:
            self.o_rma_global = self.o_rma
        if self.impl == "hierarchical" and not 1 <= self.nodes <= self.spec.P:
            raise ValueError(f"nodes must be in [1, P], got {self.nodes}")
        if self.perturbations is not None:
            self.perturbations = tuple(self.perturbations)


@dataclass
class SimResult:
    T_loop: float  # parallel loop time = max PE finish
    finish: np.ndarray  # per-PE finish time
    n_claims: int  # scheduling steps taken
    cov: float  # c.o.v. of PE finish times (load imbalance)
    per_pe_iters: np.ndarray  # iterations executed per PE
    master_serve_time: float = 0.0  # two-sided: total master time serving
    mean_claim_latency: float = 0.0  # mean time from claim issue to grant
    n_rmw_global: int = 0  # RMWs served by the global window
    n_rmw_local: int = 0  # RMWs served by node-local windows (hierarchical)
    # Per-chunk event trace (``SimConfig.collect_trace``): dicts with keys
    # pe/step/start/size/t0/t1/lat on the virtual clock, in grant order --
    # the same record shape the native executors emit (repro.replay).
    chunk_trace: Optional[List[dict]] = None

    def summary(self) -> str:
        return (
            f"T_loop={self.T_loop:.2f}s claims={self.n_claims} cov={self.cov:.3f} "
            f"serve={self.master_serve_time:.2f}s claim_lat={self.mean_claim_latency*1e6:.1f}us "
            f"rmw_g={self.n_rmw_global} rmw_l={self.n_rmw_local}"
        )


def simulate(cf: SimConfig, engine: str = "auto",
             backend: str = "numpy") -> SimResult:
    """Run one configuration through the unified DES.

    ``engine="auto"`` routes qualifying configs (non-adaptive,
    unperturbed, no trace) to the vectorized fast path
    (``repro.sim.fast``) and everything else to the event kernel;
    ``"kernel"``/``"fast"`` force a side.  Routing never changes
    results -- the two are equivalence-pinned (``tests/test_sim_fast.py``).
    """
    from repro.sim.run import simulate as _simulate

    return _simulate(cf, engine=engine, backend=backend)


def simulate_many(configs: Sequence[SimConfig], workers=None,
                  budget_s: Optional[float] = None,
                  engine: str = "auto") -> List[SimResult]:
    """Batched sweep over many configurations (``repro.sim.batch``):
    process-pool fan-out with fork-shared cost arrays; results align with
    ``configs`` (None where a wall-clock budget dropped a candidate)."""
    from repro.sim.batch import simulate_many as _many

    return _many(configs, workers=workers, budget_s=budget_s, engine=engine)


# ---------------------------------------------------------------------------
# The paper's cluster + applications
# ---------------------------------------------------------------------------

#: Effective per-core speed of a KNL (Xeon Phi 7210, 1.3 GHz Silvermont-class)
#: core relative to a Xeon E5-2640 (2.4 GHz) core.  Clock ratio alone is 0.54,
#: but Phi cores retire far fewer instructions/cycle; calibrated against the
#: paper's One_Sided SS numbers (109 s @2:1 vs 68.5 s @1:2) and cross-checked
#: on TSS/GSS/FAC2 -- see EXPERIMENTS.md "DES calibration".
KNL_SPEED = 0.205
XEON_SPEED = 1.0

#: PSIA per-image mean cost at Xeon speed implied by the calibration
#: (T_SS = N * mu / sum(speeds) solved at the paper's 109 s / ratio 2:1).
PSIA_MEAN_COST = 0.05125


def paper_cluster(ratio: str, coordinator_on: str) -> tuple:
    """The paper's 288-core mixes.  Returns (speeds, coordinator_index).

    ratio: "2:1" (192 KNL + 96 Xeon) or "1:2" (96 KNL + 192 Xeon).
    coordinator_on: "knl" | "xeon" -- the two mapping scenarios of Sec. 4.
    Xeon nodes hold the low MPI ranks (rank order matters for the Two_Sided
    smallest-rank-first service; with Xeons first the big early GSS chunks
    land on fast cores, which is what the paper's Fig. 4 magnitudes imply).
    The coordinator/master is the first Xeon (rank 0) or the first KNL.
    """
    if ratio == "2:1":
        n_knl, n_xeon = 192, 96
    elif ratio == "1:2":
        n_knl, n_xeon = 96, 192
    else:
        raise ValueError(ratio)
    speeds = np.concatenate([np.full(n_xeon, XEON_SPEED), np.full(n_knl, KNL_SPEED)])
    coord = n_xeon if coordinator_on == "knl" else 0
    return speeds, coord


def mandelbrot_iteration_counts(width: int = 1152, ct: int = 1000,
                                xlim=(-2.0, 1.0), ylim=(-1.5, 1.5)) -> np.ndarray:
    """Escape-time iteration counts for the paper's Mandelbrot variant z<-z^4+c.

    Vectorized numpy oracle (also the reference for the Pallas kernel).
    Returns an (width*width,) int array of per-pixel inner-iteration counts --
    the per-iteration cost profile of paper Algorithm 2 (highly imbalanced:
    interior pixels burn the full ``ct``).
    """
    xs = np.linspace(xlim[0], xlim[1], width)
    ys = np.linspace(ylim[0], ylim[1], width)
    c = (xs[None, :] + 1j * ys[:, None]).astype(np.complex128)
    z = np.zeros_like(c)
    counts = np.zeros(c.shape, dtype=np.int64)
    active = np.ones(c.shape, dtype=bool)
    for _ in range(ct):
        z2 = z[active] ** 4 + c[active]
        z[active] = z2
        escaped = np.abs(z2) >= 2.0
        counts[active] += 1
        act_idx = np.where(active)
        active[act_idx[0][escaped], act_idx[1][escaped]] = False
        if not active.any():
            break
    return counts.reshape(-1)


def mandelbrot_costs(n_tasks: int, width: int = 1152, ct: int = 1000,
                     sec_per_inner_iter: float = 2.4e-4) -> np.ndarray:
    """Per-scheduled-iteration costs for Mandelbrot: rows of the image.

    The paper schedules the W^2-pixel loop; with avg cost > 0.2 s their unit
    of scheduling is a block of pixels.  We schedule ``n_tasks`` equal pixel
    blocks and sum the real per-pixel inner-iteration counts within a block.
    """
    counts = mandelbrot_iteration_counts(width, ct)
    blocks = np.array_split(counts, n_tasks)
    return np.array([b.sum() * sec_per_inner_iter for b in blocks])


def psia_costs(n: int = 288_000, mean: float = 0.075, cov: float = 0.30,
               seed: int = 42) -> np.ndarray:
    """PSIA spin-image per-image cost model (lognormal around the mean).

    Each outer iteration of paper Algorithm 1 scans all 800k object points
    with a support-angle branch; per-image cost therefore varies moderately
    around the mean.  ``mean`` is at Xeon speed; calibrated so One_Sided SS
    matches the paper (see EXPERIMENTS.md).
    """
    rng = np.random.default_rng(seed)
    sigma = np.sqrt(np.log(1 + cov**2))
    mu = np.log(mean) - sigma**2 / 2
    return rng.lognormal(mu, sigma, size=n)
