"""Discrete-event simulator of DLS on heterogeneous distributed-memory clusters.

This is the faithful-reproduction engine for the paper's experiments
(Sec. 4-5): it executes the One_Sided (distributed chunk-calculation via
passive-target RMA) and Two_Sided (master-worker) protocols over a virtual
cluster of heterogeneous PEs and reports the parallel loop time
``T_p^loop``, per-PE finish times, and load-imbalance metrics.

Fidelity notes (matching the paper's observations):

* One_Sided claims are two *serialized* window RMWs (the coordinator's NIC
  is the serialization point), with the chunk calculation *in between*
  executed locally by the claiming PE -- so chunk calculations of different
  PEs overlap in time (paper Fig. 3), and the RMW service time does **not**
  depend on the coordinator core's speed (passive target: no coordinator CPU
  involved).  Lock-Polling fairness (Intel MPI) is modeled by granting the
  window to a *random* waiter (paper Sec. 5, first observation).
* Two_Sided claims queue at the master, which serves them **smallest rank
  first** (Intel MPI ``MPI_Iprobe`` behaviour per the paper) and whose
  service time scales with the *master's* core speed; the master is
  non-dedicated -- it interleaves serving with executing its own iterations
  (checks the queue every ``breakafter`` own iterations).
* Hierarchical claims (the follow-up paper's MPI+MPI two-level scheme)
  split into rare super-chunk claims through the global window
  (``o_rma_global``) and frequent local claims through per-node
  shared-memory windows (``o_rma_local``), each window a separate
  serialization point -- see EXPERIMENTS.md Sec. 2.

The DES has no wall-clock dependence; it is deterministic given a seed.
Overhead constants are calibrated against the paper's published numbers
-- derivations in EXPERIMENTS.md ("DES calibration").
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from . import chunk_calculus as cc

# ---------------------------------------------------------------------------
# Cluster + overhead model
# ---------------------------------------------------------------------------


@dataclass
class SimConfig:
    spec: cc.LoopSpec
    speeds: np.ndarray  # per-PE relative speed (1.0 = reference core)
    costs: np.ndarray  # per-iteration execution cost at speed 1.0 [seconds]
    impl: str = "one_sided"  # "one_sided" | "two_sided" | "hierarchical"
    coordinator: int = 0  # PE hosting the window / playing the master
    # -- One_Sided overheads --
    o_rma: float = 2e-6  # window service time per atomic RMW [s]
    o_claim_net: float = 1e-6  # origin-side wire latency per RMW
    t_calc: float = 5e-7  # closed-form chunk-size computation [s] at speed 1
    # Origin-side CPU time to *issue* a claim (MPI software stack), scaled by
    # the origin core's speed.  On heterogeneous systems this skews the very
    # first scheduling steps toward the fast cores -- which is what keeps the
    # largest GSS/FAC2 chunks off the slow cores in the paper's Fig. 4/5.
    o_issue: float = 5e-4
    lock_polling_random: bool = True  # Intel MPI Lock-Polling fairness
    # -- Two_Sided overheads --
    o_serve: float = 1.66e-4  # master CPU time per request [s] at speed 1
    o_req_net: float = 2e-6  # request+reply wire latency (total)
    # The master interleaves serving with its own chunk in time slices of
    # this many seconds (MPI_Iprobe polling granularity) -- a fine quantum
    # matches the paper's observation that a *fast* master shows no
    # master-worker penalty (Fig. 4b), while a slow master saturates on
    # service time alone.
    master_quantum: float = 2e-3
    seed: int = 0
    # -- Hierarchical (impl="hierarchical") overheads --
    # Outer level: node super-chunks through the global window at
    # ``o_rma_global`` per RMW (defaults to ``o_rma``); inner level: local
    # sub-scheduling through the node's shared-memory window at
    # ``o_rma_local`` per RMW (an intra-node atomic is ~an order of magnitude
    # cheaper than an inter-node RDMA -- see EXPERIMENTS.md).
    nodes: int = 1
    inner_technique: str = "ss"
    o_rma_global: Optional[float] = None  # None -> o_rma
    o_rma_local: float = 1e-7
    o_issue_local: float = 1e-5  # CPU time to issue a *local* claim
    # -- Adaptive techniques (af / awf_b..e) --
    # Chunk timings feeding the online PerfModel are perturbed by
    # multiplicative lognormal noise with this c.o.v. (timer granularity +
    # OS jitter on the measured chunk), and become *visible* to claimers
    # only o_adapt_lag seconds after chunk completion (the telemetry RMWs
    # must traverse the window before another PE's read can see them).
    # Calibration derivations: EXPERIMENTS.md "Adaptive-technique
    # calibration".
    o_meas_cov: float = 0.05
    o_adapt_lag: float = 1e-3
    # Collect a per-chunk event trace (``SimResult.chunk_trace``): one dict
    # per executed chunk with the claiming PE, grant-order step, iteration
    # range, virtual start/end timestamps, and claim latency -- the DES leg
    # of the ``repro.replay`` data plane (EXPERIMENTS.md Sec. 4).  Off by
    # default: paper-scale grids take millions of chunks.
    collect_trace: bool = False

    def __post_init__(self):
        self.speeds = np.asarray(self.speeds, dtype=np.float64)
        self.costs = np.asarray(self.costs, dtype=np.float64)
        if len(self.speeds) != self.spec.P:
            raise ValueError("speeds length must equal spec.P")
        if len(self.costs) != self.spec.N:
            raise ValueError("costs length must equal spec.N")
        if self.o_rma_global is None:
            self.o_rma_global = self.o_rma
        if self.impl == "hierarchical" and not 1 <= self.nodes <= self.spec.P:
            raise ValueError(f"nodes must be in [1, P], got {self.nodes}")


@dataclass
class SimResult:
    T_loop: float  # parallel loop time = max PE finish
    finish: np.ndarray  # per-PE finish time
    n_claims: int  # scheduling steps taken
    cov: float  # c.o.v. of PE finish times (load imbalance)
    per_pe_iters: np.ndarray  # iterations executed per PE
    master_serve_time: float = 0.0  # two-sided: total master time serving
    mean_claim_latency: float = 0.0  # mean time from claim issue to grant
    n_rmw_global: int = 0  # RMWs served by the global window
    n_rmw_local: int = 0  # RMWs served by node-local windows (hierarchical)
    # Per-chunk event trace (``SimConfig.collect_trace``): dicts with keys
    # pe/step/start/size/t0/t1/lat on the virtual clock, in grant order --
    # the same record shape the native executors emit (repro.replay).
    chunk_trace: Optional[List[dict]] = None

    def summary(self) -> str:
        return (
            f"T_loop={self.T_loop:.2f}s claims={self.n_claims} cov={self.cov:.3f} "
            f"serve={self.master_serve_time:.2f}s claim_lat={self.mean_claim_latency*1e6:.1f}us "
            f"rmw_g={self.n_rmw_global} rmw_l={self.n_rmw_local}"
        )


# ---------------------------------------------------------------------------
# Adaptive-technique telemetry (af / awf_b..e): the DES drives the *same*
# weight models the runtime policies use (core/weights.py), feeding them
# noise-perturbed, lag-delayed observations on the virtual clock -- so
# simulated and real adaptation can never use different math.
# ---------------------------------------------------------------------------


def _make_adaptive_model(technique: str, P: int):
    from .weights import AdaptiveFactoringModel, AdaptiveWeightModel

    if technique == "af":
        return AdaptiveFactoringModel(P)
    update, overhead = cc.AWF_VARIANTS[technique]
    return AdaptiveWeightModel(P, update=update, include_overhead=overhead)


class _AdaptiveTelemetry:
    """Noise + adaptation-lag front end over an adaptive weight model.

    ``observe`` queues a completed chunk's measurement (compute time
    perturbed by lognormal noise with c.o.v. ``o_meas_cov``); ``deliver``
    feeds the model every observation that has become visible by ``now``
    (completion + ``o_adapt_lag``) -- the DES analogue of telemetry RMWs
    propagating through the window before claimers can read them.
    """

    def __init__(self, model, cov: float, lag: float, rng: random.Random):
        self.model = model
        self.lag = lag
        self.rng = rng
        self.sig = math.sqrt(math.log(1.0 + cov * cov)) if cov > 0 else 0.0
        self._heap: List[tuple] = []
        self._seq = itertools.count()

    def observe(self, pe: int, iters: int, exec_t: float, sched_t: float,
                t_done: float) -> None:
        if iters <= 0:
            return
        sec = exec_t
        if self.sig:
            sec *= self.rng.lognormvariate(-0.5 * self.sig * self.sig, self.sig)
        heapq.heappush(self._heap,
                       (t_done + self.lag, next(self._seq), pe, iters, sec,
                        sched_t))

    def deliver(self, now: float) -> None:
        while self._heap and self._heap[0][0] <= now:
            _, _, pe, iters, sec, sched = heapq.heappop(self._heap)
            self.model.record(pe, iters, sec, sched)

    # -- claim-time lookups -------------------------------------------------
    def weight(self, pe: int) -> Optional[float]:
        return self.model.weight(pe)

    def af_stats(self, pe: int):
        fn = getattr(self.model, "af_stats", None)
        return fn(pe) if fn is not None else None

    def node_weight(self, node: int, bounds) -> Optional[float]:
        return self.model.node_weight(node, bounds)


def _telemetry_for(cf: SimConfig, rng: random.Random,
                   inner: Optional[str] = None,
                   lag: Optional[float] = None) -> Optional[_AdaptiveTelemetry]:
    """A telemetry front end if any scheduling level is adaptive, else None.

    When both levels are adaptive the *inner* (per-PE claim) technique
    picks the model -- claims are per-PE; the outer level only consumes the
    node-aggregated weights, which every model exposes.  ``lag`` overrides
    ``o_adapt_lag`` (the two-sided DES passes 0: telemetry is master-local,
    no window traversal to wait for).
    """
    names = [t for t in (inner, cf.spec.technique) if t in cc.ADAPTIVE]
    if not names:
        return None
    return _AdaptiveTelemetry(_make_adaptive_model(names[0], cf.spec.P),
                              cf.o_meas_cov,
                              cf.o_adapt_lag if lag is None else lag, rng)


# ---------------------------------------------------------------------------
# One_Sided DES
# ---------------------------------------------------------------------------


def _simulate_one_sided(cf: SimConfig) -> SimResult:
    spec, N = cf.spec, cf.spec.N
    P = spec.P
    rng = random.Random(cf.seed)
    pref = np.concatenate([[0.0], np.cumsum(cf.costs)])  # prefix sums of cost
    tele = _telemetry_for(cf, rng)

    # Window state (the two shared integers of the paper)
    glob_i = 0
    glob_lp = 0
    win_busy_until = 0.0
    waiters: List[tuple] = []  # (pe, phase, ready_time, k) waiting for the window

    # Event heap: (time, seq, kind, pe, payload)
    seq = itertools.count()
    evq: List[tuple] = []

    finish = np.zeros(P)
    iters = np.zeros(P, dtype=np.int64)
    claim_started = {}
    claim_latencies = []
    n_claims = 0
    n_rmw = 0
    trace = [] if cf.collect_trace else None

    def push(t, kind, pe, payload=None):
        heapq.heappush(evq, (t, next(seq), kind, pe, payload))

    def window_grant(now):
        """If the window is free and someone waits, grant one RMW."""
        nonlocal win_busy_until, n_rmw
        if not waiters or win_busy_until > now + 1e-18:
            return
        idx = rng.randrange(len(waiters)) if cf.lock_polling_random else 0
        pe, phase, ready, k = waiters.pop(idx)
        win_busy_until = now + cf.o_rma
        n_rmw += 1
        push(now + cf.o_rma, f"rmw{phase}_done", pe, k)
        push(now + cf.o_rma, "win_free", -1)

    # All PEs start by claiming at t=0 (paying their issue cost first)
    for pe in range(P):
        push(cf.o_issue / cf.speeds[pe], "want_rmw1", pe)

    done_pes = 0
    while evq and done_pes < P:
        t, _, kind, pe, payload = heapq.heappop(evq)
        if kind == "want_rmw1":
            if glob_lp >= N:  # fast-path exit (stale-read safe: re-checked later)
                finish[pe] = t
                done_pes += 1
                continue
            claim_started[pe] = t
            waiters.append((pe, 1, t, None))
            window_grant(t)  # grants only if the window is free *now*;
            # otherwise the pending win_free event picks a (random) waiter --
            # this is what models Lock-Polling fairness correctly.
        elif kind == "rmw1_done":
            i_local = glob_i
            glob_i += 1
            # Step 2: local closed-form chunk calculation (overlaps other PEs)
            if tele is None:
                k = cc.chunk_size_closed(spec, i_local, pe)
            else:
                tele.deliver(t)
                k = cc.chunk_size_closed(
                    spec, i_local, pe, weight=tele.weight(pe),
                    af_stats=tele.af_stats(pe), remaining=N - glob_lp)
            t_ready = t + cf.o_claim_net + cf.t_calc / cf.speeds[pe]
            push(t_ready, "want_rmw2", pe, k)
        elif kind == "want_rmw2":
            waiters.append((pe, 2, t, payload))
            window_grant(t)
        elif kind == "rmw2_done":
            k = payload
            start = glob_lp
            glob_lp += k
            t_got = t + cf.o_claim_net
            lat = t_got - claim_started.pop(pe)
            claim_latencies.append(lat)
            if start >= N:
                finish[pe] = t_got
                done_pes += 1
                continue
            n_claims += 1
            stop = min(start + k, N)
            iters[pe] += stop - start
            exec_t = (pref[stop] - pref[start]) / cf.speeds[pe]
            if trace is not None:
                trace.append({"pe": pe, "step": n_claims - 1, "start": start,
                              "size": stop - start, "t0": t_got,
                              "t1": t_got + exec_t, "lat": lat})
            if tele is not None:
                tele.observe(pe, stop - start, exec_t, lat, t_got + exec_t)
            push(t_got + exec_t + cf.o_issue / cf.speeds[pe], "want_rmw1", pe)
        elif kind == "win_free":
            window_grant(t)
        else:  # pragma: no cover
            raise AssertionError(kind)

    cov = float(np.std(finish) / np.mean(finish)) if np.mean(finish) > 0 else 0.0
    return SimResult(
        T_loop=float(finish.max()),
        finish=finish,
        n_claims=n_claims,
        cov=cov,
        per_pe_iters=iters,
        mean_claim_latency=float(np.mean(claim_latencies)) if claim_latencies else 0.0,
        n_rmw_global=n_rmw,
        chunk_trace=trace,
    )


# ---------------------------------------------------------------------------
# Hierarchical DES (two-level: global super-chunks + node-local windows)
# ---------------------------------------------------------------------------


def _simulate_hierarchical(cf: SimConfig) -> SimResult:
    """Two-level DLS over a virtual cluster (arXiv:1903.09510's scheme).

    Outer level: nodes claim super-chunks through the global window
    (``spec.technique`` over P=nodes, two RMWs at ``o_rma_global`` each,
    Lock-Polling fairness as in the flat sim).  Inner level: each node's
    PEs sub-schedule the live super-chunk through the node's shared-memory
    window (``inner_technique`` over the node's PEs, two RMWs at
    ``o_rma_local`` each, serialized *per node* so nodes overlap).  One PE
    per node refills at a time; node mates arriving mid-refill park until
    the super-chunk is published -- the DES analogue of the runtime's
    election protocol.
    """
    spec, N = cf.spec, cf.spec.N
    P, nodes = spec.P, cf.nodes
    rng = random.Random(cf.seed)
    pref = np.concatenate([[0.0], np.cumsum(cf.costs)])
    tele = _telemetry_for(cf, rng, inner=cf.inner_technique)

    # Topology + level specs come from the same helpers HierarchicalRuntime
    # uses, so the simulated schedule cannot drift from the real one.
    bounds, n_pes = cc.node_blocks(P, nodes)
    node_of = np.searchsorted(np.array(bounds[1:]), np.arange(P), side="right")
    outer = cc.hierarchical_outer_spec(spec, nodes)
    inner_specs = {}

    def inner_spec(node, size):
        key = (node, size)
        if key not in inner_specs:
            inner_specs[key] = cc.hierarchical_inner_spec(
                spec, cf.inner_technique, bounds, node, size)
        return inner_specs[key]

    # Global window state (outer level)
    glob_i = 0
    glob_lp = 0
    g_busy_until = 0.0
    g_waiters: List[tuple] = []  # (pe, phase, payload)

    # Per-node state (inner level)
    l_busy = [0.0] * nodes
    l_waiters: List[List[tuple]] = [[] for _ in range(nodes)]
    sc: List[Optional[dict]] = [None] * nodes  # live super-chunk per node
    refilling = [False] * nodes
    parked: List[List[int]] = [[] for _ in range(nodes)]
    node_done = [False] * nodes

    seq = itertools.count()
    evq: List[tuple] = []

    finish = np.zeros(P)
    iters = np.zeros(P, dtype=np.int64)
    claim_started = {}
    claim_latencies = []
    n_claims = 0
    n_rmw_global = 0
    n_rmw_local = 0
    done_pes = 0
    trace = [] if cf.collect_trace else None

    def push(t, kind, pe, payload=None):
        heapq.heappush(evq, (t, next(seq), kind, pe, payload))

    def g_grant(now):
        nonlocal g_busy_until, n_rmw_global
        if not g_waiters or g_busy_until > now + 1e-18:
            return
        idx = rng.randrange(len(g_waiters)) if cf.lock_polling_random else 0
        pe, phase, payload = g_waiters.pop(idx)
        g_busy_until = now + cf.o_rma_global
        n_rmw_global += 1
        push(now + cf.o_rma_global, f"g{phase}_done", pe, payload)
        push(now + cf.o_rma_global, "g_free", -1)

    def l_grant(node, now):
        nonlocal n_rmw_local
        if not l_waiters[node] or l_busy[node] > now + 1e-18:
            return
        idx = rng.randrange(len(l_waiters[node])) if cf.lock_polling_random else 0
        pe, phase, payload = l_waiters[node].pop(idx)
        l_busy[node] = now + cf.o_rma_local
        n_rmw_local += 1
        push(now + cf.o_rma_local, f"l{phase}_done", pe, payload)
        push(now + cf.o_rma_local, "l_free", -1, node)

    def pe_finish(pe, t):
        nonlocal done_pes
        finish[pe] = t
        claim_started.pop(pe, None)
        done_pes += 1

    def start_refill(pe, node, t):
        """This PE refills; node mates park until the super-chunk lands."""
        if node_done[node]:
            pe_finish(pe, t)
            return
        if refilling[node]:
            parked[node].append(pe)
            return
        if glob_lp >= N:  # fast path: drained, no RMWs burned
            drain_node(node, t)
            pe_finish(pe, t)
            return
        refilling[node] = True
        push(t + cf.o_issue / cf.speeds[pe], "want_g1", pe)

    def drain_node(node, t):
        node_done[node] = True
        refilling[node] = False
        for q in parked[node]:
            pe_finish(q, t)
        parked[node].clear()

    def want_local(pe, t):
        node = node_of[pe]
        if node_done[node]:
            pe_finish(pe, t)
            return
        if sc[node] is None:
            start_refill(pe, node, t)
            return
        claim_started.setdefault(pe, t)
        l_waiters[node].append((pe, 1, sc[node]))
        l_grant(node, t)

    for pe in range(P):
        push(cf.o_issue_local / cf.speeds[pe], "want_l1", pe)

    while evq and done_pes < P:
        t, _, kind, pe, payload = heapq.heappop(evq)
        node = node_of[pe] if pe >= 0 else -1
        if kind == "want_l1":
            want_local(pe, t)
        elif kind == "l1_done":
            s = payload  # the super-chunk this PE claimed against
            i_l = s["i"]
            s["i"] += 1
            if tele is None or cf.inner_technique not in cc.ADAPTIVE:
                k = cc.chunk_size_closed(
                    inner_spec(s["node"], s["size"]), i_l, pe - bounds[node])
            else:
                tele.deliver(t)
                k = cc.chunk_size_closed(
                    inner_spec(s["node"], s["size"]), i_l, pe - bounds[node],
                    weight=tele.weight(pe), af_stats=tele.af_stats(pe),
                    remaining=s["size"] - s["lp"])
            push(t + cf.t_calc / cf.speeds[pe], "want_l2", pe, (s, k))
        elif kind == "want_l2":
            l_waiters[node].append((pe, 2, payload))
            l_grant(node, t)
        elif kind == "l2_done":
            s, k = payload
            off = s["lp"]
            s["lp"] += k
            if off >= s["size"]:
                # epoch exhausted (or stale): first discoverer clears it
                if sc[node] is s:
                    sc[node] = None
                want_local(pe, t)
                continue
            lat = t - claim_started.pop(pe)
            claim_latencies.append(lat)
            n_claims += 1
            a = s["start"] + off
            b = s["start"] + min(off + k, s["size"])
            iters[pe] += b - a
            exec_t = (pref[b] - pref[a]) / cf.speeds[pe]
            if trace is not None:
                trace.append({"pe": pe, "step": n_claims - 1, "start": a,
                              "size": b - a, "t0": t, "t1": t + exec_t,
                              "lat": lat})
            if tele is not None:
                tele.observe(pe, b - a, exec_t, lat, t + exec_t)
            push(t + exec_t + cf.o_issue_local / cf.speeds[pe], "want_l1", pe)
        elif kind == "want_g1":
            claim_started.setdefault(pe, t)
            g_waiters.append((pe, 1, None))
            g_grant(t)
        elif kind == "g1_done":
            i_g = glob_i
            glob_i += 1
            # Weighted outer techniques consume telemetry aggregated to node
            # level (PerfModel.node_weights) -- an adaptive *outer* AF has
            # no node-level (mu, sigma), so it rides its FAC2 bootstrap.
            nw = None
            if tele is not None and spec.technique in cc.WEIGHTED:
                tele.deliver(t)
                nw = tele.node_weight(node, bounds)
            K = cc.chunk_size_closed(outer, i_g, node, weight=nw)
            push(t + cf.o_claim_net + cf.t_calc / cf.speeds[pe],
                 "want_g2", pe, K)
        elif kind == "want_g2":
            g_waiters.append((pe, 2, payload))
            g_grant(t)
        elif kind == "g2_done":
            K = payload
            start = glob_lp
            glob_lp += K
            t_got = t + cf.o_claim_net
            if start >= N:
                drain_node(node, t_got)
                pe_finish(pe, t_got)
                continue
            sc[node] = {"node": node, "start": start,
                        "size": min(K, N - start), "i": 0, "lp": 0}
            refilling[node] = False
            woken = [pe] + parked[node]
            parked[node].clear()
            for q in woken:
                push(t_got, "want_l1", q)
        elif kind == "g_free":
            g_grant(t)
        elif kind == "l_free":
            l_grant(payload, t)
        else:  # pragma: no cover
            raise AssertionError(kind)

    cov = float(np.std(finish) / np.mean(finish)) if np.mean(finish) > 0 else 0.0
    return SimResult(
        T_loop=float(finish.max()),
        finish=finish,
        n_claims=n_claims,
        cov=cov,
        per_pe_iters=iters,
        mean_claim_latency=float(np.mean(claim_latencies)) if claim_latencies else 0.0,
        n_rmw_global=n_rmw_global,
        n_rmw_local=n_rmw_local,
        chunk_trace=trace,
    )


# ---------------------------------------------------------------------------
# Two_Sided DES (master-worker)
# ---------------------------------------------------------------------------


def _simulate_two_sided(cf: SimConfig) -> SimResult:
    spec, N = cf.spec, cf.spec.N
    P = spec.P
    m = cf.coordinator
    s_m = cf.speeds[m]
    pref = np.concatenate([[0.0], np.cumsum(cf.costs)])
    # Adaptive techniques only: telemetry lives master-side (the master
    # already serializes claims), so measurements apply at the next serve
    # with noise but no extra visibility lag.
    tele = _telemetry_for(cf, random.Random(cf.seed), lag=0.0)

    # Master-side recurrence state (Table 2)
    R = N
    i_step = 0
    k_tss: Optional[int] = None
    batch_base: Optional[int] = None
    K0, Klast, S, C = cc.tss_constants(N, P, spec.min_chunk)

    def next_chunk(pe, now=0.0):
        nonlocal R, i_step, k_tss, batch_base
        if R <= 0:
            return None
        if tele is not None:
            tele.deliver(now)
        t_, Pn = spec.technique, spec.P
        if t_ == "static":
            k = int(math.ceil(N / Pn))
        elif t_ == "ss":
            k = spec.min_chunk
        elif t_ == "gss":
            k = max(int(math.ceil(R / Pn)), spec.min_chunk)
        elif t_ == "tss":
            k_tss = K0 if k_tss is None else max(k_tss - C, Klast)
            k = k_tss
        elif t_ in cc.FAC_FAMILY:
            # batch bookkeeping advances on every claim of the family, so a
            # telemetry-less bootstrap claim never reads a stale/None base
            if i_step % Pn == 0:
                batch_base = max(int(math.ceil(R / (2.0 * Pn))), spec.min_chunk)
            stats = tele.af_stats(pe) if t_ == "af" and tele is not None \
                else None
            if stats is not None:
                k = cc.af_chunk_size(stats, R, spec.min_chunk)
            else:  # includes AF's telemetry-less bootstrap
                k = batch_base
                if t_ in cc.WEIGHTED:
                    w = tele.weight(pe) if tele is not None else None
                    if w is None:
                        w = spec.weight(pe)
                    k = max(int(math.ceil(w * batch_base)), spec.min_chunk)
        elif t_ == "tfss":
            if i_step % Pn == 0:
                first = K0 - i_step * C
                mean = first - (Pn - 1) / 2.0 * C
                batch_base = max(int(math.ceil(mean)), Klast)
            k = batch_base
        else:
            raise AssertionError(t_)
        k = min(k, R)
        start = N - R
        R -= k
        i_step += 1
        return start, k

    seq = itertools.count()
    evq: List[tuple] = []

    def push(t, kind, pe, payload=None):
        heapq.heappush(evq, (t, next(seq), kind, pe, payload))

    pending: List[tuple] = []  # (rank, arrive_time) -- served smallest rank first
    finish = np.zeros(P)
    iters = np.zeros(P, dtype=np.int64)
    n_claims = 0
    serve_time = 0.0
    claim_started = {}
    claim_latencies = []
    trace = [] if cf.collect_trace else None

    # Master's own work: a claimed chunk it burns down in time slices of
    # ``master_quantum`` seconds, checking the queue in between (fine-grained
    # MPI_Iprobe polling).  The first own-claim is deferred by the master's
    # own issue cost, so at startup pending worker requests win.
    master_chunk: Optional[list] = None  # [remaining_seconds, iters]
    master_done_own = False
    master_busy = False
    workers_done = 0
    # The master self-claims without MPI, so its first own chunk is taken at
    # t=0, *before* any worker request can arrive -- with GSS this is what
    # puts K_0 on the master core (and makes a slow master catastrophic,
    # paper Fig. 4a).
    master_may_claim_at = 0.0

    def master_kick(now):
        """Master picks its next action.  Called whenever it may be free."""
        nonlocal master_busy, master_chunk, master_done_own, n_claims, serve_time
        if master_busy:
            return
        # 1) serve pending requests first (smallest rank, per Intel MPI)
        if pending:
            pending.sort()
            rank, t_arr = pending.pop(0)
            dt = cf.o_serve / s_m
            serve_time += dt
            master_busy = True
            res = next_chunk(rank, now)
            push(now + dt, "serve_done", rank, res)
            return
        # 2) own work: burn one time quantum
        if master_chunk is not None:
            dt = min(cf.master_quantum, master_chunk[0])
            master_chunk[0] -= dt
            master_busy = True
            push(now + dt, "master_slice_done", m, None)
            return
        if not master_done_own and now >= master_may_claim_at:
            res = next_chunk(m, now)
            if res is None:
                master_done_own = True
                finish[m] = max(finish[m], now)
            else:
                n_claims += 1
                start, k = res
                iters[m] += k
                exec_t = (pref[start + k] - pref[start]) / s_m
                # [remaining_s, iters, exec_s, start, step, t_claimed]
                master_chunk = [exec_t, k, exec_t, start, n_claims - 1, now]
                dt = cf.t_calc / s_m
                master_busy = True
                push(now + dt, "master_claimed", m, None)
            return
        if not master_done_own and now < master_may_claim_at:
            # poll again once the issue window has passed
            push(master_may_claim_at, "master_kick", m)
        # 3) idle: wake on next request arrival (event-driven; nothing to do)

    # workers request at t=0 (paying issue cost); master starts at t=0
    for pe in range(P):
        if pe == m:
            continue
        claim_started[pe] = 0.0
        push(cf.o_issue / cf.speeds[pe] + cf.o_req_net / 2, "request_arrive", pe)
    push(0.0, "master_kick", m)

    n_workers = P - 1
    while evq:
        t, _, kind, pe, payload = heapq.heappop(evq)
        if kind == "request_arrive":
            pending.append((pe, t))
            master_kick(t)
        elif kind == "serve_done":
            master_busy = False
            res = payload
            push(t + cf.o_req_net / 2, "reply_arrive", pe, res)
            master_kick(t)
        elif kind == "reply_arrive":
            lat = t - claim_started.pop(pe)
            claim_latencies.append(lat)
            if payload is None:
                finish[pe] = t
                workers_done += 1
                continue
            nonlocal_start, k = payload
            n_claims += 1
            stop = nonlocal_start + k
            iters[pe] += k
            exec_t = (pref[stop] - pref[nonlocal_start]) / cf.speeds[pe]
            if trace is not None:
                trace.append({"pe": pe, "step": n_claims - 1,
                              "start": nonlocal_start, "size": k, "t0": t,
                              "t1": t + exec_t, "lat": lat})
            if tele is not None:
                tele.observe(pe, k, exec_t, lat, t + exec_t)
            push(t + exec_t, "worker_done_chunk", pe)
        elif kind == "worker_done_chunk":
            claim_started[pe] = t
            push(t + cf.o_issue / cf.speeds[pe] + cf.o_req_net / 2, "request_arrive", pe)
        elif kind == "master_slice_done":
            master_busy = False
            if master_chunk[0] <= 1e-15:
                if trace is not None:
                    # t0 is claim time: master chunks interleave with serving,
                    # so t1 - t0 >= exec_s (the serve slices are inside).
                    trace.append({"pe": m, "step": master_chunk[4],
                                  "start": master_chunk[3],
                                  "size": master_chunk[1],
                                  "t0": master_chunk[5], "t1": t, "lat": 0.0})
                if tele is not None:
                    tele.observe(m, master_chunk[1], master_chunk[2], 0.0, t)
                master_chunk = None
                finish[m] = t
            master_kick(t)
        elif kind == "master_claimed":
            master_busy = False
            master_kick(t)
        elif kind == "master_kick":
            master_kick(t)
        else:  # pragma: no cover
            raise AssertionError(kind)

    cov = float(np.std(finish) / np.mean(finish)) if np.mean(finish) > 0 else 0.0
    return SimResult(
        T_loop=float(finish.max()),
        finish=finish,
        n_claims=n_claims,
        cov=cov,
        per_pe_iters=iters,
        master_serve_time=serve_time,
        mean_claim_latency=float(np.mean(claim_latencies)) if claim_latencies else 0.0,
        chunk_trace=trace,
    )


def simulate(cf: SimConfig) -> SimResult:
    if cf.impl == "one_sided":
        return _simulate_one_sided(cf)
    if cf.impl == "two_sided":
        return _simulate_two_sided(cf)
    if cf.impl == "hierarchical":
        return _simulate_hierarchical(cf)
    raise ValueError(f"unknown impl {cf.impl!r}")


# ---------------------------------------------------------------------------
# The paper's cluster + applications
# ---------------------------------------------------------------------------

#: Effective per-core speed of a KNL (Xeon Phi 7210, 1.3 GHz Silvermont-class)
#: core relative to a Xeon E5-2640 (2.4 GHz) core.  Clock ratio alone is 0.54,
#: but Phi cores retire far fewer instructions/cycle; calibrated against the
#: paper's One_Sided SS numbers (109 s @2:1 vs 68.5 s @1:2) and cross-checked
#: on TSS/GSS/FAC2 -- see EXPERIMENTS.md "DES calibration".
KNL_SPEED = 0.205
XEON_SPEED = 1.0

#: PSIA per-image mean cost at Xeon speed implied by the calibration
#: (T_SS = N * mu / sum(speeds) solved at the paper's 109 s / ratio 2:1).
PSIA_MEAN_COST = 0.05125


def paper_cluster(ratio: str, coordinator_on: str) -> tuple:
    """The paper's 288-core mixes.  Returns (speeds, coordinator_index).

    ratio: "2:1" (192 KNL + 96 Xeon) or "1:2" (96 KNL + 192 Xeon).
    coordinator_on: "knl" | "xeon" -- the two mapping scenarios of Sec. 4.
    Xeon nodes hold the low MPI ranks (rank order matters for the Two_Sided
    smallest-rank-first service; with Xeons first the big early GSS chunks
    land on fast cores, which is what the paper's Fig. 4 magnitudes imply).
    The coordinator/master is the first Xeon (rank 0) or the first KNL.
    """
    if ratio == "2:1":
        n_knl, n_xeon = 192, 96
    elif ratio == "1:2":
        n_knl, n_xeon = 96, 192
    else:
        raise ValueError(ratio)
    speeds = np.concatenate([np.full(n_xeon, XEON_SPEED), np.full(n_knl, KNL_SPEED)])
    coord = n_xeon if coordinator_on == "knl" else 0
    return speeds, coord


def mandelbrot_iteration_counts(width: int = 1152, ct: int = 1000,
                                xlim=(-2.0, 1.0), ylim=(-1.5, 1.5)) -> np.ndarray:
    """Escape-time iteration counts for the paper's Mandelbrot variant z<-z^4+c.

    Vectorized numpy oracle (also the reference for the Pallas kernel).
    Returns an (width*width,) int array of per-pixel inner-iteration counts --
    the per-iteration cost profile of paper Algorithm 2 (highly imbalanced:
    interior pixels burn the full ``ct``).
    """
    xs = np.linspace(xlim[0], xlim[1], width)
    ys = np.linspace(ylim[0], ylim[1], width)
    c = (xs[None, :] + 1j * ys[:, None]).astype(np.complex128)
    z = np.zeros_like(c)
    counts = np.zeros(c.shape, dtype=np.int64)
    active = np.ones(c.shape, dtype=bool)
    for _ in range(ct):
        z2 = z[active] ** 4 + c[active]
        z[active] = z2
        escaped = np.abs(z2) >= 2.0
        counts[active] += 1
        act_idx = np.where(active)
        active[act_idx[0][escaped], act_idx[1][escaped]] = False
        if not active.any():
            break
    return counts.reshape(-1)


def mandelbrot_costs(n_tasks: int, width: int = 1152, ct: int = 1000,
                     sec_per_inner_iter: float = 2.4e-4) -> np.ndarray:
    """Per-scheduled-iteration costs for Mandelbrot: rows of the image.

    The paper schedules the W^2-pixel loop; with avg cost > 0.2 s their unit
    of scheduling is a block of pixels.  We schedule ``n_tasks`` equal pixel
    blocks and sum the real per-pixel inner-iteration counts within a block.
    """
    counts = mandelbrot_iteration_counts(width, ct)
    blocks = np.array_split(counts, n_tasks)
    return np.array([b.sum() * sec_per_inner_iter for b in blocks])


def psia_costs(n: int = 288_000, mean: float = 0.075, cov: float = 0.30,
               seed: int = 42) -> np.ndarray:
    """PSIA spin-image per-image cost model (lognormal around the mean).

    Each outer iteration of paper Algorithm 1 scans all 800k object points
    with a support-angle branch; per-image cost therefore varies moderately
    around the mean.  ``mean`` is at Xeon speed; calibrated so One_Sided SS
    matches the paper (see EXPERIMENTS.md).
    """
    rng = np.random.default_rng(seed)
    sigma = np.sqrt(np.log(1 + cov**2))
    mu = np.log(mean) - sigma**2 / 2
    return rng.lognormal(mu, sigma, size=n)
