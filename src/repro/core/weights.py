"""PE weights for WF, online PE telemetry, and the adaptive technique family.

This module is the measurement plane of DESIGN.md Sec. 8:

* **WF** (paper Table 2): static relative weights ``Wp_j`` with
  ``sum_j Wp_j == P``, fixed before execution (the paper derives them from
  core speeds) -- ``weights_from_speeds``.
* **AWF** (Banicescu et al., the paper's cited future-work direction):
  weights *measured* during execution.  ``WeightBoard`` is the timestep-level
  EMA form used by the training plane (per-host step timings; dead hosts get
  weight 0 and their unclaimed work flows to survivors -- the one-sided
  protocol's natural elasticity).
* **PerfModel**: window-backed per-PE telemetry -- monotonic counters
  (chunks, iterations, compute/total microseconds, per-chunk mean spread)
  accumulated with the same ``fetch_add`` primitive the scheduling counters
  use, so one-sided, hierarchical, and multi-host sessions can *share* one
  telemetry plane through any ``Window`` backend.
* **AdaptiveWeightModel**: the AWF-B/C/D/E brains (Carino & Banicescu 2008)
  -- weighted-average performance over ``PerfModel`` snapshot deltas at
  batch/chunk boundaries, with or without scheduling overhead in the timing.
* **AdaptiveFactoringModel**: AF (Banicescu & Liu 2000) -- per-PE measured
  ``(mu, sigma)`` aggregated into the ``AFStats`` the closed form consumes.

The protocol adapters (``WeightPolicy`` wrappers) live in
``repro.dls.policies``; the DES (``core/sim.py``) drives these same models
with virtual-clock, noise-perturbed observations so simulated and real
adaptation can never use different math.  See DESIGN.md Sec. 8.
"""
from __future__ import annotations

import threading
from typing import Dict, List, NamedTuple, Optional, Sequence

import numpy as np

from .chunk_calculus import AFStats


def weights_from_speeds(speeds: Sequence[float]) -> np.ndarray:
    """Static WF weights from relative speeds: Wp_j = P * s_j / sum(s)."""
    s = np.asarray(speeds, dtype=np.float64)
    if np.any(s < 0):
        raise ValueError("speeds must be non-negative")
    total = s.sum()
    if total <= 0:
        raise ValueError("at least one PE must have positive speed")
    return len(s) * s / total


class WeightBoard:
    """Thread-safe live weights with exponential-moving-average throughput.

    ``record(pe, iters, seconds)`` after each chunk; ``weight(pe)`` returns the
    current normalized weight (sum == number of live PEs).  ``mark_dead``
    zeroes a PE (fault tolerance); ``revive`` restores it (elastic scale-up).
    """

    def __init__(self, P: int, ema: float = 0.5, initial_speeds: Optional[Sequence[float]] = None):
        self.P = P
        self.ema = ema
        self._lock = threading.Lock()
        init = np.asarray(initial_speeds, dtype=np.float64) if initial_speeds is not None else np.ones(P)
        self._rate = init.copy()  # EMA of iterations/second
        self._alive = np.ones(P, dtype=bool)

    def record(self, pe: int, iters: int, seconds: float) -> None:
        if seconds <= 0 or iters <= 0:
            return
        r = iters / seconds
        with self._lock:
            self._rate[pe] = self.ema * r + (1.0 - self.ema) * self._rate[pe]

    def mark_dead(self, pe: int) -> None:
        with self._lock:
            self._alive[pe] = False

    def revive(self, pe: int, rate: Optional[float] = None) -> None:
        with self._lock:
            self._alive[pe] = True
            if rate is not None:
                self._rate[pe] = rate

    def weights(self) -> np.ndarray:
        with self._lock:
            r = np.where(self._alive, self._rate, 0.0)
            total = r.sum()
            n_live = int(self._alive.sum())
            if total <= 0 or n_live == 0:
                return np.ones(self.P)
            return n_live * r / total

    def weight(self, pe: int) -> float:
        return float(self.weights()[pe])

    def alive(self) -> np.ndarray:
        with self._lock:
            return self._alive.copy()


def coefficient_of_variation(finish_times: Sequence[float]) -> float:
    """Load-imbalance metric: c.o.v. of per-PE finish times (lower = better)."""
    ft = np.asarray(finish_times, dtype=np.float64)
    m = ft.mean()
    return float(ft.std() / m) if m > 0 else 0.0


# ---------------------------------------------------------------------------
# Online PE telemetry (DESIGN.md Sec. 8): window-backed monotonic counters.
# ---------------------------------------------------------------------------

_US = 1_000_000  # fixed-point scale: microseconds
_NS = 1_000_000_000  # per-chunk mean channel: nanoseconds (sigma estimator)


class PerfSnapshot(NamedTuple):
    """Point-in-time copy of the telemetry counters (per-PE arrays)."""

    n: np.ndarray  # chunks recorded
    iters: np.ndarray  # iterations executed
    t_us: np.ndarray  # compute microseconds
    tt_us: np.ndarray  # compute + scheduling-overhead microseconds
    m_ns: np.ndarray  # sum of per-chunk mean iteration times [ns]
    m2_ns2: np.ndarray  # sum of squared per-chunk means [ns^2]


class PerfModel:
    """Per-PE measured performance from timestamped chunk completions.

    All state lives in a ``Window`` as monotonic integer counters under
    ``<prefix>/p<j>/...`` -- the exact ``fetch_add`` primitive the
    scheduling counters use -- so every runtime (one-sided, hierarchical,
    DES) and every backend (in-process, KV store) can share one telemetry
    plane; counters are never reset, so monotonic KV backends work.

    Per-counter atomicity only: a reader may see chunk ``c``'s iteration
    count before its time lands.  The consumers are statistical (rates,
    weighted averages), so the transient skew is harmless and the model
    stays lock-free across hosts.

    The sigma channel accumulates per-chunk *mean* iteration times (ns and
    ns^2): per-iteration timings are not observable at chunk granularity,
    so AF's sigma is estimated from the spread of chunk means -- see
    DESIGN.md Sec. 8.  (ns^2 sums assume sub-second chunk means on int64
    KV backends; in-process windows hold arbitrary-precision ints.)
    """

    def __init__(self, P: int, window=None, prefix: str = "perf"):
        from .rma import ThreadWindow

        self.P = P
        self.window = window if window is not None else ThreadWindow()
        self._keys = [
            tuple(f"{prefix}/p{j}/{c}"
                  for c in ("n", "iters", "t_us", "tt_us", "m_ns", "m2_ns2"))
            for j in range(P)
        ]
        # Flat key list in column-major (counter, pe) order: one
        # ``read_many`` batch per snapshot instead of 6*P read rounds.
        self._flat_keys = [self._keys[j][c] for c in range(6)
                           for j in range(P)]

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        """One completed chunk: ``iters`` iterations in ``seconds`` of
        compute, claimed with ``sched_seconds`` of scheduling overhead."""
        if iters <= 0 or seconds < 0:
            return
        kn, ki, kt, ktt, km, km2 = self._keys[pe]
        m_ns = int(round(seconds / iters * _NS))
        w = self.window
        w.fetch_add(kn, 1)
        w.fetch_add(ki, int(iters))
        w.fetch_add(kt, int(round(seconds * _US)))
        w.fetch_add(ktt, int(round((seconds + max(sched_seconds, 0.0)) * _US)))
        w.fetch_add(km, m_ns)
        w.fetch_add(km2, m_ns * m_ns)

    def snapshot(self) -> PerfSnapshot:
        # The squared-mean channel is float64: in-process windows hold
        # arbitrary-precision ints and second-scale iteration means push
        # ns^2 sums past int64 within a few chunks -- the sigma estimator
        # is statistical, so float rounding is harmless there.
        vals = self.window.read_many(self._flat_keys)
        P = self.P
        cols = [np.asarray(vals[c * P:(c + 1) * P],
                           dtype=np.int64 if c < 5 else np.float64)
                for c in range(6)]
        return PerfSnapshot(*cols)

    # -- derived quantities -------------------------------------------------
    def mu(self, snap: Optional[PerfSnapshot] = None,
           include_overhead: bool = False) -> np.ndarray:
        """Mean iteration time per PE [s]; NaN where nothing is measured."""
        s = snap or self.snapshot()
        t = s.tt_us if include_overhead else s.t_us
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(s.iters > 0, t / (_US * np.maximum(s.iters, 1)),
                            np.nan)

    def sigma2(self, snap: Optional[PerfSnapshot] = None) -> np.ndarray:
        """Variance of per-chunk mean iteration times [s^2] (AF's sigma
        estimator); 0.0 until a PE has at least two chunks."""
        s = snap or self.snapshot()
        n = np.maximum(s.n, 1)
        mean = s.m_ns / n
        var_ns2 = np.maximum(s.m2_ns2 / n - mean * mean, 0.0)
        return np.where(s.n >= 2, var_ns2 / (_NS * _NS), 0.0)

    def rates(self, snap: Optional[PerfSnapshot] = None) -> np.ndarray:
        """Measured iterations/second per PE; NaN where unmeasured."""
        mu = self.mu(snap)
        with np.errstate(divide="ignore", invalid="ignore"):
            return 1.0 / mu

    def node_weights(self, bounds: Sequence[int],
                     snap: Optional[PerfSnapshot] = None) -> Optional[np.ndarray]:
        """Aggregate per-PE measured rates into node weights (sum == nodes).

        The hierarchical runtime's outer (super-chunk) level claims with
        these instead of a priori ``LoopSpec`` weights -- the node-level
        reuse of the same telemetry.  None until any PE is measured;
        unmeasured PEs contribute the measured mean rate.
        """
        r = self.rates(snap)
        if np.isnan(r).all():
            return None
        r = np.where(np.isnan(r), np.nanmean(r), r)
        nodes = len(bounds) - 1
        agg = np.array([r[bounds[j]:bounds[j + 1]].sum() for j in range(nodes)])
        total = agg.sum()
        if total <= 0:
            return None
        return nodes * agg / total


class WapTracker:
    """Incremental weighted-average performance (the AWF weight recurrence).

    At update ordinal ``s`` (1-based) each PE contributes its interval
    performance ``pi_p,s`` (seconds/iteration); silent PEs carry their last
    ``pi`` forward.  The weighted average ``wap_p = sum_s s*pi_p,s / sum_s s``
    emphasizes recent intervals linearly (Carino & Banicescu 2008); weights
    are speed-normalized to sum to P, with never-measured PEs assigned the
    measured mean wap.
    """

    def __init__(self, P: int):
        self.P = P
        self._num = np.zeros(P)
        self._den = np.zeros(P)
        self._pi = np.full(P, np.nan)
        self._s = 0
        self.weights: Optional[np.ndarray] = None

    def add(self, pi_new: np.ndarray) -> Optional[np.ndarray]:
        """One update interval; returns the new weights (None if still blind)."""
        self._s += 1
        fresh = ~np.isnan(pi_new)
        self._pi[fresh] = np.maximum(pi_new[fresh], 1e-12)
        seen = ~np.isnan(self._pi)
        if not seen.any():
            self._s -= 1  # a fully-silent interval is not an update
            return None
        self._num[seen] += self._s * self._pi[seen]
        self._den[seen] += self._s
        wap = np.full(self.P, np.nan)
        wap[seen] = self._num[seen] / self._den[seen]
        if not seen.all():
            wap[~seen] = np.nanmean(wap)
        inv = 1.0 / wap
        self.weights = self.P * inv / inv.sum()
        return self.weights


class AdaptiveWeightModel:
    """AWF-B/C/D/E: live weights from PerfModel deltas at update boundaries.

    ``update="batch"`` recomputes after every P recorded chunks (one
    factoring batch: AWF-B/D); ``update="chunk"`` after every chunk
    (AWF-C/E).  ``include_overhead`` times chunks as compute + scheduling
    overhead (AWF-D/E) -- the variant axis of Carino & Banicescu 2008 as
    catalogued by arXiv:1804.11115.  See DESIGN.md Sec. 8.
    """

    def __init__(self, P: int, update: str = "batch",
                 include_overhead: bool = False, perf: Optional[PerfModel] = None,
                 window=None, trace_limit: int = 1024):
        if update not in ("batch", "chunk"):
            raise ValueError(f"update must be 'batch' or 'chunk', got {update!r}")
        self.P = P
        self.update = update
        self.include_overhead = include_overhead
        self.perf = perf if perf is not None else PerfModel(P, window=window)
        self._tracker = WapTracker(P)
        self._last = self.perf.snapshot()
        self._since = 0
        self._lock = threading.Lock()
        self.trace: List[dict] = []
        self.trace_limit = trace_limit
        self.n_updates = 0

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        self.perf.record(pe, iters, seconds, sched_seconds)
        with self._lock:
            self._since += 1
            if self.update == "chunk" or self._since >= self.P:
                self._flush_locked()

    def advance(self) -> None:
        """Force an update boundary (timestep-style callers)."""
        with self._lock:
            self._flush_locked()

    def _flush_locked(self) -> None:
        snap = self.perf.snapshot()
        d_iters = snap.iters - self._last.iters
        t_now = snap.tt_us if self.include_overhead else snap.t_us
        t_then = self._last.tt_us if self.include_overhead else self._last.t_us
        d_t = (t_now - t_then) / _US
        with np.errstate(divide="ignore", invalid="ignore"):
            pi = np.where(d_iters > 0, d_t / np.maximum(d_iters, 1), np.nan)
        w = self._tracker.add(pi)
        self._last = snap
        self._since = 0
        if w is not None:
            self.n_updates += 1
            if len(self.trace) < self.trace_limit:
                self.trace.append(
                    {"update": self.n_updates, "weights": w.tolist()})

    # -- WeightPolicy surface ----------------------------------------------
    def weight(self, pe: int) -> Optional[float]:
        w = self._tracker.weights
        return None if w is None else float(w[pe])

    def node_weight(self, node: int, bounds: Sequence[int]) -> Optional[float]:
        nw = self.perf.node_weights(bounds)
        return None if nw is None else float(nw[node])


class AdaptiveFactoringModel:
    """AF (Banicescu & Liu 2000): measured (mu, sigma) -> ``AFStats``.

    ``af_stats(pe)`` returns None until PE ``pe`` has completed a chunk
    (the closed form then bootstraps through FAC2); other still-unmeasured
    PEs contribute the measured mean ``mu`` / ``sigma2`` so the cluster
    aggregates D and T are always well-defined.  See DESIGN.md Sec. 8.
    """

    def __init__(self, P: int, perf: Optional[PerfModel] = None, window=None,
                 trace_limit: int = 1024):
        self.P = P
        self.perf = perf if perf is not None else PerfModel(P, window=window)
        self.trace: List[dict] = []
        self.trace_limit = trace_limit
        self.n_updates = 0
        self._lock = threading.Lock()

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        self.perf.record(pe, iters, seconds, sched_seconds)
        with self._lock:
            self.n_updates += 1
            if len(self.trace) < self.trace_limit:
                self.trace.append(
                    {"update": self.n_updates, "pe": pe,
                     "mu": seconds / max(iters, 1)})

    def af_stats(self, pe: int) -> Optional[AFStats]:
        snap = self.perf.snapshot()
        if snap.iters[pe] <= 0:
            return None
        mu = self.perf.mu(snap)
        s2 = self.perf.sigma2(snap)
        measured = ~np.isnan(mu)
        fill_mu = np.nanmean(mu)
        mu = np.maximum(np.where(measured, mu, fill_mu), 1e-12)
        s2 = np.where(measured, s2, float(s2[measured].mean()))
        D = float(np.sum(s2 / mu))
        T = 1.0 / float(np.sum(1.0 / mu))
        return AFStats(mu=float(mu[pe]), D=D, T=T)

    # -- WeightPolicy surface ----------------------------------------------
    def weight(self, pe: int) -> Optional[float]:
        return None  # AF feeds the closed form through af_stats, not weight

    def node_weight(self, node: int, bounds: Sequence[int]) -> Optional[float]:
        nw = self.perf.node_weights(bounds)
        return None if nw is None else float(nw[node])
