"""PE weights for WF, and adaptive reweighting (AWF) for straggler mitigation.

WF (paper Table 2): static relative weights ``Wp_j`` with ``sum_j Wp_j == P``,
fixed before execution (the paper derives them from core speeds).

AWF (Banicescu et al., the paper's cited future-work direction): weights are
*measured* during execution -- each PE's observed throughput (iterations per
second over its completed chunks) updates its weight.  In this framework AWF
is the straggler-mitigation mechanism of the training plane: per-host step
timings feed a ``WeightBoard`` and the DLS sampler hands slow hosts smaller
chunks (and dead hosts, weight 0 -- their unclaimed work is simply claimed by
survivors, which is what makes the one-sided protocol naturally elastic).
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

import numpy as np


def weights_from_speeds(speeds: Sequence[float]) -> np.ndarray:
    """Static WF weights from relative speeds: Wp_j = P * s_j / sum(s)."""
    s = np.asarray(speeds, dtype=np.float64)
    if np.any(s < 0):
        raise ValueError("speeds must be non-negative")
    total = s.sum()
    if total <= 0:
        raise ValueError("at least one PE must have positive speed")
    return len(s) * s / total


class WeightBoard:
    """Thread-safe live weights with exponential-moving-average throughput.

    ``record(pe, iters, seconds)`` after each chunk; ``weight(pe)`` returns the
    current normalized weight (sum == number of live PEs).  ``mark_dead``
    zeroes a PE (fault tolerance); ``revive`` restores it (elastic scale-up).
    """

    def __init__(self, P: int, ema: float = 0.5, initial_speeds: Optional[Sequence[float]] = None):
        self.P = P
        self.ema = ema
        self._lock = threading.Lock()
        init = np.asarray(initial_speeds, dtype=np.float64) if initial_speeds is not None else np.ones(P)
        self._rate = init.copy()  # EMA of iterations/second
        self._alive = np.ones(P, dtype=bool)

    def record(self, pe: int, iters: int, seconds: float) -> None:
        if seconds <= 0 or iters <= 0:
            return
        r = iters / seconds
        with self._lock:
            self._rate[pe] = self.ema * r + (1.0 - self.ema) * self._rate[pe]

    def mark_dead(self, pe: int) -> None:
        with self._lock:
            self._alive[pe] = False

    def revive(self, pe: int, rate: Optional[float] = None) -> None:
        with self._lock:
            self._alive[pe] = True
            if rate is not None:
                self._rate[pe] = rate

    def weights(self) -> np.ndarray:
        with self._lock:
            r = np.where(self._alive, self._rate, 0.0)
            total = r.sum()
            n_live = int(self._alive.sum())
            if total <= 0 or n_live == 0:
                return np.ones(self.P)
            return n_live * r / total

    def weight(self, pe: int) -> float:
        return float(self.weights()[pe])

    def alive(self) -> np.ndarray:
        with self._lock:
            return self._alive.copy()


def coefficient_of_variation(finish_times: Sequence[float]) -> float:
    """Load-imbalance metric: c.o.v. of per-PE finish times (lower = better)."""
    ft = np.asarray(finish_times, dtype=np.float64)
    m = ft.mean()
    return float(ft.std() / m) if m > 0 else 0.0
