"""Self-scheduling runtimes: One_Sided (the paper) vs Two_Sided (baseline).

``OneSidedRuntime`` is the paper's distributed chunk-calculation protocol:

  Step 1: the PE atomically fetch-adds the step counter  ``i += 1``
  Step 2: the PE computes ``K_i`` locally from its private copy of ``i``
          (closed form -- no shared state needed)
  Step 3: the PE atomically fetch-adds the loop pointer ``lp += K_i``
  ...and executes iterations [lp, min(lp + K_i, N)).

``TwoSidedRuntime`` is the classical master-worker baseline the paper
compares against: a (non-dedicated) master owns the Table-2 recurrence and
serves claims one at a time from a request queue.

``HierarchicalRuntime`` is the follow-up work's two-level scheme
(arXiv:1903.09510): nodes claim *super-chunks* through the global window
with an outer technique's closed form, and PEs within a node sub-schedule
the super-chunk through a cheap node-local window with an inner technique
-- slashing the number of claims that pay the global serialization point.

Both implement the ``repro.dls`` Runtime contract -- ``claim(pe, weight=)``,
``remaining_lower_bound()``, ``drained()``, ``state()``/``restore()`` -- so
the ``DLSession`` facade can drive either interchangeably (see DESIGN.md).
Construct them through ``repro.dls.loop(...)``; the ``run_threaded_*``
shims that once lived here (deprecated since PR 1) were removed in ISSUE 5
-- use ``dls.loop(...).execute(work_fn, executor="threads")``.

Both run over real threads (in-process "PEs") or over hosts (KVStoreWindow);
the clocked versions of all three protocols live in the ``repro.sim``
event kernel for the paper's heterogeneous-cluster experiments.
"""
from __future__ import annotations

import bisect
import itertools
import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

from . import chunk_calculus as cc
from .rma import HierarchicalWindow, ThreadWindow, Window

_loop_ids = itertools.count()


@dataclass
class Claim:
    step: int  # scheduling step index i
    start: int  # first iteration (lp_start before accumulate)
    size: int  # K_i, already truncated to [0, N)

    @property
    def stop(self) -> int:
        return self.start + self.size


class OneSidedRuntime:
    """Distributed chunk calculation via two atomic fetch-and-adds."""

    def __init__(self, spec: cc.LoopSpec, window: Optional[Window] = None,
                 loop_id: Optional[int] = None):
        self.spec = spec
        self.window = window if window is not None else ThreadWindow()
        # Namespace the two counters per loop so monotonic KV backends work.
        lid = next(_loop_ids) if loop_id is None else loop_id
        self.loop_id = lid  # published: a child process rebuilding this
        # runtime against the same (shared) window must reuse the namespace
        self._ki = f"loop{lid}/i"
        self._kl = f"loop{lid}/lp"

    def claim(self, pe: int = 0, weight: Optional[float] = None,
              af: Optional[cc.AFStats] = None) -> Optional[Claim]:
        """One scheduling step for PE ``pe``; None when the loop is exhausted.

        ``weight`` overrides the spec's static weight for this claim (the
        AWF family, whose weights evolve during execution).  ``af`` carries
        Adaptive Factoring's measured ``AFStats``; its remaining-iterations
        term reuses the loop-pointer read the drain fast path already pays
        (a slightly stale R -- the honest distributed estimate; Step 3
        still truncates exactly, so conservation is unaffected).
        """
        N = self.spec.N
        # Fast-path exit: if the loop pointer is already past N, don't burn
        # a step index.  (A stale read here is harmless -- Step 3 re-checks.)
        lp = self.window.read(self._kl)
        if lp >= N:
            return None
        i = self.window.fetch_add(self._ki, 1)  # Step 1
        k = cc.chunk_size_closed(self.spec, i, pe, weight=weight,
                                 af_stats=af, remaining=N - lp)  # Step 2 (local)
        start = self.window.fetch_add(self._kl, k)  # Step 3
        if start >= N:
            return None
        return Claim(step=i, start=start, size=min(k, N - start))

    def remaining_lower_bound(self) -> int:
        return max(self.spec.N - self.window.read(self._kl), 0)

    def drained(self) -> bool:
        """True once the loop pointer has passed N: no PE can claim work."""
        return self.remaining_lower_bound() == 0

    # -- checkpointable window counters (i, lp_start) ----------------------
    def state(self) -> Dict[str, int]:
        return {"i": self.window.read(self._ki), "lp": self.window.read(self._kl)}

    def restore(self, st: Dict[str, int]) -> None:
        self.window.reset(self._ki, st["i"])
        self.window.reset(self._kl, st["lp"])


# Internal sentinel: "this epoch is exhausted, advance to the next one".
_RETRY = object()


class HierarchicalRuntime:
    """Two-level self-scheduling: node super-chunks + local sub-scheduling.

    The follow-up paper's MPI+MPI scheme (arXiv:1903.09510) on top of the
    closed forms: ``spec.technique`` is the *outer* technique, applied over
    ``nodes`` virtual PEs to claim node-level super-chunks through the
    global window (two expensive inter-node RMWs per super-chunk); the
    *inner* technique then partitions each super-chunk among the node's PEs
    through the node-local window (cheap shared-memory RMWs).  With e.g.
    GSS over nodes + SS within nodes, the number of claims paying the
    global serialization point drops from O(N/min_chunk) to the outer
    technique's step count over ``nodes`` -- the claim-count reduction the
    follow-up measures.

    The protocol stays masterless at both levels.  Node-local state is a
    sequence of *epochs*, one per super-chunk, each with its own counter
    namespace ``n<node>/e<epoch>/{token,start,size,ready,i,lp,adv}``:

      * a PE finding the current epoch unready elects itself refiller with
        one local fetch-add on ``token`` (old value 0 wins); the winner
        claims a super-chunk from the global window (outer closed form) and
        publishes ``start``/``size`` then ``ready``; losers spin on
        ``ready`` (shared-memory read, no global traffic).
      * local claims are the paper's two fetch-adds against the epoch's
        ``i``/``lp`` with the inner closed form over ``N=size``,
        ``P=pes-in-node``.
      * a PE that overruns the epoch (``lp >= size``) bumps the node's
        ``seq`` hint (once, elected via ``adv``) and retries on the next
        epoch.  Because exhausted epochs keep their counters, late claims
        against them fail harmlessly -- no resets, so monotonic windows work.
      * a refill that finds the global pool drained publishes a ``size=0``
        sentinel epoch: every PE of the node then sees ``None``.

    Work never migrates across nodes (no stealing); the outer technique's
    decaying super-chunks bound the end-of-loop imbalance, exactly as in
    the follow-up paper.
    """

    def __init__(self, spec: cc.LoopSpec, nodes: int,
                 window: Optional[Window] = None,
                 inner_technique: str = "ss",
                 loop_id: Optional[int] = None):
        if not 1 <= nodes <= spec.P:
            raise ValueError(f"nodes must be in [1, P={spec.P}], got {nodes}")
        if inner_technique not in cc.TECHNIQUES:
            raise ValueError(f"unknown inner technique {inner_technique!r}")
        self.spec = spec
        self.nodes = nodes
        self.inner_technique = inner_technique
        if window is None:
            window = HierarchicalWindow(nodes)
        elif not isinstance(window, HierarchicalWindow):
            # a plain Window becomes the global level; locals stay in-process
            window = HierarchicalWindow(nodes, global_window=window)
        if window.nodes != nodes:
            raise ValueError(
                f"window has {window.nodes} node levels, runtime wants {nodes}")
        self.window = window
        lid = next(_loop_ids) if loop_id is None else loop_id
        self.loop_id = lid  # published for cross-process runtime rebuilds
        self._pfx = f"loop{lid}"
        self._gi = f"{self._pfx}/i"
        self._gl = f"{self._pfx}/lp"
        self._nseq = [f"{self._pfx}/n{n}/seq" for n in range(nodes)]
        self._ekeys: Dict[tuple, tuple] = {}  # (node, epoch) -> key tuple
        # Topology + level specs (shared with the DES via chunk_calculus so
        # simulated schedules can never drift from the real runtime's).
        self._bounds, self._n_pes = cc.node_blocks(spec.P, nodes)
        self._outer_spec = cc.hierarchical_outer_spec(spec, nodes)
        self._inner_specs: Dict[tuple, cc.LoopSpec] = {}
        # Optional live node-weight source for weighted *outer* techniques:
        # ``node -> weight`` (None = use the outer spec's aggregated static
        # weights).  The session facade points this at the weight policy's
        # telemetry aggregation (PerfModel.node_weights) so super-chunk
        # claims track measured node speed -- DESIGN.md Sec. 8.
        self.outer_weight_fn: Optional[Callable[[int], Optional[float]]] = None

    # -- PE -> node mapping -------------------------------------------------
    def node_of(self, pe: int) -> int:
        return min(max(bisect.bisect_right(self._bounds, pe) - 1, 0),
                   self.nodes - 1)

    def _local_rank(self, pe: int, node: int) -> int:
        return min(max(pe - self._bounds[node], 0), self._n_pes[node] - 1)

    def _inner_spec(self, node: int, size: int) -> cc.LoopSpec:
        key = (node, size)
        spec = self._inner_specs.get(key)
        if spec is None:
            spec = cc.hierarchical_inner_spec(
                self.spec, self.inner_technique, self._bounds, node, size)
            self._inner_specs[key] = spec
        return spec

    # Epoch counter-key tuple indices (see _epoch_keys).
    _TOKEN, _START, _SIZE, _READY, _I, _LP, _ADV = range(7)

    def _epoch_keys(self, node: int, e: int) -> tuple:
        """Cached counter keys for (node, epoch) -- claim() is a hot path."""
        keys = self._ekeys.get((node, e))
        if keys is None:
            ep = f"{self._pfx}/n{node}/e{e}"
            keys = (f"{ep}/token", f"{ep}/start", f"{ep}/size", f"{ep}/ready",
                    f"{ep}/i", f"{ep}/lp", f"{ep}/adv")
            self._ekeys[(node, e)] = keys
        return keys

    # -- claiming -----------------------------------------------------------
    def claim(self, pe: int = 0, weight: Optional[float] = None,
              af: Optional[cc.AFStats] = None) -> Optional[Claim]:
        """One scheduling step for PE ``pe``; None once drained for its node.

        ``weight``/``af`` act at the *inner* (within-node) level; weighted
        outer techniques take live node weights from ``outer_weight_fn``.
        """
        node = self.node_of(pe)
        local = self.window.local(node)
        e = local.read(self._nseq[node])
        while True:
            got = self._claim_in_epoch(pe, node, local, e, weight, af)
            if got is not _RETRY:
                return got
            e += 1

    def _claim_in_epoch(self, pe, node, local, e, weight, af=None):
        k_ = self._epoch_keys(node, e)
        if local.read(k_[self._READY]) == 0:
            if local.fetch_add(k_[self._TOKEN], 1) == 0:
                # elected refiller: one global super-chunk claim
                start, size = self._claim_super_chunk(node)
                if start:
                    local.fetch_add(k_[self._START], start)
                local.fetch_add(k_[self._SIZE], size)
                local.fetch_add(k_[self._READY], 1)
            else:
                while local.read(k_[self._READY]) == 0:
                    time.sleep(0)  # another PE is refilling; local spin
        size = local.read(k_[self._SIZE])
        if size == 0:
            return None  # sentinel epoch: global pool drained, node done
        start = local.read(k_[self._START])
        lp_seen = local.read(k_[self._LP])  # AF's remaining-in-epoch estimate
        i_l = local.fetch_add(k_[self._I], 1)
        k = cc.chunk_size_closed(self._inner_spec(node, size), i_l,
                                 self._local_rank(pe, node), weight=weight,
                                 af_stats=af, remaining=size - lp_seen)
        off = local.fetch_add(k_[self._LP], k)
        if off < size:
            return Claim(step=i_l, start=start + off, size=min(k, size - off))
        # epoch exhausted: exactly one PE advances the seq hint
        if local.fetch_add(k_[self._ADV], 1) == 0:
            local.fetch_add(self._nseq[node], 1)
        return _RETRY

    def _claim_super_chunk(self, node: int) -> tuple:
        """Outer-level claim through the global window: (start, size).

        (0, 0) means the global pool is drained.  Exactly the paper's
        two-fetch-add protocol, with nodes as the PEs.
        """
        G, N = self.window, self.spec.N
        if G.read(self._gl) >= N:  # fast path: no step burn once drained
            return 0, 0
        i_g = G.fetch_add(self._gi, 1)
        w = self.outer_weight_fn(node) if self.outer_weight_fn is not None \
            else None
        K = cc.chunk_size_closed(self._outer_spec, i_g, node, weight=w)
        start = G.fetch_add(self._gl, K)
        if start >= N:
            return 0, 0
        return start, min(K, N - start)

    # -- drain contract -----------------------------------------------------
    def remaining_lower_bound(self) -> int:
        rem = max(self.spec.N - self.window.read(self._gl), 0)
        for node in range(self.nodes):
            local = self.window.local(node)
            k_ = self._epoch_keys(node, local.read(self._nseq[node]))
            if local.read(k_[self._READY]):
                size = local.read(k_[self._SIZE])
                rem += max(size - local.read(k_[self._LP]), 0)
            elif local.read(k_[self._TOKEN]):
                # refill in flight: the pool may still grow this node's way,
                # so the drain question is not decided yet
                rem += 1
        return rem

    def drained(self) -> bool:
        return self.remaining_lower_bound() == 0

    # -- checkpointable state ------------------------------------------------
    def state(self) -> Dict:
        """Global counters + per-node in-flight super-chunk remainders."""
        st: Dict = {"i": self.window.read(self._gi),
                    "lp": self.window.read(self._gl), "sc": []}
        for node in range(self.nodes):
            local = self.window.local(node)
            k_ = self._epoch_keys(node, local.read(self._nseq[node]))
            entry = None
            if local.read(k_[self._READY]):
                size = local.read(k_[self._SIZE])
                done = min(local.read(k_[self._LP]), size)
                if done < size:
                    entry = [local.read(k_[self._START]) + done, size - done]
            st["sc"].append(entry)
        return st

    def restore(self, st: Dict) -> None:
        """Rebuild from a checkpoint (quiescent windows, reset-capable).

        In-flight super-chunk remainders reopen as fresh epochs with the
        inner schedule restarted over the remainder (``N=size-done``) --
        the partition property is exact; only the remainder's chunk-size
        series may differ from an uninterrupted run (same caveat as the
        two-sided mid-batch restore).
        """
        self.window.reset(self._gi, st["i"])
        self.window.reset(self._gl, st["lp"])
        for node, entry in enumerate(st.get("sc", [None] * self.nodes)):
            local = self.window.local(node)
            e = local.read(self._nseq[node]) + 1  # a never-used epoch
            k_ = self._epoch_keys(node, e)
            if entry is not None:
                start, size = entry
                local.reset(k_[self._START], start)
                local.reset(k_[self._SIZE], size)
                local.reset(k_[self._I], 0)
                local.reset(k_[self._LP], 0)
                local.reset(k_[self._READY], 1)
            # entry None: leave the epoch unready -> next claimer refills
            local.reset(self._nseq[node], e)


class TwoSidedRuntime:
    """Master-worker baseline: a master thread serves the Table-2 recurrence.

    Workers put (pe, reply_queue) requests on a queue; the master pops one at
    a time, advances the recurrence state (R, K_prev), and replies.  The
    master is *non-dedicated*: it can also execute loop chunks (the paper's
    setup) -- see ``repro.dls.executors``.  ``claim`` is the synchronous
    master-inline form of the same recurrence (the Runtime contract); the
    queue path (``request``/``serve_*``) is the threaded protocol.
    """

    _SHUTDOWN = object()

    def __init__(self, spec: cc.LoopSpec):
        self.spec = spec
        self._req: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._R = spec.N
        self._i = 0
        self._k_tss: Optional[int] = None
        self._batch_base: Optional[int] = None
        self._K0, self._Klast, self._S, self._C = cc.tss_constants(
            spec.N, spec.P, spec.min_chunk
        )

    # -- master-side recurrence (one claim), mirrors chunk_series_recurrence --
    def claim(self, pe: int = 0, weight: Optional[float] = None,
              af: Optional[cc.AFStats] = None) -> Optional[Claim]:
        import math

        spec = self.spec
        t, P = spec.technique, spec.P
        with self._lock:
            if self._R <= 0:
                return None
            R, i = self._R, self._i
            if t == "static":
                k = int(math.ceil(spec.N / P))
            elif t == "ss":
                k = spec.min_chunk
            elif t == "gss":
                k = max(int(math.ceil(R / P)), spec.min_chunk)
            elif t == "tss":
                self._k_tss = (
                    self._K0 if self._k_tss is None else max(self._k_tss - self._C, self._Klast)
                )
                k = self._k_tss
            elif t in cc.FAC_FAMILY:
                # batch bookkeeping advances on *every* claim of the family
                # (an AF claim that lands on a batch boundary must still
                # refresh the base, or a telemetry-less PE's next bootstrap
                # claim would read a stale/None base)
                if i % P == 0:
                    self._batch_base = max(int(math.ceil(R / (2.0 * P))), spec.min_chunk)
                if t == "af" and af is not None:
                    # the master holds the exact remainder; AF's closed form
                    # consumes it directly (no stale-read estimate needed)
                    k = cc.af_chunk_size(af, R, spec.min_chunk)
                else:  # includes AF's telemetry-less bootstrap
                    k = self._batch_base
                    if t in cc.WEIGHTED:
                        w = spec.weight(pe) if weight is None else weight
                        k = max(int(math.ceil(w * self._batch_base)), spec.min_chunk)
            elif t == "tfss":
                if i % P == 0:
                    first = self._K0 - i * self._C
                    mean = first - (P - 1) / 2.0 * self._C
                    self._batch_base = max(int(math.ceil(mean)), self._Klast)
                k = self._batch_base
            else:
                raise AssertionError(t)
            if spec.max_chunk:
                k = min(k, spec.max_chunk)
            k = min(k, R)
            start = spec.N - self._R
            self._R -= k
            self._i += 1
            return Claim(step=i, start=start, size=k)

    # Backwards-compatible private alias (older call sites / tests).
    _next_chunk = claim

    def remaining_lower_bound(self) -> int:
        with self._lock:
            return max(self._R, 0)

    def drained(self) -> bool:
        return self.remaining_lower_bound() == 0

    def state(self) -> Dict[str, int]:
        with self._lock:
            return {"i": self._i, "lp": self.spec.N - self._R}

    def restore(self, st: Dict[str, int]) -> None:
        import math

        spec = self.spec
        with self._lock:
            self._i = i = st["i"]
            self._R = spec.N - st["lp"]
            # Re-derive the recurrence state: the master's (_k_tss,
            # _batch_base) are history-dependent, so a restored runtime must
            # rebuild them or the next claim crashes / continues a stale
            # ramp.  TSS/TFSS are exact (index-only); FAC2/WF/AWF mid-batch
            # use the *current* remainder (the batch-start remainder is not
            # recoverable from (i, lp) alone) -- the partition property is
            # unaffected, only the in-flight batch's size may differ from an
            # uninterrupted run.
            self._k_tss = (
                None if i == 0 else max(self._K0 - (i - 1) * self._C, self._Klast))
            if i % spec.P == 0:
                self._batch_base = None  # recomputed at the next batch start
            elif spec.technique == "tfss":
                first = self._K0 - (i - i % spec.P) * self._C
                mean = first - (spec.P - 1) / 2.0 * self._C
                self._batch_base = max(int(math.ceil(mean)), self._Klast)
            else:
                self._batch_base = max(
                    int(math.ceil(max(self._R, 0) / (2.0 * spec.P))), spec.min_chunk)

    # -- two-sided protocol --
    def request(self, pe: int, weight: Optional[float] = None,
                af: Optional[cc.AFStats] = None) -> "queue.Queue":
        reply: "queue.Queue" = queue.Queue(maxsize=1)
        self._req.put((pe, weight, af, reply))
        return reply

    def serve_pending(self, limit: Optional[int] = None) -> int:
        """Master serves up to ``limit`` queued requests; returns count served."""
        served = 0
        while limit is None or served < limit:
            try:
                item = self._req.get_nowait()
            except queue.Empty:
                break
            if item is self._SHUTDOWN:
                break
            pe, weight, af, reply = item
            reply.put(self.claim(pe, weight=weight, af=af))
            served += 1
        return served

    def serve_blocking(self, timeout: float = 0.05) -> bool:
        """Serve one request, blocking up to ``timeout``.  False on idle."""
        try:
            item = self._req.get(timeout=timeout)
        except queue.Empty:
            return False
        if item is self._SHUTDOWN:
            return False
        pe, weight, af, reply = item
        reply.put(self.claim(pe, weight=weight, af=af))
        return True


