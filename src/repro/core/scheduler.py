"""Self-scheduling runtimes: One_Sided (the paper) vs Two_Sided (baseline).

``OneSidedRuntime`` is the paper's distributed chunk-calculation protocol:

  Step 1: the PE atomically fetch-adds the step counter  ``i += 1``
  Step 2: the PE computes ``K_i`` locally from its private copy of ``i``
          (closed form -- no shared state needed)
  Step 3: the PE atomically fetch-adds the loop pointer ``lp += K_i``
  ...and executes iterations [lp, min(lp + K_i, N)).

``TwoSidedRuntime`` is the classical master-worker baseline the paper
compares against: a (non-dedicated) master owns the Table-2 recurrence and
serves claims one at a time from a request queue.

Both run over real threads (in-process "PEs") or over hosts (KVStoreWindow);
the discrete-event simulator in ``sim.py`` has its own clocked versions of
both protocols for the paper's heterogeneous-cluster experiments.
"""
from __future__ import annotations

import itertools
import queue
import threading
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from . import chunk_calculus as cc
from .rma import ThreadWindow, Window

_loop_ids = itertools.count()


@dataclass
class Claim:
    step: int  # scheduling step index i
    start: int  # first iteration (lp_start before accumulate)
    size: int  # K_i, already truncated to [0, N)

    @property
    def stop(self) -> int:
        return self.start + self.size


class OneSidedRuntime:
    """Distributed chunk calculation via two atomic fetch-and-adds."""

    def __init__(self, spec: cc.LoopSpec, window: Optional[Window] = None,
                 loop_id: Optional[int] = None):
        self.spec = spec
        self.window = window if window is not None else ThreadWindow()
        # Namespace the two counters per loop so monotonic KV backends work.
        lid = next(_loop_ids) if loop_id is None else loop_id
        self._ki = f"loop{lid}/i"
        self._kl = f"loop{lid}/lp"

    def claim(self, pe: int = 0, weight: Optional[float] = None) -> Optional[Claim]:
        """One scheduling step for PE ``pe``; None when the loop is exhausted.

        ``weight`` overrides the spec's static weight for this claim (used by
        AWF, whose weights evolve during execution).
        """
        N = self.spec.N
        # Fast-path exit: if the loop pointer is already past N, don't burn
        # a step index.  (A stale read here is harmless -- Step 3 re-checks.)
        if self.window.read(self._kl) >= N:
            return None
        i = self.window.fetch_add(self._ki, 1)  # Step 1
        if weight is not None and self.spec.technique in cc.WEIGHTED:
            # AWF: live weight overrides the spec's static one.  The closed
            # form is the WF/FAC2 expression scaled by the claimer's weight.
            import math

            spec = self.spec
            b = i // spec.P + 1
            base = 0.5 ** b * spec.N / spec.P
            k = max(int(math.ceil(weight * base)), spec.min_chunk)
        else:
            k = cc.chunk_size_closed(self.spec, i, pe)  # Step 2 (local)
        start = self.window.fetch_add(self._kl, k)  # Step 3
        if start >= N:
            return None
        return Claim(step=i, start=start, size=min(k, N - start))

    def remaining_lower_bound(self) -> int:
        return max(self.spec.N - self.window.read(self._kl), 0)


class TwoSidedRuntime:
    """Master-worker baseline: a master thread serves the Table-2 recurrence.

    Workers put (pe, reply_queue) requests on a queue; the master pops one at
    a time, advances the recurrence state (R, K_prev), and replies.  The
    master is *non-dedicated*: ``master_work`` lets the owning thread also
    execute loop chunks (the paper's setup) -- see ``run_threaded``.
    """

    _SHUTDOWN = object()

    def __init__(self, spec: cc.LoopSpec):
        self.spec = spec
        self._req: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._R = spec.N
        self._i = 0
        self._k_tss: Optional[int] = None
        self._batch_base: Optional[int] = None
        self._K0, self._Klast, self._S, self._C = cc.tss_constants(
            spec.N, spec.P, spec.min_chunk
        )

    # -- master-side recurrence (one claim), mirrors chunk_series_recurrence --
    def _next_chunk(self, pe: int) -> Optional[Claim]:
        import math

        spec = self.spec
        t, P = spec.technique, spec.P
        with self._lock:
            if self._R <= 0:
                return None
            R, i = self._R, self._i
            if t == "static":
                k = int(math.ceil(spec.N / P))
            elif t == "ss":
                k = spec.min_chunk
            elif t == "gss":
                k = max(int(math.ceil(R / P)), spec.min_chunk)
            elif t == "tss":
                self._k_tss = (
                    self._K0 if self._k_tss is None else max(self._k_tss - self._C, self._Klast)
                )
                k = self._k_tss
            elif t in ("fac2", "wf", "awf"):
                if i % P == 0:
                    self._batch_base = max(int(math.ceil(R / (2.0 * P))), spec.min_chunk)
                k = self._batch_base
                if t in cc.WEIGHTED:
                    k = max(int(math.ceil(spec.weight(pe) * self._batch_base)), spec.min_chunk)
            elif t == "tfss":
                if i % P == 0:
                    first = self._K0 - i * self._C
                    mean = first - (P - 1) / 2.0 * self._C
                    self._batch_base = max(int(math.ceil(mean)), self._Klast)
                k = self._batch_base
            else:
                raise AssertionError(t)
            k = min(k, R)
            start = spec.N - self._R
            self._R -= k
            self._i += 1
            return Claim(step=i, start=start, size=k)

    # -- two-sided protocol --
    def request(self, pe: int) -> "queue.Queue":
        reply: "queue.Queue" = queue.Queue(maxsize=1)
        self._req.put((pe, reply))
        return reply

    def serve_pending(self, limit: Optional[int] = None) -> int:
        """Master serves up to ``limit`` queued requests; returns count served."""
        served = 0
        while limit is None or served < limit:
            try:
                item = self._req.get_nowait()
            except queue.Empty:
                break
            if item is self._SHUTDOWN:
                break
            pe, reply = item
            reply.put(self._next_chunk(pe))
            served += 1
        return served

    def serve_blocking(self, timeout: float = 0.05) -> bool:
        """Serve one request, blocking up to ``timeout``.  False on idle."""
        try:
            item = self._req.get(timeout=timeout)
        except queue.Empty:
            return False
        if item is self._SHUTDOWN:
            return False
        pe, reply = item
        reply.put(self._next_chunk(pe))
        return True


def run_threaded_one_sided(
    spec: cc.LoopSpec,
    work_fn: Callable[[int, int], None],
    n_threads: Optional[int] = None,
    window: Optional[Window] = None,
    weight_fn: Optional[Callable[[int], float]] = None,
) -> List[Claim]:
    """Execute a real loop with the one-sided protocol over threads.

    ``work_fn(start, stop)`` executes iterations [start, stop).  Returns all
    claims (the partition of [0, N)).  ``weight_fn(pe)`` supplies live AWF
    weights.
    """
    n_threads = n_threads or spec.P
    rt = OneSidedRuntime(spec, window)
    claims: List[List[Claim]] = [[] for _ in range(n_threads)]

    def worker(pe: int):
        while True:
            w = weight_fn(pe) if weight_fn is not None else None
            c = rt.claim(pe, weight=w)
            if c is None:
                return
            work_fn(c.start, c.stop)
            claims[pe].append(c)

    threads = [threading.Thread(target=worker, args=(j,), name=f"dls-{j}")
               for j in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return [c for per in claims for c in per]


def run_threaded_two_sided(
    spec: cc.LoopSpec,
    work_fn: Callable[[int, int], None],
    n_threads: Optional[int] = None,
    master_pe: int = 0,
) -> List[Claim]:
    """Master-worker execution: PE ``master_pe`` is the non-dedicated master.

    The master interleaves serving requests with executing its own chunks
    (checks the queue between chunks, like the LB tool's breakAfter).
    """
    n_threads = n_threads or spec.P
    rt = TwoSidedRuntime(spec)
    claims: List[List[Claim]] = [[] for _ in range(n_threads)]
    done = threading.Event()

    def worker(pe: int):
        while True:
            reply = rt.request(pe)
            c = reply.get()
            if c is None:
                return
            work_fn(c.start, c.stop)
            claims[pe].append(c)

    def master():
        my_claim: Optional[Claim] = None
        workers_live = True
        while True:
            rt.serve_pending()
            if my_claim is None:
                my_claim = rt._next_chunk(master_pe)
                if my_claim is None:
                    # loop exhausted: keep serving until workers drain
                    while not done.is_set():
                        if not rt.serve_blocking(timeout=0.01):
                            if done.is_set():
                                break
                    rt.serve_pending()
                    return
            work_fn(my_claim.start, my_claim.stop)
            claims[master_pe].append(my_claim)
            my_claim = None

    threads = [
        threading.Thread(target=worker, args=(j,), name=f"dls-{j}")
        for j in range(n_threads)
        if j != master_pe
    ]
    mt = threading.Thread(target=master)
    for t in threads:
        t.start()
    mt.start()
    for t in threads:
        t.join()
    done.set()
    mt.join()
    return [c for per in claims for c in per]
