"""Self-scheduling runtimes: One_Sided (the paper) vs Two_Sided (baseline).

``OneSidedRuntime`` is the paper's distributed chunk-calculation protocol:

  Step 1: the PE atomically fetch-adds the step counter  ``i += 1``
  Step 2: the PE computes ``K_i`` locally from its private copy of ``i``
          (closed form -- no shared state needed)
  Step 3: the PE atomically fetch-adds the loop pointer ``lp += K_i``
  ...and executes iterations [lp, min(lp + K_i, N)).

``TwoSidedRuntime`` is the classical master-worker baseline the paper
compares against: a (non-dedicated) master owns the Table-2 recurrence and
serves claims one at a time from a request queue.

Both implement the ``repro.dls`` Runtime contract -- ``claim(pe, weight=)``,
``remaining_lower_bound()``, ``drained()``, ``state()``/``restore()`` -- so
the ``DLSession`` facade can drive either interchangeably (see DESIGN.md).
Prefer constructing them through ``repro.dls.loop(...)``; the threaded
``run_threaded_*`` helpers below are deprecated shims over
``DLSession.execute(..., executor="threads")``.

Both run over real threads (in-process "PEs") or over hosts (KVStoreWindow);
the discrete-event simulator in ``sim.py`` has its own clocked versions of
both protocols for the paper's heterogeneous-cluster experiments.
"""
from __future__ import annotations

import itertools
import queue
import threading
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from . import chunk_calculus as cc
from .rma import ThreadWindow, Window

_loop_ids = itertools.count()


@dataclass
class Claim:
    step: int  # scheduling step index i
    start: int  # first iteration (lp_start before accumulate)
    size: int  # K_i, already truncated to [0, N)

    @property
    def stop(self) -> int:
        return self.start + self.size


class OneSidedRuntime:
    """Distributed chunk calculation via two atomic fetch-and-adds."""

    def __init__(self, spec: cc.LoopSpec, window: Optional[Window] = None,
                 loop_id: Optional[int] = None):
        self.spec = spec
        self.window = window if window is not None else ThreadWindow()
        # Namespace the two counters per loop so monotonic KV backends work.
        lid = next(_loop_ids) if loop_id is None else loop_id
        self._ki = f"loop{lid}/i"
        self._kl = f"loop{lid}/lp"

    def claim(self, pe: int = 0, weight: Optional[float] = None) -> Optional[Claim]:
        """One scheduling step for PE ``pe``; None when the loop is exhausted.

        ``weight`` overrides the spec's static weight for this claim (used by
        AWF, whose weights evolve during execution).
        """
        N = self.spec.N
        # Fast-path exit: if the loop pointer is already past N, don't burn
        # a step index.  (A stale read here is harmless -- Step 3 re-checks.)
        if self.window.read(self._kl) >= N:
            return None
        i = self.window.fetch_add(self._ki, 1)  # Step 1
        k = cc.chunk_size_closed(self.spec, i, pe, weight=weight)  # Step 2 (local)
        start = self.window.fetch_add(self._kl, k)  # Step 3
        if start >= N:
            return None
        return Claim(step=i, start=start, size=min(k, N - start))

    def remaining_lower_bound(self) -> int:
        return max(self.spec.N - self.window.read(self._kl), 0)

    def drained(self) -> bool:
        """True once the loop pointer has passed N: no PE can claim work."""
        return self.remaining_lower_bound() == 0

    # -- checkpointable window counters (i, lp_start) ----------------------
    def state(self) -> Dict[str, int]:
        return {"i": self.window.read(self._ki), "lp": self.window.read(self._kl)}

    def restore(self, st: Dict[str, int]) -> None:
        self.window.reset(self._ki, st["i"])
        self.window.reset(self._kl, st["lp"])


class TwoSidedRuntime:
    """Master-worker baseline: a master thread serves the Table-2 recurrence.

    Workers put (pe, reply_queue) requests on a queue; the master pops one at
    a time, advances the recurrence state (R, K_prev), and replies.  The
    master is *non-dedicated*: it can also execute loop chunks (the paper's
    setup) -- see ``repro.dls.executors``.  ``claim`` is the synchronous
    master-inline form of the same recurrence (the Runtime contract); the
    queue path (``request``/``serve_*``) is the threaded protocol.
    """

    _SHUTDOWN = object()

    def __init__(self, spec: cc.LoopSpec):
        self.spec = spec
        self._req: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._R = spec.N
        self._i = 0
        self._k_tss: Optional[int] = None
        self._batch_base: Optional[int] = None
        self._K0, self._Klast, self._S, self._C = cc.tss_constants(
            spec.N, spec.P, spec.min_chunk
        )

    # -- master-side recurrence (one claim), mirrors chunk_series_recurrence --
    def claim(self, pe: int = 0, weight: Optional[float] = None) -> Optional[Claim]:
        import math

        spec = self.spec
        t, P = spec.technique, spec.P
        with self._lock:
            if self._R <= 0:
                return None
            R, i = self._R, self._i
            if t == "static":
                k = int(math.ceil(spec.N / P))
            elif t == "ss":
                k = spec.min_chunk
            elif t == "gss":
                k = max(int(math.ceil(R / P)), spec.min_chunk)
            elif t == "tss":
                self._k_tss = (
                    self._K0 if self._k_tss is None else max(self._k_tss - self._C, self._Klast)
                )
                k = self._k_tss
            elif t in ("fac2", "wf", "awf"):
                if i % P == 0:
                    self._batch_base = max(int(math.ceil(R / (2.0 * P))), spec.min_chunk)
                k = self._batch_base
                if t in cc.WEIGHTED:
                    w = spec.weight(pe) if weight is None else weight
                    k = max(int(math.ceil(w * self._batch_base)), spec.min_chunk)
            elif t == "tfss":
                if i % P == 0:
                    first = self._K0 - i * self._C
                    mean = first - (P - 1) / 2.0 * self._C
                    self._batch_base = max(int(math.ceil(mean)), self._Klast)
                k = self._batch_base
            else:
                raise AssertionError(t)
            if spec.max_chunk:
                k = min(k, spec.max_chunk)
            k = min(k, R)
            start = spec.N - self._R
            self._R -= k
            self._i += 1
            return Claim(step=i, start=start, size=k)

    # Backwards-compatible private alias (older call sites / tests).
    _next_chunk = claim

    def remaining_lower_bound(self) -> int:
        with self._lock:
            return max(self._R, 0)

    def drained(self) -> bool:
        return self.remaining_lower_bound() == 0

    def state(self) -> Dict[str, int]:
        with self._lock:
            return {"i": self._i, "lp": self.spec.N - self._R}

    def restore(self, st: Dict[str, int]) -> None:
        import math

        spec = self.spec
        with self._lock:
            self._i = i = st["i"]
            self._R = spec.N - st["lp"]
            # Re-derive the recurrence state: the master's (_k_tss,
            # _batch_base) are history-dependent, so a restored runtime must
            # rebuild them or the next claim crashes / continues a stale
            # ramp.  TSS/TFSS are exact (index-only); FAC2/WF/AWF mid-batch
            # use the *current* remainder (the batch-start remainder is not
            # recoverable from (i, lp) alone) -- the partition property is
            # unaffected, only the in-flight batch's size may differ from an
            # uninterrupted run.
            self._k_tss = (
                None if i == 0 else max(self._K0 - (i - 1) * self._C, self._Klast))
            if i % spec.P == 0:
                self._batch_base = None  # recomputed at the next batch start
            elif spec.technique == "tfss":
                first = self._K0 - (i - i % spec.P) * self._C
                mean = first - (spec.P - 1) / 2.0 * self._C
                self._batch_base = max(int(math.ceil(mean)), self._Klast)
            else:
                self._batch_base = max(
                    int(math.ceil(max(self._R, 0) / (2.0 * spec.P))), spec.min_chunk)

    # -- two-sided protocol --
    def request(self, pe: int, weight: Optional[float] = None) -> "queue.Queue":
        reply: "queue.Queue" = queue.Queue(maxsize=1)
        self._req.put((pe, weight, reply))
        return reply

    def serve_pending(self, limit: Optional[int] = None) -> int:
        """Master serves up to ``limit`` queued requests; returns count served."""
        served = 0
        while limit is None or served < limit:
            try:
                item = self._req.get_nowait()
            except queue.Empty:
                break
            if item is self._SHUTDOWN:
                break
            pe, weight, reply = item
            reply.put(self.claim(pe, weight=weight))
            served += 1
        return served

    def serve_blocking(self, timeout: float = 0.05) -> bool:
        """Serve one request, blocking up to ``timeout``.  False on idle."""
        try:
            item = self._req.get(timeout=timeout)
        except queue.Empty:
            return False
        if item is self._SHUTDOWN:
            return False
        pe, weight, reply = item
        reply.put(self.claim(pe, weight=weight))
        return True


# ---------------------------------------------------------------------------
# Deprecated threaded helpers -- thin shims over the repro.dls facade.
# ---------------------------------------------------------------------------


def run_threaded_one_sided(
    spec: cc.LoopSpec,
    work_fn: Callable[[int, int], None],
    n_threads: Optional[int] = None,
    window: Optional[Window] = None,
    weight_fn: Optional[Callable[[int], float]] = None,
) -> List[Claim]:
    """Deprecated: use ``repro.dls.loop(...).execute(..., executor="threads")``.

    Execute a real loop with the one-sided protocol over threads.
    ``work_fn(start, stop)`` executes iterations [start, stop).  Returns all
    claims (the partition of [0, N)).  ``weight_fn(pe)`` supplies live AWF
    weights.
    """
    warnings.warn(
        "run_threaded_one_sided is deprecated; use "
        "repro.dls.loop(...).execute(work_fn, executor='threads')",
        DeprecationWarning, stacklevel=2)
    from repro.dls import CallableWeights, DLSession

    session = DLSession(
        spec, OneSidedRuntime(spec, window),
        weights=CallableWeights(weight_fn) if weight_fn is not None else None)
    return session.execute(work_fn, executor="threads", n_threads=n_threads).claims


def run_threaded_two_sided(
    spec: cc.LoopSpec,
    work_fn: Callable[[int, int], None],
    n_threads: Optional[int] = None,
    master_pe: int = 0,
) -> List[Claim]:
    """Deprecated: use ``repro.dls.loop(..., runtime="two_sided").execute(...)``.

    Master-worker execution: PE ``master_pe`` is the non-dedicated master.
    """
    warnings.warn(
        "run_threaded_two_sided is deprecated; use "
        "repro.dls.loop(..., runtime='two_sided').execute(work_fn, executor='threads')",
        DeprecationWarning, stacklevel=2)
    from repro.dls import DLSession

    session = DLSession(spec, TwoSidedRuntime(spec))
    return session.execute(
        work_fn, executor="threads", n_threads=n_threads, master_pe=master_pe
    ).claims
