"""Chunk calculus for dynamic loop self-scheduling (DLS).

This module is the mathematical heart of the paper (Table 2 + Eq. 1-3 of
Eleliemy & Ciorba 2018): for each self-scheduling technique it provides

  * the **recurrence form** ``chunk_series_recurrence`` -- the classical
    master-side computation ``K_i = f(K_{i-1}, R_i)`` (Table 2), which is
    inherently sequential, and
  * the **closed form** ``chunk_size_closed`` -- ``K'_i`` as a pure function
    of the scheduling-step index ``i`` alone (Eq. 1-3), which is what makes
    the *distributed* chunk calculation possible: any PE that atomically
    fetches an ``i`` can compute its chunk with no other shared state,
  * a **batched planner** ``plan`` -- the TPU-native corollary: because
    ``K'_i`` is index-only, chunk *starts* are ``cumsum(K'_0..K'_{i-1})``,
    i.e. an associative scan.  A whole schedule can be materialized in one
    vectorized pass (numpy) or on-device (``plan_jax``).  The master-worker
    recurrence cannot do this.  This is recorded in DESIGN.md as the key
    beyond-paper optimization the closed forms unlock.

Techniques: STATIC, SS, GSS, TSS, FAC2, WF (paper) + TFSS, AWF (beyond
paper; Chronopoulos 2005 / Banicescu 2003 -- the paper cites both families
as derived work) + the *adaptive* family of the verification study
(Mohammed et al., arXiv:1804.11115): AF (Banicescu & Liu 2000) and the
AWF batch/chunk variants AWF-B/C/D/E (Carino & Banicescu 2008).  The
adaptive forms measure PE performance online -- the telemetry layer lives
in ``core/weights.py`` (``PerfModel``), see DESIGN.md Sec. 8; this module
holds only the per-claim chunk math.

Everything here is host-plane math over integers; numpy is the default
backend.  ``chunk_sizes_closed`` also accepts ``jnp`` arrays and is
traceable (used by ``plan_jax`` and the on-device planner tests).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Sequence

import numpy as np

#: Single source of truth for the technique roster.  Every name dispatched
#: anywhere in the repo (runtimes, DES, planner, facade, docs tables) comes
#: from this registry; README.md / DESIGN.md tables are generated from it
#: (``technique_table()``) and CI fails if they drift (tests/test_docs.py).
TECHNIQUE_INFO = {
    "static": dict(label="Static", summary="one ceil(N/P) block per PE",
                   source="paper Table 2"),
    "ss": dict(label="SS", summary="self-scheduling, min_chunk per claim",
               source="paper Table 2"),
    "gss": dict(label="GSS", summary="guided: ceil of 1/P of the remainder",
                source="paper Eq. 1"),
    "tss": dict(label="TSS", summary="trapezoid: linear ramp K_0 -> 1",
                source="paper Eq. 2"),
    "fac2": dict(label="FAC2", summary="factoring: batches halving the "
                 "remainder, split P ways", source="paper Eq. 3"),
    "wf": dict(label="WF", summary="FAC2 scaled by static PE weights",
               source="paper Table 2"),
    "tfss": dict(label="TFSS", summary="trapezoid factoring: batches of P "
                 "mean-TSS chunks", source="Chronopoulos 2005"),
    "awf": dict(label="AWF", summary="WF with timestep-measured weights "
                "(EMA WeightBoard)", source="Banicescu 2003"),
    "af": dict(label="AF", summary="adaptive factoring from measured "
               "per-PE (mu, sigma)", source="Banicescu & Liu 2000"),
    "awf_b": dict(label="AWF-B", summary="AWF reweighted every batch",
                  source="Carino & Banicescu 2008"),
    "awf_c": dict(label="AWF-C", summary="AWF reweighted every chunk",
                  source="Carino & Banicescu 2008"),
    "awf_d": dict(label="AWF-D", summary="AWF-B timing compute + scheduling "
                  "overhead", source="Carino & Banicescu 2008"),
    "awf_e": dict(label="AWF-E", summary="AWF-C timing compute + scheduling "
                  "overhead", source="Carino & Banicescu 2008"),
}

TECHNIQUES = tuple(TECHNIQUE_INFO)

# Techniques whose chunk size depends on the claiming PE's weight
# (the WF closed form scaled by a static or live weight).
WEIGHTED = ("wf", "awf", "awf_b", "awf_c", "awf_d", "awf_e")

# Techniques that *measure* PE performance online instead of trusting a
# priori weights (arXiv:1804.11115's adaptive rows).  ``awf`` is excluded
# on purpose: in this repo it is the timestep-level variant whose weights
# are supplied by an external policy (``weights="awf"``), while the
# techniques below default to an online ``PerfModel``-driven policy.
ADAPTIVE = ("af", "awf_b", "awf_c", "awf_d", "awf_e")

#: (update boundary, include scheduling overhead) per AWF variant --
#: shared by the weight policies (repro.dls.policies) and the DES.
AWF_VARIANTS = {
    "awf_b": ("batch", False),
    "awf_c": ("chunk", False),
    "awf_d": ("batch", True),
    "awf_e": ("chunk", True),
}

# Techniques that consume a WeightPolicy at claim time (weight-scaled or
# AF-stat-fed) -- the facade's "your weights will actually act" set.
POLICY_DRIVEN = tuple(dict.fromkeys(WEIGHTED + ADAPTIVE))

# The transformed-FAC2 family: one batch-halving closed form, optionally
# weight-scaled.  AF bootstraps through this form until telemetry exists.
FAC_FAMILY = ("fac2", "wf", "awf", "awf_b", "awf_c", "awf_d", "awf_e", "af")


def technique_table() -> str:
    """The markdown technique table embedded in README.md / DESIGN.md.

    Generated (``scripts/gen_technique_table.py``) and drift-checked
    (``tests/test_docs.py``) so the docs can never disagree with the code.
    """
    rows = ["| name | label | chunk rule | weighted | adaptive | source |",
            "|------|-------|------------|----------|----------|--------|"]
    for name, info in TECHNIQUE_INFO.items():
        rows.append(
            f"| `{name}` | {info['label']} | {info['summary']} "
            f"| {'yes' if name in WEIGHTED else 'no'} "
            f"| {'yes' if name in ADAPTIVE else 'no'} "
            f"| {info['source']} |")
    return "\n".join(rows)


class AFStats(NamedTuple):
    """Adaptive Factoring's per-claim telemetry snapshot (seconds/iteration).

    ``mu``: the claiming PE's measured mean iteration time; ``D``/``T`` the
    cluster aggregates ``sum_j sigma_j^2/mu_j`` and ``1/sum_j (1/mu_j)``
    (Banicescu & Liu 2000).  Produced by ``weights.AdaptiveFactoringModel``.
    """

    mu: float
    D: float
    T: float


@dataclasses.dataclass(frozen=True)
class LoopSpec:
    """A scheduling problem: N independent iterations over P processing elements."""

    technique: str
    N: int
    P: int
    # Relative PE weights (sum == P), only used by WF/AWF.  Defaults to uniform.
    weights: Optional[tuple] = None
    # SS/FAC2 style minimum chunk; also TSS's K_{S-1}.
    min_chunk: int = 1
    # Optional chunk-size cap (beyond-paper FT refinement): bounds the work
    # lost when a PE dies mid-chunk.  Still a pure function of i, so the
    # distributed protocol is unchanged.
    max_chunk: Optional[int] = None

    def __post_init__(self):
        if self.technique not in TECHNIQUES:
            raise ValueError(f"unknown technique {self.technique!r}; pick from {TECHNIQUES}")
        if self.N <= 0 or self.P <= 0:
            raise ValueError("N and P must be positive")
        if self.weights is not None and len(self.weights) != self.P:
            raise ValueError("weights must have length P")

    def weight(self, pe: int) -> float:
        if self.weights is None:
            return 1.0
        return float(self.weights[pe])


# ---------------------------------------------------------------------------
# TSS constants (paper Table 2): K_0 = ceil(N/2P), K_{S-1} = 1,
# S = ceil(2N / (K_0 + K_{S-1})), C = floor((K_0 - K_{S-1}) / (S - 1)).
# ---------------------------------------------------------------------------

def tss_constants(N: int, P: int, min_chunk: int = 1):
    K0 = max(int(math.ceil(N / (2.0 * P))), min_chunk)
    Klast = min_chunk
    S = max(int(math.ceil(2.0 * N / (K0 + Klast))), 1)
    C = 0 if S <= 1 else (K0 - Klast) // (S - 1)
    return K0, Klast, S, C


# ---------------------------------------------------------------------------
# Closed forms (paper Eq. 1-3).  Pure functions of the step index i.
# ---------------------------------------------------------------------------

def chunk_size_closed(spec: LoopSpec, i: int, pe: int = 0,
                      weight: Optional[float] = None,
                      af_stats: Optional[AFStats] = None,
                      remaining: Optional[int] = None) -> int:
    """K'_i -- chunk size at scheduling step ``i`` (closed form, scalar).

    This is exactly what a PE computes in Step 2 of the paper's protocol,
    using only its private copy of ``i`` (and, for WF/AWF, its own weight).
    ``weight`` overrides the spec's static weight for the WF family -- this
    is how the AWF variants' live, measured weights enter the closed form;
    it is ignored by unweighted techniques.  ``af_stats``/``remaining``
    feed Adaptive Factoring; with either absent, AF bootstraps through the
    FAC2 form (no telemetry yet, the standard AF cold start).
    """
    k = _chunk_size_closed(spec, i, pe, weight, af_stats, remaining)
    return min(k, spec.max_chunk) if spec.max_chunk else k


def af_chunk_size(stats: AFStats, remaining: int, min_chunk: int = 1) -> int:
    """Adaptive Factoring chunk size (Banicescu & Liu 2000).

    K_j = (D + 2*T*R - sqrt(D^2 + 4*D*T*R)) / (2*mu_j), with R the
    remaining iterations.  With zero measured variance (D = 0) this
    degenerates to T*R/mu_j -- each PE's speed-proportional share of 1/P
    of the remainder; the variance term shrinks chunks when iteration
    times are noisy.  Not a pure function of ``i``: the distributed
    protocol feeds it the loop-pointer read it already performs for the
    drain fast path (see ``OneSidedRuntime.claim``).
    """
    R = max(int(remaining), 0)
    if R <= 0:
        return min_chunk
    mu = max(stats.mu, 1e-12)
    D = max(stats.D, 0.0)
    T = max(stats.T, 1e-12)
    k = (D + 2.0 * T * R - math.sqrt(D * D + 4.0 * D * T * R)) / (2.0 * mu)
    return max(int(math.ceil(k)), min_chunk)


def _chunk_size_closed(spec: LoopSpec, i: int, pe: int = 0,
                       weight: Optional[float] = None,
                       af_stats: Optional[AFStats] = None,
                       remaining: Optional[int] = None) -> int:
    t, N, P = spec.technique, spec.N, spec.P
    if t == "static":
        return int(math.ceil(N / P))
    if t == "ss":
        return spec.min_chunk
    if t == "gss":
        # Eq. 1: K'_i = ceil(((P-1)/P)^i * N/P)
        return max(int(math.ceil(((P - 1.0) / P) ** i * N / P)), spec.min_chunk)
    if t == "tss":
        # Eq. 2: K'_i = K_0 - i*C
        K0, Klast, S, C = tss_constants(N, P, spec.min_chunk)
        return max(K0 - i * C, Klast)
    if t == "af" and af_stats is not None and remaining is not None:
        return af_chunk_size(af_stats, remaining, spec.min_chunk)
    if t == "fac2" or (t == "af"):
        # Eq. 3: K'_i = ceil((1/2)^(floor(i/P)+1) * N/P).  AF without
        # telemetry (cold start, or the offline planner) takes this form.
        b = i // P + 1
        return max(int(math.ceil(0.5 ** b * N / P)), spec.min_chunk)
    if t in WEIGHTED:
        # WF inherits the transformed FAC2 function, scaled by the claimer's
        # relative weight (paper Table 2 last row).  The AWF family is the
        # same form with the live measured weight substituted for the
        # static one (timestep/batch/chunk granularity per variant).
        w = spec.weight(pe) if weight is None else weight
        b = i // P + 1
        base = 0.5 ** b * N / P
        return max(int(math.ceil(w * base)), spec.min_chunk)
    if t == "tfss":
        # TFSS (Chronopoulos 2005): batches of P chunks, each the mean of the
        # TSS chunks of that batch -- closed form via the TSS linear ramp.
        K0, Klast, S, C = tss_constants(N, P, spec.min_chunk)
        b = i // P
        mean = K0 - (b * P + (P - 1) / 2.0) * C
        return max(int(math.ceil(mean)), Klast)
    raise AssertionError(t)


def chunk_sizes_closed(spec: LoopSpec, idx, xp=np, weights_per_step=None):
    """Vectorized K'_i over an array of step indices.

    ``xp`` may be numpy or jax.numpy -- the expression is trace-friendly
    (no data-dependent Python control flow).  ``weights_per_step`` optionally
    supplies the claimer weight per step for WF/AWF.
    """
    k = _chunk_sizes_closed(spec, idx, xp, weights_per_step)
    return xp.minimum(k, spec.max_chunk) if spec.max_chunk else k


def _chunk_sizes_closed(spec: LoopSpec, idx, xp=np, weights_per_step=None):
    t, N, P = spec.technique, spec.N, spec.P
    idx = xp.asarray(idx)
    fidx = idx.astype(xp.float64 if xp is np else xp.float32)
    if t == "static":
        return xp.full_like(idx, int(math.ceil(N / P)))
    if t == "ss":
        return xp.full_like(idx, spec.min_chunk)
    if t == "gss":
        k = xp.ceil(((P - 1.0) / P) ** fidx * (N / P))
        return xp.maximum(k, spec.min_chunk).astype(idx.dtype)
    if t == "tss":
        K0, Klast, S, C = tss_constants(N, P, spec.min_chunk)
        return xp.maximum(K0 - idx * C, Klast).astype(idx.dtype)
    if t in FAC_FAMILY:
        # The batched planner is offline: the AWF variants take their
        # statically-known weights (or ``weights_per_step``), AF its FAC2
        # bootstrap -- there is no telemetry before execution.
        b = idx // P + 1
        base = (0.5 ** b.astype(fidx.dtype)) * (N / P)
        if t in WEIGHTED and weights_per_step is not None:
            base = base * xp.asarray(weights_per_step)
        k = xp.ceil(base)
        return xp.maximum(k, spec.min_chunk).astype(idx.dtype)
    if t == "tfss":
        K0, Klast, S, C = tss_constants(N, P, spec.min_chunk)
        b = idx // P
        mean = K0 - (b * P + (P - 1) / 2.0) * C
        return xp.maximum(xp.ceil(mean), Klast).astype(idx.dtype)
    raise AssertionError(t)


def max_steps_bound(spec: LoopSpec) -> int:
    """A safe upper bound on the number of scheduling steps S."""
    base = _max_steps_bound(spec)
    if spec.max_chunk:
        # capped steps deliver exactly max_chunk each; uncapped ones are
        # bounded by the technique's own bound
        return base + -(-spec.N // spec.max_chunk) + spec.P
    return base


def _max_steps_bound(spec: LoopSpec) -> int:
    t, N, P = spec.technique, spec.N, spec.P
    if t == "static":
        return P
    if t == "ss":
        return int(math.ceil(N / spec.min_chunk))
    if t == "gss":
        # K'_i >= 1, and the geometric part reaches < 1 after
        # i > ln(P/N)/ln(1-1/P); afterwards chunks are 1.
        if N <= P or P == 1:
            return N
        geo = int(math.ceil(math.log(N / P) / -math.log(1.0 - 1.0 / P))) + 1
        return geo + N  # ultra-safe: tail of 1s can cover the remainder
    if t in ("tss", "tfss"):
        K0, Klast, S, C = tss_constants(N, P, spec.min_chunk)
        return S + N // max(Klast, 1) + 1
    if t in FAC_FAMILY:
        # batch b assigns ~ half the remainder; <= P*log2(N) + tail of 1s.
        # Live AWF/AF weights can shrink chunks below the unweighted
        # halving assumed here -- ``plan`` grows its bound until covered,
        # and the runtimes loop until drained, so the bound stays safe.
        return P * (int(math.ceil(math.log2(max(N, 2)))) + 2) + P
    raise AssertionError(t)


# ---------------------------------------------------------------------------
# Two-level (hierarchical) topology math, shared by HierarchicalRuntime and
# the DES so the simulated schedule can never drift from the real one.
# ---------------------------------------------------------------------------

def node_blocks(P: int, nodes: int):
    """Contiguous PE blocks per node: (bounds, n_pes).

    Block ``n`` is ``[bounds[n], bounds[n+1])``; every block is non-empty
    for ``1 <= nodes <= P``.
    """
    bounds = [n * P // nodes for n in range(nodes + 1)]
    return bounds, [bounds[j + 1] - bounds[j] for j in range(nodes)]


def hierarchical_outer_spec(spec: LoopSpec, nodes: int) -> LoopSpec:
    """The super-chunk-level spec: ``spec.technique`` over nodes-as-PEs.

    Per-PE weights aggregate into node weights (sum == nodes).  min_chunk
    scales by the largest node so a super-chunk never starves a node's
    PEs; max_chunk is *not* lifted (it bounds per-PE work lost, and a
    super-chunk is drained by the whole node).
    """
    bounds, n_pes = node_blocks(spec.P, nodes)
    node_w = None
    if spec.weights is not None:
        sums = [sum(spec.weights[bounds[j]:bounds[j + 1]])
                for j in range(nodes)]
        tot = sum(sums) or 1.0
        node_w = tuple(s * nodes / tot for s in sums)
    return LoopSpec(spec.technique, N=spec.N, P=nodes, weights=node_w,
                    min_chunk=spec.min_chunk * max(n_pes))


def hierarchical_inner_spec(spec: LoopSpec, inner_technique: str,
                            bounds, node: int, size: int) -> LoopSpec:
    """The within-node spec for one super-chunk of ``size`` iterations.

    A weighted inner technique renormalizes the node's PE weights to sum
    to the node's PE count (the closed forms' convention).
    """
    n_pes = bounds[node + 1] - bounds[node]
    w = None
    if spec.weights is not None and inner_technique in WEIGHTED:
        sub = spec.weights[bounds[node]:bounds[node + 1]]
        tot = sum(sub) or 1.0
        w = tuple(x * n_pes / tot for x in sub)
    return LoopSpec(inner_technique, N=size, P=n_pes, weights=w,
                    min_chunk=min(spec.min_chunk, size),
                    max_chunk=spec.max_chunk)


# ---------------------------------------------------------------------------
# Recurrence forms (paper Table 2) -- the sequential master-side computation.
# ---------------------------------------------------------------------------

def chunk_series_recurrence(
    spec: LoopSpec, pe_sequence: Optional[Sequence[int]] = None
) -> list:
    """Full chunk series computed the classical way (master-worker).

    This is the paper's Table 2: the master tracks the remaining iterations
    ``R`` (and ``K_{i-1}`` for TSS) and serves one claim at a time -- the
    serialization the closed forms remove.  ``pe_sequence`` gives which PE
    claims at each step (needed by WF to pick the weight); defaults to
    round-robin.  Chunk sizes sum exactly to N (final chunk truncated).
    """
    t, N, P = spec.technique, spec.N, spec.P
    K0, Klast, S, C = tss_constants(N, P, spec.min_chunk)
    out = []
    R = N
    i = 0
    k_tss = None  # TSS: previous chunk (untruncated)
    batch_base = None  # FAC2/WF/TFSS: chunk size fixed at batch start
    while R > 0:
        pe = pe_sequence[i] if pe_sequence is not None else i % P
        if t == "static":
            k = int(math.ceil(N / P))
        elif t == "ss":
            k = spec.min_chunk
        elif t == "gss":
            k = max(int(math.ceil(R / P)), spec.min_chunk)
        elif t == "tss":
            k_tss = K0 if k_tss is None else max(k_tss - C, Klast)
            k = k_tss
        elif t in FAC_FAMILY:
            if i % P == 0:  # new batch: half the remainder, split P ways
                batch_base = max(int(math.ceil(R / (2.0 * P))), spec.min_chunk)
            k = batch_base
            if t in WEIGHTED:
                k = max(int(math.ceil(spec.weight(pe) * batch_base)), spec.min_chunk)
        elif t == "tfss":
            if i % P == 0:  # mean of this batch's P TSS ramp values
                first = K0 - i * C
                mean = first - (P - 1) / 2.0 * C
                batch_base = max(int(math.ceil(mean)), Klast)
            k = batch_base
        else:
            raise AssertionError(t)
        if spec.max_chunk:
            k = min(k, spec.max_chunk)
        k = min(k, R)
        out.append(k)
        R -= k
        i += 1
    return out


# ---------------------------------------------------------------------------
# Batched planner (beyond paper): closed form + prefix sum.
# ---------------------------------------------------------------------------

def plan(spec: LoopSpec, weights_per_step=None):
    """Materialize the whole schedule: (sizes, starts), both int64 numpy.

    sizes sum exactly to N; starts[i] = cumsum(sizes[:i]).  This is the
    vectorized realization of the paper's Step-1..3 protocol when claims are
    conflict-free (planning mode), used by the deterministic data-pipeline
    sharder and by tests as the ground truth partition.
    """
    S_hi = max_steps_bound(spec)
    while True:
        idx = np.arange(S_hi, dtype=np.int64)
        sizes = chunk_sizes_closed(spec, idx, np, weights_per_step).astype(np.int64)
        csum = np.cumsum(sizes)
        if len(csum) and csum[-1] >= spec.N:
            break
        # Small supplied weights can shrink chunks below the unweighted
        # halving the bound assumes; chunks are >= min_chunk >= 1, so
        # doubling (capped by N steps) always terminates.
        if weights_per_step is None or S_hi >= spec.N:
            raise ValueError("weights_per_step too short to cover the loop")
        S_hi = min(S_hi * 2, spec.N)
        if len(weights_per_step) < S_hi:
            weights_per_step = np.concatenate(
                [np.asarray(weights_per_step, dtype=np.float64),
                 np.ones(S_hi - len(weights_per_step))])
    # first index where cumulative >= N
    cut = int(np.searchsorted(csum, spec.N))
    sizes = sizes[: cut + 1].copy()
    csum = csum[: cut + 1]
    sizes[-1] -= int(csum[-1] - spec.N)  # truncate final chunk
    if sizes[-1] == 0:
        sizes = sizes[:-1]
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    return sizes, starts


def plan_jax(spec: LoopSpec, max_steps: Optional[int] = None):
    """On-device planner: returns (sizes, starts, n_valid) as jnp arrays.

    Fixed-shape (padded to ``max_steps``) so it can live inside jit.  Padding
    chunks have size 0.  This is the TPU-native batched form of the paper's
    distributed chunk calculation.
    """
    import jax.numpy as jnp

    S_hi = int(max_steps or max_steps_bound(spec))
    idx = jnp.arange(S_hi, dtype=jnp.int32)
    sizes = chunk_sizes_closed(spec, idx, jnp).astype(jnp.int32)
    csum = jnp.cumsum(sizes)
    prev = csum - sizes  # exclusive prefix
    # clamp each chunk into [0, N): size = clip(N - prev, 0, size)
    sizes = jnp.clip(jnp.minimum(sizes, spec.N - prev), 0, None)
    starts = jnp.minimum(prev, spec.N)
    n_valid = jnp.sum((sizes > 0).astype(jnp.int32))
    return sizes, starts, n_valid


def scheduling_steps(spec: LoopSpec) -> int:
    """Number of scheduling steps S for the closed-form schedule."""
    return len(plan(spec)[0])
