"""Training step: CE loss, grad accumulation over microbatches, remat.

``make_train_step`` builds a jit-able pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with gradient accumulation expressed as a ``lax.scan`` over the microbatch
axis -- activations for only one microbatch are ever live (plus remat policy
inside the layer scan), which is what bounds activation memory at
train_4k x global_batch 256 scale.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import api
from repro.optim import adamw
from repro.shard.spec import NO_SHARD, ShardCtx


def ce_loss(logits, labels, mask=None):
    """Next-token cross entropy in f32.  logits (B,T,V); labels (B,T)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    # shift: predict token t+1 from position t
    lp = lp[:, :-1]
    tgt = labels[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


def loss_fn(params, cfg, batch, *, ctx: ShardCtx = NO_SHARD, backend="xla",
            remat="none"):
    logits = api.forward(params, cfg, batch, ctx=ctx, backend=backend, remat=remat)
    labels = batch["tokens"]
    logits = logits[:, -labels.shape[1]:]  # drop vlm prefix positions
    return ce_loss(logits, labels, batch.get("mask"))


def make_train_step(
    cfg,
    opt_cfg: adamw.AdamWConfig,
    *,
    ctx: ShardCtx = NO_SHARD,
    microbatches: int = 1,
    backend: str = "xla",
    remat: str = "none",
    donate: bool = True,
    acc_dtype=jnp.float32,
):
    """Returns train_step(params, opt_state, batch) (wrap in jax.jit yourself,
    with shardings, at the launcher level)."""

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b, ctx=ctx, backend=backend, remat=remat)
    )

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            from repro.shard.spec import cs

            def split(x):
                B = x.shape[0]
                assert B % microbatches == 0, (B, microbatches)
                # interleaved split: microbatch j takes samples {k*mb + j}, so
                # every data shard contributes equally to every microbatch --
                # communication-free under batch sharding (a contiguous split
                # would reshard each microbatch across the mesh)
                x = x.reshape((B // microbatches, microbatches) + x.shape[1:])
                x = jnp.swapaxes(x, 0, 1)
                return cs(x, None, "batch", *([None] * (x.ndim - 2)), ctx=ctx)

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                loss, grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dtype) / microbatches,
                    g_acc, grads)
                return (loss_acc + loss / microbatches, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zeros), micro)

        new_params, new_opt, metrics = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return step


def make_eval_step(cfg, *, ctx: ShardCtx = NO_SHARD, backend="xla"):
    def step(params, batch):
        return loss_fn(params, cfg, batch, ctx=ctx, backend=backend)

    return step
