"""Training: loss, step, trainer loop, straggler mitigation."""
from .step import ce_loss, loss_fn, make_eval_step, make_train_step  # noqa: F401
from .trainer import SimCluster, TrainConfig, Trainer  # noqa: F401
