"""Training loop: DLS-claimed data, AWF straggler mitigation, checkpoints.

``Trainer`` is the single-process driver (one JAX process = one "host").
``SimCluster`` runs H logical hosts as threads against one shared RMA window
-- the paper's execution model in-process -- so fault-tolerance/elasticity
tests can kill and revive hosts and watch the unclaimed work get picked up
by survivors (the one-sided protocol's natural elasticity).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.core.weights import WeightBoard
from repro.data.pipeline import DLSSampler, EpochState, HostDataIterator
from repro.models import api
from repro.optim import adamw
from repro.shard.spec import NO_SHARD

from .step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    per_host_batch: int = 8
    seq_len: int = 128
    n_samples: int = 10_000
    n_hosts: int = 1
    host_id: int = 0
    technique: str = "fac2"
    microbatches: int = 1
    remat: str = "none"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg, tcfg: TrainConfig, opt_cfg: Optional[adamw.AdamWConfig] = None,
                 *, window=None, board: Optional[WeightBoard] = None,
                 ctx=NO_SHARD, log: Callable[[str], None] = print):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=tcfg.steps)
        self.log = log
        self.board = board or WeightBoard(tcfg.n_hosts)
        self.sampler = DLSSampler(
            tcfg.n_samples, tcfg.n_hosts, tcfg.host_id,
            technique=tcfg.technique, window=window, weight_board=self.board)
        self.data = HostDataIterator(
            self.sampler, seq_len=tcfg.seq_len, vocab=cfg.vocab,
            per_host_batch=tcfg.per_host_batch, seed=tcfg.seed)
        self.step_fn = jax.jit(make_train_step(
            cfg, self.opt_cfg, ctx=ctx, microbatches=tcfg.microbatches,
            remat=tcfg.remat), donate_argnums=(0, 1))
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, host_id=tcfg.host_id)
                     if tcfg.ckpt_dir else None)
        self.state_step = 0
        self.history: list = []

    # ------------------------------------------------------------------
    def init_or_restore(self):
        params = api.init_params(jax.random.key(self.tcfg.seed), self.cfg)
        opt_state = adamw.init(params)
        if self.ckpt is not None:
            restored, extra = self.ckpt.restore({"params": params, "opt": opt_state})
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                self.state_step = int(extra["step"])
                self.sampler.restore(EpochState(**extra["data"]))
                self.log(f"[trainer] resumed at step {self.state_step}, "
                         f"epoch state {extra['data']}")
        return params, opt_state

    def run(self, params=None, opt_state=None, *, hooks=None):
        if params is None:
            params, opt_state = self.init_or_restore()
        it = iter(self.data)
        t_hist = []
        while self.state_step < self.tcfg.steps:
            batch_np = next(it)
            batch = {"tokens": jax.numpy.asarray(batch_np["tokens"])}
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            t_hist.append(dt)
            self.state_step += 1
            # AWF: feed measured throughput back into the chunk weights
            self.board.record(self.tcfg.host_id,
                              iters=self.tcfg.per_host_batch, seconds=dt)
            self.history.append(float(metrics["loss"]))
            if hooks:
                for h in hooks:
                    h(self.state_step, params, metrics)
            if self.state_step % self.tcfg.log_every == 0:
                self.log(
                    f"[trainer] step {self.state_step} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms "
                    f"w={self.board.weight(self.tcfg.host_id):.2f}")
            if self.ckpt is not None and self.state_step % self.tcfg.ckpt_every == 0:
                st = self.sampler.state()
                self.ckpt.save(
                    self.state_step, {"params": params, "opt": opt_state},
                    extra={"step": self.state_step, "data": dataclasses.asdict(st)})
        if self.ckpt is not None:
            st = self.sampler.state()
            self.ckpt.save(self.state_step, {"params": params, "opt": opt_state},
                           extra={"step": self.state_step,
                                  "data": dataclasses.asdict(st)}, block=True)
            self.ckpt.wait()
        return params, opt_state


# ---------------------------------------------------------------------------
# Simulated multi-host cluster (threads sharing one window) for FT tests
# ---------------------------------------------------------------------------


class SimCluster:
    """H logical hosts as threads; shared RMA window; per-host speed model.

    Used by the fault-tolerance tests and examples: hosts claim data chunks
    via DLS; ``kill(h)`` makes a host stop claiming (its in-flight chunk is
    lost work but the *unclaimed* iteration space is picked up by others --
    with synthetic-deterministic data there is no data loss, only the
    in-flight batch's gradient contribution).
    """

    def __init__(self, n_hosts: int, n_samples: int, *, technique="fac2",
                 speeds=None):
        from repro.core.rma import ThreadWindow

        self.window = ThreadWindow()
        self.board = WeightBoard(
            n_hosts, initial_speeds=speeds if speeds is not None else None)
        self.n_hosts = n_hosts
        self.n_samples = n_samples
        self.technique = technique
        self.speeds = np.asarray(speeds if speeds is not None else np.ones(n_hosts))
        self.alive = np.ones(n_hosts, dtype=bool)
        self.claimed: list = [[] for _ in range(n_hosts)]

    def sampler(self, host_id: int, max_chunk: Optional[int] = None) -> DLSSampler:
        return DLSSampler(self.n_samples, self.n_hosts, host_id,
                          technique=self.technique, window=self.window,
                          weight_board=self.board, max_chunk=max_chunk)

    def kill(self, host_id: int):
        self.alive[host_id] = False
        self.board.mark_dead(host_id)

    def revive(self, host_id: int, rate: float = 1.0):
        self.alive[host_id] = True
        self.board.revive(host_id, rate)

    def run_epoch(self, batch_size: int, *, work_time=None,
                  kill_at: Optional[dict] = None):
        """All hosts drain one epoch; returns per-host sample counts.

        ``work_time(h)`` seconds of simulated compute per batch;
        ``kill_at={host: after_n_batches}`` schedules failures.

        Chunks are capped at 4x the batch size (LoopSpec.max_chunk) so a
        dying host strands at most that much claimed-but-unprocessed work.
        """
        import threading

        samplers = [self.sampler(h, max_chunk=4 * batch_size)
                    for h in range(self.n_hosts)]
        counts = np.zeros(self.n_hosts, dtype=np.int64)
        kill_at = kill_at or {}

        def host(h):
            n_batches = 0
            while self.alive[h]:
                t0 = time.perf_counter()
                idx = samplers[h].claim_batch(batch_size)
                if idx is None:
                    return
                if work_time is not None:
                    time.sleep(work_time(h))
                counts[h] += len(idx)
                self.claimed[h].append(idx)
                self.board.record(h, len(idx), time.perf_counter() - t0)
                n_batches += 1
                if kill_at.get(h) == n_batches:
                    self.kill(h)
                    return

        ts = [threading.Thread(target=host, args=(h,)) for h in range(self.n_hosts)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        return counts
