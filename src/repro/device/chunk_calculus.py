"""On-device chunk calculus: traceable ports of the paper's closed forms.

The distributed protocol's whole premise is that ``K'_i`` is a pure
function of the fetched step index ``i`` (core/chunk_calculus.py).  That
property survives a change of hardware: this module re-expresses the
closed forms in jax so a Pallas kernel block that fetch-adds ``i`` from
the device window can compute its chunk *on the accelerator*, with no
host round trip.

Parity contract (pinned by tests/test_device.py): for every technique
here, ``chunk_size_device(t, idx, ...)`` equals
``core.chunk_calculus.chunk_sizes_closed(host_spec(t, ...), idx)``
index-for-index.  Two numeric traps are designed around:

  * GSS: the host evaluates ``ceil(((P-1)/P)**i * N/P)`` in float64, and
    accelerators only have f32 -- where a plain f32 ``power`` disagrees
    with f64 exactly at integer ceil boundaries (e.g. N=513, P=3, i=2:
    the true value is the integer 76; f32 rounds the power up and ceils
    to 77).  The device form therefore computes the product in
    *double-float* (two-f32 compensated) arithmetic -- Dekker two-product
    and square-and-multiply over the bits of ``i``, ~48 bits of effective
    precision from f32-only ops -- which reproduces the f64 ceil on every
    grid swept (N<=100k, P<=64, plus randomized sweeps in tests).
  * FAC2 avoids floats entirely: ``ceil(0.5**b * N/P)`` is computed as
    nested integer ceil-division ``ceil(ceil(N/P) / 2**b)`` (the two are
    identical for positive integers), with ``b`` clamped so the shift
    never overflows int32 -- past that point the chunk is min_chunk
    anyway.

Techniques: the non-adaptive, non-weighted subset of the host registry
(static/SS/GSS/TSS/FAC2) plus ``fsc`` -- fixed-size chunking with a
caller-chosen K, which is the host's ``ss`` with ``min_chunk=K`` (the
``host_spec`` mapping tests pin against).  Weighted/adaptive techniques
need live telemetry and stay host-side.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.chunk_calculus import LoopSpec, tss_constants

#: Techniques the device kernels implement.  ``fsc`` is device-only
#: naming; everything else matches core.chunk_calculus.TECHNIQUES.
DEVICE_TECHNIQUES = ("static", "ss", "fsc", "gss", "tss", "fac2")


def host_spec(technique: str, N: int, P: int, chunk: int = 1,
              max_chunk: Optional[int] = None) -> LoopSpec:
    """The host ``LoopSpec`` a device schedule must match index-for-index.

    ``fsc`` (fixed-size chunking of K iterations) maps onto the host's
    ``ss`` with ``min_chunk=K``; for every other technique ``chunk`` is
    the host ``min_chunk``.
    """
    if technique not in DEVICE_TECHNIQUES:
        raise ValueError(
            f"technique {technique!r} has no device closed form; "
            f"pick from {DEVICE_TECHNIQUES}")
    t = "ss" if technique == "fsc" else technique
    return LoopSpec(t, N=N, P=P, min_chunk=chunk, max_chunk=max_chunk)


def _two_prod(a, b):
    """Dekker's exact product: a*b == p + err, f32-only (Veltkamp split)."""
    split = jnp.float32(4097.0)  # 2**12 + 1
    p = a * b
    ca = split * a
    a_hi = ca - (ca - a)
    a_lo = a - a_hi
    cb = split * b
    b_hi = cb - (cb - b)
    b_lo = b - b_hi
    err = ((a_hi * b_hi - p) + a_hi * b_lo + a_lo * b_hi) + a_lo * b_lo
    return p, err


def _df_mul(ah, al, bh, bl):
    """Double-float multiply: (ah+al)*(bh+bl) -> renormalized (hi, lo)."""
    p, e = _two_prod(ah, bh)
    e = e + (ah * bl + al * bh)
    hi = p + e
    lo = e - (hi - p)
    return hi, lo


def _gss_geometric_df(i, N: int, P: int, i_bits: int = 31):
    """``((P-1)/P)**i * (N/P)`` in double-float, then a boundary-safe ceil.

    Square-and-multiply over the ``i_bits`` bits of ``i`` keeps ~48 bits
    of effective precision from f32-only ops, so the ceil agrees with
    the host's f64 even when the true value sits exactly on an integer.
    Both constants are split hi/lo on the host in f64.  Callers that
    know a bound on ``i`` (the protocol kernel knows its step budget)
    pass a smaller ``i_bits`` to shorten the unrolled trace.
    """
    q64 = (P - 1.0) / P
    q_hi = np.float32(q64)
    q_lo = np.float32(q64 - np.float64(q_hi))
    np64 = N / P
    n_hi = np.float32(np64)
    n_lo = np.float32(np64 - np.float64(n_hi))

    fi = i.astype(jnp.int32)
    rh = jnp.ones_like(fi, jnp.float32)
    rl = jnp.zeros_like(fi, jnp.float32)
    bh = jnp.full_like(rh, q_hi)
    bl = jnp.full_like(rh, q_lo)
    i_bits = max(1, min(int(i_bits), 31))
    for bit in range(i_bits):
        take = ((fi >> bit) & 1) == 1
        mh, ml = _df_mul(rh, rl, bh, bl)
        rh = jnp.where(take, mh, rh)
        rl = jnp.where(take, ml, rl)
        if bit < i_bits - 1:
            bh, bl = _df_mul(bh, bl, bh, bl)
    vh, vl = _df_mul(rh, rl, jnp.full_like(rh, n_hi), jnp.full_like(rh, n_lo))

    # ceil(vh + vl): vl only matters when vh sits next to an integer, and
    # there (|vh - round(vh)| < 0.25) the small difference is exact in f32.
    near_int = jnp.round(vh)
    d = (vh - near_int) + vl
    near = jnp.abs(vh - near_int) < 0.25
    return jnp.where(near, near_int + (d > 0).astype(jnp.float32),
                     jnp.ceil(vh))


def chunk_size_device(technique: str, i, *, N: int, P: int, chunk: int = 1,
                      max_chunk: Optional[int] = None,
                      i_bits: int = 31):
    """K'_i as a traced int32 (scalar or array) -- Step 2 on the device.

    ``i`` may be a traced scalar (inside the protocol kernel) or an index
    array (vectorized parity checks); every op is elementwise so the same
    expression serves both.  N/P/chunk are static Python ints: the
    technique constants fold into the trace, exactly like the host PE's
    "private copy of the closed form".  ``i_bits`` (GSS only) bounds the
    bit width of ``i`` to shorten the double-float power's unrolled trace
    when the caller knows its step budget.
    """
    if technique not in DEVICE_TECHNIQUES:
        raise ValueError(
            f"technique {technique!r} has no device closed form; "
            f"pick from {DEVICE_TECHNIQUES}")
    i = jnp.asarray(i, jnp.int32)
    mc = jnp.int32(chunk)
    if technique == "static":
        k = jnp.full_like(i, -(-N // P))
    elif technique in ("ss", "fsc"):
        k = jnp.full_like(i, chunk)
    elif technique == "gss":
        # Eq. 1: ceil(((P-1)/P)^i * N/P) in double-float (module docstring).
        g = _gss_geometric_df(i, N, P, i_bits)
        k = jnp.maximum(g.astype(jnp.int32), mc)
    elif technique == "tss":
        # Eq. 2 is integer-exact: K_0 - i*C with host-computed constants.
        K0, Klast, _S, C = tss_constants(N, P, chunk)
        k = jnp.maximum(jnp.int32(K0) - i * jnp.int32(C), jnp.int32(Klast))
    else:  # fac2
        # Eq. 3 via nested integer ceil-division (see module docstring).
        # b is clamped so 1 << b stays in int32; beyond the clamp the
        # halved chunk is <= 1 <= min_chunk for any representable N.
        a = jnp.int32(-(-N // P))  # ceil(N/P)
        b = jnp.minimum(i // jnp.int32(P) + 1, 30)
        k = (a + (jnp.int32(1) << b) - 1) >> b
        k = jnp.maximum(k, mc)
    if max_chunk:
        k = jnp.minimum(k, jnp.int32(max_chunk))
    return k


def max_steps_device(technique: str, N: int, P: int, chunk: int = 1,
                     max_chunk: Optional[int] = None) -> int:
    """Static bound on scheduling steps (sizes the kernel's fori_loop and
    the schedule output buffer) -- the host bound over ``host_spec``."""
    from repro.core.chunk_calculus import max_steps_bound

    return int(max_steps_bound(host_spec(technique, N, P, chunk, max_chunk)))


def plan_device(technique: str, N: int, P: int, chunk: int = 1,
                max_chunk: Optional[int] = None):
    """Vectorized device schedule: (sizes, starts, n_valid) int32 jnp arrays.

    The batched realization of the device closed forms (padded, sizes
    truncated into [0, N)) -- the on-device analogue of
    ``core.chunk_calculus.plan`` and the cheap half of the parity pin
    (the expensive half runs the sequential protocol kernel).
    """
    S = max_steps_device(technique, N, P, chunk, max_chunk)
    idx = jnp.arange(S, dtype=jnp.int32)
    sizes = chunk_size_device(technique, idx, N=N, P=P, chunk=chunk,
                              max_chunk=max_chunk)
    csum = jnp.cumsum(sizes)
    prev = csum - sizes  # exclusive prefix = the loop pointer per step
    sizes = jnp.clip(jnp.minimum(sizes, N - prev), 0, None)
    starts = jnp.minimum(prev, N)
    n_valid = jnp.sum((sizes > 0).astype(jnp.int32))
    return sizes, starts, n_valid


def ceil_div(a: int, b: int) -> int:
    """Host-side integer ceil division (shared by the wrappers)."""
    return -(-a // b)
