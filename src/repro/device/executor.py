"""executor="device": drain the session's loop inside the protocol kernel.

One persistent-kernel launch (``device/persistent.py``) runs the whole
claim loop against the session's ``DeviceWindow`` slab; the executor then
adopts the mutated counters back into the window (so ``drained()`` /
``state()`` read the device truth), replays the granted claims into the
session's metrics plane, and emits an ordinary ``SessionReport`` whose
``chunk_times`` carry the modeled earliest-free-worker timeline -- which
is exactly what ``repro.replay`` capture -> calibrate -> gantt consume,
unchanged.

``work_fn(start, stop)`` (optional) executes each chunk host-side in
grant order -- the hook tests use to assert coverage; the persistent
*compute* kernels (kernels/*/persistent.py) are the on-device way to
attach real work to the same schedule.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.scheduler import Claim

from .persistent import claim_schedule, schedule_timeline
from .runtime import DeviceRuntime


def execute_device(session, work_fn: Optional[Callable[[int, int], None]] = None,
                   *, costs=None, interpret: Optional[bool] = None):
    """Drain ``session`` via the on-device claim loop; returns its report."""
    rt = session.runtime
    if not isinstance(rt, DeviceRuntime):
        raise ValueError(
            'executor="device" requires dls.loop(..., runtime="device") '
            f"(got a {type(rt).__name__} session)")
    spec = session.spec
    win = rt.window
    i_slot, lp_slot = rt.counter_slots()

    if costs is None:
        costs = np.ones(spec.N, np.float64)
    sched = claim_schedule(
        spec.technique, spec.N, spec.P,
        chunk=spec.min_chunk, max_chunk=spec.max_chunk,
        costs=costs, slab=win.slab(), i_slot=i_slot, lp_slot=lp_slot,
        interpret=interpret)
    win.adopt(sched.slab, n_rmw=sched.n_rmw)

    t0s, t1s = schedule_timeline(sched, costs=costs)
    rows = []
    for r in range(sched.n_steps):
        w = int(sched.workers[r])
        c = Claim(step=int(sched.steps[r]), start=int(sched.starts[r]),
                  size=int(sched.sizes[r]))
        session.log_claim(w, c)
        if work_fn is not None:
            work_fn(c.start, c.stop)
        rows.append((w, c, float(t0s[r]), float(t1s[r])))
    # record in canonical completion order (matches the sim executor)
    for w, c, t0, t1 in sorted(rows, key=lambda x: (x[2], x[3], x[0])):
        session.record_remote(w, c.size, t1 - t0, sched_seconds=0.0,
                              claim=c, t_start=t0, t_end=t1)
    return session.report("device", wall_time=sched.makespan())
