"""DeviceWindow: the paper's RMA window relocated to device memory.

The window is an int32 slab living as a jax device array (HBM on an
accelerator) with the same append-only key directory as the shared-memory
slab (``repro.pt.window``): a key is published once, its slot index never
moves, counters are monotonic per loop id.

Fallback ladder (what "atomic fetch-add against device memory" means on
each rung -- ``capability_tier()`` reports which one this process gets):

  ``atomics``   GPU backends expose real device atomics to Pallas kernels;
                the persistent kernel's claim loop would use them across
                concurrent blocks.  Probed, not yet exercised (this repo's
                CI has no GPU) -- the tier exists so ``availability()``
                consumers can route on it.
  ``aliased``   compiled TPU/CPU: the slab is threaded through jitted
                updates (host side) and through ``input_output_aliases``
                (kernel side), so every RMW is an in-place accumulator
                update on the *same* device buffer -- one logical window,
                never copied per claim.
  ``interpret`` CPU CI: the identical aliased-slab protocol runs under the
                Pallas interpreter, byte-exact with the compiled path.

Host-side ``fetch_add``/``read``/``reset`` satisfy the ordinary ``Window``
contract, so every existing consumer (``OneSidedRuntime``, sessions,
``HierarchicalWindow`` composition) works unchanged -- the counters just
happen to live on the accelerator.  ``fetch_add_traced`` is the
host-callback shim: an ordered ``io_callback`` RMW usable from *traced*
code (jitted host-plane claim loops) against the very same counters.

The in-kernel protocol (``device/persistent.py``) borrows the slab with
``slab()``/``slot()`` and hands the mutated counters back via ``adopt``.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.rma import Window


@functools.lru_cache(maxsize=2)
def _updater(donate: bool):
    """The jitted aliased-accumulator update: (old, new_slab).

    Donation makes the update genuinely in-place on backends that support
    buffer donation; the CPU backend ignores donation (with a warning), so
    the interpret tier compiles without it -- same values either way.
    """
    import jax

    def fa(slab, slot, delta):
        return slab[slot], slab.at[slot].add(delta)

    return jax.jit(fa, donate_argnums=(0,) if donate else ())


class DeviceWindow(Window):
    """Passive-target window over named int32 counters in device memory."""

    def __init__(self, capacity: int = 256, device=None):
        ok, reason = self.availability()
        if not ok:
            raise RuntimeError(f"DeviceWindow unavailable: {reason}")
        import jax
        import jax.numpy as jnp

        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self.tier = self.capability_tier()
        slab = jnp.zeros((capacity,), jnp.int32)
        if device is not None:
            slab = jax.device_put(slab, device)
        self.device = device
        self._slab = slab
        self._slots: Dict[str, int] = {}
        self._fa = _updater(donate=self.tier != "interpret")
        self.n_rmw = 0  # RMWs paid against this window (host + adopted)

    # -- capability probe (satellite: availability precedent) -------------
    @classmethod
    def availability(cls) -> "tuple[bool, str]":
        """Usable iff jax can place an array on some device.

        Like the kvstore/shm probes this is the single source of truth:
        ``make_window("device")`` and the test skips both route through it.
        """
        try:
            import jax

            jax.devices()
            return True, ""
        except Exception as e:
            return False, f"no jax device backend available ({e!r})"

    @classmethod
    def capability_tier(cls) -> str:
        """Which rung of the fallback ladder this process gets
        ('atomics' | 'aliased' | 'interpret'), see module docstring."""
        import jax

        backend = jax.default_backend()
        if backend == "gpu":
            return "atomics"
        if backend == "cpu":
            return "interpret"
        return "aliased"

    # -- slab plumbing for the persistent kernels -------------------------
    def slot(self, key: str) -> int:
        """The key's slab index (published on first use, never moves)."""
        idx = self._slots.get(key)
        if idx is None:
            if len(self._slots) >= self.capacity:
                raise RuntimeError(
                    f"device window directory full ({self.capacity} keys); "
                    "create the window with a larger capacity")
            idx = len(self._slots)
            self._slots[key] = idx
        return idx

    def keys(self) -> List[str]:
        return list(self._slots)

    def slab(self):
        """The live counter slab (hand this to the protocol kernel)."""
        return self._slab

    def adopt(self, slab, n_rmw: int = 0) -> None:
        """Take ownership of a kernel-mutated slab (+ its in-kernel RMWs)."""
        if slab.shape != (self.capacity,):
            raise ValueError(
                f"adopted slab shape {slab.shape} != ({self.capacity},)")
        self._slab = slab
        self.n_rmw += int(n_rmw)

    # -- Window contract (host side) --------------------------------------
    def fetch_add(self, key: str, delta: int) -> int:
        idx = self.slot(key)
        self.n_rmw += 1
        old, self._slab = self._fa(self._slab, idx, delta)
        return int(old)

    def read(self, key: str) -> int:
        return int(self._slab[self.slot(key)])

    def reset(self, key: str, value: int = 0) -> None:
        self._slab = self._slab.at[self.slot(key)].set(value)

    def read_many(self, keys: Sequence[str]) -> List[int]:
        # one device->host transfer for the whole batch
        host = np.asarray(self._slab)
        return [int(host[self.slot(k)]) for k in keys]

    # -- host-callback shim for traced callers ----------------------------
    def fetch_add_traced(self, key: str, delta):
        """Atomic fetch-add callable from *traced* host-plane code.

        An ordered ``io_callback`` so RMWs from inside ``jit`` serialize
        against each other and against host-side ``fetch_add`` calls --
        the shim that lets interpret-mode CI drive the one window from
        both planes byte-exactly.  Returns a traced int32 (the old value).
        """
        import jax
        import jax.numpy as jnp
        from jax.experimental import io_callback

        self.slot(key)  # publish outside the trace

        def _host_rmw(d):
            return np.int32(self.fetch_add(key, int(d)))

        return io_callback(_host_rmw, jax.ShapeDtypeStruct((), jnp.int32),
                           jnp.asarray(delta, jnp.int32), ordered=True)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"DeviceWindow(capacity={self.capacity}, tier={self.tier!r}, "
                f"keys={len(self._slots)})")
