"""repro.device -- the paper's protocol *inside* the kernel.

Everything below the ``dls`` facade so far ran the claim loop on the
host: threads, real processes, or the DES, all fetch-adding counters a
host-side ``Window`` holds.  This package relocates the RMA window into
device memory and lets a fixed set of Pallas program instances claim
variable-sized tile chunks straight from it -- the ROADMAP's "DLS
on-device" item (see DESIGN.md Sec. 14):

  window.py         ``DeviceWindow``: the two protocol counters in an
                    int32 device-array slab behind the ordinary
                    ``Window`` contract (fallback ladder: on-device
                    atomics -> input/output-aliased slab update ->
                    interpret mode, byte-exact on CPU CI; plus an
                    ``io_callback`` shim for traced host-plane code).
  chunk_calculus.py jax-traceable SS/FSC/GSS/TSS/FAC2 closed forms,
                    index-for-index equal to ``core.chunk_calculus``.
  persistent.py     the protocol kernel: one persistent launch walks
                    Step 1-3 of the paper against the aliased slab and
                    emits the full (step, worker, start, size) schedule.
  runtime.py        ``DeviceRuntime`` -- ``OneSidedRuntime`` over a
                    ``DeviceWindow`` (``dls.loop(runtime="device")``).
  executor.py       ``executor="device"``: run the in-kernel protocol,
                    adopt the final counters, replay the device-made
                    schedule into an ordinary ``SessionReport``.
"""
from .chunk_calculus import (  # noqa: F401
    DEVICE_TECHNIQUES,
    chunk_size_device,
    host_spec,
)
from .executor import execute_device  # noqa: F401
from .persistent import DeviceSchedule, claim_schedule, schedule_timeline  # noqa: F401
from .runtime import DEVICE_SPEC_TECHNIQUES, DeviceRuntime  # noqa: F401
from .window import DeviceWindow  # noqa: F401
