"""The persistent protocol kernel: Step 1-3 of the paper inside Pallas.

One ``pallas_call`` launch owns the whole scheduling loop.  The window
counters arrive as an input/output-aliased int32 slab (the device window
itself -- never copied, handed back mutated), and the kernel repeats the
paper's protocol until the loop drains:

  Step 1  fetch-add the step counter ``i``     (slab RMW)
  Step 2  K'_i from the on-device closed form  (device/chunk_calculus.py)
  Step 3  fetch-add the loop pointer ``lp``    (slab RMW)
  ...     truncate into [0, N), append (i, worker, start, size) to the
          schedule output.

Worker assignment: a fixed fleet of ``P`` program instances is modeled by
per-worker virtual clocks held in the kernel -- each claim goes to the
worker with the minimum accumulated cost (ties to the lowest index), and
that worker's clock advances by the chunk's cost (a prefix-sum lookup
over the caller's per-tile cost model).  This is exactly "the next claim
is taken by the earliest-free block": on sequentially-executed grids
(TPU cores, interpret mode) it is the deterministic realization of the
concurrent protocol, byte-stable for CI, and the emitted schedule is what
the persistent *compute* kernels (kernels/*/persistent.py) then execute
with real parallel programs.

Chunk-sequence parity with the host ``plan()`` is pinned index-for-index
(tests/test_device.py): same technique, same (N, P, chunk) => same
(start, size) sequence, summing exactly to N.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

from repro.core.chunk_calculus import max_steps_bound

from .chunk_calculus import chunk_size_device, host_spec


def _protocol_kernel(
    ctr_in,      # (cap,) int32 -- the device window slab (aliased)
    csum_ref,    # (N+1,) f32   -- prefix sum of per-iteration costs
    ctr_out,     # (cap,) int32 -- aliased output (the same slab)
    sched_ref,   # (S, 4) int32 -- rows (step, worker, start, size)
    clocks_ref,  # (P,) f32     -- per-worker virtual busy clocks
    counts_ref,  # (P,) int32   -- per-worker (per-block) claim counts
    *,
    technique: str,
    N: int,
    P: int,
    chunk: int,
    max_chunk: Optional[int],
    S: int,
    i_slot: int,
    lp_slot: int,
):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    ctr_out[...] = ctr_in[...]
    sched_ref[...] = jnp.full((S, 4), -1, jnp.int32)
    clocks_ref[...] = jnp.zeros((P,), jnp.float32)
    counts_ref[...] = jnp.zeros((P,), jnp.int32)

    def step(s, carry):
        lp = ctr_out[lp_slot]

        @pl.when(lp < N)
        def _claim():
            i = ctr_out[i_slot]          # Step 1: fetch...
            ctr_out[i_slot] = i + 1      # ...add
            # Step 2 (local): i < 2*S here (resumed loops start past 0),
            # so the GSS double-float power unrolls only that many bits
            k = chunk_size_device(technique, i, N=N, P=P, chunk=chunk,
                                  max_chunk=max_chunk,
                                  i_bits=(2 * S).bit_length())
            start = ctr_out[lp_slot]     # Step 3: fetch...
            ctr_out[lp_slot] = start + k  # ...add

            @pl.when(start < N)
            def _grant():
                size = jnp.minimum(k, N - start)
                w = jnp.argmin(clocks_ref[...]).astype(jnp.int32)
                cost = csum_ref[start + size] - csum_ref[start]
                clocks_ref[w] = clocks_ref[w] + cost
                counts_ref[w] = counts_ref[w] + 1
                sched_ref[s, 0] = i
                sched_ref[s, 1] = w
                sched_ref[s, 2] = start
                sched_ref[s, 3] = size

        return carry

    jax.lax.fori_loop(0, S, step, 0)


@dataclasses.dataclass
class DeviceSchedule:
    """A fully-materialized device-made schedule (+ the mutated slab).

    ``steps/workers/starts/sizes`` are the granted claims in protocol
    order; ``counts``/``clocks`` are the per-block claim counts and
    modeled busy clocks the report plane surfaces; ``slab`` is the
    window slab *after* the kernel ran (adopt it back into the window).
    """

    technique: str
    N: int
    P: int
    chunk: int
    steps: np.ndarray    # (n_steps,) int32
    workers: np.ndarray  # (n_steps,) int32
    starts: np.ndarray   # (n_steps,) int32
    sizes: np.ndarray    # (n_steps,) int32
    counts: np.ndarray   # (P,) int64 per-worker claim counts
    clocks: np.ndarray   # (P,) float modeled busy time
    slab: object         # jnp (cap,) int32 -- final window counters

    @property
    def n_steps(self) -> int:
        return len(self.sizes)

    @property
    def n_rmw(self) -> int:
        """Protocol RMWs the kernel paid (two fetch-adds per step)."""
        return 2 * self.n_steps

    def makespan(self) -> float:
        """Modeled finish time of the busiest worker."""
        return float(self.clocks.max()) if len(self.clocks) else 0.0

    def worker_lists(self):
        """Padded per-worker claim tables for the compute kernels.

        Returns ``(nclaims (P,), starts (P, C), sizes (P, C))`` int32,
        ``C = max(claims per worker, 1)``; padding rows are zero-sized.
        """
        C = max(int(self.counts.max()) if len(self.counts) else 0, 1)
        nclaims = np.zeros(self.P, np.int32)
        starts = np.zeros((self.P, C), np.int32)
        sizes = np.zeros((self.P, C), np.int32)
        for w, st, sz in zip(self.workers, self.starts, self.sizes):
            c = nclaims[w]
            starts[w, c] = st
            sizes[w, c] = sz
            nclaims[w] = c + 1
        return nclaims, starts, sizes


def claim_schedule(
    technique: str,
    N: int,
    P: int,
    *,
    chunk: int = 1,
    max_chunk: Optional[int] = None,
    costs=None,
    slab=None,
    i_slot: int = 0,
    lp_slot: int = 1,
    max_steps: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> DeviceSchedule:
    """Run the in-kernel claim loop over ``[0, N)`` with ``P`` workers.

    ``costs`` is the per-iteration cost model (length N; uniform when
    None) driving the earliest-free-worker assignment; ``slab`` is a
    device window slab whose ``i_slot``/``lp_slot`` counters seed the
    protocol (fresh zeros when None -- note nonzero counters resume a
    partially-drained loop, exactly like the host runtime).  Runs under
    the Pallas interpreter on CPU (``kernels.resolve_interpret``).
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from repro.kernels import resolve_interpret

    interpret = resolve_interpret(interpret)
    spec = host_spec(technique, N, P, chunk, max_chunk)
    S = int(max_steps or max_steps_bound(spec))

    if costs is None:
        costs = np.ones(N, np.float32)
    costs = np.asarray(costs, np.float64)
    if costs.shape != (N,):
        raise ValueError(f"costs must have shape ({N},), got {costs.shape}")
    csum = np.zeros(N + 1, np.float32)
    np.cumsum(costs, out=csum[1:])

    if slab is None:
        slab = jnp.zeros(max(i_slot, lp_slot) + 1, jnp.int32)
    cap = int(slab.shape[0])
    if not (0 <= i_slot < cap and 0 <= lp_slot < cap and i_slot != lp_slot):
        raise ValueError(f"bad counter slots ({i_slot}, {lp_slot}) "
                         f"for slab of capacity {cap}")

    kern = functools.partial(
        _protocol_kernel, technique=technique, N=N, P=P, chunk=chunk,
        max_chunk=max_chunk, S=S, i_slot=i_slot, lp_slot=lp_slot)
    new_slab, sched, clocks, counts = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((cap,), lambda g: (0,)),
            pl.BlockSpec((N + 1,), lambda g: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((cap,), lambda g: (0,)),
            pl.BlockSpec((S, 4), lambda g: (0, 0)),
            pl.BlockSpec((P,), lambda g: (0,)),
            pl.BlockSpec((P,), lambda g: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap,), jnp.int32),
            jax.ShapeDtypeStruct((S, 4), jnp.int32),
            jax.ShapeDtypeStruct((P,), jnp.float32),
            jax.ShapeDtypeStruct((P,), jnp.int32),
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(slab, jnp.asarray(csum))

    sched = np.asarray(sched)
    n = int((sched[:, 1] >= 0).sum())  # granted rows form a prefix
    return DeviceSchedule(
        technique=technique, N=N, P=P, chunk=chunk,
        steps=sched[:n, 0].copy(), workers=sched[:n, 1].copy(),
        starts=sched[:n, 2].copy(), sizes=sched[:n, 3].copy(),
        counts=np.asarray(counts, np.int64), clocks=np.asarray(clocks),
        slab=new_slab)


def schedule_timeline(schedule: DeviceSchedule, costs=None):
    """Per-claim (t0, t1) under the earliest-free-worker model.

    Recomputes the kernel's clock walk on the host (same csum, same
    order => same numbers) so executors can emit ``chunk_times`` rows
    without shipping timestamps out of the kernel.
    """
    N = schedule.N
    if costs is None:
        costs = np.ones(N, np.float64)
    csum = np.zeros(N + 1, np.float32)
    np.cumsum(np.asarray(costs, np.float64), out=csum[1:])
    clocks = np.zeros(schedule.P, np.float32)
    t0s = np.zeros(schedule.n_steps, np.float64)
    t1s = np.zeros(schedule.n_steps, np.float64)
    for r, (w, st, sz) in enumerate(
            zip(schedule.workers, schedule.starts, schedule.sizes)):
        cost = csum[st + sz] - csum[st]
        t0s[r] = clocks[w]
        clocks[w] = np.float32(clocks[w] + cost)
        t1s[r] = clocks[w]
    return t0s, t1s
