"""DeviceRuntime: the one-sided protocol over a device-memory window.

Deliberately *is a* ``OneSidedRuntime`` -- the claim protocol (two atomic
fetch-adds + local closed form) is untouched; only where the counters
live changes.  That inheritance is also what keeps the reporting plane
unchanged: ``DLSession.runtime_kind`` stays ``"one_sided"``, so device
traces calibrate and re-simulate through ``repro.replay`` with the
one-sided DES model (the correct one -- the protocol is one-sided).

Host-side ``claim()`` works (each RMW is one aliased slab update), which
is how checkpoint/restore and partially-host runs interoperate; the fast
path is ``executor="device"`` (``device/executor.py``), which runs the
*entire* claim loop inside the persistent kernel and adopts the final
counters, so ``drained()``/``state()`` afterwards read the device truth.
"""
from __future__ import annotations

from typing import Optional

from repro.core.chunk_calculus import LoopSpec
from repro.core.scheduler import OneSidedRuntime

from .chunk_calculus import DEVICE_TECHNIQUES
from .window import DeviceWindow

#: host-registry techniques the device closed forms cover ("fsc" is the
#: device-only alias of ss-with-chosen-K and never appears in a LoopSpec).
DEVICE_SPEC_TECHNIQUES = tuple(t for t in DEVICE_TECHNIQUES if t != "fsc")


class DeviceRuntime(OneSidedRuntime):
    """Distributed chunk calculation with the window in device memory."""

    def __init__(self, spec: LoopSpec, window: Optional[DeviceWindow] = None,
                 loop_id: Optional[int] = None):
        if spec.technique not in DEVICE_SPEC_TECHNIQUES:
            raise ValueError(
                f"technique {spec.technique!r} has no device closed form "
                f"(weighted/adaptive techniques need live host telemetry); "
                f"pick from {DEVICE_SPEC_TECHNIQUES}")
        if spec.weights is not None:
            raise ValueError("runtime=\"device\" techniques are unweighted")
        if window is None:
            window = DeviceWindow()
        if not isinstance(window, DeviceWindow):
            raise TypeError(
                f"DeviceRuntime needs a DeviceWindow, got {type(window).__name__}")
        super().__init__(spec, window, loop_id=loop_id)
        # Publish both counters now so their slab slots exist before any
        # kernel launch borrows the slab.
        window.slot(self._ki)
        window.slot(self._kl)

    def counter_slots(self) -> "tuple[int, int]":
        """(i_slot, lp_slot) -- where the kernel finds this loop's counters."""
        return self.window.slot(self._ki), self.window.slot(self._kl)
