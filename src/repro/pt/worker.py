"""Child-process side of the ``processes`` executor.

Everything here is importable with no side effects (spawn/forkserver rule:
children re-import this module and rebuild all state from the picklable
``cfg`` dict the executor hands to :func:`pe_main`).  A worker:

  1. attaches the shared window by name and rebuilds the *same* runtime the
     parent session holds (same ``loop_id`` -> same counter namespace), or,
     for two-sided runtimes, a queue-backed claim proxy served by the
     master in the parent;
  2. rebuilds its weight policy -- adaptive variants bind to the shared
     telemetry slab, so all PEs adapt off one cross-process PerfModel
     plane, exactly like threads over one window;
  3. runs the unmodified claim loop: timed claim, publish the in-flight
     range to its crash slot, execute in ``progress``-sized sub-blocks
     (bumping the slot's high-water mark), report the chunk record to the
     parent, clear the slot;
  4. after its drain, blocks on the orphan queue: ranges abandoned by dead
     PEs are re-executed by survivors until the parent sends the sentinel.

Crash slots (one per PE in a lock-free ``mp.Array`` of int64, single
writer each) are what make death accountable: ``seq`` pairs the slot with
the last chunk record the parent actually received, so the monitor can
tell "died before reporting" from "reported then died", synthesize a
record for the executed prefix, and orphan exactly the unexecuted
remainder.  See DESIGN.md Sec. 11.
"""
from __future__ import annotations

import os
import time
import traceback
from typing import Optional

from repro.core import chunk_calculus as cc
from repro.core.chunk_calculus import AWF_VARIANTS, WEIGHTED
from repro.core.scheduler import Claim, HierarchicalRuntime, OneSidedRuntime
from repro.core.weights import WeightBoard
from repro.dls.policies import (
    AdaptiveFactoring,
    AdaptiveWeights,
    AWFVariantWeights,
)

from .window import SharedMemWindow, attach_hier

# The PE index this process is running as (None in the parent).  Workloads
# may consult it -- the fault-tolerance tests use it to make one specific
# PE die mid-chunk.
CURRENT_PE: Optional[int] = None

# crash-slot field offsets (int64 x SLOT_FIELDS per PE, single writer)
SLOT_FIELDS = 6
SEQ, STATE, START, STOP, DONE, T0_US = range(SLOT_FIELDS)
IDLE, CHUNK, ORPHAN = 0, 1, 2


def _publish(slots, pe: int, seq: int, state: int, start: int, stop: int,
             t0_us: int) -> None:
    b = pe * SLOT_FIELDS
    slots[b + SEQ] = seq
    slots[b + START] = start
    slots[b + STOP] = stop
    slots[b + DONE] = start
    slots[b + T0_US] = t0_us
    slots[b + STATE] = state  # last: STATE is the "slot is valid" flag


def _clear(slots, pe: int) -> None:
    slots[pe * SLOT_FIELDS + STATE] = IDLE


def _exec_range(work_fn, start: int, stop: int, stride: int,
                slots, pe: int) -> None:
    b = pe * SLOT_FIELDS
    a = start
    while a < stop:
        nxt = min(a + stride, stop)
        if work_fn is not None:
            work_fn(a, nxt)
        a = nxt
        slots[b + DONE] = a  # crash high-water mark


class QueueRuntime:
    """Two-sided claim proxy: requests go to the master in the parent."""

    def __init__(self, req_q, reply_q, pe: int):
        self._req = req_q
        self._reply = reply_q
        self._pe = pe

    def claim(self, pe: int = 0, weight=None, af=None) -> Optional[Claim]:
        # weight/af are computed master-side from the parent's policy (the
        # two-sided protocol: the master owns all scheduling state)
        self._req.put(("req", self._pe))
        c = self._reply.get()
        return None if c is None else Claim(*c)


def _build_runtime(cfg):
    rcfg = cfg["runtime"]
    kind = rcfg["kind"]
    if kind == "one_sided":
        win = SharedMemWindow.attach(rcfg["window"])
        rt = OneSidedRuntime(cfg["spec"], win, loop_id=rcfg["loop_id"])
        return rt, win
    if kind == "hierarchical":
        hw = attach_hier(rcfg["window"])
        rt = HierarchicalRuntime(cfg["spec"], rcfg["nodes"], hw,
                                 inner_technique=rcfg["inner_technique"],
                                 loop_id=rcfg["loop_id"])
        return rt, hw
    if kind == "two_sided":
        return QueueRuntime(cfg["req_q"], cfg["reply_q"], cfg["pe"]), None
    raise ValueError(f"unknown runtime kind {kind!r}")


def _build_policy(cfg):
    """Child-side weight policy per the parent's descriptor.

    AWF-B/C/D/E and AF bind to the shared telemetry slab (cross-process
    PerfModel); plain AWF keeps a process-local WeightBoard (its EMA state
    is not window-backed -- prefer the variants for processes runs).
    """
    pcfg = cfg["policy"]
    kind = pcfg.get("kind", "uniform")
    P = cfg["spec"].P
    tele = pcfg.get("telemetry")
    win = SharedMemWindow.attach(tele) if tele is not None else None
    if kind == "af":
        return AdaptiveFactoring(P, window=win)
    if kind in AWF_VARIANTS:
        return AWFVariantWeights(P, variant=kind, window=win)
    if kind == "awf":
        return AdaptiveWeights(WeightBoard(P))
    return None  # uniform/static -- weight comes from pcfg["weights"]/spec


def pe_main(cfg) -> None:
    """Process entry point for one PE (all runtimes)."""
    global CURRENT_PE
    pe = cfg["pe"]
    CURRENT_PE = pe
    rec_q = cfg["rec_q"]
    try:
        _pe_body(cfg, pe, rec_q)
    except BaseException:
        try:
            rec_q.put({"kind": "error", "pe": pe,
                       "trace": traceback.format_exc()})
        except Exception:
            pass
        os._exit(1)


def _pe_body(cfg, pe: int, rec_q) -> None:
    spec: cc.LoopSpec = cfg["spec"]
    rt, win = _build_runtime(cfg)
    policy = _build_policy(cfg)
    pcfg = cfg["policy"]
    static_w = pcfg.get("weights")
    wants_af = pcfg.get("wants_af", False) and hasattr(policy, "af_stats")
    two_sided = cfg["runtime"]["kind"] == "two_sided"
    if (isinstance(rt, HierarchicalRuntime) and spec.technique in WEIGHTED
            and hasattr(policy, "node_weight")):
        bounds = rt._bounds
        rt.outer_weight_fn = lambda node: policy.node_weight(node, bounds)

    slots = cfg["slots"]
    orphan_q = cfg["orphan_q"]
    work_fn = cfg["work_fn"]
    stride = cfg["progress"]

    cfg["barrier"].wait()  # everyone attached; parent stamps the origin
    origin = cfg["origin"].value

    n_chunks = 0
    seq = 0
    while True:
        tc = time.monotonic()
        if two_sided:
            c = rt.claim(pe)  # master computes weight/af from its policy
        else:
            w = policy.weight(pe) if policy is not None else (
                static_w[pe] if static_w is not None else None)
            af = policy.af_stats(pe) if wants_af else None
            c = rt.claim(pe, weight=w, af=af)
        lat = time.monotonic() - tc
        if c is None:
            break
        seq += 1
        t0 = time.monotonic() - origin
        _publish(slots, pe, seq, CHUNK, c.start, c.stop, int(t0 * 1e6))
        _exec_range(work_fn, c.start, c.stop, stride, slots, pe)
        t1 = time.monotonic() - origin
        if policy is not None and not two_sided:
            policy.record(pe, c.size, t1 - t0, lat)
        n_chunks += 1
        rec_q.put({"kind": "chunk", "pe": pe, "seq": seq, "step": c.step,
                   "start": c.start, "size": c.size, "t0": t0, "t1": t1,
                   "lat": lat})
        _clear(slots, pe)

    rec_q.put({"kind": "drained", "pe": pe})

    # orphan phase: survivors re-execute ranges abandoned by dead PEs
    n_orphans = 0
    while True:
        item = orphan_q.get()
        if item is None:
            break
        start, stop, from_pe = item
        seq += 1
        t0 = time.monotonic() - origin
        _publish(slots, pe, seq, ORPHAN, start, stop, int(t0 * 1e6))
        _exec_range(work_fn, start, stop, stride, slots, pe)
        t1 = time.monotonic() - origin
        if policy is not None and not two_sided:
            policy.record(pe, stop - start, t1 - t0, 0.0)
        n_orphans += 1
        rec_q.put({"kind": "orphan", "pe": pe, "seq": seq, "start": start,
                   "size": stop - start, "t0": t0, "t1": t1,
                   "from_pe": from_pe})
        _clear(slots, pe)

    if isinstance(win, SharedMemWindow):
        g_rmw, l_rmw, backend = win.n_rmw, 0, win.backend
    elif win is not None:  # hierarchical composition
        g_rmw, l_rmw = win.n_rmw_global, win.n_rmw_local
        backend = win.global_window.backend
    else:
        g_rmw, l_rmw, backend = 0, 0, "queue"
    rec_q.put({"kind": "exit", "pe": pe, "pid": os.getpid(),
               "n_chunks": n_chunks, "n_orphans": n_orphans,
               "rmw_global": g_rmw, "rmw_local": l_rmw, "backend": backend})


def hammer_main(desc, key: str, ops: int, barrier, out_q) -> None:
    """Contention-measurement child: ``ops`` fetch-adds on one hot key."""
    win = SharedMemWindow.attach(desc)
    win.fetch_add(key, 0)  # fault in the slot + directory cache
    barrier.wait()
    t0 = time.perf_counter()
    for _ in range(ops):
        win.fetch_add(key, 1)
    out_q.put(time.perf_counter() - t0)
    win.close()
