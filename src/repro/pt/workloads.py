"""Picklable work functions for the ``processes`` executor.

Under spawn/forkserver a ``work_fn`` travels to the worker by pickle, so
it must be a module-level function (or ``functools.partial`` of one) --
closures and lambdas only survive ``fork``.  These cover what the tests,
benchmarks, and examples need:

  * ``mark_hits`` -- each executed iteration increments one byte of a named
    shared-memory array; conservation checks then assert every byte == 1
    (exactly-once execution across all processes).
  * ``sleep_iters`` -- per-iteration sleep costs: the cross-process
    analogue of the DES's cost vector.  Sleeps overlap across processes
    even on a single core, so measured T_loop tracks the DES's parallel
    model on any machine.
  * ``die_at`` -- kills the process (``os._exit``) when a chosen PE first
    reaches a chosen iteration: the deterministic mid-chunk death used by
    the fault-tolerance tests.  Dying at a sub-block boundary keeps the
    crash slot's high-water mark exact (see DESIGN.md Sec. 11).

``alloc_hits``/``read_hits`` manage the hits array; workers attach it once
per process (cached); the creating process owns its lifetime.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional

_attached: Dict[str, "object"] = {}  # per-process cache: name -> SharedMemory


def alloc_hits(n: int):
    """Create a zeroed n-byte hits array; returns (shm, name).  The caller
    owns it: close()+unlink() when done."""
    from multiprocessing import shared_memory

    shm = shared_memory.SharedMemory(create=True, size=max(n, 1))
    shm.buf[:n] = bytes(n)
    return shm, shm.name


def _attach(name: str):
    shm = _attached.get(name)
    if shm is None:
        from multiprocessing import shared_memory

        # attachers share the owner's resource tracker (mp children inherit
        # the tracker fd), so the duplicate register dedupes -- no
        # unregister, or the owner's registration would be dropped
        shm = shared_memory.SharedMemory(name=name, create=False)
        _attached[name] = shm
    return shm


def read_hits(name: str, n: int) -> bytes:
    return bytes(_attach(name).buf[:n])


def mark_hits(name: str, a: int, b: int) -> None:
    """work_fn: increment hits[a:b] (use functools.partial(mark_hits, name))."""
    buf = _attach(name).buf
    for i in range(a, b):
        buf[i] += 1


def sleep_iters(cost_us: float, a: int, b: int) -> None:
    """work_fn: homogeneous per-iteration cost of ``cost_us`` microseconds."""
    time.sleep((b - a) * cost_us * 1e-6)


def sleep_iters_var(costs, a: int, b: int) -> None:
    """work_fn: per-iteration costs in *seconds* from a pickled sequence."""
    time.sleep(float(sum(costs[a:b])))


_calls = 0  # per-process count of the victim's executed sub-blocks


def die_at(name: str, victim_pe: int, die_after: int, cost_us: float,
           a: int, b: int) -> None:
    """work_fn: ``mark_hits`` + sleep, but the victim PE dies (SIGKILL-style
    ``os._exit``) on its ``die_after + 1``-th handed sub-block -- *before*
    executing it, so the crash slot's high-water mark is exact and the
    remainder is recoverable.  Deterministic: every PE is guaranteed its
    batch-0 chunk (claims are independent and barrier-synced), so with
    ``die_after >= 1`` the victim dies *mid-chunk* whenever its first chunk
    spans multiple sub-blocks -- exercising both salvage (executed prefix)
    and orphaning (unexecuted remainder)."""
    global _calls
    from . import worker

    if worker.CURRENT_PE == victim_pe:
        if _calls >= die_after:
            os._exit(77)
        _calls += 1
    if cost_us:
        time.sleep((b - a) * cost_us * 1e-6)
    mark_hits(name, a, b)
