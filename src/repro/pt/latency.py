"""Measured RMW latency and contention scaling of the real window.

The calibrated-DES loop (``repro.replay``) fits the window service time
``o_rma`` from claim latencies *inside* a traced run.  This module closes
the loop from the other side: measure the fetch-and-add cost directly
against a live :class:`SharedMemWindow` --

  * **uncontended** (:func:`measure_rmw_latency`): one process in a tight
    fetch-add loop; the per-op mean/min is the slab's intrinsic RMW
    service time for the active atomicity backend ("atomics" vs "lockf"
    differ by an order of magnitude -- the report records which one ran);
  * **contended** (:func:`measure_contention`): P real OS processes all
    hammering *one hot key* -- the chunk-calculus serialization point the
    paper's scalability argument is about.  Each child's perceived per-op
    latency grows ~linearly with P when RMWs serialize; the returned
    per-P table is the measured analogue of the DES's window queue.

``RMWLatency.calibration_overrides()`` packages the measurement as the
``o_rma=``/``o_rma_local=`` keyword overrides that
:func:`repro.replay.calibrate` accepts, so ``benchmarks/pt_contention.py``
can pin measured-vs-predicted T_loop with *measured* constants instead of
trace-fitted ones.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence

from .window import SharedMemWindow


@dataclasses.dataclass
class RMWLatency:
    """One measurement of the live window's fetch-and-add cost."""

    backend: str  # atomicity backend that actually ran ("atomics"/"lockf")
    o_rma_mean: float  # uncontended per-op latency, mean [s]
    o_rma_min: float  # uncontended per-op latency, min over repeats [s]
    ops: int  # fetch-adds per timing repeat
    # contention table: P -> mean per-op latency perceived by one of P
    # concurrently hammering processes [s]; empty if not measured
    per_p: Dict[int, float] = dataclasses.field(default_factory=dict)

    def calibration_overrides(self, contended_p: Optional[int] = None) -> dict:
        """Keyword overrides for :func:`repro.replay.calibrate`.

        With ``contended_p`` the override is the latency measured *at that
        process count* (what a claim actually pays mid-run); without it,
        the uncontended mean.  Node-local windows are the same slab
        mechanism, so ``o_rma_local`` gets the uncontended figure.
        """
        o = self.per_p.get(contended_p, self.o_rma_mean) \
            if contended_p is not None else self.o_rma_mean
        return {"o_rma": o, "o_rma_local": self.o_rma_mean}

    def summary(self) -> str:
        tbl = " ".join(f"P={p}:{v * 1e6:.1f}us"
                       for p, v in sorted(self.per_p.items()))
        return (f"rmw[{self.backend}] uncontended "
                f"mean={self.o_rma_mean * 1e6:.2f}us "
                f"min={self.o_rma_min * 1e6:.2f}us ops={self.ops}"
                + (f" contended: {tbl}" if tbl else ""))


def measure_rmw_latency(window: Optional[SharedMemWindow] = None,
                        ops: int = 2000, repeats: int = 5) -> RMWLatency:
    """Uncontended per-RMW latency of a shared-memory window.

    Times ``repeats`` runs of ``ops`` fetch-adds on one key (slot faulted
    in first, so the directory scan is off the clock) and reports
    mean-of-means and the min single run.  Owns (and unlinks) a fresh
    window unless one is passed in.
    """
    own = window is None
    win = SharedMemWindow.create(capacity=64) if own else window
    try:
        win.fetch_add("lat/probe", 0)  # fault in slot + per-instance cache
        samples = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(ops):
                win.fetch_add("lat/probe", 1)
            samples.append((time.perf_counter() - t0) / ops)
        return RMWLatency(backend=win.backend,
                          o_rma_mean=sum(samples) / len(samples),
                          o_rma_min=min(samples), ops=ops)
    finally:
        if own:
            win.close()


def measure_contention(p_list: Sequence[int] = (1, 2, 4, 8),
                       ops: int = 500,
                       start_method: Optional[str] = None,
                       base: Optional[RMWLatency] = None) -> RMWLatency:
    """Contention scaling: P real processes fetch-adding one hot key.

    For each P in ``p_list``, spawns P children (``worker.hammer_main``)
    that attach the slab by name, rendezvous on a barrier, then each issue
    ``ops`` fetch-adds on the *same* key.  The per-P figure is the mean
    per-op latency perceived across children -- i.e. what a claim pays at
    that contention level.  Extends ``base`` (or a fresh uncontended
    measurement) with the ``per_p`` table.
    """
    from .executor import _get_ctx, pick_start_method

    lat = base or measure_rmw_latency(ops=max(ops, 500))
    ctx = _get_ctx(pick_start_method(start_method))
    for p in p_list:
        win = SharedMemWindow.create(capacity=64)
        try:
            win.fetch_add("lat/hot", 0)
            barrier = ctx.Barrier(p + 1)
            out_q = ctx.Queue()
            procs = [ctx.Process(target=_hammer_entry,
                                 args=(win.descriptor(), "lat/hot", ops,
                                       barrier, out_q),
                                 daemon=True)
                     for _ in range(p)]
            for pr in procs:
                pr.start()
            barrier.wait()
            elapsed = [out_q.get(timeout=60.0) for _ in range(p)]
            for pr in procs:
                pr.join(timeout=10.0)
            lat.per_p[p] = sum(elapsed) / len(elapsed) / ops
            assert win.read("lat/hot") == p * ops, "contention run lost RMWs"
        finally:
            win.close()
    return lat


def _hammer_entry(desc, key, ops, barrier, out_q):
    # module-level shim: picklable under spawn/forkserver
    from repro.pt.worker import hammer_main

    hammer_main(desc, key, ops, barrier, out_q)
