"""The ``processes`` executor: one real OS process per PE.

Reached through ``dls.loop(...).execute(work_fn, executor="processes")``.
The parent keeps the session (metrics, policy, report); each PE is a child
process that attaches the session's :class:`SharedMemWindow` by name and
runs the unmodified claim protocol (``repro.pt.worker``).  Two-sided
runtimes keep the master in the parent (non-dedicated: it serves the
request queue between executing its own chunks, exactly like the threads
executor's master thread).

Start methods (spawn-safety, mirroring ``repro.sim.batch``): ``fork`` only
when the parent is provably fork-safe (single-threaded, no jax);
``forkserver`` otherwise -- its server process is spawned fresh, so a
jax-infested parent cannot poison children.  ``spawn`` works too (workers
rebuild everything from picklable descriptors); pick explicitly with
``start_method=`` or ``REPRO_PT_START_METHOD``.  ``work_fn`` must be
picklable under spawn/forkserver -- use module-level functions/partials
(see ``repro.pt.workloads``).

Fault story: each worker publishes its in-flight range to a crash slot
before executing and bumps a high-water mark per sub-block.  The parent's
monitor harvests dead workers (no exit record + process gone): the
executed prefix becomes a synthesized chunk record, the unexecuted
remainder goes on the orphan queue, and drained survivors re-execute it
-- conservation holds to exactly N.  All-workers-dead (no survivor to
re-claim) raises, mirroring the DES's PEFailure scenario.  A SIGKILL that
lands *inside* the claim protocol itself (between the window fetch-adds
and the slot publish, a ~microsecond window) can strand iterations
unaccountably -- the honest limit of crash recovery without transactional
claims; the fault tests therefore kill at sub-block boundaries.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import queue as _queue
import sys
import threading
import time
from typing import Dict, List, Optional

from repro.core.rma import HierarchicalWindow
from repro.core.scheduler import (
    Claim,
    HierarchicalRuntime,
    OneSidedRuntime,
    TwoSidedRuntime,
)

from . import worker as W
from .window import SharedMemWindow, hier_descriptor

_FORKSERVER_READY = set()


def pick_start_method(start_method: Optional[str] = None) -> str:
    """fork when provably safe, else forkserver (fresh server process)."""
    m = start_method or os.environ.get("REPRO_PT_START_METHOD")
    if m:
        return m
    if threading.active_count() == 1 and "jax" not in sys.modules:
        return "fork"
    return "forkserver"


def _get_ctx(method: str):
    ctx = mp.get_context(method)
    if method == "forkserver" and method not in _FORKSERVER_READY:
        try:  # server imports the worker once; every fork after is cheap
            ctx.set_forkserver_preload(["repro.pt.worker"])
        except Exception:
            pass
        _FORKSERVER_READY.add(method)
    return ctx


def _runtime_desc(session) -> Dict:
    rt = session.runtime
    if isinstance(rt, HierarchicalRuntime):
        win = rt.window
        if not (isinstance(win, HierarchicalWindow)
                and isinstance(win.global_window, SharedMemWindow)
                and all(isinstance(w, SharedMemWindow)
                        for w in win.local_windows)):
            raise ValueError(
                'executor="processes" needs an all-shared-memory '
                'hierarchical window -- open the session with '
                'dls.loop(..., runtime="hierarchical", window="shm")')
        return {"kind": "hierarchical", "window": hier_descriptor(win),
                "nodes": rt.nodes, "inner_technique": rt.inner_technique,
                "loop_id": rt.loop_id}
    if isinstance(rt, OneSidedRuntime):
        if not isinstance(rt.window, SharedMemWindow):
            raise ValueError(
                'executor="processes" needs a cross-process window -- open '
                'the session with dls.loop(..., window="shm")')
        return {"kind": "one_sided", "window": rt.window.descriptor(),
                "loop_id": rt.loop_id}
    if isinstance(rt, TwoSidedRuntime):
        return {"kind": "two_sided"}
    raise TypeError(f"unsupported runtime {type(rt).__name__}")


def _policy_desc(session, two_sided: bool):
    """(descriptor for children, telemetry slab or None).

    Adaptive one-sided/hierarchical policies get a dedicated telemetry
    slab: children bind the same PerfModel plane to it, and the parent's
    policy is rebound onto it too, so post-run weight queries see the
    children's measurements.  (Separate slab on purpose: telemetry RMWs
    stay out of the scheduling window's per-PE RMW accounting.)
    Two-sided children carry no policy -- the master computes weights
    parent-side, the protocol's point.
    """
    from repro.core.chunk_calculus import AWF_VARIANTS
    from repro.dls import policies as pol
    from repro.dls.session import _record_call_style

    p = session.policy
    desc = {"kind": "uniform", "wants_af": session._wants_af}
    if isinstance(p, pol.AWFVariantWeights):
        desc["kind"] = p.variant
    elif isinstance(p, pol.AdaptiveFactoring):
        desc["kind"] = "af"
    elif isinstance(p, pol.AdaptiveWeights):
        desc["kind"] = "awf"
    elif isinstance(p, pol.StaticWeights):
        desc["kind"] = "static"
        desc["weights"] = list(p._w)
    elif session.spec.weights is not None:
        desc["kind"] = "static"
        desc["weights"] = list(session.spec.weights)
    if two_sided or desc["kind"] not in (*AWF_VARIANTS, "af"):
        return desc, None
    P = session.spec.P
    tele = SharedMemWindow.create(capacity=max(64, 16 * P))
    desc["telemetry"] = tele.descriptor()
    if desc["kind"] == "af":
        session.policy = pol.AdaptiveFactoring(P, window=tele)
    else:
        session.policy = pol.AWFVariantWeights(P, variant=desc["kind"],
                                               window=tele)
    session._record_style = _record_call_style(session.policy)
    session._wire_outer_weights()
    return desc, tele


class _Monitor:
    """Parent-side bookkeeping: records in, deaths harvested, orphans out."""

    def __init__(self, session, ctx, worker_pes: List[int], origin_val,
                 feed_policy: bool):
        self.session = session
        self.rec_q = ctx.Queue()
        self.orphan_q = ctx.Queue()
        self.slots = ctx.Array("q", session.spec.P * W.SLOT_FIELDS,
                               lock=False)
        self.origin_val = origin_val
        self.feed_policy = feed_policy
        self.worker_pes = list(worker_pes)
        self.live = set(worker_pes)
        self.drained = set()
        self.exited: Dict[int, dict] = {}
        self.dead: Dict[int, dict] = {}
        self.last_seq = {pe: 0 for pe in worker_pes}
        self.outstanding = 0
        self.orphans_log: List[dict] = []
        self.errors: List[dict] = []
        self.procs: Dict[int, mp.Process] = {}

    # -- record intake -----------------------------------------------------
    def drain_records(self, timeout: float = 0.02) -> int:
        n = 0
        while True:
            try:
                msg = self.rec_q.get(timeout=timeout if n == 0 else 0)
            except _queue.Empty:
                return n
            n += 1
            timeout = 0.0
            self._handle(msg)

    def _handle(self, msg: dict) -> None:
        kind, pe = msg["kind"], msg.get("pe")
        s = self.session
        if kind in ("chunk", "orphan"):
            self.last_seq[pe] = msg["seq"]
            c = Claim(step=msg.get("step", -1), start=msg["start"],
                      size=msg["size"])
            s.log_claim(pe, c)
            s.record_remote(pe, msg["size"], msg["t1"] - msg["t0"],
                            msg.get("lat", 0.0), claim=c, t_start=msg["t0"],
                            t_end=msg["t1"], feed_policy=self.feed_policy)
            if kind == "orphan":
                self.outstanding -= 1
                self.orphans_log.append(
                    {"from_pe": msg["from_pe"], "by_pe": pe,
                     "start": msg["start"], "size": msg["size"]})
        elif kind == "drained":
            self.drained.add(pe)
        elif kind == "exit":
            self.exited[pe] = msg
        elif kind == "error":
            self.errors.append(msg)

    # -- death harvesting --------------------------------------------------
    def check_deaths(self) -> None:
        for pe in [p for p in self.live]:
            proc = self.procs[pe]
            if proc.is_alive() or pe in self.exited:
                continue
            proc.join(timeout=0.1)
            self._harvest(pe, proc)

    def _harvest(self, pe: int, proc: mp.Process) -> None:
        self.live.discard(pe)
        b = pe * W.SLOT_FIELDS
        sl = self.slots
        state, slot_seq = sl[b + W.STATE], sl[b + W.SEQ]
        info = {"pe": pe, "exitcode": proc.exitcode, "orphaned": 0,
                "salvaged": 0}
        if state != W.IDLE and slot_seq > self.last_seq[pe]:
            start, stop, done = sl[b + W.START], sl[b + W.STOP], sl[b + W.DONE]
            now = time.monotonic() - self.origin_val.value
            if state == W.ORPHAN:
                # its orphan assignment died with it; re-account below
                self.outstanding -= 1
            if done > start:
                # executed-but-unreported prefix: synthesize the record so
                # the claim log still sums to exactly N
                c = Claim(step=-1, start=start, size=done - start)
                self.session.log_claim(pe, c)
                self.session.record_remote(
                    pe, c.size, max(now - sl[b + W.T0_US] / 1e6, 0.0), 0.0,
                    claim=c, t_start=sl[b + W.T0_US] / 1e6, t_end=now,
                    feed_policy=False)
                info["salvaged"] = done - start
            if stop > done:
                self.orphan_q.put((done, stop, pe))
                self.outstanding += 1
                info["orphaned"] = stop - done
        self.dead[pe] = info
        # a fully-dead hierarchical node can no longer drain its in-flight
        # super-chunk through its own local window -- grab the remainder
        rt = self.session.runtime
        if isinstance(rt, HierarchicalRuntime):
            node = rt.node_of(pe)
            peers = range(rt._bounds[node], rt._bounds[node] + rt._n_pes[node])
            if not any(q in self.live for q in peers):
                rng = _strand_node(rt, node)
                if rng is not None:
                    self.orphan_q.put((rng[0], rng[1], pe))
                    self.outstanding += 1
                    info["orphaned"] += rng[1] - rng[0]

    # -- completion --------------------------------------------------------
    def workers_done(self) -> bool:
        return (all(pe in self.drained for pe in self.live)
                and self.outstanding == 0)

    def finish_workers(self, join_timeout: float = 10.0) -> None:
        for _ in self.live:
            self.orphan_q.put(None)
        deadline = time.monotonic() + join_timeout
        while (any(pe not in self.exited for pe in self.live)
               and time.monotonic() < deadline):
            self.drain_records(timeout=0.05)
            self.check_deaths()
        for pe in list(self.live):
            proc = self.procs[pe]
            proc.join(timeout=max(deadline - time.monotonic(), 0.1))
            if proc.is_alive():  # hung worker: hard teardown
                proc.terminate()
                proc.join(timeout=2.0)
                if proc.is_alive():
                    proc.kill()
                    proc.join(timeout=2.0)
        self.drain_records(timeout=0.05)

    def kill_all(self) -> None:
        for pe, proc in self.procs.items():
            if proc.is_alive():
                proc.kill()
        for proc in self.procs.values():
            proc.join(timeout=2.0)


def _strand_node(rt: HierarchicalRuntime, node: int):
    """Claim a fully-dead node's in-flight epoch remainder for orphaning.

    One local fetch-add of the whole epoch size atomically takes whatever
    is left (racing nobody -- the node's PEs are dead); returns the
    stranded range or None.
    """
    local = rt.window.local(node)
    e = local.read(rt._nseq[node])
    k_ = rt._epoch_keys(node, e)
    if not local.read(k_[rt._READY]):
        return None
    size = local.read(k_[rt._SIZE])
    if size == 0:
        return None
    off = local.fetch_add(k_[rt._LP], size)
    if off >= size:
        return None
    start = local.read(k_[rt._START])
    return start + off, start + size


def execute_processes(session, work_fn, *, start_method: Optional[str] = None,
                      progress: int = 64, timeout: float = 300.0,
                      spawn_timeout: float = 60.0, master_pe: int = 0):
    """Drain the session with one OS process per PE; returns a report.

    progress: sub-block stride (iterations) between crash-slot high-water
        updates -- the granularity at which a killed worker's executed
        prefix is salvageable.
    timeout: hard wall-clock bound on the whole run (hangs are the failure
        mode of multi-process schedulers; on expiry all workers are killed
        and RuntimeError is raised).
    spawn_timeout: bound on process startup + window attach.
    master_pe: two-sided only -- the PE the parent executes as (the
        non-dedicated master).
    """
    spec = session.spec
    rdesc = _runtime_desc(session)
    two_sided = rdesc["kind"] == "two_sided"
    pdesc, telemetry = _policy_desc(session, two_sided)
    method = pick_start_method(start_method)
    ctx = _get_ctx(method)

    worker_pes = [pe for pe in range(spec.P)
                  if not (two_sided and pe == master_pe)]
    origin_val = ctx.Value("d", 0.0, lock=False)
    mon = _Monitor(session, ctx, worker_pes, origin_val,
                   feed_policy=two_sided)
    barrier = ctx.Barrier(len(worker_pes) + 1)
    reply_qs = {pe: ctx.Queue() for pe in worker_pes} if two_sided else {}
    req_q = ctx.Queue() if two_sided else None

    for pe in worker_pes:
        cfg = {"pe": pe, "spec": spec, "runtime": rdesc, "policy": pdesc,
               "work_fn": work_fn, "progress": progress,
               "rec_q": mon.rec_q, "orphan_q": mon.orphan_q,
               "slots": mon.slots, "barrier": barrier, "origin": origin_val}
        if two_sided:
            cfg["req_q"] = req_q
            cfg["reply_q"] = reply_qs[pe]
        p = ctx.Process(target=W.pe_main, args=(cfg,), name=f"dls-pe{pe}")
        p.daemon = True
        mon.procs[pe] = p
    t_spawn = time.monotonic()
    for p in mon.procs.values():
        p.start()

    # wait for every worker to attach; a pre-barrier death must not hang us
    while barrier.n_waiting < len(worker_pes):
        mon.drain_records(timeout=0.01)
        if any(not p.is_alive() for p in mon.procs.values()):
            mon.kill_all()
            mon.drain_records(timeout=0.2)
            trace = mon.errors[0]["trace"] if mon.errors else "(killed)"
            raise RuntimeError(f"worker died during startup:\n{trace}")
        if time.monotonic() - t_spawn > spawn_timeout:
            mon.kill_all()
            raise RuntimeError(
                f"workers failed to attach within {spawn_timeout}s")
    origin_val.value = time.monotonic()
    barrier.wait()

    deadline = origin_val.value + timeout
    try:
        if two_sided:
            _master_loop(session, mon, req_q, reply_qs, work_fn, progress,
                         master_pe, deadline)
        else:
            while not mon.workers_done():
                mon.drain_records()
                mon.check_deaths()
                if not mon.live and not mon.workers_done():
                    raise RuntimeError(
                        "all PEs died with work outstanding "
                        f"(orphans={mon.outstanding}, "
                        f"remaining>={session.remaining()}); no survivor "
                        "can re-claim -- mirroring the DES all-dead failure")
                if time.monotonic() > deadline:
                    raise RuntimeError(
                        f"processes executor exceeded timeout={timeout}s "
                        f"(drained={sorted(mon.drained)}, "
                        f"orphans={mon.outstanding})")
        mon.finish_workers()
    except BaseException:
        mon.kill_all()
        raise
    wall = time.monotonic() - origin_val.value
    if mon.errors:
        raise RuntimeError(
            "worker raised:\n" + mon.errors[0]["trace"])

    report = session.report("processes", wall_time=wall)
    stats = _process_stats(mon, method, rdesc, pdesc, telemetry)
    if report.chunk_times:
        # T_loop is completion of the last iteration (the paper's
        # measurand, and what the DES predicts) -- not worker teardown,
        # which on a loaded host can cost as much as the loop itself.
        t_last = max(c["t1"] for c in report.chunk_times)
        stats["teardown_s"] = max(wall - t_last, 0.0)
        report.wall_time = t_last
    report.process_stats = stats
    rg = sum(e.get("rmw_global", 0) for e in mon.exited.values())
    rl = sum(e.get("rmw_local", 0) for e in mon.exited.values())
    if not two_sided:  # child window instances carry the true RMW counts
        report.n_rmw_global = rg or None
        report.n_rmw_local = rl if rg else None
    return report


def _master_loop(session, mon, req_q, reply_qs, work_fn, progress,
                 master_pe, deadline) -> None:
    """Two-sided parent: serve the request queue between own chunks."""
    my_drained = False
    origin = mon.origin_val.value
    while True:
        # serve everything pending (the master's first duty)
        while True:
            try:
                _, pe = req_q.get_nowait()
            except _queue.Empty:
                break
            c = session.claim(pe)  # parent policy supplies weight/af
            if c is not None:
                # claimed on behalf of the worker: move the log entry when
                # the worker's own record arrives (log_claim re-logs) -- so
                # drop the master-side log to avoid double counting
                session._claim_log[pe].pop()
            reply_qs[pe].put(None if c is None
                             else (c.step, c.start, c.size))
        mon.drain_records(timeout=0.0)
        mon.check_deaths()
        if time.monotonic() > deadline:
            raise RuntimeError("processes executor exceeded its timeout "
                               "(two-sided master loop)")
        if not my_drained:
            tc = time.monotonic()
            c = session.claim(master_pe)
            lat = time.monotonic() - tc
            if c is None:
                my_drained = True
            else:
                t0 = time.monotonic() - origin
                if work_fn is not None:
                    a = c.start
                    while a < c.stop:  # serve between sub-blocks: the
                        b = min(a + progress, c.stop)  # non-dedicated master
                        work_fn(a, b)
                        a = b
                        while True:
                            try:
                                _, pe = req_q.get_nowait()
                            except _queue.Empty:
                                break
                            cw = session.claim(pe)
                            if cw is not None:
                                session._claim_log[pe].pop()
                            reply_qs[pe].put(None if cw is None
                                             else (cw.step, cw.start, cw.size))
                t1 = time.monotonic() - origin
                session.record(master_pe, c.size, t1 - t0,
                               sched_seconds=lat, claim=c, t_start=t0,
                               t_end=t1)
            continue
        # master drained: orphans with no survivors fall to the master
        if not mon.live and mon.outstanding > 0:
            try:
                start, stop, from_pe = mon.orphan_q.get_nowait()
            except _queue.Empty:
                time.sleep(0.005)
                continue
            t0 = time.monotonic() - origin
            if work_fn is not None:
                work_fn(start, stop)
            t1 = time.monotonic() - origin
            c = Claim(step=-1, start=start, size=stop - start)
            session.log_claim(master_pe, c)
            session.record(master_pe, c.size, t1 - t0, claim=c,
                           t_start=t0, t_end=t1)
            mon.outstanding -= 1
            mon.orphans_log.append({"from_pe": from_pe, "by_pe": master_pe,
                                    "start": start, "size": stop - start})
            continue
        if mon.workers_done():
            return
        time.sleep(0.001)


def _process_stats(mon, method, rdesc, pdesc, telemetry) -> dict:
    per_pe = []
    for pe in mon.worker_pes:
        e = mon.exited.get(pe)
        d = mon.dead.get(pe)
        entry = {"pe": pe, "died": d is not None and e is None}
        if e is not None:
            entry.update({"pid": e["pid"], "n_chunks": e["n_chunks"],
                          "n_orphans": e["n_orphans"],
                          "rmw_global": e["rmw_global"],
                          "rmw_local": e["rmw_local"],
                          "backend": e["backend"]})
        if d is not None:
            entry.update({"exitcode": d["exitcode"],
                          "salvaged_iters": d["salvaged"],
                          "orphaned_iters": d["orphaned"]})
        per_pe.append(entry)
    backend = next((e["backend"] for e in mon.exited.values()
                    if e.get("backend") not in (None, "queue")), "queue")
    return {
        "start_method": method,
        "runtime": rdesc["kind"],
        "window_backend": backend,
        "policy": pdesc["kind"],
        "shared_telemetry": telemetry is not None,
        "n_deaths": len(mon.dead),
        "orphans": list(mon.orphans_log),
        "per_pe": per_pe,
    }
