"""SharedMemWindow: the paper's RMA window over multiprocessing.shared_memory.

Layout of one slab (all integers little-endian int64)::

    header     MAGIC | capacity | n_slots | reserved          (32 bytes)
    directory  capacity x 64-byte key cells (len byte + utf-8)
    values     capacity x int64

A key is *published* by writing its directory cell and then bumping
``n_slots`` -- both under the directory lock, so a reader that misses its
per-process cache takes the directory lock and rescans; a hit never touches
any lock metadata again.  Slots are never freed (counters are monotonic per
loop id, exactly like the KV-store backend).

Atomicity backends for ``fetch_add`` (resolved once per process, recorded
in session reports via ``backend``):

  * ``"atomics"`` -- lock-free CAS/fetch-add on the mapped slot through the
    ``atomics`` package, when importable.  True passive-target RMW.
  * ``"lockf"``   -- POSIX record locks (``fcntl.lockf``) on a sidecar lock
    file, one byte range per slot, plus an in-process ``threading.Lock``
    per slot (POSIX locks do not exclude threads of the owning process).
    The kernel releases record locks when a process dies, so a SIGKILLed
    worker can never deadlock the window -- the property that makes the
    fault-tolerance story (orphan re-claiming) safe to build on.

``read`` is a raw 8-byte aligned load with no lock -- on every platform
CPython supports, aligned word loads are single-copy atomic, which is the
moral equivalent of ``MPI_Get`` under a shared lock: a genuinely one-sided
read that never blocks a concurrent RMW.

Spawn-safety: instances do not pickle.  A child process receives
``descriptor()`` (a dict of names) and calls ``SharedMemWindow.attach`` --
see ``repro.pt.worker``.
"""
from __future__ import annotations

import fcntl
import os
import secrets
import struct
import tempfile
import threading
from typing import Dict, List, Optional, Sequence

from repro.core.rma import HierarchicalWindow, Window

_MAGIC = 0x30_31_57_54_50  # "PTW10"
_HDR = 32
_KEY_BYTES = 64
_INT = struct.Struct("<q")

try:  # optional lock-free backend; never a hard dependency
    import atomics as _atomics  # type: ignore
except Exception:  # pragma: no cover - not installed in this environment
    _atomics = None

# Per-process registry of sidecar lock files: POSIX record locks are
# per-(process, file), and closing ANY fd on the file drops ALL of the
# process's locks on it -- so every SharedMemWindow instance of the same
# slab in one process must share a single fd (and the per-slot thread
# locks that make lockf thread-correct).
_LOCK_REG: Dict[str, dict] = {}
_LOCK_REG_GUARD = threading.Lock()


def _lock_entry(path: str, create: bool) -> dict:
    with _LOCK_REG_GUARD:
        ent = _LOCK_REG.get(path)
        if ent is None:
            flags = os.O_RDWR | (os.O_CREAT if create else 0)
            ent = {"fd": os.open(path, flags, 0o600),
                   "locks": {}, "guard": threading.Lock()}
            _LOCK_REG[path] = ent
        return ent


def _slot_thread_lock(ent: dict, idx: int) -> threading.Lock:
    lk = ent["locks"].get(idx)
    if lk is None:
        with ent["guard"]:
            lk = ent["locks"].setdefault(idx, threading.Lock())
    return lk


class SharedMemWindow(Window):
    """Cross-process passive-target window over a named shared-memory slab.

    Build with :meth:`create` (owner) or :meth:`attach` (any other process,
    by name).  ``fetch_add``/``read``/``reset``/``read_many`` follow the
    :class:`repro.core.rma.Window` contract; ``n_rmw`` counts this
    instance's fetch-adds (per-PE accounting for reports).
    """

    # directory-lock byte range sits after all slot ranges
    def __init__(self, shm, lock_path: str, owner: bool, backend: str):
        self._shm = shm
        self._buf = shm.buf
        magic, cap = struct.unpack_from("<qq", self._buf, 0)
        if magic != _MAGIC:
            raise RuntimeError(
                f"shared memory segment {shm.name!r} is not a pt window slab")
        self.capacity = cap
        self._dir_off = _HDR
        self._val_off = _HDR + cap * _KEY_BYTES
        self._lock_path = lock_path
        self._owner = owner
        self.backend = backend
        self._ent = _lock_entry(lock_path, create=owner)
        self._slots: Dict[str, int] = {}  # per-instance key -> slot cache
        self.n_rmw = 0
        self._closed = False

    # -- construction ------------------------------------------------------
    @classmethod
    def create(cls, capacity: int = 8192, name: Optional[str] = None,
               backend: Optional[str] = None) -> "SharedMemWindow":
        from multiprocessing import shared_memory

        name = name or f"ptw-{secrets.token_hex(6)}"
        size = _HDR + capacity * (_KEY_BYTES + 8)
        shm = shared_memory.SharedMemory(name=name, create=True, size=size)
        struct.pack_into("<qqq", shm.buf, 0, _MAGIC, capacity, 0)
        lock_path = cls._lock_path_for(name)
        win = cls(shm, lock_path, owner=True, backend=cls._pick_backend(backend))
        return win

    @classmethod
    def attach(cls, desc) -> "SharedMemWindow":
        """Attach by name or by a :meth:`descriptor` dict (child processes)."""
        from multiprocessing import shared_memory

        if isinstance(desc, str):
            desc = {"name": desc}
        # CPython (<=3.12) registers *attached* segments with the resource
        # tracker too.  That is fine here -- every attacher is either the
        # owner's process or a multiprocessing child *sharing the owner's
        # tracker* (the tracker fd rides along under spawn/fork/forkserver),
        # and the tracker's cache is a per-name set: the duplicate register
        # dedupes, the owner's unlink unregisters exactly once, and a
        # crashed owner still gets its slab reclaimed at tracker shutdown.
        # Do NOT unregister on attach: with a shared tracker that would
        # drop the owner's registration and leak the slab on crash.
        shm = shared_memory.SharedMemory(name=desc["name"], create=False)
        lock_path = desc.get("lock_path") or cls._lock_path_for(desc["name"])
        backend = desc.get("backend") or cls._pick_backend(None)
        return cls(shm, lock_path, owner=False, backend=backend)

    @property
    def name(self) -> str:
        """The slab's shared-memory name (what :meth:`attach` takes)."""
        return self._shm.name

    def descriptor(self) -> Dict[str, str]:
        """Everything a child process needs to ``attach`` this window."""
        return {"name": self._shm.name, "lock_path": self._lock_path,
                "backend": self.backend}

    @staticmethod
    def _lock_path_for(name: str) -> str:
        return os.path.join(tempfile.gettempdir(), f"{name}.ptlock")

    @staticmethod
    def _pick_backend(backend: Optional[str]) -> str:
        if backend in ("atomics", "lockf"):
            if backend == "atomics" and _atomics is None:
                raise RuntimeError("backend='atomics' requested but the "
                                   "atomics package is not importable")
            return backend
        return "atomics" if _atomics is not None else "lockf"

    @classmethod
    def availability(cls) -> "tuple[bool, str]":
        """Usable iff named shared memory can actually be created here
        (containers sometimes mount /dev/shm read-only or not at all)."""
        try:
            from multiprocessing import shared_memory

            probe = shared_memory.SharedMemory(create=True, size=8)
            probe.close()
            probe.unlink()
            return True, ""
        except Exception as e:
            return False, f"cannot create POSIX shared memory ({e!r})"

    # -- key directory -----------------------------------------------------
    def _dir_lock(self):
        return _SlotLock(self._ent, self.capacity)

    def _scan(self, kb: bytes, n: int) -> Optional[int]:
        buf, off = self._buf, self._dir_off
        for idx in range(n):
            cell = off + idx * _KEY_BYTES
            ln = buf[cell]
            if ln == len(kb) and bytes(buf[cell + 1:cell + 1 + ln]) == kb:
                return idx
        return None

    def _slot(self, key: str, create: bool = True) -> int:
        idx = self._slots.get(key)
        if idx is not None:
            return idx
        kb = key.encode()
        if len(kb) >= _KEY_BYTES:
            raise ValueError(f"key too long for directory cell: {key!r}")
        with self._dir_lock():
            n = _INT.unpack_from(self._buf, 16)[0]
            idx = self._scan(kb, n)
            if idx is None:
                if not create:
                    return -1
                if n >= self.capacity:
                    raise RuntimeError(
                        f"window directory full ({self.capacity} keys); "
                        "create the slab with a larger capacity")
                idx = n
                cell = self._dir_off + idx * _KEY_BYTES
                self._buf[cell] = len(kb)
                self._buf[cell + 1:cell + 1 + len(kb)] = kb
                _INT.pack_into(self._buf, self._val_off + idx * 8, 0)
                _INT.pack_into(self._buf, 16, n + 1)  # publish
        self._slots[key] = idx
        return idx

    # -- Window contract ---------------------------------------------------
    def fetch_add(self, key: str, delta: int) -> int:
        idx = self._slot(key)
        off = self._val_off + idx * 8
        self.n_rmw += 1
        if self.backend == "atomics":  # pragma: no cover - optional package
            with _atomics.atomicview(buffer=self._buf[off:off + 8],
                                     atype=_atomics.INT) as a:
                return a.fetch_add(delta)
        with _SlotLock(self._ent, idx):
            old = _INT.unpack_from(self._buf, off)[0]
            _INT.pack_into(self._buf, off, old + delta)
            return old

    def read(self, key: str) -> int:
        idx = self._slot(key)
        # aligned 8-byte load: single-copy atomic on supported platforms --
        # a one-sided read that never blocks a concurrent fetch_add
        return _INT.unpack_from(self._buf, self._val_off + idx * 8)[0]

    def read_many(self, keys: Sequence[str]) -> List[int]:
        buf, off, slot = self._buf, self._val_off, self._slot
        return [_INT.unpack_from(buf, off + slot(k) * 8)[0] for k in keys]

    def reset(self, key: str, value: int = 0) -> None:
        idx = self._slot(key)
        off = self._val_off + idx * 8
        if self.backend == "atomics":  # pragma: no cover - optional package
            with _atomics.atomicview(buffer=self._buf[off:off + 8],
                                     atype=_atomics.INT) as a:
                a.store(value)
            return
        with _SlotLock(self._ent, idx):
            _INT.pack_into(self._buf, off, value)

    def keys(self) -> List[str]:
        """All published keys (one directory pass; diagnostic use)."""
        n = _INT.unpack_from(self._buf, 16)[0]
        out = []
        for idx in range(n):
            cell = self._dir_off + idx * _KEY_BYTES
            ln = self._buf[cell]
            out.append(bytes(self._buf[cell + 1:cell + 1 + ln]).decode())
        return out

    # -- lifetime ----------------------------------------------------------
    def close(self, unlink: Optional[bool] = None) -> None:
        """Detach; the owner (or ``unlink=True``) also destroys the slab."""
        if self._closed:
            return
        self._closed = True
        unlink = self._owner if unlink is None else unlink
        self._buf = None
        try:
            self._shm.close()
        except Exception:
            pass
        if unlink:
            try:
                self._shm.unlink()
            except Exception:
                pass
            try:
                os.unlink(self._lock_path)
            except OSError:
                pass
            with _LOCK_REG_GUARD:
                ent = _LOCK_REG.pop(self._lock_path, None)
            if ent is not None:
                try:
                    os.close(ent["fd"])
                except OSError:
                    pass

    def __del__(self):  # best-effort: owners reclaim /dev/shm on GC
        try:
            self.close()
        except Exception:
            pass


class _SlotLock:
    """Record lock on one slot's byte of the sidecar file + thread lock."""

    def __init__(self, ent: dict, idx: int):
        self._ent = ent
        self._idx = idx
        self._tlock = _slot_thread_lock(ent, idx)

    def __enter__(self):
        self._tlock.acquire()
        fcntl.lockf(self._ent["fd"], fcntl.LOCK_EX, 1, self._idx, os.SEEK_SET)
        return self

    def __exit__(self, *exc):
        fcntl.lockf(self._ent["fd"], fcntl.LOCK_UN, 1, self._idx, os.SEEK_SET)
        self._tlock.release()
        return False


# -- hierarchical composition ---------------------------------------------

def shm_hierarchical(nodes: int, capacity: int = 8192,
                     local_capacity: Optional[int] = None,
                     backend: Optional[str] = None) -> HierarchicalWindow:
    """Global shm slab + one shm slab per node: the all-real-memory
    two-level window (``SharedMemWindow.hier`` delegates here)."""
    g = SharedMemWindow.create(capacity=capacity, backend=backend)
    locs = [SharedMemWindow.create(capacity=local_capacity or capacity,
                                   backend=backend) for _ in range(nodes)]
    return HierarchicalWindow(nodes, global_window=g, local_windows=locs)


def hier(nodes: int, **kw) -> HierarchicalWindow:
    return shm_hierarchical(nodes, **kw)


SharedMemWindow.hier = staticmethod(shm_hierarchical)


def hier_descriptor(hw: HierarchicalWindow) -> Dict:
    """Picklable attach info for a hierarchical all-shm window."""
    g = hw.global_window
    if not isinstance(g, SharedMemWindow):
        raise TypeError("hier_descriptor needs SharedMemWindow levels")
    return {"nodes": hw.nodes, "global": g.descriptor(),
            "locals": [w.descriptor() for w in hw.local_windows]}


def attach_hier(desc: Dict) -> HierarchicalWindow:
    """Child-side rebuild of a hierarchical window from its descriptor.

    Per-level RMW accounting restarts at zero in each process (it counts
    *this process's* claims, which is what per-PE stats want)."""
    g = SharedMemWindow.attach(desc["global"])
    locs = [SharedMemWindow.attach(d) for d in desc["locals"]]
    return HierarchicalWindow(desc["nodes"], global_window=g,
                              local_windows=locs)
