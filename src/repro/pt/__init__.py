"""repro.pt -- real cross-process passive-target execution.

The first execution substrate in this repo that is neither fake-parallel
(threads under one GIL) nor simulated: a :class:`SharedMemWindow` lays the
paper's RMA window out in ``multiprocessing.shared_memory`` so any OS
process can attach it *by name* and issue atomic fetch-and-adds against it
with no cycles on any other worker -- the passive-target property over
``/dev/shm`` instead of MPI-3.  The ``processes`` executor
(:func:`repro.pt.executor.execute_processes`, reached through
``dls.loop(...).execute(work_fn, executor="processes")``) runs each PE as a
real OS process driving the *existing* claim loops (one-sided, two-sided,
hierarchical) against that window, with orphaned-chunk accounting when a
worker dies.  ``pt.latency`` measures real per-RMW latency and contention
scaling so ``replay.calibrate`` can be fed measured constants -- closing
the reproduce-then-predict loop against real processes.

Everything in this package is stdlib-only (no jax, no numpy in the hot
path) and spawn-safe: workers re-import ``repro.pt.worker`` and rebuild
state from picklable descriptors.  See DESIGN.md Sec. 11.
"""
from .window import SharedMemWindow, shm_hierarchical, hier_descriptor, attach_hier  # noqa: F401
from .latency import measure_rmw_latency, measure_contention, RMWLatency  # noqa: F401
from .executor import execute_processes  # noqa: F401

__all__ = [
    "SharedMemWindow", "shm_hierarchical", "hier_descriptor", "attach_hier",
    "measure_rmw_latency", "measure_contention", "RMWLatency",
    "execute_processes",
]
