"""internvl2-26b [vlm]: 48L d6144 48H (GQA kv=8) d_ff=16384 vocab 92553
(InternLM2 backbone; InternViT frontend is a STUB providing 256 precomputed
patch embeddings per image).  [arXiv:2404.16821; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16_384,
    vocab=92_553, frontend="vision", n_prefix_tokens=256,
    source="arXiv:2404.16821; hf",
)
