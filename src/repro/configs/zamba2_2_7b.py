"""zamba2-2.7b [hybrid]: 54L d2560 Mamba2 backbone (state 64) + ONE shared
attention+MLP block (32H, kv=32, d_ff=10240) applied every 6 layers with
reused weights, vocab 32000.  [arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10_240,
    vocab=32_000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=6, shared_attn=True,
    source="arXiv:2411.15242; hf",
)
