"""Architecture configuration schema for the assigned model zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One LM architecture.  A single schema covers all ten assigned archs:
    dense / MoE / SWA / enc-dec / SSM / hybrid / frontend-stub families.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int  # 0 => attention-free (pure SSM)
    n_kv_heads: int
    d_ff: int  # dense MLP hidden (or per-expert hidden for MoE)
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0  # always-on experts (llama4-style)
    capacity_factor: float = 1.25
    # --- attention ---
    window: Optional[int] = None  # sliding-window attention (SWA)
    rope_theta: float = 10_000.0
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # --- hybrid (zamba2-style) ---
    attn_every: int = 0  # insert a (shared) attention block every k layers
    shared_attn: bool = False  # reuse ONE attention block's weights
    # --- encoder-decoder ---
    enc_layers: int = 0  # >0 => enc-dec; n_layers is then the decoder depth
    # --- modality frontend stubs ---
    frontend: Optional[str] = None  # "audio" | "vision": inputs include embeddings
    n_prefix_tokens: int = 0  # vlm: patch tokens prepended to the text
    # --- misc ---
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    # citation / provenance string from the assignment table
    source: str = ""

    # ---------------- derived ----------------
    @property
    def hd(self) -> int:
        if self.n_heads == 0:
            return 0
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.is_ssm else 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context?  SSM/hybrid/SWA only."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        return _param_count(self, active_only=True)

    def reduced(self, **over) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 4 if not self.attn_every else 2 * self.attn_every),
            d_model=256,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_heads else self.n_kv_heads,
            d_ff=512 if self.d_ff else 0,
            vocab=512,
            head_dim=64 if self.n_heads else None,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            window=64 if self.window else None,
            ssm_state=min(self.ssm_state, 32),
            ssm_head_dim=32 if self.is_ssm else self.ssm_head_dim,
            attn_every=2 if self.attn_every else 0,
            enc_layers=min(self.enc_layers, 2),
            n_prefix_tokens=min(self.n_prefix_tokens, 16),
            dtype="float32",
            name=self.name + "-reduced",
        )
        small.update(over)
        return dataclasses.replace(self, **small)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    n = 0
    # embeddings (+ output head unless tied)
    n += cfg.vocab * d
    if not cfg.tie_embeddings:
        n += cfg.vocab * d

    def attn_params():
        hd = cfg.hd
        return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d

    def mlp_params(ff):
        return 3 * d * ff  # gate, up, down

    def moe_params():
        router = d * cfg.n_experts
        experts = cfg.top_k if active_only else cfg.n_experts
        shared = cfg.n_shared_experts
        return router + (experts + shared) * mlp_params(cfg.d_ff) // 1

    def mamba_params():
        di, s = cfg.d_inner, cfg.ssm_state
        in_proj = d * (2 * di + 2 * s + cfg.ssm_heads)  # z, x, B, C, dt
        conv = cfg.ssm_conv * (di + 2 * s)
        extra = 2 * cfg.ssm_heads + di  # A, D, gated-norm
        out = di * d
        return in_proj + conv + extra + out

    if cfg.family in ("dense", "vlm"):
        n += cfg.n_layers * (attn_params() + mlp_params(cfg.d_ff) + 2 * d)
    elif cfg.family == "moe":
        n += cfg.n_layers * (attn_params() + moe_params() + 2 * d)
    elif cfg.family == "ssm":
        n += cfg.n_layers * (mamba_params() + d)
    elif cfg.family == "hybrid":
        n += cfg.n_layers * (mamba_params() + d)
        blocks = 1 if cfg.shared_attn else max(cfg.n_layers // max(cfg.attn_every, 1), 1)
        n += blocks * (attn_params() + mlp_params(cfg.d_ff) + 2 * d)
    elif cfg.family == "encdec":
        n += cfg.enc_layers * (attn_params() + mlp_params(cfg.d_ff) + 2 * d)
        # decoder: self-attn + cross-attn + mlp
        n += cfg.n_layers * (2 * attn_params() + mlp_params(cfg.d_ff) + 3 * d)
    n += d  # final norm
    return n


# Shape cells assigned to every architecture.
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32_768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524_288, global_batch=1),
}


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """long_500k only for sub-quadratic archs (see DESIGN.md skip notes)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return tuple(names)
