"""h2o-danube-3-4b [dense]: 24L d3840 32H (GQA kv=8) d_ff=10240 vocab 32000,
llama+mistral mix with sliding-window attention (window 4096) -- the SWA
makes this arch sub-quadratic, so it runs the long_500k cell.
[arXiv:2401.16818; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10_240,
    vocab=32_000, window=4096,
    source="arXiv:2401.16818; unverified",
)
