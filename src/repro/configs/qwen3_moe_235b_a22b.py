"""qwen3-moe-235b-a22b [moe]: 94L d4096 64H (GQA kv=4) d_ff=1536/expert,
vocab 151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab=151_936, n_experts=128, top_k=8,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
