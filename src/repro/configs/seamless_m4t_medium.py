"""seamless-m4t-medium [audio]: enc-dec, 12L encoder + 12L decoder, d1024
16H (kv=16) d_ff=4096 vocab 256206.  Modality frontend is a STUB per the
brief: input_specs() provides precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256_206, frontend="audio",
    source="arXiv:2308.11596; hf",
)
