"""mamba2-370m [ssm]: 48L d1024, attention-free SSD (state-space duality),
ssm_state=128, vocab 50280.  Ties embeddings (mamba convention).
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50_280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
