"""The assigned architecture zoo: 10 configs + shape cells.

Every arch is selectable via ``--arch <id>`` in the launchers; ids use the
assignment's hyphenated names.
"""
from . import (
    deepseek_67b,
    h2o_danube_3_4b,
    internvl2_26b,
    llama4_scout_17b_a16e,
    mamba2_370m,
    qwen3_moe_235b_a22b,
    seamless_m4t_medium,
    stablelm_12b,
    tinyllama_1_1b,
    zamba2_2_7b,
)
from .base import SHAPES, ModelConfig, applicable_shapes  # noqa: F401

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen3_moe_235b_a22b, llama4_scout_17b_a16e, deepseek_67b,
        tinyllama_1_1b, stablelm_12b, h2o_danube_3_4b, seamless_m4t_medium,
        mamba2_370m, zamba2_2_7b, internvl2_26b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
