"""Pallas TPU kernels (validated in interpret mode on CPU).

  mandelbrot      -- paper app 2: escape-time z<-z^4+c (variable-cost loop)
  spin_image      -- paper app 1: PSIA histogram via one-hot reduction
  flash_attention -- fused attention (causal/SWA/GQA), transformer hot spot
  ssd_scan        -- Mamba2 SSD chunked scan with VMEM-carried state
"""
from .flash_attention.ops import attention_oracle, flash_attention  # noqa: F401
from .mandelbrot.ops import mandelbrot, mandelbrot_ref  # noqa: F401
from .spin_image.ops import spin_images, spin_images_oracle  # noqa: F401
from .ssd_scan.ops import ssd_scan, ssd_scan_oracle  # noqa: F401
