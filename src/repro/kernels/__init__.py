"""Pallas TPU kernels (validated in interpret mode on CPU).

  mandelbrot      -- paper app 2: escape-time z<-z^4+c (variable-cost loop)
  spin_image      -- paper app 1: PSIA histogram via one-hot reduction
  flash_attention -- fused attention (causal/SWA/GQA), transformer hot spot
  ssd_scan        -- Mamba2 SSD chunked scan with VMEM-carried state

``mandelbrot`` and ``flash_attention`` additionally ship *persistent
self-scheduled* variants (``*_persistent``): a fixed worker grid claiming
variable-sized tile chunks through the device-window protocol of
``repro.device`` instead of a static grid -- DESIGN.md Sec. 14.
"""
import jax


def resolve_interpret(interpret=None) -> bool:
    """The one interpret-mode autodetect every kernel entry point shares.

    ``None`` means "interpret exactly when there is no accelerator"
    (Pallas kernels run under the interpreter on the CPU backend, compiled
    otherwise); an explicit bool passes through.  Defined before the
    submodule re-exports below so kernel modules can import it from this
    package without a cycle.
    """
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)


from .flash_attention.ops import attention_oracle, flash_attention  # noqa: F401,E402
from .flash_attention.persistent import flash_attention_persistent  # noqa: F401,E402
from .mandelbrot.ops import mandelbrot, mandelbrot_ref  # noqa: F401,E402
from .mandelbrot.persistent import mandelbrot_persistent  # noqa: F401,E402
from .spin_image.ops import spin_images, spin_images_oracle  # noqa: F401,E402
from .ssd_scan.ops import ssd_scan, ssd_scan_oracle  # noqa: F401,E402
