"""Persistent self-scheduled attention over variable-length batches.

The static grid (``ops.flash_attention``) gives every (head, q-block) the
same kv extent, so a varlen batch makes short sequences idle while long
ones grind -- the exact imbalance profile the paper's protocol targets.
Here the loop is the linearized (batch*heads, q-block) tile space and the
per-tile cost is its *actual* kv-block count (``varlen_tile_costs``): the
device claim loop (``repro.device``, DESIGN.md Sec. 14) hands variable
chunks of tiles to a fixed fleet of persistent programs, each of which
runs online-softmax attention with a *traced* kv trip count -- work
proportional to the sequence actually attended, not the padded maximum.

Scope: causal or full attention with GQA and per-batch ``lengths``;
sliding-window masking stays on the static path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.device.persistent import DeviceSchedule, claim_schedule

from .kernel import NEG_INF


def _persistent_kernel(
    nclaims_ref,  # (W,)   int32
    starts_ref,   # (W, C) int32
    sizes_ref,    # (W, C) int32
    q_ref,        # (B*H,   nq*blk_q, D)
    k_ref,        # (B*Hkv, nk*blk_k, D)
    v_ref,        # (B*Hkv, nk*blk_k, D)
    len_ref,      # (B,) int32 -- valid kv length per batch row
    o_ref,        # (B*H, nq*blk_q, D)
    *,
    scale: float,
    causal: bool,
    seq_q: int,
    blk_q: int,
    blk_k: int,
    H: int,
    Hkv: int,
    nq: int,
    D: int,
):
    w = pl.program_id(0)
    group = H // Hkv

    def tile_body(tile):
        bh = tile // nq
        qi = tile - bh * nq
        b = bh // H
        kv = b * Hkv + (bh - b * H) // group
        q_start = qi * blk_q
        len_b = len_ref[b]
        # traced kv trip count: only the blocks this tile actually attends
        limit = jnp.minimum(len_b, q_start + blk_q) if causal else len_b
        jmax = (limit + blk_k - 1) // blk_k

        q = q_ref[bh, pl.ds(q_start, blk_q), :].astype(jnp.float32) * scale
        rows1 = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)

        def kv_body(j, carry):
            m_prev, l_prev, acc = carry
            k_start = j * blk_k
            k = k_ref[kv, pl.ds(k_start, blk_k), :].astype(jnp.float32)
            v = v_ref[kv, pl.ds(k_start, blk_k), :].astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)  # (blk_q, blk_k)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1)
            mask = (rows1 < seq_q) & (cols < len_b)
            if causal:
                mask &= cols <= rows1
            s = jnp.where(mask, s, NEG_INF)
            m_cur = jnp.max(s, axis=1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            # mask multiply: fully-masked rows keep l == 0 (zeros on flush)
            p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
            alpha = jnp.exp(m_prev - m_new)
            l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
            acc = acc * alpha + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            return m_new, l_new, acc

        init = (jnp.full((blk_q, 1), NEG_INF, jnp.float32),
                jnp.zeros((blk_q, 1), jnp.float32),
                jnp.zeros((blk_q, D), jnp.float32))
        _, l, acc = jax.lax.fori_loop(0, jmax, kv_body, init)
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[bh, pl.ds(q_start, blk_q), :] = (acc / safe).astype(o_ref.dtype)

    def claim_body(c, _):
        st = starts_ref[w, c]

        def step(t, __):
            tile_body(st + t)
            return __

        jax.lax.fori_loop(0, sizes_ref[w, c], step, 0)
        return _

    jax.lax.fori_loop(0, nclaims_ref[w], claim_body, 0)


def varlen_tile_costs(lengths, H: int, nq: int, blk_q: int, blk_k: int,
                      causal: bool = True):
    """kv blocks actually visited per (batch*head, q-block) tile.

    Row-major over ``B*H*nq`` tiles, matching the persistent kernel's
    linearization -- the cost model the device claim loop balances on.
    """
    lengths = np.asarray(lengths, np.int64)
    B = len(lengths)
    costs = np.zeros(B * H * nq, np.float64)
    for tile in range(B * H * nq):
        b = tile // (H * nq)
        qi = tile % nq
        limit = min(lengths[b], (qi + 1) * blk_q) if causal else lengths[b]
        costs[tile] = max(-(-int(limit) // blk_k), 0)
    return costs


def flash_attention_persistent(
    q,  # (B, H, Tq, D)
    k,  # (B, Hkv, Tk, D)
    v,  # (B, Hkv, Tk, D)
    *,
    lengths=None,
    causal: bool = True,
    scale: float | None = None,
    blk_q: int = 128,
    blk_k: int = 128,
    technique: str = "gss",
    workers: int = 4,
    chunk: int = 1,
    interpret: bool | None = None,
    costs=None,
    schedule: DeviceSchedule | None = None,
):
    """Self-scheduled attention; returns ``(out, DeviceSchedule)``.

    ``lengths`` (B,) caps each batch row's kv extent (default: full Tk).
    ``costs`` defaults to the varlen kv-block count per tile; pass
    ``schedule`` to reuse a previous claim run on the same tile space.
    """
    from repro.kernels import resolve_interpret

    interpret = resolve_interpret(interpret)
    B, H, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert H % Hkv == 0, "GQA requires H divisible by Hkv"
    scale = (D ** -0.5) if scale is None else scale

    nq = -(-Tq // blk_q)
    nk = -(-Tk // blk_k)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, nq * blk_q - Tq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, nk * blk_k - Tk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, nk * blk_k - Tk), (0, 0)))
    qp = qp.reshape(B * H, nq * blk_q, D)
    kp = kp.reshape(B * Hkv, nk * blk_k, D)
    vp = vp.reshape(B * Hkv, nk * blk_k, D)

    if lengths is None:
        lengths = np.full(B, Tk, np.int32)
    lengths = np.asarray(lengths, np.int32)
    if lengths.shape != (B,):
        raise ValueError(f"lengths must have shape ({B},), got {lengths.shape}")

    N = B * H * nq
    if schedule is None:
        if costs is None:
            costs = varlen_tile_costs(lengths, H, nq, blk_q, blk_k, causal)
        schedule = claim_schedule(
            technique, N, workers, chunk=chunk, costs=costs,
            interpret=interpret)
    if schedule.N != N or schedule.P != workers:
        raise ValueError(
            f"schedule is for (N={schedule.N}, P={schedule.P}), "
            f"this tile space needs (N={N}, P={workers})")
    nclaims, starts, sizes = schedule.worker_lists()
    C = starts.shape[1]

    kern = functools.partial(
        _persistent_kernel,
        scale=float(scale), causal=causal, seq_q=Tq,
        blk_q=blk_q, blk_k=blk_k, H=H, Hkv=Hkv, nq=nq, D=D,
    )
    out = pl.pallas_call(
        kern,
        grid=(workers,),
        in_specs=[
            pl.BlockSpec((workers,), lambda w: (0,)),
            pl.BlockSpec((workers, C), lambda w: (0, 0)),
            pl.BlockSpec((workers, C), lambda w: (0, 0)),
            pl.BlockSpec((B * H, nq * blk_q, D), lambda w: (0, 0, 0)),
            pl.BlockSpec((B * Hkv, nk * blk_k, D), lambda w: (0, 0, 0)),
            pl.BlockSpec((B * Hkv, nk * blk_k, D), lambda w: (0, 0, 0)),
            pl.BlockSpec((B,), lambda w: (0,)),
        ],
        # one shared output block: the claims partition the tile space, so
        # together the workers write every (bh, q-block) slab exactly once
        out_specs=pl.BlockSpec((B * H, nq * blk_q, D), lambda w: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * blk_q, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(nclaims), jnp.asarray(starts), jnp.asarray(sizes),
      qp, kp, vp, jnp.asarray(lengths))
    return out.reshape(B, H, nq * blk_q, D)[:, :, :Tq, :], schedule
