"""Jitted public entry points for fused attention."""
from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_pallas
from .ref import attention_ref


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "blk_q", "blk_k", "interpret")
)
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    blk_q=128, blk_k=128, interpret=None):
    """Fused attention.  q (B,H,Tq,D); k,v (B,Hkv,Tk,D) -> (B,H,Tq,D)."""
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        blk_q=blk_q, blk_k=blk_k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale"))
def attention_oracle(q, k, v, *, causal=True, window=None, scale=None):
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
