"""Fused multi-head attention Pallas TPU kernel (flash-attention style).

The transformer hot spot of the framework: online-softmax tiled attention
with causal and sliding-window (SWA) masking and GQA (the kv-head index is
derived *in the BlockSpec index map*, so grouped queries share kv tiles
without materializing the expansion in HBM).

Tiling: grid = (batch*heads, q blocks, kv blocks), kv innermost.  Running
(max, denom, accum) state lives in VMEM scratch across kv blocks; the output
tile is normalized and written on the last kv block.  Block shapes default to
(128, 128) -- MXU-aligned for the two matmuls (q@k^T and p@v).  Fully-masked
kv blocks (beyond the causal frontier or the SWA window) are skipped with
``pl.when``, which is what makes long-context SWA linear-time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref,  # (1, blk_q, d)
    k_ref,  # (1, blk_k, d)
    v_ref,  # (1, blk_k, d)
    o_ref,  # (1, blk_q, d)
    acc_ref,  # scratch (blk_q, d) f32
    m_ref,  # scratch (blk_q, LANES) f32
    l_ref,  # scratch (blk_q, LANES) f32
    *,
    scale: float,
    causal: bool,
    window: int | None,
    seq_q: int,
    seq_k: int,
    blk_q: int,
    blk_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * blk_q
    k_start = ki * blk_k

    # Static-shape skip test (trace-time constants qi/ki are dynamic, so the
    # predicate is a traced bool -- pl.when skips the body at runtime).
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_start <= q_start + blk_q - 1  # block intersects causal tri
    if window is not None:
        relevant &= k_start + blk_k - 1 >= q_start - window  # inside SWA band

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (blk_q, d)
        k = k_ref[0].astype(jnp.float32)  # (blk_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (blk_q, blk_k)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = (rows < seq_q) & (cols < seq_k)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (blk_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # mask multiply (not just NEG_INF bias): when a whole row is masked,
        # exp(NEG_INF - NEG_INF) would be 1 -- the mask kills those terms so
        # l stays 0 and the flush writes zeros.
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)  # (blk_q, blk_k)
        alpha = jnp.exp(m_prev - m_new)  # (blk_q, 1)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def _flush():
        l = l_ref[:, :1]
        safe = jnp.where(l > 0.0, l, 1.0)  # fully-masked rows -> 0 output
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention_pallas(
    q,  # (B, H, Tq, D)
    k,  # (B, Hkv, Tk, D)
    v,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool | None = None,
):
    from repro.kernels import resolve_interpret

    interpret = resolve_interpret(interpret)
    B, H, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert H % Hkv == 0, "GQA requires H divisible by Hkv"
    group = H // Hkv
    scale = (D ** -0.5) if scale is None else scale

    nq = -(-Tq // blk_q)
    nk = -(-Tk // blk_k)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, nq * blk_q - Tq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, nk * blk_k - Tk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, nk * blk_k - Tk), (0, 0)))
    qp = qp.reshape(B * H, nq * blk_q, D)
    kp = kp.reshape(B * Hkv, nk * blk_k, D)
    vp = vp.reshape(B * Hkv, nk * blk_k, D)

    kern = functools.partial(
        _fa_kernel,
        scale=float(scale), causal=causal, window=window,
        seq_q=Tq, seq_k=Tk, blk_q=blk_q, blk_k=blk_k,
    )
    LANES = 128
    out = pl.pallas_call(
        kern,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
            # GQA: grouped q heads read the same kv tile via the index map
            pl.BlockSpec((1, blk_k, D), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * blk_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, D), jnp.float32),
            pltpu.VMEM((blk_q, LANES), jnp.float32),
            pltpu.VMEM((blk_q, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(B, H, nq * blk_q, D)[:, :, :Tq, :]
