"""Pure-jnp oracle for fused attention (dense softmax, f32)."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Dense attention with GQA + causal + sliding-window masking.

    q: (B, H, Tq, D); k, v: (B, Hkv, Tk, D).  Matches the kernel's semantics
    exactly, including zero output for fully-masked rows.
    """
    B, H, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = H // Hkv
    scale = (D ** -0.5) if scale is None else scale
    ke = jnp.repeat(k, group, axis=1)
    ve = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), ke.astype(jnp.float32)) * scale
    rows = jnp.arange(Tq)[:, None]
    cols = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * mask[None, None].astype(jnp.float32)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, ve.astype(jnp.float32))
    out = out / jnp.where(l > 0, l, 1.0)  # fully-masked rows -> zeros
    return out.astype(q.dtype)
