"""Pure-jnp oracle for the Mandelbrot escape-time kernel (paper Algorithm 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mandelbrot_counts_ref(
    width: int,
    height: int | None = None,
    *,
    ct: int = 1000,
    xlim=(-2.0, 1.0),
    ylim=(-1.5, 1.5),
):
    """Reference escape counts, (height, width) int32, float32 arithmetic.

    Identical update rule to the kernel: count an iteration while active,
    then retire pixels with |z|^2 >= 4 (z <- z^4 + c, the paper's variant).
    """
    height = width if height is None else height
    dx = (xlim[1] - xlim[0]) / max(width - 1, 1)
    dy = (ylim[1] - ylim[0]) / max(height - 1, 1)
    cr = (xlim[0] + jnp.arange(width, dtype=jnp.float32) * dx)[None, :]
    ci = (ylim[0] + jnp.arange(height, dtype=jnp.float32) * dy)[:, None]
    cr = jnp.broadcast_to(cr, (height, width))
    ci = jnp.broadcast_to(ci, (height, width))

    def body(_, carry):
        zr, zi, cnt, active = carry
        zr2 = zr * zr - zi * zi
        zi2 = 2.0 * zr * zi
        zr4 = zr2 * zr2 - zi2 * zi2
        zi4 = 2.0 * zr2 * zi2
        nzr = zr4 + cr
        nzi = zi4 + ci
        mag2 = nzr * nzr + nzi * nzi
        cnt = cnt + active.astype(jnp.int32)
        still = active & (mag2 < 4.0)
        zr = jnp.where(active, nzr, zr)
        zi = jnp.where(active, nzi, zi)
        return zr, zi, cnt, still

    zeros = jnp.zeros((height, width), jnp.float32)
    init = (zeros, zeros, jnp.zeros((height, width), jnp.int32),
            jnp.ones((height, width), jnp.bool_))
    _, _, cnt, _ = jax.lax.fori_loop(0, ct, body, init)
    return cnt
