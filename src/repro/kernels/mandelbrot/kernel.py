"""Pallas TPU kernel for the paper's Mandelbrot variant (Algorithm 2).

The paper's second application iterates ``z <- z^4 + c`` per pixel until
``|z| >= 2`` or ``CT`` iterations -- a textbook *variable-cost* loop (interior
pixels burn the full CT, exterior pixels escape in a handful), i.e. exactly
the load-imbalance profile DLS techniques exist for.

TPU adaptation (vs. the paper's scalar CPU loop): escape-time iteration is a
*data-parallel masked loop* -- each VMEM tile of pixels runs the full-CT
``fori_loop`` on the VPU with an ``active`` mask; per-pixel early exit becomes
mask retirement.  Complex arithmetic is expressed over (re, im) float32 pairs
(TPUs have no complex dtype).  Tiles are (block_h x block_w) = (128, 128) by
default -- lane-aligned and small enough that 6 live f32 tiles fit easily in
VMEM (6 * 64 KiB).

The kernel needs **no input arrays**: pixel coordinates are derived from the
grid position via ``broadcasted_iota``, so the only HBM traffic is the final
count tile write -- the kernel is pure compute, which is what makes it a good
roofline probe for the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def escape_counts_tile(
    rows,
    cols,
    *,
    ct: int,
    width: int,
    height: int,
    xmin: float,
    xmax: float,
    ymin: float,
    ymax: float,
):
    """Escape counts for one tile of pixel indices (rows, cols) int32.

    Shared by the static-grid kernel below and the persistent
    self-scheduled variant (persistent.py) so the two paths can never
    drift numerically -- their outputs are compared exactly in tests.
    """
    shape = rows.shape
    dx = (xmax - xmin) / max(width - 1, 1)
    dy = (ymax - ymin) / max(height - 1, 1)
    cr = xmin + cols.astype(jnp.float32) * dx
    ci = ymin + rows.astype(jnp.float32) * dy

    def body(_, carry):
        zr, zi, cnt, active = carry
        # z^2
        zr2 = zr * zr - zi * zi
        zi2 = 2.0 * zr * zi
        # z^4 = (z^2)^2
        zr4 = zr2 * zr2 - zi2 * zi2
        zi4 = 2.0 * zr2 * zi2
        nzr = zr4 + cr
        nzi = zi4 + ci
        mag2 = nzr * nzr + nzi * nzi
        cnt = cnt + active.astype(jnp.int32)
        still = active & (mag2 < 4.0)
        # freeze escaped pixels so overflow cannot propagate NaNs
        zr = jnp.where(active, nzr, zr)
        zi = jnp.where(active, nzi, zi)
        return zr, zi, cnt, still

    zeros = jnp.zeros(shape, jnp.float32)
    init = (zeros, zeros, jnp.zeros(shape, jnp.int32),
            jnp.ones(shape, jnp.bool_))
    _, _, cnt, _ = jax.lax.fori_loop(0, ct, body, init)
    # out-of-image padding pixels carry zeros (sliced off by the wrapper)
    in_image = (rows < height) & (cols < width)
    return jnp.where(in_image, cnt, 0)


def _mandelbrot_kernel(
    counts_ref,
    *,
    ct: int,
    width: int,
    height: int,
    xmin: float,
    xmax: float,
    ymin: float,
    ymax: float,
    block_h: int,
    block_w: int,
):
    bi = pl.program_id(0)
    bj = pl.program_id(1)
    rows = bi * block_h + jax.lax.broadcasted_iota(jnp.int32, (block_h, block_w), 0)
    cols = bj * block_w + jax.lax.broadcasted_iota(jnp.int32, (block_h, block_w), 1)
    counts_ref[...] = escape_counts_tile(
        rows, cols, ct=ct, width=width, height=height,
        xmin=xmin, xmax=xmax, ymin=ymin, ymax=ymax)


def mandelbrot_counts_pallas(
    width: int,
    height: int | None = None,
    *,
    ct: int = 1000,
    xlim=(-2.0, 1.0),
    ylim=(-1.5, 1.5),
    block_h: int = 128,
    block_w: int = 128,
    interpret: bool | None = None,
):
    """Escape-iteration counts, shape (height, width) int32."""
    from repro.kernels import resolve_interpret

    height = width if height is None else height
    interpret = resolve_interpret(interpret)
    gh = -(-height // block_h)
    gw = -(-width // block_w)
    kern = functools.partial(
        _mandelbrot_kernel,
        ct=ct,
        width=width,
        height=height,
        xmin=float(xlim[0]),
        xmax=float(xlim[1]),
        ymin=float(ylim[0]),
        ymax=float(ylim[1]),
        block_h=block_h,
        block_w=block_w,
    )
    out = pl.pallas_call(
        kern,
        grid=(gh, gw),
        out_specs=pl.BlockSpec((block_h, block_w), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gh * block_h, gw * block_w), jnp.int32),
        interpret=interpret,
    )()
    return out[:height, :width]
