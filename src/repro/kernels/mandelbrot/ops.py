"""Jitted public entry points for the Mandelbrot kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import mandelbrot_counts_pallas
from .ref import mandelbrot_counts_ref


@functools.partial(
    jax.jit,
    static_argnames=("width", "height", "ct", "xlim", "ylim", "block_h", "block_w", "interpret"),
)
def mandelbrot(width, height=None, *, ct=1000, xlim=(-2.0, 1.0), ylim=(-1.5, 1.5),
               block_h=128, block_w=128, interpret=None):
    """Escape-iteration counts (height, width) int32 via the Pallas kernel."""
    return mandelbrot_counts_pallas(
        width, height, ct=ct, xlim=xlim, ylim=ylim,
        block_h=block_h, block_w=block_w, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("width", "height", "ct", "xlim", "ylim"))
def mandelbrot_ref(width, height=None, *, ct=1000, xlim=(-2.0, 1.0), ylim=(-1.5, 1.5)):
    return mandelbrot_counts_ref(width, height, ct=ct, xlim=xlim, ylim=ylim)
