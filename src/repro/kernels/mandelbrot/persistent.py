"""Persistent self-scheduled Mandelbrot: a fixed worker grid, device claims.

The static entry point (``ops.mandelbrot``) launches one program per tile.
This variant launches ``workers`` persistent program instances and lets the
device-window protocol (``repro.device``, DESIGN.md Sec. 14) decide which
tiles each one executes: the claim loop runs on-device in the protocol
kernel, producing per-worker claim tables (variable-sized chunks of the
linearized tile space); each persistent program then walks its own table
with dynamic-slice writes into the shared counts image.

Pixel math is ``escape_counts_tile`` -- the *same* function the static
kernel calls -- so the two paths are exactly equal (pinned in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.device.persistent import DeviceSchedule, claim_schedule

from .kernel import escape_counts_tile


def _persistent_kernel(
    nclaims_ref,  # (W,)   int32 -- claims per worker
    starts_ref,   # (W, C) int32 -- first tile of each claim
    sizes_ref,    # (W, C) int32 -- tiles in each claim
    out_ref,      # (gh*block_h, gw*block_w) int32 -- whole counts image
    *,
    ct: int,
    width: int,
    height: int,
    xmin: float,
    xmax: float,
    ymin: float,
    ymax: float,
    block_h: int,
    block_w: int,
    gw: int,
    C: int,
):
    w = pl.program_id(0)

    def claim_body(c, _):
        st = starts_ref[w, c]
        sz = sizes_ref[w, c]

        def tile_body(t, __):
            tile = st + t
            ti = tile // gw
            tj = tile - ti * gw
            rows = ti * block_h + jax.lax.broadcasted_iota(
                jnp.int32, (block_h, block_w), 0)
            cols = tj * block_w + jax.lax.broadcasted_iota(
                jnp.int32, (block_h, block_w), 1)
            cnt = escape_counts_tile(
                rows, cols, ct=ct, width=width, height=height,
                xmin=xmin, xmax=xmax, ymin=ymin, ymax=ymax)
            out_ref[pl.ds(ti * block_h, block_h),
                    pl.ds(tj * block_w, block_w)] = cnt
            return __

        jax.lax.fori_loop(0, sz, tile_body, 0)
        return _

    jax.lax.fori_loop(0, nclaims_ref[w], claim_body, 0)


def mandelbrot_persistent(
    width: int,
    height: int | None = None,
    *,
    ct: int = 1000,
    xlim=(-2.0, 1.0),
    ylim=(-1.5, 1.5),
    block_h: int = 128,
    block_w: int = 128,
    technique: str = "gss",
    workers: int = 4,
    chunk: int = 1,
    interpret: bool | None = None,
    costs=None,
    schedule: DeviceSchedule | None = None,
):
    """Self-scheduled counts image; returns ``(counts, DeviceSchedule)``.

    The loop is the linearized tile grid (N = ceil(h/bh) * ceil(w/bw));
    ``technique``/``workers``/``chunk`` parameterize the device claim loop.
    Pass ``schedule`` to reuse a previously-claimed schedule (it must match
    this grid), or ``costs`` (length N, per-tile) to shape the assignment.
    """
    from repro.kernels import resolve_interpret

    height = width if height is None else height
    interpret = resolve_interpret(interpret)
    gh = -(-height // block_h)
    gw = -(-width // block_w)
    N = gh * gw

    if schedule is None:
        schedule = claim_schedule(
            technique, N, workers, chunk=chunk, costs=costs,
            interpret=interpret)
    if schedule.N != N or schedule.P != workers:
        raise ValueError(
            f"schedule is for (N={schedule.N}, P={schedule.P}), "
            f"this grid needs (N={N}, P={workers})")
    nclaims, starts, sizes = schedule.worker_lists()
    C = starts.shape[1]

    kern = functools.partial(
        _persistent_kernel,
        ct=ct, width=width, height=height,
        xmin=float(xlim[0]), xmax=float(xlim[1]),
        ymin=float(ylim[0]), ymax=float(ylim[1]),
        block_h=block_h, block_w=block_w, gw=gw, C=C,
    )
    out = pl.pallas_call(
        kern,
        grid=(workers,),
        in_specs=[
            pl.BlockSpec((workers,), lambda w: (0,)),
            pl.BlockSpec((workers, C), lambda w: (0, 0)),
            pl.BlockSpec((workers, C), lambda w: (0, 0)),
        ],
        # every program maps to the same (whole-image) block: the claims
        # partition [0, N), so together the workers write every tile once
        out_specs=pl.BlockSpec((gh * block_h, gw * block_w), lambda w: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((gh * block_h, gw * block_w), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(nclaims), jnp.asarray(starts), jnp.asarray(sizes))
    return out[:height, :width], schedule


def mandelbrot_tile_costs(counts, block_h: int = 128, block_w: int = 128):
    """Per-tile cost model from a counts image: total escape iterations.

    Linearized row-major over the tile grid -- feed to ``claim_schedule`` /
    ``mandelbrot_persistent(costs=...)`` so the claim loop sees the real
    variable-cost profile (interior tiles burn CT per pixel, exterior ones
    almost nothing).
    """
    counts = np.asarray(counts)
    h, w = counts.shape
    gh = -(-h // block_h)
    gw = -(-w // block_w)
    padded = np.zeros((gh * block_h, gw * block_w), np.float64)
    padded[:h, :w] = counts
    return (padded.reshape(gh, block_h, gw, block_w)
                  .sum(axis=(1, 3)).reshape(gh * gw))
