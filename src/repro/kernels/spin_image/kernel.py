"""Pallas TPU kernel for PSIA spin-image generation (paper Algorithm 1).

The paper's first application converts a 3D point cloud into M spin-images:
for image center P_i (with normal n_i), every cloud point X_j is binned into
a (W x W) histogram by its in-plane/out-of-plane distances (alpha/beta),
gated by a support-angle test on the normals.

GPU/CPU implementations scatter into ``tempSpinImage[k, l]++``.  TPUs have no
efficient scatter; the TPU-native adaptation is **histogram-by-comparison**:
with the paper's W = 5 there are only 25 bins, so we one-hot the (k*W + l)
bin index of each (image, point) pair against a lane-aligned bin axis
(padded to 128) and *sum over points* -- turning the scatter into a dense
masked reduction the VPU executes at full width.

Grid: (image blocks, point blocks), point axis innermost; the per-image
histogram accumulates in a VMEM scratch across point blocks and is written
out on the last one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128  # padded bin axis (>= W*W)


def _spin_image_kernel(
    centers_ref,  # (BM, 3)   image-center points
    cnormals_ref,  # (BM, 3)   their normals
    points_ref,  # (BP, 3)   cloud points
    pnormals_ref,  # (BP, 3)   cloud normals
    out_ref,  # (BM, LANES) histogram (padded)
    acc_ref,  # scratch (BM, LANES) f32
    *,
    img_width: int,
    bin_size: float,
    cos_support: float,
    n_points: int,
    n_images: int,
    block_m: int,
    block_p: int,
):
    mi = pl.program_id(0)
    pj = pl.program_id(1)
    n_pblocks = pl.num_programs(1)

    @pl.when(pj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    P = centers_ref[...].astype(jnp.float32)  # (BM, 3)
    nP = cnormals_ref[...].astype(jnp.float32)  # (BM, 3)
    X = points_ref[...].astype(jnp.float32)  # (BP, 3)
    nX = pnormals_ref[...].astype(jnp.float32)  # (BP, 3)

    # pairwise geometry: diff (BM, BP, 3)
    diff = X[None, :, :] - P[:, None, :]
    beta = jnp.sum(nP[:, None, :] * diff, axis=-1)  # (BM, BP) out-of-plane
    r2 = jnp.sum(diff * diff, axis=-1)  # (BM, BP)
    alpha = jnp.sqrt(jnp.maximum(r2 - beta * beta, 0.0))  # in-plane
    cos_ang = jnp.sum(nP[:, None, :] * nX[None, :, :], axis=-1)

    k = jnp.ceil((img_width / 2.0 - beta) / bin_size).astype(jnp.int32)
    l = jnp.ceil(alpha / bin_size).astype(jnp.int32)

    m_idx = mi * block_m + jax.lax.broadcasted_iota(jnp.int32, (block_m, block_p), 0)
    p_idx = pj * block_p + jax.lax.broadcasted_iota(jnp.int32, (block_m, block_p), 1)
    valid = (
        (cos_ang >= cos_support)
        & (k >= 0) & (k < img_width)
        & (l >= 0) & (l < img_width)
        & (m_idx < n_images) & (p_idx < n_points)
    )
    bins = jnp.where(valid, k * img_width + l, -1)  # -1 never matches a lane

    # histogram-by-comparison: (BM, BP, LANES) one-hot summed over points
    lane = jax.lax.broadcasted_iota(jnp.int32, (block_m, block_p, LANES), 2)
    onehot = (bins[:, :, None] == lane).astype(jnp.float32)
    acc_ref[...] += jnp.sum(onehot, axis=1)

    @pl.when(pj == n_pblocks - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(jnp.int32)


def spin_images_pallas(
    points,  # (N, 3) float
    normals,  # (N, 3) float unit normals
    n_images: int,  # first n_images points are the image centers (paper: M)
    *,
    img_width: int = 5,
    bin_size: float = 0.01,
    support_angle: float = 2.0,  # radians; paper uses 2
    block_m: int = 8,
    block_p: int = 128,
    interpret: bool | None = None,
):
    """Spin images for the first ``n_images`` points; (n_images, W, W) int32."""
    import math

    from repro.kernels import resolve_interpret

    interpret = resolve_interpret(interpret)
    n_points = points.shape[0]
    gm = -(-n_images // block_m)
    gp = -(-n_points // block_p)
    mp, pp = gm * block_m, gp * block_p

    pad_pts = jnp.pad(points.astype(jnp.float32), ((0, pp - n_points), (0, 0)))
    pad_nrm = jnp.pad(normals.astype(jnp.float32), ((0, pp - n_points), (0, 0)))
    centers = jnp.pad(points[:n_images].astype(jnp.float32), ((0, mp - n_images), (0, 0)))
    cnorms = jnp.pad(normals[:n_images].astype(jnp.float32), ((0, mp - n_images), (0, 0)))

    kern = functools.partial(
        _spin_image_kernel,
        img_width=img_width,
        bin_size=float(bin_size),
        cos_support=float(math.cos(support_angle)),
        n_points=n_points,
        n_images=n_images,
        block_m=block_m,
        block_p=block_p,
    )
    out = pl.pallas_call(
        kern,
        grid=(gm, gp),
        in_specs=[
            pl.BlockSpec((block_m, 3), lambda mi, pj: (mi, 0)),
            pl.BlockSpec((block_m, 3), lambda mi, pj: (mi, 0)),
            pl.BlockSpec((block_p, 3), lambda mi, pj: (pj, 0)),
            pl.BlockSpec((block_p, 3), lambda mi, pj: (pj, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, LANES), lambda mi, pj: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, LANES), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, LANES), jnp.float32)],
        interpret=interpret,
    )(centers, cnorms, pad_pts, pad_nrm)
    return out[:n_images, : img_width * img_width].reshape(n_images, img_width, img_width)
