"""Pure-jnp oracle for spin-image generation (paper Algorithm 1)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def spin_images_ref(
    points,
    normals,
    n_images: int,
    *,
    img_width: int = 5,
    bin_size: float = 0.01,
    support_angle: float = 2.0,
):
    """Dense (n_images, W, W) histograms over all (image, point) pairs."""
    pts = points.astype(jnp.float32)
    nrm = normals.astype(jnp.float32)
    P = pts[:n_images]  # (M, 3)
    nP = nrm[:n_images]
    diff = pts[None, :, :] - P[:, None, :]  # (M, N, 3)
    beta = jnp.sum(nP[:, None, :] * diff, axis=-1)
    r2 = jnp.sum(diff * diff, axis=-1)
    alpha = jnp.sqrt(jnp.maximum(r2 - beta * beta, 0.0))
    cos_ang = jnp.sum(nP[:, None, :] * nrm[None, :, :], axis=-1)
    k = jnp.ceil((img_width / 2.0 - beta) / bin_size).astype(jnp.int32)
    l = jnp.ceil(alpha / bin_size).astype(jnp.int32)
    valid = (
        (cos_ang >= math.cos(support_angle))
        & (k >= 0) & (k < img_width)
        & (l >= 0) & (l < img_width)
    )
    bins = jnp.where(valid, k * img_width + l, img_width * img_width)  # overflow bin
    onehot = jnp.zeros((n_images, img_width * img_width + 1), jnp.int32)
    # one-hot sum over points via comparison (same math as the kernel)
    lane = jnp.arange(img_width * img_width + 1)[None, None, :]
    onehot = jnp.sum((bins[:, :, None] == lane).astype(jnp.int32), axis=1)
    return onehot[:, :-1].reshape(n_images, img_width, img_width)
