"""Jitted public entry points for the spin-image kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import spin_images_pallas
from .ref import spin_images_ref


@functools.partial(
    jax.jit,
    static_argnames=("n_images", "img_width", "bin_size", "support_angle",
                     "block_m", "block_p", "interpret"),
)
def spin_images(points, normals, n_images, *, img_width=5, bin_size=0.01,
                support_angle=2.0, block_m=8, block_p=128, interpret=None):
    return spin_images_pallas(
        points, normals, n_images, img_width=img_width, bin_size=bin_size,
        support_angle=support_angle, block_m=block_m, block_p=block_p,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("n_images", "img_width", "bin_size", "support_angle")
)
def spin_images_oracle(points, normals, n_images, *, img_width=5, bin_size=0.01,
                       support_angle=2.0):
    return spin_images_ref(points, normals, n_images, img_width=img_width,
                           bin_size=bin_size, support_angle=support_angle)
