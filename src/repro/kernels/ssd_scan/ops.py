"""Jitted public entry points for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan_pallas
from .ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    """Chunked Mamba2 SSD scan (Pallas).  Returns y (B,T,H,Dh)."""
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


@jax.jit
def ssd_scan_oracle(x, dt, A, Bm, Cm):
    return ssd_scan_ref(x, dt, A, Bm, Cm)
