"""Pure-jnp oracles for the SSD scan.

``ssd_scan_ref``          -- the naive sequential recurrence (ground truth;
                             O(T) scan steps, state round-trips HBM per step).
``ssd_scan_chunked_xla``  -- the SSD *block decomposition* in pure XLA: the
                             same math as the Pallas kernel (chunk-local
                             matmuls + one inter-chunk state carry), which is
                             the production train/prefill path off-TPU.  The
                             chunk body is ``jax.checkpoint``-ed so backward
                             recomputes the (L, L) decay products instead of
                             saving them (O(T * L * H) would otherwise leak
                             into residuals).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Sequential scan over T. x (B,T,H,Dh), dt (B,T,H), A (H,), Bm/Cm (B,T,S).

        h_t = exp(dt_t A_h) h_{t-1} + dt_t (B_t (x) x_t);   y_t = C_t . h_t
    """
    Bsz, T, H, Dh = x.shape
    S = Bm.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,Dh), (B,H), (B,S), (B,S)
        decay = jnp.exp(dt_t * A[None, :])  # (B,H)
        inject = (
            dt_t[:, :, None, None]
            * b_t[:, None, :, None]
            * x_t[:, :, None, :]
        )  # (B,H,S,Dh)
        h = decay[:, :, None, None] * h + inject
        y_t = jnp.einsum("bs,bhsd->bhd", c_t, h)
        return h, y_t

    h0 = jnp.zeros((Bsz, H, S, Dh), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Cm.astype(jnp.float32), 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,T,H,Dh)


def ssd_scan_chunked_xla(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Chunked SSD in pure jnp.  Same signature/semantics as ``ssd_scan_ref``.

    Returns (y (B,T,H,Dh) in x.dtype, final_state (B,H,S,Dh) f32).
    """
    Bsz, T, H, P = x.shape
    S = Bm.shape[-1]
    nc = -(-T // chunk)
    Tp = nc * chunk
    # dt=0 padding is exact: decay exp(0)=1, zero input contribution
    xp = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0), (0, 0))).astype(jnp.float32)
    dtp = jnp.pad(dt, ((0, 0), (0, Tp - T), (0, 0))).astype(jnp.float32)
    Bp = jnp.pad(Bm, ((0, 0), (0, Tp - T), (0, 0))).astype(jnp.float32)
    Cp = jnp.pad(Cm, ((0, 0), (0, Tp - T), (0, 0))).astype(jnp.float32)
    Af = A.astype(jnp.float32)

    xs = jnp.moveaxis(xp.reshape(Bsz, nc, chunk, H, P), 1, 0)
    dts = jnp.moveaxis(dtp.reshape(Bsz, nc, chunk, H), 1, 0)
    Bs = jnp.moveaxis(Bp.reshape(Bsz, nc, chunk, S), 1, 0)
    Cs = jnp.moveaxis(Cp.reshape(Bsz, nc, chunk, S), 1, 0)
    tril = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])

    @jax.checkpoint
    def step(h, inp):
        xc, dtc, bc, cc = inp  # (B,L,H,P), (B,L,H), (B,L,S), (B,L,S)
        la = dtc * Af[None, None, :]          # (B,L,H) log decays (<= 0)
        acum = jnp.cumsum(la, axis=1)          # inclusive prefix
        G = jnp.einsum("bis,bjs->bij", cc, bc)  # (B,L,L)
        # mask the *exponent*: the upper triangle has positive exponents that
        # overflow to inf, and inf*0 in the VJP of a post-hoc where is NaN
        diff = acum[:, :, None, :] - acum[:, None, :, :]  # (B,L,L,H)
        diff = jnp.where(tril[None, :, :, None], diff, -jnp.inf)
        W = G[..., None] * jnp.exp(diff) * dtc[:, None, :, :]  # dt_j
        y = jnp.einsum("bijh,bjhp->bihp", W, xc)
        y = y + jnp.einsum("bis,bih,bhsp->bihp", cc, jnp.exp(acum), h)
        w_state = dtc * jnp.exp(acum[:, -1:, :] - acum)  # (B,L,H)
        h = (jnp.exp(acum[:, -1])[:, :, None, None] * h
             + jnp.einsum("bjs,bjh,bjhp->bhsp", bc, w_state, xc))
        return h, y

    h0 = jnp.zeros((Bsz, H, S, P), jnp.float32)
    h, ys = jax.lax.scan(step, h0, (xs, dts, Bs, Cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Tp, H, P)[:, :T]
    return y.astype(x.dtype), h
