"""Mamba2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

Computes the selective state-space recurrence

    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * (B_t (x) x_t)
    y_t = C_t . h_t

in *chunks* of L steps (the SSD block decomposition, arXiv:2405.21060):
within a chunk everything is dense matmuls (MXU work), and the only
sequential dependency is the (S x Dh) inter-chunk state.

TPU adaptation: TPU Pallas grids iterate **sequentially**, so the running
state is carried in a VMEM scratch accumulator across the chunk axis of the
grid -- no host loop, no HBM round-trip for the state.  Grid order is
(batch, head, chunk) with chunk innermost; the scratch is re-zeroed at
chunk == 0.

Per chunk (L = 128 default, S = state dim, Dh = head dim):
    la      = dt * A[h]                              (L,)  log-decays
    acum    = cumsum(la)                             (L,)  inclusive
    Y_intra = ((C B^T) o decay o tril) diag(dt) X    (L,L)@(L,Dh)  MXU
    Y_inter = (C o exp(acum)) h_prev                 (L,S)@(S,Dh)  MXU
    h_new   = exp(acum[-1]) h_prev
              + (B o dt o exp(acum[-1]-acum))^T X    (S,L)@(L,Dh)  MXU
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, L, 1, Dh)
    dt_ref,  # (1, L, 1)
    a_ref,  # (1,)           A (log-decay rate) for this head
    b_ref,  # (1, L, S)
    c_ref,  # (1, L, S)
    y_ref,  # (1, L, 1, Dh)
    h_ref,  # scratch (S, Dh) f32  -- carried across chunks
    *,
    L: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, Dh)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    A = a_ref[0].astype(jnp.float32)  # scalar
    B = b_ref[0].astype(jnp.float32)  # (L, S)
    C = c_ref[0].astype(jnp.float32)  # (L, S)

    la = dt * A  # (L,) log decay (A < 0)
    acum = jnp.cumsum(la)  # inclusive prefix

    # intra-chunk: W[i,j] = (C_i . B_j) exp(acum_i - acum_j) dt_j  for j <= i
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    decay = jnp.exp(acum[:, None] - acum[None, :])
    tril = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    )
    W = jnp.where(tril, G * decay, 0.0) * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, Dh)

    # inter-chunk: contribution of the carried state
    h_prev = h_ref[...]
    y += jax.lax.dot_general(C * jnp.exp(acum)[:, None], h_prev,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update
    wB = B * (dt * jnp.exp(acum[-1] - acum))[:, None]  # (L, S)
    h_ref[...] = jnp.exp(acum[-1]) * h_prev + jax.lax.dot_general(
        wB, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (S, Dh)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan_pallas(
    x,  # (B, T, H, Dh)
    dt,  # (B, T, H)     positive step sizes
    A,  # (H,)           negative log-decay rates
    Bm,  # (B, T, S)
    Cm,  # (B, T, S)
    *,
    chunk: int = 128,
    interpret: bool | None = None,
):
    """Chunked SSD scan; returns y (B, T, H, Dh) in x.dtype."""
    from repro.kernels import resolve_interpret

    interpret = resolve_interpret(interpret)
    Bsz, T, H, Dh = x.shape
    S = Bm.shape[-1]
    nc = -(-T // chunk)
    Tp = nc * chunk
    # dt=0 padding is exact: decay exp(0)=1, no input contribution.
    xp = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, Tp - T), (0, 0)))
    Bp = jnp.pad(Bm, ((0, 0), (0, Tp - T), (0, 0)))
    Cp = jnp.pad(Cm, ((0, 0), (0, Tp - T), (0, 0)))

    kern = functools.partial(_ssd_kernel, L=chunk)
    out = pl.pallas_call(
        kern,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, Dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, S), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, S), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, Dh), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, Tp, H, Dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((S, Dh), jnp.float32)],
        interpret=interpret,
    )(xp, dtp, A, Bp, Cp)
    return out[:, :T]
