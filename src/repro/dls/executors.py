"""Pluggable executors: how a session's claims actually get executed.

Three built-ins, all draining a ``DLSession`` to completion and returning a
``SessionReport``:

  * ``serial``  -- round-robin claims on the calling thread.  Deterministic;
    the reference executor for tests and planners.
  * ``threads`` -- real concurrency, one thread per PE.  One-sided runtimes
    claim independently (the paper's protocol); two-sided runtimes run the
    non-dedicated master-worker protocol (master interleaves serving the
    request queue with its own chunks).
  * ``sim``     -- the discrete-event simulator (``core/sim.py``): no real
    execution; pass per-iteration ``costs`` and per-PE ``speeds``.  This is
    how the paper's heterogeneous-cluster experiments run.
  * ``device``  -- the whole claim loop inside a persistent Pallas kernel
    against a ``DeviceWindow`` slab (``repro.device``, DESIGN.md Sec. 14);
    requires ``runtime="device"``.

``work_fn(start, stop)`` executes iterations ``[start, stop)``.  Executors
time every chunk and feed ``session.record`` so AWF weights and the
busy-time metrics see the same signal.  See DESIGN.md Sec. 4.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.core.scheduler import Claim, TwoSidedRuntime

EXECUTORS = ("serial", "threads", "processes", "sim", "device")

WorkFn = Callable[[int, int], None]


def execute(session, work_fn: Optional[WorkFn], executor: str = "threads",
            **kw):
    if executor == "serial":
        return _serial(session, work_fn, **kw)
    if executor == "threads":
        if isinstance(session.runtime, TwoSidedRuntime):
            return _threads_two_sided(session, work_fn, **kw)
        return _threads_one_sided(session, work_fn, **kw)
    if executor == "processes":
        # real OS processes over a shared-memory window (repro.pt): the
        # session must have been opened with window="shm"
        from repro.pt.executor import execute_processes

        return execute_processes(session, work_fn, **kw)
    if executor == "sim":
        return _sim(session, **kw)
    if executor == "device":
        # the whole claim loop runs inside a persistent Pallas kernel
        # against the session's DeviceWindow slab (repro.device)
        from repro.device.executor import execute_device

        return execute_device(session, work_fn, **kw)
    raise ValueError(f"unknown executor {executor!r}; pick from {EXECUTORS}")


def _run_chunk(session, pe: int, c: Claim, work_fn: Optional[WorkFn],
               sched_seconds: float = 0.0,
               origin: Optional[float] = None) -> None:
    t0 = time.perf_counter()
    if work_fn is not None:
        work_fn(c.start, c.stop)
    t1 = time.perf_counter()
    if origin is None:
        session.record(pe, c.size, t1 - t0, sched_seconds=sched_seconds)
    else:
        # Timestamps relative to the executor's start feed the per-chunk
        # timing log (SessionReport.chunk_times -- the replay capture plane).
        session.record(pe, c.size, t1 - t0, sched_seconds=sched_seconds,
                       claim=c, t_start=t0 - origin, t_end=t1 - origin)


def _timed_claim(session, pe: int):
    """(claim, seconds spent claiming) -- the scheduling overhead that the
    overhead-timing adaptive variants (AWF-D/E) fold into chunk timings."""
    t0 = time.perf_counter()
    c = session.claim(pe)
    return c, time.perf_counter() - t0


def _serial(session, work_fn: Optional[WorkFn]):
    """Round-robin over the spec's P logical PEs, one claim at a time."""
    P = session.spec.P
    t0 = time.perf_counter()
    # A PE's None retires that PE only: hierarchical runtimes drain per
    # *node* (a PE of an exhausted node sees None while other nodes still
    # hold super-chunk remainders), so the drain ends when every PE is done.
    done = [False] * P
    n_done = 0
    pe = 0
    while n_done < P:
        if not done[pe]:
            c, sched = _timed_claim(session, pe)
            if c is None:
                done[pe] = True
                n_done += 1
            else:
                _run_chunk(session, pe, c, work_fn, sched, origin=t0)
        pe = (pe + 1) % P
    return session.report("serial", wall_time=time.perf_counter() - t0)


def _threads_one_sided(session, work_fn: Optional[WorkFn],
                       n_threads: Optional[int] = None):
    """The paper's execution model: every PE claims for itself, no master.

    Hierarchical runtimes take this path too -- claims stay self-service;
    the runtime internally routes them through the node-local window.
    """
    n_threads = n_threads or session.spec.P
    t0 = time.perf_counter()

    def worker(pe: int):
        while True:
            c, sched = _timed_claim(session, pe)
            if c is None:
                return
            _run_chunk(session, pe, c, work_fn, sched, origin=t0)

    threads = [threading.Thread(target=worker, args=(j,), name=f"dls-{j}")
               for j in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return session.report("threads", wall_time=time.perf_counter() - t0)


def _threads_two_sided(session, work_fn: Optional[WorkFn],
                       n_threads: Optional[int] = None, master_pe: int = 0):
    """Master-worker execution: PE ``master_pe`` is the non-dedicated master.

    The master interleaves serving requests with executing its own chunks
    (checks the queue between chunks, like the LB tool's breakAfter).
    """
    rt: TwoSidedRuntime = session.runtime
    n_threads = n_threads or session.spec.P
    done = threading.Event()
    t0 = time.perf_counter()

    def worker(pe: int):
        while True:
            tc = time.perf_counter()
            af = session.policy.af_stats(pe) if session._wants_af else None
            reply = rt.request(pe, weight=session.policy.weight(pe), af=af)
            c = reply.get()
            sched = time.perf_counter() - tc
            if c is None:
                return
            session.log_claim(pe, c)
            _run_chunk(session, pe, c, work_fn, sched, origin=t0)

    def master():
        my_claim: Optional[Claim] = None
        my_sched = 0.0
        while True:
            rt.serve_pending()
            if my_claim is None:
                my_claim, my_sched = _timed_claim(session, master_pe)
                if my_claim is None:
                    # loop exhausted: keep serving until workers drain
                    while not done.is_set():
                        if not rt.serve_blocking(timeout=0.01):
                            if done.is_set():
                                break
                    rt.serve_pending()
                    return
            _run_chunk(session, master_pe, my_claim, work_fn, my_sched,
                       origin=t0)
            my_claim = None

    threads = [
        threading.Thread(target=worker, args=(j,), name=f"dls-{j}")
        for j in range(n_threads)
        if j != master_pe
    ]
    mt = threading.Thread(target=master)
    for t in threads:
        t.start()
    mt.start()
    for t in threads:
        t.join()
    done.set()
    mt.join()
    return session.report("threads", wall_time=time.perf_counter() - t0)


def _sim(session, costs=None, speeds=None, **sim_kw):
    """Discrete-event simulation of this session's spec (no real execution).

    ``costs``: per-iteration execution cost (length N, seconds at speed 1);
    ``speeds``: per-PE relative speed (length P, defaults to homogeneous).
    Wall time in the returned report is the *virtual* ``T_p^loop``.
    Hierarchical sessions carry their ``nodes``/``inner_technique`` into the
    DES and report per-level RMW counts.  ``collect_trace=True`` records
    the DES's per-chunk events into ``report.chunk_times`` (virtual-clock
    timestamps) so simulated runs are replayable like native ones.
    ``perturbations=(...)`` forwards a ``repro.sim.perturb`` scenario
    (PE failure/churn, stragglers, speed drift) into the kernel.
    """
    from repro.core.scheduler import HierarchicalRuntime
    from repro.core.sim import SimConfig, simulate
    from .report import SessionReport

    spec = session.spec
    if costs is None:
        raise ValueError("executor='sim' needs per-iteration costs=")
    if speeds is None:
        speeds = np.ones(spec.P)
    if isinstance(session.runtime, HierarchicalRuntime):
        sim_kw.setdefault("nodes", session.runtime.nodes)
        sim_kw.setdefault("inner_technique", session.runtime.inner_technique)
    r = simulate(SimConfig(spec, np.asarray(speeds), np.asarray(costs),
                           impl=session.runtime_kind, **sim_kw))
    chunk_times = None
    if r.chunk_trace is not None:
        # Canonical completion-ordering (two-sided master chunks are
        # recorded at completion, out of grant order).
        chunk_times = sorted(r.chunk_trace,
                             key=lambda d: (d["t0"], d["t1"], d["pe"]))
    return SessionReport(
        technique=spec.technique,
        N=spec.N,
        P=spec.P,
        runtime=session.runtime_kind,
        executor="sim",
        min_chunk=spec.min_chunk,
        max_chunk=spec.max_chunk,
        per_pe_claims=[[] for _ in range(spec.P)],  # DES logs counts, not claims
        per_pe_iters=np.asarray(r.per_pe_iters, dtype=np.int64),
        busy_time=np.asarray(r.finish, dtype=np.float64),
        wall_time=float(r.T_loop),
        n_claims=r.n_claims,
        n_rmw_global=r.n_rmw_global,
        n_rmw_local=r.n_rmw_local,
        chunk_times=chunk_times,
        auto_decision=session.auto_decision,
    )
