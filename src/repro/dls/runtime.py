"""The Runtime contract: what a claim source must implement.

Both protocol implementations in ``repro.core.scheduler`` --
``OneSidedRuntime`` (the paper's two-fetch-add distributed chunk
calculation) and ``TwoSidedRuntime`` (the master-worker baseline) --
satisfy this contract, which is what lets ``DLSession`` and the executors
treat them interchangeably.  See DESIGN.md Sec. 2.
"""
from __future__ import annotations

from typing import Dict, Optional

try:
    from typing import Protocol, runtime_checkable
except ImportError:  # pragma: no cover
    Protocol = object  # type: ignore

    def runtime_checkable(cls):  # type: ignore
        return cls

from repro.core.chunk_calculus import LoopSpec
from repro.core.rma import Window, make_window
from repro.core.scheduler import Claim, OneSidedRuntime, TwoSidedRuntime

RUNTIMES = ("one_sided", "two_sided")


@runtime_checkable
class Runtime(Protocol):
    """A source of loop claims over a shared iteration space."""

    spec: LoopSpec

    def claim(self, pe: int = 0, weight: Optional[float] = None) -> Optional[Claim]:
        """One scheduling step for ``pe``; None once the loop is exhausted."""
        ...

    def remaining_lower_bound(self) -> int:
        """Unclaimed iterations still in the pool (0 once drained)."""
        ...

    def drained(self) -> bool:
        """True when no PE can obtain further work."""
        ...

    def state(self) -> Dict[str, int]:
        """Checkpointable counters (step index ``i``, loop pointer ``lp``)."""
        ...

    def restore(self, st: Dict[str, int]) -> None:
        ...


def make_runtime(
    spec: LoopSpec,
    runtime: str = "one_sided",
    window=None,
    loop_id: Optional[int] = None,
) -> Runtime:
    """Build a Runtime.  ``window`` is a backend name or a ``Window`` object
    (shared across sessions for multi-claimer setups); two-sided runtimes
    keep all state master-side and take no window."""
    if runtime == "one_sided":
        if window is None:
            window = "thread"
        if isinstance(window, str):
            window = make_window(window)
        elif not isinstance(window, Window):
            raise TypeError(f"window must be a backend name or Window, got {window!r}")
        return OneSidedRuntime(spec, window, loop_id=loop_id)
    if runtime == "two_sided":
        return TwoSidedRuntime(spec)
    raise ValueError(f"unknown runtime {runtime!r}; pick from {RUNTIMES}")
