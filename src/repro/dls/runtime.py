"""The Runtime contract: what a claim source must implement.

All three protocol implementations in ``repro.core.scheduler`` --
``OneSidedRuntime`` (the paper's two-fetch-add distributed chunk
calculation), ``TwoSidedRuntime`` (the master-worker baseline), and
``HierarchicalRuntime`` (two-level node/global scheduling,
arXiv:1903.09510) -- satisfy this contract, which is what lets
``DLSession`` and the executors treat them interchangeably.  See
DESIGN.md Sec. 2 and 7.
"""
from __future__ import annotations

from typing import Dict, Optional

try:
    from typing import Protocol, runtime_checkable
except ImportError:  # pragma: no cover
    Protocol = object  # type: ignore

    def runtime_checkable(cls):  # type: ignore
        return cls

from repro.core.chunk_calculus import AFStats, LoopSpec
from repro.core.rma import HierarchicalWindow, Window, make_window
from repro.core.scheduler import (
    Claim,
    HierarchicalRuntime,
    OneSidedRuntime,
    TwoSidedRuntime,
)

RUNTIMES = ("one_sided", "two_sided", "hierarchical", "device")


@runtime_checkable
class Runtime(Protocol):
    """A source of loop claims over a shared iteration space."""

    spec: LoopSpec

    def claim(self, pe: int = 0, weight: Optional[float] = None,
              af: Optional[AFStats] = None) -> Optional[Claim]:
        """One scheduling step for ``pe``; None once the loop is exhausted.

        ``weight`` is the AWF-family live weight; ``af`` is Adaptive
        Factoring's measured ``AFStats`` snapshot (both optional -- static
        techniques ignore them).
        """
        ...

    def remaining_lower_bound(self) -> int:
        """Unclaimed iterations still in the pool (0 once drained)."""
        ...

    def drained(self) -> bool:
        """True when no PE can obtain further work."""
        ...

    def state(self) -> Dict[str, int]:
        """Checkpointable counters (step index ``i``, loop pointer ``lp``)."""
        ...

    def restore(self, st: Dict[str, int]) -> None:
        ...


def make_runtime(
    spec: LoopSpec,
    runtime: str = "one_sided",
    window=None,
    loop_id: Optional[int] = None,
    nodes: Optional[int] = None,
    inner_technique: Optional[str] = None,
) -> Runtime:
    """Build a Runtime.  ``window`` is a backend name or a ``Window`` object
    (shared across sessions for multi-claimer setups); two-sided runtimes
    keep all state master-side and take no window.

    ``runtime="hierarchical"`` needs ``nodes=`` and optionally an
    ``inner_technique`` (default SS within the node).  Its window may be a
    ``HierarchicalWindow``, a plain ``Window``/backend name for the *global*
    level (node-local levels stay in-process -- on a cluster the global
    level is the KV store and locals are per-host shared memory), or
    ``"sim"`` for per-level clocked accounting.
    """
    if runtime == "hierarchical":
        if nodes is None:
            raise ValueError('runtime="hierarchical" requires nodes=')
        if not isinstance(window, HierarchicalWindow):
            if window is None or window == "thread":
                window = HierarchicalWindow(nodes)
            elif window == "sim":
                window = HierarchicalWindow.sim(nodes)
            elif window == "shm":
                # both levels in shared memory: the processes executor
                # needs every level attachable from any OS process
                from repro.pt.window import shm_hierarchical

                window = shm_hierarchical(nodes)
            elif isinstance(window, str):
                window = HierarchicalWindow(nodes, global_window=make_window(window))
            elif isinstance(window, Window):
                window = HierarchicalWindow(nodes, global_window=window)
            else:
                raise TypeError(
                    f"window must be a backend name or Window, got {window!r}")
        return HierarchicalRuntime(spec, nodes, window,
                                   inner_technique=inner_technique or "ss",
                                   loop_id=loop_id)
    if nodes is not None or inner_technique is not None:
        raise ValueError(
            f'nodes=/inner_technique= only apply to runtime="hierarchical", '
            f"got runtime={runtime!r}")
    if runtime == "device":
        # one-sided protocol, counters in device memory (repro.device)
        from repro.device.runtime import DeviceRuntime
        from repro.device.window import DeviceWindow

        if window is None or window == "device":
            window = make_window("device")
        if not isinstance(window, DeviceWindow):
            raise TypeError(
                f'runtime="device" needs a DeviceWindow '
                f"(window=None or window=\"device\"), got {window!r}")
        return DeviceRuntime(spec, window, loop_id=loop_id)
    if runtime == "one_sided":
        if window is None:
            window = "thread"
        if isinstance(window, str):
            window = make_window(window)
        elif not isinstance(window, Window):
            raise TypeError(f"window must be a backend name or Window, got {window!r}")
        return OneSidedRuntime(spec, window, loop_id=loop_id)
    if runtime == "two_sided":
        return TwoSidedRuntime(spec)
    raise ValueError(f"unknown runtime {runtime!r}; pick from {RUNTIMES}")
