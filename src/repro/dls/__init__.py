"""repro.dls -- the public facade for dynamic loop self-scheduling.

One composable session API over the paper's machinery (see DESIGN.md):

    from repro import dls

    session = dls.loop(1_000_000, technique="awf", P=288,
                       runtime="one_sided", window="auto", weights="awf")
    report = session.execute(work_fn, executor="threads")
    print(report.summary())  # steps, chunk sizes, per-PE busy, c.o.v.

Layers behind the facade (all swappable):
  Runtime      -- one_sided (two atomic fetch-adds, paper Sec. 3),
                  two_sided (master-worker baseline), hierarchical
  Window       -- thread | kvstore | sim | auto (repro.core.rma)
  WeightPolicy -- uniform | static WF | the adaptive family (AWF EMA,
                  AWF-B/C/D/E, AF) over online PerfModel telemetry
                  (DESIGN.md Sec. 8)
  Executor     -- serial | threads | sim

``repro.core``'s ``run_threaded_*`` helpers were deprecation shims
over this package; they were removed in ISSUE 5 -- use
``loop(...).execute(work_fn, executor="threads")``.
"""
from repro.core.chunk_calculus import (  # noqa: F401  (re-exported surface)
    ADAPTIVE,
    TECHNIQUES,
    WEIGHTED,
    AFStats,
    LoopSpec,
    technique_table,
)
from repro.core.rma import HierarchicalWindow  # noqa: F401
from repro.core.scheduler import Claim, HierarchicalRuntime  # noqa: F401
from repro.core.weights import PerfModel  # noqa: F401

from .executors import EXECUTORS, execute  # noqa: F401
from .policies import (  # noqa: F401
    POLICY_NAMES,
    AdaptiveFactoring,
    AdaptiveWeights,
    AWFVariantWeights,
    CallableWeights,
    StaticWeights,
    UniformWeights,
    WeightPolicy,
    make_weight_policy,
)
from .report import SessionReport  # noqa: F401
from .runtime import RUNTIMES, Runtime, make_runtime  # noqa: F401
from .session import DLSession, loop  # noqa: F401

__all__ = [
    "ADAPTIVE",
    "AFStats",
    "AWFVariantWeights",
    "AdaptiveFactoring",
    "AdaptiveWeights",
    "CallableWeights",
    "Claim",
    "DLSession",
    "EXECUTORS",
    "HierarchicalRuntime",
    "HierarchicalWindow",
    "LoopSpec",
    "POLICY_NAMES",
    "PerfModel",
    "RUNTIMES",
    "Runtime",
    "SessionReport",
    "StaticWeights",
    "TECHNIQUES",
    "UniformWeights",
    "WEIGHTED",
    "WeightPolicy",
    "execute",
    "loop",
    "make_runtime",
    "make_weight_policy",
    "technique_table",
]
