"""Per-session scheduling metrics (the paper's Sec. 5 measurands).

Every claim a ``DLSession`` hands out is logged per PE; execution feedback
(``session.record``) accumulates per-PE busy time.  ``SessionReport``
aggregates both into the quantities the paper reports: number of
scheduling steps, chunk-size series, per-PE iteration counts, and the
load-imbalance coefficient of variation of per-PE busy/finish times.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.scheduler import Claim
from repro.core.weights import coefficient_of_variation


@dataclasses.dataclass
class SessionReport:
    """Aggregated metrics for one (possibly partial) session execution."""

    technique: str
    N: int
    P: int
    runtime: str  # "one_sided" | "two_sided" | "hierarchical"
    executor: Optional[str]  # "serial" | "threads" | "sim" | None (manual)
    per_pe_claims: List[List[Claim]]
    per_pe_iters: np.ndarray  # iterations executed (sim) or claimed, per PE
    busy_time: np.ndarray  # seconds of work_fn execution per PE
    wall_time: float  # wall-clock of execute() (sim: virtual T_loop)
    n_claims: Optional[int] = None  # overrides len(claims) (sim executor)
    # Per-level RMW counts (the follow-up paper's headline metric): how many
    # window RMWs paid the global serialization point vs a node-local one.
    # None when the window backend does not account (plain one-sided
    # ThreadWindow); flat sessions over counting windows report local=0.
    n_rmw_global: Optional[int] = None
    n_rmw_local: Optional[int] = None
    # Adaptation trace (adaptive policies only, DESIGN.md Sec. 8): the
    # policy's weight-update history -- for the AWF variants one entry per
    # update boundary ({"update": ordinal, "weights": [per-PE]}), for AF
    # one per recorded chunk ({"update", "pe", "mu"}).  None for static
    # policies; capped at the policy's trace_limit.
    adaptation: Optional[List[dict]] = None

    @property
    def claims(self) -> List[Claim]:
        return [c for per in self.per_pe_claims for c in per]

    @property
    def chunk_sizes(self) -> List[int]:
        return [c.size for c in self.claims]

    @property
    def total_iters(self) -> int:
        return int(self.per_pe_iters.sum())

    @property
    def steps(self) -> int:
        n = len(self.claims) if self.n_claims is None else self.n_claims
        return n

    @property
    def cov(self) -> float:
        """Load imbalance: c.o.v. of per-PE busy time (lower = better)."""
        if self.busy_time.sum() <= 0:
            return 0.0
        return coefficient_of_variation(self.busy_time)

    @property
    def n_weight_updates(self) -> int:
        """How many times the weight policy adapted during this session."""
        return len(self.adaptation) if self.adaptation else 0

    def final_weights(self) -> Optional[List[float]]:
        """The last adapted per-PE weights (AWF variants), if any."""
        if not self.adaptation:
            return None
        for entry in reversed(self.adaptation):
            if "weights" in entry:
                return entry["weights"]
        return None

    def summary(self) -> str:
        rmw = ""
        if self.n_rmw_global is not None:
            rmw = f" rmw_g={self.n_rmw_global}"
            if self.n_rmw_local is not None:
                rmw += f" rmw_l={self.n_rmw_local}"
        if self.adaptation:
            rmw += f" adapt={self.n_weight_updates}"
        return (
            f"{self.technique} N={self.N} P={self.P} [{self.runtime}"
            f"{'/' + self.executor if self.executor else ''}] "
            f"steps={self.steps} iters={self.total_iters} "
            f"cov={self.cov:.3f} wall={self.wall_time:.3f}s{rmw}"
        )
