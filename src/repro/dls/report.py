"""Per-session scheduling metrics (the paper's Sec. 5 measurands).

Every claim a ``DLSession`` hands out is logged per PE; execution feedback
(``session.record``) accumulates per-PE busy time.  ``SessionReport``
aggregates both into the quantities the paper reports: number of
scheduling steps, chunk-size series, per-PE iteration counts, and the
load-imbalance coefficient of variation of per-PE busy/finish times.

Reports are persistable: ``to_json()``/``from_json()`` round-trip every
field under an explicit ``schema_version`` -- the ``repro.replay`` trace
store is built on the per-chunk timing (``chunk_times``) carried here, so
a recorded run can be replayed/calibrated long after the session is gone
(DESIGN.md Sec. 9).
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

import numpy as np

from repro.core.scheduler import Claim
from repro.core.weights import coefficient_of_variation

#: Version of the serialized-report schema (``to_json``).  Bump on any
#: backward-incompatible field change; ``from_json`` rejects newer majors.
REPORT_SCHEMA_VERSION = 1


@dataclasses.dataclass
class SessionReport:
    """Aggregated metrics for one (possibly partial) session execution."""

    technique: str
    N: int
    P: int
    runtime: str  # "one_sided" | "two_sided" | "hierarchical"
    executor: Optional[str]  # "serial"|"threads"|"processes"|"sim"|None (manual)
    per_pe_claims: List[List[Claim]]
    per_pe_iters: np.ndarray  # iterations executed (sim) or claimed, per PE
    busy_time: np.ndarray  # seconds of work_fn execution per PE
    wall_time: float  # wall-clock of execute() (sim: virtual T_loop)
    # Chunk bounds of the spec that produced this report: without them a
    # replayed/predicted schedule would silently use default bounds.
    min_chunk: int = 1
    max_chunk: Optional[int] = None
    n_claims: Optional[int] = None  # overrides len(claims) (sim executor)
    # Per-level RMW counts (the follow-up paper's headline metric): how many
    # window RMWs paid the global serialization point vs a node-local one.
    # None when the window backend does not account (plain one-sided
    # ThreadWindow); flat sessions over counting windows report local=0.
    n_rmw_global: Optional[int] = None
    n_rmw_local: Optional[int] = None
    # Adaptation trace (adaptive policies only, DESIGN.md Sec. 8): the
    # policy's weight-update history -- for the AWF variants one entry per
    # update boundary ({"update": ordinal, "weights": [per-PE]}), for AF
    # one per recorded chunk ({"update", "pe", "mu"}).  None for static
    # policies; capped at the policy's trace_limit.
    adaptation: Optional[List[dict]] = None
    # Per-chunk timing (the repro.replay data plane, DESIGN.md Sec. 9):
    # one dict per executed chunk -- {"pe", "step", "start", "size", "t0",
    # "t1", "lat"} with t0/t1 seconds since execute() began (the DES's
    # virtual clock for executor="sim") and lat the claim latency.  None
    # when the session was driven without timestamps (manual claim loops).
    chunk_times: Optional[List[dict]] = None
    # technique="auto" only: the selection record -- chosen technique,
    # predicted ranking (ordered sweep of simulated T_loop), seed, budget,
    # and workload source.  None for explicitly chosen techniques.
    auto_decision: Optional[dict] = None
    # executor="processes" only (repro.pt): start method, atomicity
    # backend ("atomics"/"lockf"), per-PE process stats (pid, chunks,
    # RMW counts, death/salvage/orphan accounting), and the orphan
    # hand-off log.  None for in-process executors.
    process_stats: Optional[dict] = None
    # Serving scenarios only (repro.serve.scenarios): the SLO slice this
    # session (= one admission epoch) contributed -- an ``SLOReport``
    # dict -- and the online re-selection decisions taken at this
    # epoch's boundary (full predicted ranking included).  None outside
    # the serving plane.
    slo: Optional[dict] = None
    reselections: Optional[List[dict]] = None

    @property
    def claims(self) -> List[Claim]:
        return [c for per in self.per_pe_claims for c in per]

    @property
    def chunk_sizes(self) -> List[int]:
        return [c.size for c in self.claims]

    @property
    def total_iters(self) -> int:
        return int(self.per_pe_iters.sum())

    @property
    def steps(self) -> int:
        n = len(self.claims) if self.n_claims is None else self.n_claims
        return n

    @property
    def cov(self) -> float:
        """Load imbalance: c.o.v. of per-PE busy time (lower = better)."""
        if self.busy_time.sum() <= 0:
            return 0.0
        return coefficient_of_variation(self.busy_time)

    @property
    def n_weight_updates(self) -> int:
        """How many times the weight policy adapted during this session."""
        return len(self.adaptation) if self.adaptation else 0

    def final_weights(self) -> Optional[List[float]]:
        """The last adapted per-PE weights (AWF variants), if any."""
        if not self.adaptation:
            return None
        for entry in reversed(self.adaptation):
            if "weights" in entry:
                return entry["weights"]
        return None

    def summary(self) -> str:
        rmw = ""
        if self.n_rmw_global is not None:
            rmw = f" rmw_g={self.n_rmw_global}"
            if self.n_rmw_local is not None:
                rmw += f" rmw_l={self.n_rmw_local}"
        if self.adaptation:
            rmw += f" adapt={self.n_weight_updates}"
        if self.auto_decision:
            rmw += f" auto->{self.auto_decision.get('chosen')}"
        if self.process_stats:
            ps = self.process_stats
            rmw += (f" procs[{ps.get('start_method')}/"
                    f"{ps.get('window_backend')}"
                    f"{' deaths=' + str(ps['n_deaths']) if ps.get('n_deaths') else ''}]")
        return (
            f"{self.technique} N={self.N} P={self.P} [{self.runtime}"
            f"{'/' + self.executor if self.executor else ''}] "
            f"steps={self.steps} iters={self.total_iters} "
            f"cov={self.cov:.3f} wall={self.wall_time:.3f}s{rmw}"
        )

    # ------------------------------------------------------------------
    # persistence (schema-versioned; the replay trace store depends on it)
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        """Plain-JSON representation (claims as [step, start, size])."""
        return {
            "schema_version": REPORT_SCHEMA_VERSION,
            "technique": self.technique,
            "N": self.N,
            "P": self.P,
            "runtime": self.runtime,
            "executor": self.executor,
            "per_pe_claims": [[[c.step, c.start, c.size] for c in per]
                              for per in self.per_pe_claims],
            "per_pe_iters": [int(x) for x in self.per_pe_iters],
            "busy_time": [float(x) for x in self.busy_time],
            "wall_time": float(self.wall_time),
            "min_chunk": self.min_chunk,
            "max_chunk": self.max_chunk,
            "n_claims": self.n_claims,
            "n_rmw_global": self.n_rmw_global,
            "n_rmw_local": self.n_rmw_local,
            "adaptation": self.adaptation,
            "chunk_times": self.chunk_times,
            "auto_decision": self.auto_decision,
            "process_stats": self.process_stats,
            "slo": self.slo,
            "reselections": self.reselections,
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        """Canonical JSON text (sorted keys, so equal reports serialize
        byte-identically -- the trace store's round-trip contract)."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent,
                          separators=(",", ":") if indent is None else None)

    @classmethod
    def from_dict(cls, d: dict) -> "SessionReport":
        ver = d.get("schema_version")
        if ver is None or ver > REPORT_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported SessionReport schema_version {ver!r} "
                f"(this build reads <= {REPORT_SCHEMA_VERSION})")
        return cls(
            technique=d["technique"],
            N=d["N"],
            P=d["P"],
            runtime=d["runtime"],
            executor=d.get("executor"),
            per_pe_claims=[[Claim(step=c[0], start=c[1], size=c[2])
                            for c in per]
                           for per in d["per_pe_claims"]],
            per_pe_iters=np.asarray(d["per_pe_iters"], dtype=np.int64),
            busy_time=np.asarray(d["busy_time"], dtype=np.float64),
            wall_time=float(d["wall_time"]),
            min_chunk=int(d.get("min_chunk", 1)),
            max_chunk=d.get("max_chunk"),
            n_claims=d.get("n_claims"),
            n_rmw_global=d.get("n_rmw_global"),
            n_rmw_local=d.get("n_rmw_local"),
            adaptation=d.get("adaptation"),
            chunk_times=d.get("chunk_times"),
            auto_decision=d.get("auto_decision"),
            process_stats=d.get("process_stats"),
            slo=d.get("slo"),
            reselections=d.get("reselections"),
        )

    @classmethod
    def from_json(cls, text: str) -> "SessionReport":
        return cls.from_dict(json.loads(text))
