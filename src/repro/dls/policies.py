"""Weight policies: who gets how much of the loop, per claim.

The paper's WF scales the FAC2 closed form by a *static* per-PE weight;
its cited adaptive follow-ups make the weight a *measured* quantity.  A
``WeightPolicy`` decouples that choice from the runtimes: the session asks
the policy for the claimer's weight on every claim and feeds execution
timings back through ``record``.  ``weight() -> None`` means "no override"
-- the closed form then falls back to ``LoopSpec.weights`` (static WF) or
1.0 (uniform).

The adaptive family (DESIGN.md Sec. 8) is implemented over the online
telemetry models in ``repro.core.weights``:

  * ``AdaptiveWeights``      -- AWF: timestep-level EMA ``WeightBoard``
  * ``AWFVariantWeights``    -- AWF-B/C/D/E: weighted-average performance
    over ``PerfModel`` snapshot deltas at batch/chunk boundaries,
    optionally timing scheduling overhead (``chunk_calculus.AWF_VARIANTS``)
  * ``AdaptiveFactoring``    -- AF: measured per-PE (mu, sigma) feeding the
    ``AFStats`` closed form via ``af_stats`` instead of ``weight``

All three expose ``node_weight(node, bounds)`` so the hierarchical
runtime's outer (super-chunk) level can claim with telemetry aggregated
to node granularity, and ``trace``/``n_updates`` so sessions can report
the adaptation history (``SessionReport.adaptation``).  See DESIGN.md
Sec. 3 and 8.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

try:  # Protocol is 3.8+; keep a soft fallback for exotic interpreters
    from typing import Protocol, runtime_checkable
except ImportError:  # pragma: no cover
    Protocol = object  # type: ignore

    def runtime_checkable(cls):  # type: ignore
        return cls

from repro.core.chunk_calculus import ADAPTIVE, AWF_VARIANTS
from repro.core.weights import (
    AdaptiveFactoringModel,
    AdaptiveWeightModel,
    WeightBoard,
)


@runtime_checkable
class WeightPolicy(Protocol):
    """Per-claim weight source + throughput feedback sink."""

    def weight(self, pe: int) -> Optional[float]:
        """Weight override for PE ``pe``'s next claim; None = use the spec."""
        ...

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        """Feed back observed execution (no-op for static policies).

        ``sched_seconds`` is the claim's scheduling overhead -- only the
        overhead-timing variants (AWF-D/E) consume it.
        """
        ...


class UniformWeights:
    """No override: every PE gets the spec's static weight (or 1.0)."""

    def weight(self, pe: int) -> Optional[float]:
        return None

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        pass


class StaticWeights:
    """Fixed relative weights (the paper's WF), e.g. from core speeds."""

    def __init__(self, weights: Sequence[float]):
        self._w = [float(w) for w in weights]

    def weight(self, pe: int) -> Optional[float]:
        return self._w[pe]

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        pass


class AdaptiveWeights:
    """AWF: live weights from a ``WeightBoard`` EMA of measured throughput."""

    def __init__(self, board: WeightBoard):
        self.board = board

    def weight(self, pe: int) -> Optional[float]:
        return self.board.weight(pe)

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        self.board.record(pe, iters, seconds)


class AWFVariantWeights:
    """AWF-B/C/D/E over a window-backed ``AdaptiveWeightModel``.

    A thin protocol adapter: the adaptation math (weighted-average
    performance over ``PerfModel`` deltas) lives in ``repro.core.weights``
    so the DES drives the identical model.  ``variant`` is one of
    ``chunk_calculus.AWF_VARIANTS``; pass ``window=`` to share telemetry
    across sessions/hosts, or ``perf=`` to share a ready ``PerfModel``.
    """

    def __init__(self, P: int, variant: str = "awf_b", perf=None, window=None):
        if variant not in AWF_VARIANTS:
            raise ValueError(
                f"unknown AWF variant {variant!r}; pick from {tuple(AWF_VARIANTS)}")
        update, overhead = AWF_VARIANTS[variant]
        self.variant = variant
        self.model = AdaptiveWeightModel(
            P, update=update, include_overhead=overhead, perf=perf,
            window=window)

    def weight(self, pe: int) -> Optional[float]:
        return self.model.weight(pe)

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        self.model.record(pe, iters, seconds, sched_seconds)

    def advance(self) -> None:
        """Force an update boundary (timestep-style callers)."""
        self.model.advance()

    def node_weight(self, node: int, bounds) -> Optional[float]:
        return self.model.node_weight(node, bounds)

    @property
    def trace(self):
        return self.model.trace

    @property
    def n_updates(self) -> int:
        return self.model.n_updates


class AdaptiveFactoring:
    """AF over a window-backed ``AdaptiveFactoringModel``.

    AF does not scale a weight: ``weight()`` stays None and the session
    feeds ``af_stats(pe)`` -- the measured (mu, D, T) snapshot -- to the
    runtime, which hands it to ``chunk_calculus.af_chunk_size``.
    """

    def __init__(self, P: int, perf=None, window=None):
        self.model = AdaptiveFactoringModel(P, perf=perf, window=window)

    def weight(self, pe: int) -> Optional[float]:
        return None

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        self.model.record(pe, iters, seconds, sched_seconds)

    def af_stats(self, pe: int):
        return self.model.af_stats(pe)

    def node_weight(self, node: int, bounds) -> Optional[float]:
        return self.model.node_weight(node, bounds)

    @property
    def trace(self):
        return self.model.trace

    @property
    def n_updates(self) -> int:
        return self.model.n_updates


class CallableWeights:
    """Adapter for a plain ``pe -> weight`` callable (legacy ``weight_fn``)."""

    def __init__(self, fn: Callable[[int], float]):
        self.fn = fn

    def weight(self, pe: int) -> Optional[float]:
        return self.fn(pe)

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0) -> None:
        pass


def _named_policies(P: int) -> dict:
    """Name -> factory for every string ``loop(weights=...)`` accepts.

    One source of truth: the adaptive names come from
    ``chunk_calculus.ADAPTIVE``/``AWF_VARIANTS``, so facade errors,
    warnings, and docs can never drift from the technique roster.
    """
    named = {
        "uniform": lambda: UniformWeights(),
        "awf": lambda: AdaptiveWeights(WeightBoard(P)),
        "af": lambda: AdaptiveFactoring(P),
    }
    for v in AWF_VARIANTS:
        named[v] = (lambda v=v: AWFVariantWeights(P, variant=v))
    assert set(ADAPTIVE) <= set(named)
    return named


POLICY_NAMES = ("uniform", "awf") + ADAPTIVE


def make_weight_policy(
    weights: Union[None, str, WeightPolicy, WeightBoard, Sequence[float]],
    P: int,
) -> WeightPolicy:
    """Coerce the ``loop(weights=...)`` argument into a policy.

    Accepts None/"uniform", an adaptive technique name ("awf", "af",
    "awf_b".."awf_e" -- fresh telemetry), a WeightBoard, a float sequence
    (static WF weights), or any ready-made WeightPolicy.
    """
    if weights is None:
        return UniformWeights()
    if isinstance(weights, str):
        named = _named_policies(P)
        if weights in named:
            return named[weights]()
        raise ValueError(
            f"unknown weight policy {weights!r}; pick from {POLICY_NAMES}")
    if isinstance(weights, WeightBoard):
        return AdaptiveWeights(weights)
    if isinstance(weights, (UniformWeights, StaticWeights, AdaptiveWeights,
                            AWFVariantWeights, AdaptiveFactoring,
                            CallableWeights)):
        return weights
    if callable(getattr(weights, "weight", None)) and callable(
            getattr(weights, "record", None)):
        return weights  # duck-typed WeightPolicy
    if isinstance(weights, (list, tuple)) or hasattr(weights, "__len__"):
        if len(weights) != P:
            raise ValueError(f"weights must have length P={P}")
        return StaticWeights(weights)
    raise TypeError(f"cannot build a WeightPolicy from {weights!r}")
