"""Weight policies: who gets how much of the loop, per claim.

The paper's WF scales the FAC2 closed form by a *static* per-PE weight;
its cited AWF follow-up makes the weight a *measured* quantity.  A
``WeightPolicy`` decouples that choice from the runtimes: the session asks
the policy for the claimer's weight on every claim and feeds execution
timings back through ``record``.  ``weight() -> None`` means "no override"
-- the closed form then falls back to ``LoopSpec.weights`` (static WF) or
1.0 (uniform).  See DESIGN.md Sec. 3.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

try:  # Protocol is 3.8+; keep a soft fallback for exotic interpreters
    from typing import Protocol, runtime_checkable
except ImportError:  # pragma: no cover
    Protocol = object  # type: ignore

    def runtime_checkable(cls):  # type: ignore
        return cls

from repro.core.weights import WeightBoard


@runtime_checkable
class WeightPolicy(Protocol):
    """Per-claim weight source + throughput feedback sink."""

    def weight(self, pe: int) -> Optional[float]:
        """Weight override for PE ``pe``'s next claim; None = use the spec."""
        ...

    def record(self, pe: int, iters: int, seconds: float) -> None:
        """Feed back observed execution (no-op for static policies)."""
        ...


class UniformWeights:
    """No override: every PE gets the spec's static weight (or 1.0)."""

    def weight(self, pe: int) -> Optional[float]:
        return None

    def record(self, pe: int, iters: int, seconds: float) -> None:
        pass


class StaticWeights:
    """Fixed relative weights (the paper's WF), e.g. from core speeds."""

    def __init__(self, weights: Sequence[float]):
        self._w = [float(w) for w in weights]

    def weight(self, pe: int) -> Optional[float]:
        return self._w[pe]

    def record(self, pe: int, iters: int, seconds: float) -> None:
        pass


class AdaptiveWeights:
    """AWF: live weights from a ``WeightBoard`` EMA of measured throughput."""

    def __init__(self, board: WeightBoard):
        self.board = board

    def weight(self, pe: int) -> Optional[float]:
        return self.board.weight(pe)

    def record(self, pe: int, iters: int, seconds: float) -> None:
        self.board.record(pe, iters, seconds)


class CallableWeights:
    """Adapter for a plain ``pe -> weight`` callable (legacy ``weight_fn``)."""

    def __init__(self, fn: Callable[[int], float]):
        self.fn = fn

    def weight(self, pe: int) -> Optional[float]:
        return self.fn(pe)

    def record(self, pe: int, iters: int, seconds: float) -> None:
        pass


def make_weight_policy(
    weights: Union[None, str, WeightPolicy, WeightBoard, Sequence[float]],
    P: int,
) -> WeightPolicy:
    """Coerce the ``loop(weights=...)`` argument into a policy.

    Accepts None/"uniform", "awf" (fresh board), a WeightBoard, a float
    sequence (static WF weights), or any ready-made WeightPolicy.
    """
    if weights is None:
        return UniformWeights()
    if isinstance(weights, str):
        if weights == "uniform":
            return UniformWeights()
        if weights == "awf":
            return AdaptiveWeights(WeightBoard(P))
        raise ValueError(f"unknown weight policy {weights!r}")
    if isinstance(weights, WeightBoard):
        return AdaptiveWeights(weights)
    if isinstance(weights, (UniformWeights, StaticWeights, AdaptiveWeights,
                            CallableWeights)):
        return weights
    if callable(getattr(weights, "weight", None)) and callable(
            getattr(weights, "record", None)):
        return weights  # duck-typed WeightPolicy
    if isinstance(weights, (list, tuple)) or hasattr(weights, "__len__"):
        if len(weights) != P:
            raise ValueError(f"weights must have length P={P}")
        return StaticWeights(weights)
    raise TypeError(f"cannot build a WeightPolicy from {weights!r}")
