"""DLSession: the one entry point for self-scheduled loops.

A session binds a ``LoopSpec`` to a ``Runtime`` (one-sided / two-sided), a
``WeightPolicy`` (uniform / static WF / adaptive AWF), and a metrics log,
behind one small surface:

    from repro import dls

    with dls.loop(100_000, technique="fac2", P=16) as s:
        report = s.execute(work_fn, executor="threads")

    # or pipeline-style, one claim at a time:
    for c in s.claims(pe=3):
        consume(c.start, c.stop)

Sessions are namespaced per loop (monotonic KV windows work), resettable
(``reset()`` opens a fresh namespace on the same window), and
checkpointable (``state()``/``restore()`` round-trip the two window
counters).  See DESIGN.md.
"""
from __future__ import annotations

import inspect
import itertools
import threading
import warnings
from typing import Callable, Iterator, List, Optional

import numpy as np

from repro.core.chunk_calculus import ADAPTIVE, POLICY_DRIVEN, WEIGHTED, LoopSpec
from repro.core.rma import HierarchicalWindow, SimWindow
from repro.core.scheduler import Claim, HierarchicalRuntime, OneSidedRuntime

from .policies import UniformWeights, WeightPolicy, make_weight_policy
from .report import SessionReport
from .runtime import Runtime, make_runtime

_session_ids = itertools.count(1)


def _record_call_style(policy: WeightPolicy) -> str:
    """How to feed ``sched_seconds`` to ``policy.record``: "positional"
    (a 4th positional parameter or *args), "keyword" (keyword-only
    ``sched_seconds`` / **kwargs), or "legacy" (3-argument policies)."""
    try:
        sig = inspect.signature(policy.record)
    except (TypeError, ValueError):  # pragma: no cover - builtins etc.
        return "legacy"
    params = list(sig.parameters.values())
    kinds = inspect.Parameter
    if any(p.kind is kinds.VAR_POSITIONAL for p in params):
        return "positional"
    positional = [p for p in params
                  if p.kind in (kinds.POSITIONAL_ONLY,
                                kinds.POSITIONAL_OR_KEYWORD)]
    if len(positional) >= 4:
        return "positional"
    if any((p.kind is kinds.KEYWORD_ONLY and p.name == "sched_seconds")
           or p.kind is kinds.VAR_KEYWORD for p in params):
        return "keyword"
    return "legacy"


class DLSession:
    """A self-scheduling session over ``[0, N)`` (see module docstring)."""

    def __init__(
        self,
        spec: LoopSpec,
        runtime: Runtime,
        *,
        weights: Optional[WeightPolicy] = None,
        record_metrics: bool = True,
    ):
        self.spec = spec
        self.runtime = runtime
        self.policy: WeightPolicy = weights if weights is not None else UniformWeights()
        self.record_metrics = record_metrics
        if isinstance(runtime, HierarchicalRuntime):
            self.runtime_kind = "hierarchical"
        elif isinstance(runtime, OneSidedRuntime):
            self.runtime_kind = "one_sided"
        else:
            self.runtime_kind = "two_sided"
        self._claim_log: List[List[Claim]] = [[] for _ in range(spec.P)]
        self._busy: List[float] = [0.0] * spec.P
        # Per-chunk timing records (repro.replay capture plane): appended in
        # completion order by ``record`` when executors pass timestamps.
        self._chunk_times: List[dict] = []
        # technique="auto" selection record, set by ``loop`` (DESIGN.md
        # Sec. 9); threaded into every report.
        self.auto_decision: Optional[dict] = None
        self._grow_lock = threading.Lock()  # only for pe >= P growth
        # Adaptive wiring (DESIGN.md Sec. 8): AF feeds measured AFStats to
        # the claim-level technique (the inner one for hierarchical
        # runtimes); weighted outer techniques pull telemetry aggregated to
        # node level.  Legacy 3-argument ``record`` policies keep working.
        claim_tech = (runtime.inner_technique
                      if isinstance(runtime, HierarchicalRuntime)
                      else spec.technique)
        self._wants_af = (claim_tech == "af"
                          and hasattr(self.policy, "af_stats"))
        self._record_style = _record_call_style(self.policy)
        self._wire_outer_weights()
        # RMW counts are reported as deltas against this baseline, so a
        # session on a shared (or reused) window reports only its own loop.
        self._rmw_base = self._rmw_snapshot()
        # Hot-path shortcut: with no weight policy and no metrics the session
        # claim is *exactly* the runtime claim (benchmarks/overhead.py relies
        # on per-claim overhead parity with the raw runtimes).
        if not record_metrics and isinstance(self.policy, UniformWeights):
            self.claim = self.runtime.claim  # type: ignore[method-assign]

    def _wire_outer_weights(self) -> None:
        """Point a hierarchical runtime's super-chunk claims at the policy's
        node-aggregated telemetry (no-op for static/uniform policies)."""
        if (isinstance(self.runtime, HierarchicalRuntime)
                and self.spec.technique in WEIGHTED
                and hasattr(self.policy, "node_weight")):
            policy, bounds = self.policy, self.runtime._bounds
            self.runtime.outer_weight_fn = (
                lambda node: policy.node_weight(node, bounds))

    # ------------------------------------------------------------------
    # claiming
    # ------------------------------------------------------------------
    def claim(self, pe: int = 0, weight: Optional[float] = None) -> Optional[Claim]:
        """One scheduling step for PE ``pe``; None once the loop is drained.

        ``weight`` overrides the policy's weight for this single claim.
        AF sessions additionally hand the policy's measured ``AFStats`` to
        the runtime (None until telemetry exists -- the FAC2 bootstrap).
        """
        if weight is None:
            weight = self.policy.weight(pe)
        if self._wants_af:
            c = self.runtime.claim(pe, weight=weight,
                                   af=self.policy.af_stats(pe))
        else:
            c = self.runtime.claim(pe, weight=weight)
        if c is not None and self.record_metrics:
            self._ensure_pe(pe)
            self._claim_log[pe].append(c)
        return c

    def claims(self, pe: int = 0) -> Iterator[Claim]:
        """Iterate this PE's claims until the loop drains (pipeline form)."""
        while True:
            c = self.claim(pe)
            if c is None:
                return
            yield c

    def log_claim(self, pe: int, c: Claim) -> None:
        """Log a claim obtained outside ``claim()`` (two-sided queue path)."""
        if self.record_metrics:
            self._ensure_pe(pe)
            self._claim_log[pe].append(c)

    def record(self, pe: int, iters: int, seconds: float,
               sched_seconds: float = 0.0, *,
               claim: Optional[Claim] = None,
               t_start: Optional[float] = None,
               t_end: Optional[float] = None) -> None:
        """Feed back observed execution: adaptive weights + busy metrics.

        ``sched_seconds`` is the scheduling overhead paid to obtain the
        chunk (claim latency) -- consumed by the overhead-timing AWF
        variants (D/E); executors measure and pass it automatically.

        ``claim``/``t_start``/``t_end`` (executor-supplied, seconds since
        the executor began) additionally log a per-chunk timing record --
        the ``repro.replay`` capture plane (``SessionReport.chunk_times``).
        """
        self._feed_policy(pe, iters, seconds, sched_seconds)
        self._log_metrics(pe, iters, seconds, sched_seconds, claim,
                          t_start, t_end)

    def record_remote(self, pe: int, iters: int, seconds: float,
                      sched_seconds: float = 0.0, *,
                      claim: Optional[Claim] = None,
                      t_start: Optional[float] = None,
                      t_end: Optional[float] = None,
                      feed_policy: bool = False) -> None:
        """Metrics-only feedback for a chunk executed in *another process*.

        The ``processes`` executor's workers feed their own (shared-slab)
        adaptive policies as they execute; feeding this session's policy
        again for the same chunk would double-count every observation --
        so policy feedback is opt-in here (two-sided masters opt in: their
        workers carry no policy at all).
        """
        if feed_policy:
            self._feed_policy(pe, iters, seconds, sched_seconds)
        self._log_metrics(pe, iters, seconds, sched_seconds, claim,
                          t_start, t_end)

    def _feed_policy(self, pe: int, iters: int, seconds: float,
                     sched_seconds: float) -> None:
        if self._record_style == "positional":
            self.policy.record(pe, iters, seconds, sched_seconds)
        elif self._record_style == "keyword":
            self.policy.record(pe, iters, seconds, sched_seconds=sched_seconds)
        else:  # legacy 3-argument policies
            self.policy.record(pe, iters, seconds)

    def _log_metrics(self, pe, iters, seconds, sched_seconds, claim,
                     t_start, t_end) -> None:
        if self.record_metrics:
            self._ensure_pe(pe)
            self._busy[pe] += seconds
            if t_start is not None and t_end is not None:
                self._chunk_times.append({
                    "pe": pe,
                    "step": claim.step if claim is not None else -1,
                    "start": claim.start if claim is not None else -1,
                    "size": iters,
                    "t0": float(t_start),
                    "t1": float(t_end),
                    "lat": float(sched_seconds),
                })

    def advance_timestep(self) -> None:
        """Signal a timestep boundary to timestep-granular adaptive policies
        (no-op when the policy has no ``advance``)."""
        fn = getattr(self.policy, "advance", None)
        if fn is not None:
            fn()

    # ------------------------------------------------------------------
    # drain contract
    # ------------------------------------------------------------------
    def remaining(self) -> int:
        """Lower bound on unclaimed iterations (0 once drained)."""
        return self.runtime.remaining_lower_bound()

    def drained(self) -> bool:
        return self.runtime.drained()

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def execute(
        self,
        work_fn: Optional[Callable[[int, int], None]],
        executor: str = "threads",
        **kw,
    ) -> SessionReport:
        """Drain the loop through an executor; returns a ``SessionReport``.

        executor: "serial" (round-robin claims on the calling thread),
        "threads" (real concurrency; two-sided runs the non-dedicated
        master-worker protocol), "processes" (one real OS process per PE
        over a shared-memory window -- open the session with
        ``window="shm"``; ``work_fn`` must be picklable under
        spawn/forkserver), or "sim" (discrete-event simulation -- pass
        ``costs=`` and ``speeds=`` instead of executing ``work_fn``).
        """
        from . import executors

        return executors.execute(self, work_fn, executor=executor, **kw)

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    def report(self, executor: Optional[str] = None,
               wall_time: float = 0.0) -> SessionReport:
        """Snapshot the per-claim metrics collected so far."""
        rmw_g, rmw_l = self._rmw_counts()
        return SessionReport(
            technique=self.spec.technique,
            N=self.spec.N,
            P=self.spec.P,
            runtime=self.runtime_kind,
            executor=executor,
            min_chunk=self.spec.min_chunk,
            max_chunk=self.spec.max_chunk,
            per_pe_claims=[list(per) for per in self._claim_log],
            per_pe_iters=np.array(
                [sum(c.size for c in per) for per in self._claim_log],
                dtype=np.int64),
            busy_time=np.asarray(self._busy, dtype=np.float64),
            wall_time=wall_time,
            n_rmw_global=rmw_g,
            n_rmw_local=rmw_l,
            adaptation=self._adaptation_trace(),
            chunk_times=list(self._chunk_times) or None,
            auto_decision=self.auto_decision,
        )

    def _adaptation_trace(self) -> Optional[List[dict]]:
        """The policy's weight-update history (adaptive policies only)."""
        trace = getattr(self.policy, "trace", None)
        return list(trace) if trace is not None else None

    def _rmw_snapshot(self):
        """Window RMW totals (global, local), or None if it doesn't count.

        Hierarchical windows account both levels for any backend; a flat
        one-sided session over a counting window (``SimWindow``, or a
        device window -- both carry ``n_rmw``) reports its RMWs as global
        (every flat claim pays the global serialization point).
        """
        win = getattr(self.runtime, "window", None)
        if isinstance(win, HierarchicalWindow):
            return win.n_rmw_global, win.n_rmw_local
        if hasattr(win, "n_rmw"):
            return win.n_rmw, 0
        return None

    def _rmw_counts(self):
        """This session's per-level RMW counts (delta over the baseline)."""
        snap = self._rmw_snapshot()
        if snap is None:
            return None, None
        base = self._rmw_base or (0, 0)
        return snap[0] - base[0], snap[1] - base[1]

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def reset(self, loop_id: Optional[int] = None) -> "DLSession":
        """Rewind to a full loop and clear metrics.

        One-sided sessions open a *fresh counter namespace* on the same
        window (monotonic KV backends never decrement); two-sided sessions
        rewind the master recurrence in place.
        """
        if isinstance(self.runtime, HierarchicalRuntime):
            self.runtime = HierarchicalRuntime(
                self.spec, self.runtime.nodes, self.runtime.window,
                inner_technique=self.runtime.inner_technique, loop_id=loop_id)
        elif isinstance(self.runtime, OneSidedRuntime):
            self.runtime = OneSidedRuntime(
                self.spec, self.runtime.window, loop_id=loop_id)
        else:
            self.runtime.restore({"i": 0, "lp": 0})
        self._claim_log = [[] for _ in range(len(self._claim_log))]
        self._busy = [0.0] * len(self._busy)
        self._chunk_times = []
        self._wire_outer_weights()  # fresh runtime objects need re-pointing
        self._rmw_base = self._rmw_snapshot()  # metrics restart at zero
        if not self.record_metrics and isinstance(self.policy, UniformWeights):
            self.claim = self.runtime.claim  # type: ignore[method-assign]
        return self

    def state(self) -> dict:
        """Checkpointable scheduling state (window counters i, lp)."""
        return self.runtime.state()

    def restore(self, st: dict) -> None:
        self.runtime.restore(st)

    def close(self) -> None:
        """Release window resources that own OS state (shared-memory slabs).

        No-op for in-process windows.  Un-closed shm windows are reclaimed
        on garbage collection; call this for deterministic teardown."""
        win = getattr(self.runtime, "window", None)
        wins = ([win.global_window, *win.local_windows]
                if isinstance(win, HierarchicalWindow) else [win])
        for w in wins:
            fn = getattr(w, "close", None)
            if fn is not None:
                fn()

    def __enter__(self) -> "DLSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    # ------------------------------------------------------------------
    def _ensure_pe(self, pe: int) -> None:
        if pe < len(self._claim_log):
            return
        with self._grow_lock:
            while len(self._claim_log) <= pe:
                self._claim_log.append([])
                self._busy.append(0.0)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"DLSession({self.spec.technique!r}, N={self.spec.N}, "
                f"P={self.spec.P}, runtime={self.runtime_kind!r})")


def loop(
    N: int,
    technique: str = "fac2",
    *,
    P: int = 1,
    runtime: str = "one_sided",
    window=None,
    weights=None,
    min_chunk: int = 1,
    max_chunk: Optional[int] = None,
    loop_id: Optional[int] = None,
    record_metrics: bool = True,
    nodes: Optional[int] = None,
    inner_technique: Optional[str] = None,
    costs=None,
    speeds=None,
    trace=None,
    auto_seed: int = 0,
    auto_budget_s: Optional[float] = 2.0,
    auto_workers=None,
    auto_engine: str = "auto",
) -> DLSession:
    """Open a DLS session over ``[0, N)`` -- the facade's front door.

    N, technique, P, min_chunk, max_chunk: the ``LoopSpec`` fields.
        ``technique="auto"`` runs the calibrated DES sweep of
        ``repro.replay`` (seeded, bounded-time) over every technique and
        adopts the predicted-best one; the decision (chosen technique +
        full predicted ranking) lands in ``SessionReport.auto_decision``.
    runtime: "one_sided" (paper protocol) | "two_sided" (master-worker) |
        "hierarchical" (two-level node/global scheduling; needs ``nodes=``) |
        "device" (the one-sided protocol with counters in accelerator
        memory -- ``repro.device``; pair with ``executor="device"`` to run
        the claim loop inside a persistent Pallas kernel).
    window: "thread" | "shm" | "kvstore" | "sim" | "device" | "auto" | a shared
        ``Window`` object | None (thread).  "shm" is the real
        cross-process backend (``repro.pt``) the ``processes`` executor
        requires.  Ignored by two-sided runtimes; for hierarchical
        runtimes this is the *global* level (or a ready
        ``HierarchicalWindow``) and node-local levels stay in-process --
        except "shm", which builds shared-memory slabs at *both* levels.
    weights: None/"uniform" | an adaptive policy name ("awf", "af",
        "awf_b".."awf_e") | a float sequence (static WF; also stored on
        the spec) | a ``WeightBoard`` | a ``WeightPolicy``.  Adaptive
        *techniques* left at ``weights=None`` auto-adopt their matching
        telemetry policy (fresh in-process ``PerfModel``).
    loop_id: explicit counter namespace (defaults to a fresh id) -- pass a
        stable value to share one logical loop across host processes.
    record_metrics: disable to make ``claim`` a zero-overhead passthrough.
    nodes / inner_technique: hierarchical only -- number of node-local
        scheduling domains, and the technique used *within* a node
        (defaults to SS; ``technique`` becomes the outer, super-chunk-level
        technique).  Rejected for flat runtimes.
    costs / speeds / trace / auto_seed / auto_budget_s / auto_workers:
        selection inputs, consumed only by ``technique="auto"`` -- a
        per-iteration cost hint (any length; resampled), a per-PE speed
        hint, a recorded ``repro.replay`` Trace (or path) to calibrate
        the sweep from, the sweep's DES seed, its wall-clock budget in
        seconds (None = unbounded), and the ``simulate_many`` worker
        knob for the candidate sweep (None = adaptive process fan-out).
        See DESIGN.md Sec. 9-10.
    auto_engine: DES execution strategy for the selection sweep
        ("auto" routes non-adaptive candidates through the vectorized
        fast path, DESIGN.md Sec. 12; "kernel" forces the event
        kernel).  Either way the ranking is identical -- the routes are
        equivalence-pinned.
    """
    auto_decision = None
    if technique == "auto":
        from repro.replay.select import choose_technique

        auto_decision = choose_technique(
            N=N, P=P, runtime=runtime, nodes=nodes,
            inner_technique=inner_technique, costs=costs, speeds=speeds,
            trace=trace, min_chunk=min_chunk, max_chunk=max_chunk,
            seed=auto_seed, budget_s=auto_budget_s, workers=auto_workers,
            engine=auto_engine)
        technique = auto_decision["chosen"]
    elif costs is not None or speeds is not None or trace is not None:
        warnings.warn(
            "costs=/speeds=/trace= are technique=\"auto\" selection hints "
            "and have no effect on an explicitly chosen technique "
            "(pass executor costs to execute(..., executor=\"sim\") instead)",
            stacklevel=2)
    spec_weights = None
    if (weights is not None and not isinstance(weights, str)
            and hasattr(weights, "__len__") and len(weights) == P):
        spec_weights = tuple(float(w) for w in weights)
    spec = LoopSpec(technique, N=N, P=P, weights=spec_weights,
                    min_chunk=min_chunk, max_chunk=max_chunk)
    rt = make_runtime(spec, runtime=runtime, window=window, loop_id=loop_id,
                      nodes=nodes, inner_technique=inner_technique)
    # Adaptive techniques measure PE performance online: with no explicit
    # policy they auto-adopt their own (technique-named) telemetry policy.
    # The claim-level technique decides (inner for hierarchical runtimes,
    # the outer falls back to node-aggregated telemetry either way).
    claim_tech = (inner_technique or "ss") if runtime == "hierarchical" \
        else technique
    if weights is None:
        for t in (claim_tech, technique):
            if t in ADAPTIVE:
                weights = t
                break
    policy = make_weight_policy(weights, P)
    # ``POLICY_DRIVEN`` (chunk_calculus) is the single source of truth for
    # which techniques consume a weight policy -- this warning, the policy
    # name registry, and the docs tables all derive from it.
    weighted = technique in POLICY_DRIVEN or (
        runtime == "hierarchical" and (inner_technique or "ss") in POLICY_DRIVEN)
    if weights is not None and not weighted \
            and not isinstance(policy, UniformWeights):
        warnings.warn(
            f"technique {technique!r} ignores weights (only techniques in "
            f"{POLICY_DRIVEN} consume a weight policy); the supplied policy "
            f"will have no effect",
            stacklevel=2)
    session = DLSession(spec, rt, weights=policy,
                        record_metrics=record_metrics)
    session.auto_decision = auto_decision
    return session
