"""repro: distributed chunk-calculation DLS (Eleliemy & Ciorba 2018) as the
work-distribution layer of a multi-pod JAX training/serving framework."""
__version__ = "1.0.0"
