"""AdamW with ZeRO-style sharded states + optional gradient compression.

Pure JAX (no optax in this environment).  Optimizer state mirrors the param
pytree, so the launcher shards m/v exactly like the params (FSDP axis) --
that is the ZeRO-3 arrangement.  ``compress`` optionally casts gradients to
bf16 *before* the (pseudo-)all-reduce boundary -- under pjit the cast happens
pre-reduction, halving cross-pod gradient bytes (the distributed-optimization
trick recorded in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    compress: Optional[str] = None  # None | "bf16"
    # optimizer-state dtype: float32 (default) or bfloat16 ("8-bit Adam"
    # style memory saving -- halves m/v; fine with the f32 update math below)
    state_dtype: str = "float32"
    warmup_steps: int = 100
    schedule: str = "cosine"  # "cosine" | "constant"
    total_steps: int = 10_000


def lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def init(params, state_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def init_for(cfg: "AdamWConfig", params):
    import numpy as _np  # noqa: F401

    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.state_dtype]
    return init(params, dt)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    if cfg.compress == "bf16":
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    sdt = state["m"] and jax.tree.leaves(state["m"])[0].dtype
    new_m = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                      + (1 - cfg.b1) * g).astype(sdt), state["m"], grads)
    new_v = jax.tree.map(
        lambda v, g: (cfg.b2 * v.astype(jnp.float32)
                      + (1 - cfg.b2) * g * g).astype(sdt), state["v"], grads)

    def upd(p, m, v):
        mh = m.astype(jnp.float32) / b1c
        vh = v.astype(jnp.float32) / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
