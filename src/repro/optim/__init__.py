"""AdamW optimizer + schedules + gradient compression."""
from .adamw import AdamWConfig, global_norm, init, init_for, lr_at, update  # noqa: F401
