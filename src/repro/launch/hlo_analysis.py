"""Trip-count-aware analysis of post-SPMD compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, which makes
it useless for scan-over-layers programs (it undercounts FLOPs, bytes, and
collective traffic by layers x microbatches).  This module re-derives the
three roofline inputs directly from ``compiled.as_text()``:

  * ``flops``          -- sum over ``dot`` ops of 2*prod(result)*K, each
                          weighted by its computation's execution count
                          (while trip counts are explicit in
                          ``backend_config={"known_trip_count":...}``).
                          Non-dot FLOPs (elementwise, softmax, reductions)
                          are excluded -- <2% for LM workloads.
  * ``traffic_bytes``  -- HBM traffic model: for every *top-level* op in
                          every computation, operand bytes (reads) + result
                          bytes (writes), weighted by execution count.
                          Post-optimization HLO exposes only fusion
                          *boundaries*, so this is exactly the
                          write-once/read-once roofline model; tuple
                          plumbing (parameter/tuple/gte/bitcast/constant)
                          costs zero.
  * ``collectives``    -- per-op inventory (type, result bytes, group size,
                          ring-model bytes moved per device), weighted by
                          execution count.

Everything is *per device* (the module is the SPMD-partitioned program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
                "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],\{\}:\sTS\(\)]*?))\s*"
    r"([a-z][\w\-]*)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)|"
    r"branch_computations=\{([^}]*)\}"
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

#: ops that are pure plumbing (no HBM traffic of their own)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "domain", "while",
             "call", "conditional", "custom-call", "opt-barrier"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_dims(type_str: str):
    """Total bytes of a (possibly tuple) type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims_list(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",") if d]


def split_computations(txt: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in txt.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _parse_ops(lines: List[str]):
    """(name -> type_str) symbol table + op records."""
    sym: Dict[str, str] = {}
    ops = []
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        sym[name] = type_str
        ops.append({"name": name, "type": type_str, "opcode": opcode,
                    "rest": rest, "line": line})
    return sym, ops


def analyze_hlo(txt: str) -> dict:
    comps = split_computations(txt)
    parsed = {c: _parse_ops(lines) for c, lines in comps.items()}

    # ---- call graph with execution multipliers ----
    mult: Dict[str, float] = defaultdict(float)
    entry = None
    for line in txt.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1]
    mult[entry] = 1.0

    # BFS over call edges.  Computations reached through ``calls=`` /
    # ``to_apply=`` are *fusion/reducer-internal*: their ops execute but do
    # not individually touch HBM (the fusion boundary at the call site
    # carries the traffic).  ``body=``/``condition=``/branches stay
    # top-level.
    internal = set()
    seen_order = [entry]
    idx = 0
    while idx < len(seen_order):
        comp = seen_order[idx]
        idx += 1
        _, ops = parsed.get(comp, ({}, []))
        for op in ops:
            k = mult[comp]
            if k == 0:
                continue
            trip = 1.0
            if op["opcode"] == "while":
                tm = _TRIP_RE.search(op["line"])
                trip = float(tm.group(1)) if tm else 1.0
            for cm in _CALLED_RE.finditer(op["line"]):
                via_internal = cm.group(1) is not None and (
                    f"calls={'%' + cm.group(1)}" in op["line"]
                    or f"calls={cm.group(1)}" in op["line"]
                    or f"to_apply={'%' + cm.group(1)}" in op["line"]
                    or f"to_apply={cm.group(1)}" in op["line"]
                )
                names = [cm.group(1)] if cm.group(1) else [
                    s.strip().lstrip("%") for s in cm.group(2).split(",")]
                for cn in names:
                    if cn not in parsed:
                        continue
                    factor = trip if op["opcode"] == "while" else 1.0
                    if mult[cn] == 0:
                        seen_order.append(cn)
                    mult[cn] += k * factor
                    if via_internal or comp in internal:
                        internal.add(cn)

    flops = 0.0
    traffic = 0.0
    colls: List[dict] = []
    flops_by_name: Dict[str, float] = defaultdict(float)
    traffic_by_name: Dict[str, float] = defaultdict(float)

    def _opname(line: str) -> str:
        m = re.search(r'op_name="([^"]*)"', line)
        if not m:
            return "(none)"
        # keep the tail of the jaxpr path -- the model-level op identity
        parts = m.group(1).split("/")
        return "/".join(parts[-2:]) if len(parts) >= 2 else m.group(1)
    for comp, (sym, ops) in parsed.items():
        k = mult.get(comp, 0.0)
        if k == 0:
            continue
        is_internal = comp in internal
        for op in ops:
            oc = op["opcode"]
            if oc in _FREE_OPS:
                continue
            res_bytes = _shape_dims(op["type"])
            opnd_bytes = 0
            # operands: %refs before the first ")," attr boundary
            arglist = op["rest"].split("), ")[0]
            for ref in _OPERAND_RE.findall(arglist):
                if ref in sym:
                    opnd_bytes += _shape_dims(sym[ref])
            # traffic: fusion boundaries only (internal ops are in-register).
            # In-place/indexed ops are modeled as the TPU executes them:
            #  * gather/dynamic-slice read+write only the slice (not the
            #    whole table -- embedding lookups!),
            #  * dynamic-update-slice updates in place (slice-sized traffic),
            #  * copy of loop carries is a CPU-backend artifact that buffer
            #    donation elides on TPU.
            if not is_internal:
                if oc in ("gather", "dynamic-slice"):
                    t_op = k * 2 * res_bytes
                elif oc in ("dynamic-update-slice", "scatter"):
                    upd = 0
                    refs = _OPERAND_RE.findall(arglist)
                    if len(refs) >= 2 and refs[1] in sym:
                        upd = _shape_dims(sym[refs[1]])
                    t_op = k * 2 * upd
                elif oc == "copy":
                    t_op = 0.0
                else:
                    t_op = k * (res_bytes + opnd_bytes)
                traffic += t_op
                if t_op:
                    traffic_by_name[f"{oc}:{_opname(op['line'])}"] += t_op

            if oc == "dot":
                # flops = 2 * prod(result dims) * K(contracting)
                _, rdims = _dims_list(op["type"])
                mlhs = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op["line"])
                refs = _OPERAND_RE.findall(arglist)
                kdim = 1
                if mlhs and refs and refs[0] in sym:
                    _, ldims = _dims_list(sym[refs[0]])
                    for ci in mlhs.group(1).split(","):
                        if ci != "" and int(ci) < len(ldims):
                            kdim *= ldims[int(ci)]
                rprod = 1
                for d in rdims:
                    rprod *= d
                flops += k * 2.0 * rprod * kdim
                flops_by_name[f"dot:{_opname(op['line'])}"] += k * 2.0 * rprod * kdim

            if (not is_internal and any(oc.startswith(c) for c in _COLLECTIVES)
                    and not oc.endswith("-done")):
                base = oc.replace("-start", "")
                gm = _GROUP_RE.search(op["line"])
                gsize = int(gm.group(2)) if gm else 1
                gf = (gsize - 1) / gsize if gsize > 1 else 1.0
                if base == "all-gather":
                    moved = res_bytes * gf
                elif base == "all-reduce":
                    moved = 2.0 * res_bytes * gf
                elif base == "reduce-scatter":
                    moved = res_bytes * max(gsize - 1, 1)
                elif base == "all-to-all":
                    moved = res_bytes * gf
                else:
                    moved = float(res_bytes)
                colls.append({"op": base, "result_bytes": res_bytes,
                              "group_size": gsize, "count": k,
                              "moved_bytes": k * moved})

    by_op: Dict[str, dict] = {}
    for c in colls:
        d = by_op.setdefault(c["op"], {"count": 0.0, "moved_bytes": 0.0})
        d["count"] += c["count"]
        d["moved_bytes"] += c["moved_bytes"]

    top = lambda d, n=12: sorted(d.items(), key=lambda kv: -kv[1])[:n]
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collectives": by_op,
        "collective_moved_bytes": sum(c["moved_bytes"] for c in colls),
        "n_computations": len(comps),
        "top_flops": top(flops_by_name),
        "top_traffic": top(traffic_by_name),
    }
