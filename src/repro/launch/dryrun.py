import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
# (--devices N below rewrites the flag, still before any jax import.)
import sys  # noqa: E402

if "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (and stores under experiments/dryrun/):
  * compiled.memory_analysis()  -- proves the program fits per-device HBM
  * compiled.cost_analysis()    -- HLO FLOPs / bytes for the roofline
  * the collective inventory parsed from the post-SPMD HLO text
    (op type, result shape, group size, modeled bytes moved per device)

Roofline terms themselves are derived in benchmarks/roofline.py from these
JSONs (hardware constants live there).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --arch mamba2-370m --shape long_500k \
      --devices 8   # scaled-down mesh for CI
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, applicable_shapes, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh, make_test_mesh  # noqa: E402
from repro.models import api  # noqa: E402
from repro.models.layers import dtype_of  # noqa: E402
from repro.optim import AdamWConfig, adamw  # noqa: E402
from repro.shard import (  # noqa: E402
    batch_pspecs_for_mesh,
    cache_pspecs,
    make_ctx,
    params_pspecs,
    shardings_for,
)
from repro.train.step import make_train_step  # noqa: E402

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg, shape_name: str, *, batch_override=None):
    """Batch pytree of SDS for one cell.  See DESIGN.md for enc-dec/vlm
    conventions (src len == seq for train/prefill; 4096 for decode)."""
    sh = SHAPES[shape_name]
    B = batch_override or sh["global_batch"]
    T = sh["seq_len"]
    dt = dtype_of(cfg.dtype)
    if sh["kind"] == "train":
        if cfg.is_encdec:
            return {"tokens": SDS((B, T), jnp.int32),
                    "src_embeds": SDS((B, T, cfg.d_model), dt)}
        if cfg.frontend == "vision":
            return {"tokens": SDS((B, T - cfg.n_prefix_tokens), jnp.int32),
                    "prefix_embeds": SDS((B, cfg.n_prefix_tokens, cfg.d_model), dt)}
        return {"tokens": SDS((B, T), jnp.int32)}
    if sh["kind"] == "prefill":
        if cfg.is_encdec:
            return {"tokens": SDS((B, T), jnp.int32),
                    "src_embeds": SDS((B, T, cfg.d_model), dt)}
        if cfg.frontend == "vision":
            return {"tokens": SDS((B, T - cfg.n_prefix_tokens), jnp.int32),
                    "prefix_embeds": SDS((B, cfg.n_prefix_tokens, cfg.d_model), dt)}
        return {"tokens": SDS((B, T), jnp.int32)}
    if sh["kind"] == "decode":
        return {"token": SDS((B,), jnp.int32)}
    raise ValueError(shape_name)


def cache_specs(cfg, shape_name: str, *, batch_override=None):
    sh = SHAPES[shape_name]
    B = batch_override or sh["global_batch"]
    T = sh["seq_len"]
    src = 4096 if cfg.is_encdec and sh["kind"] == "decode" else T
    return jax.eval_shape(
        lambda: api.init_cache(cfg, B, T, src_len=src if cfg.is_encdec else None))


def default_microbatches(cfg, shape_name, mesh, batch_override=None) -> int:
    """Enough grad-accumulation that one microbatch is ~1 seq per data shard."""
    if SHAPES[shape_name]["kind"] != "train":
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    B = batch_override or SHAPES[shape_name]["global_batch"]
    per_shard = max(B // dp, 1)
    if cfg.d_model >= 4096 or cfg.n_layers >= 48:
        return per_shard  # 1 seq per shard per microbatch
    return max(per_shard // 4, 1)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def _divisible_batch_axes(mesh, B):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    use, rem = [], B
    for a in ("pod", "data"):
        if a in sizes and rem % sizes[a] == 0:
            use.append(a)
            rem //= sizes[a]
    return tuple(use) if len(use) > 1 else (use[0] if use else None)


def _logits_sharding(mesh, B, vocab):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = "model" if vocab % sizes.get("model", 1) == 0 else None
    return NamedSharding(mesh, P(_divisible_batch_axes(mesh, B), model))


def default_remat(cfg):
    """Per-family activation policy (tuned in EXPERIMENTS.md §Perf):
    grouped recursive checkpointing for DEEP dense stacks (8x fewer
    layer-input saves, ~+6% flops); plain full remat for shallow dense
    models (the group's live recompute set exceeds what it saves --
    measured +9 GiB on the 24-layer danube), for MoE (group recompute
    re-runs the dispatch all-to-alls -- measured 2x collectives) and for
    SSM/hybrid (their saves are small)."""
    if cfg.family in ("dense", "vlm") and cfg.n_layers >= 40:
        return "group:8"
    return "full"


def build_cell(cfg, shape_name, mesh, *, microbatches=None, remat=None,
               logits_f32=True, batch_override=None, lean=True):
    remat = remat or default_remat(cfg)
    """Returns (fn, example_args_SDS, in_shardings, out_shardings, donate)."""
    ctx = make_ctx(mesh)
    sh = SHAPES[shape_name]
    params_sds = api.abstract_params(cfg)
    p_shard = shardings_for(params_sds, params_pspecs(params_sds), mesh)

    if sh["kind"] == "train":
        mb = microbatches or default_microbatches(cfg, shape_name, mesh,
                                                  batch_override)
        ocfg = AdamWConfig(state_dtype="bfloat16" if lean else "float32")
        opt_sds = jax.eval_shape(lambda p: adamw.init_for(ocfg, p), params_sds)
        opt_shard = shardings_for(opt_sds, params_pspecs(opt_sds), mesh)
        batch_sds = input_specs(cfg, shape_name, batch_override=batch_override)
        b_shard = shardings_for(batch_sds, batch_pspecs_for_mesh(batch_sds, mesh), mesh)
        step = make_train_step(
            cfg, ocfg, ctx=ctx, microbatches=mb, remat=remat,
            acc_dtype=jnp.bfloat16 if lean else jnp.float32)
        in_sh = (p_shard, opt_shard, b_shard)
        out_sh = (p_shard, opt_shard,
                  jax.tree.map(lambda _: NamedSharding(mesh, P()),
                               {"grad_norm": 0, "lr": 0, "loss": 0}))
        args = (params_sds, opt_sds, batch_sds)
        return step, args, in_sh, out_sh, (0, 1), {"microbatches": mb}

    if sh["kind"] == "prefill":
        batch_sds = input_specs(cfg, shape_name, batch_override=batch_override)
        cache_sds = cache_specs(cfg, shape_name, batch_override=batch_override)
        b_shard = shardings_for(batch_sds, batch_pspecs_for_mesh(batch_sds, mesh), mesh)
        c_pspec = cache_pspecs(cache_sds, mesh, kv_heads=cfg.n_kv_heads or None)
        c_shard = shardings_for(cache_sds, c_pspec, mesh)
        logits_shard = _logits_sharding(mesh, batch_sds["tokens"].shape[0], cfg.vocab)

        def fn(params, batch, cache):
            return api.prefill(params, cfg, batch, cache, ctx=ctx)

        return (fn, (params_sds, batch_sds, cache_sds),
                (p_shard, b_shard, c_shard), (logits_shard, c_shard), (2,), {})

    # decode
    batch_sds = input_specs(cfg, shape_name, batch_override=batch_override)
    cache_sds = cache_specs(cfg, shape_name, batch_override=batch_override)
    tok_shard = shardings_for(
        batch_sds, batch_pspecs_for_mesh(batch_sds, mesh), mesh)["token"]
    c_pspec = cache_pspecs(cache_sds, mesh, kv_heads=cfg.n_kv_heads or None)
    c_shard = shardings_for(cache_sds, c_pspec, mesh)
    logits_shard = _logits_sharding(mesh, batch_sds["token"].shape[0], cfg.vocab)

    def fn(params, token, cache):
        return api.decode_step(params, cfg, token, cache, ctx=ctx)

    return (fn, (params_sds, batch_sds["token"], cache_sds),
            (p_shard, tok_shard, c_shard), (logits_shard, c_shard), (2,), {})


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, devices=None,
             microbatches=None, remat=None, out_dir="experiments/dryrun",
             batch_override=None, tag="", baseline=False, lean=True):
    if baseline:
        # paper-faithful first implementation: dense attention, sequential
        # SSD scan, f32 optimizer states, plain full remat
        import repro.models.layers as _L
        import repro.models.ssm as _S

        _L.CHUNKED_ATTN_THRESHOLD = 1 << 60
        _S.SSD_MODE = "sequential"
        lean = False
        remat = remat or "full"

    cfg = get_config(arch)
    if shape_name not in applicable_shapes(cfg):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped",
               "reason": "quadratic attention at 500k (see DESIGN.md)",
               "tag": tag}
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        with open(os.path.join(
                out_dir, f"{arch}_{shape_name}_{mesh_kind}{suffix}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    multi = mesh_kind == "multipod"
    if devices:
        mesh = make_test_mesh(int(devices), multi_pod=multi)
    else:
        mesh = make_production_mesh(multi_pod=multi)

    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
           "remat": remat or default_remat(get_config(arch)),
           "lean": lean, "tag": tag, "baseline": baseline}
    try:
        fn, args, in_sh, out_sh, donate, extra = build_cell(
            cfg, shape_name, mesh, microbatches=microbatches, remat=remat,
            batch_override=batch_override, lean=lean)
        rec.update(extra)
        with mesh:
            lowered = jax.jit(
                fn, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        # trip-count-aware analysis of the post-SPMD module (XLA's own
        # cost_analysis counts while bodies once -- useless under scan)
        from repro.launch.hlo_analysis import analyze_hlo

        hlo_text = compiled.as_text()
        hlo = analyze_hlo(hlo_text)
        # persist the (gzipped) HLO so analyzer iterations don't recompile
        import gzip

        hlo_dir = os.path.join(os.path.dirname(out_dir.rstrip("/")) or ".",
                               "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        _sfx = f"_{tag}" if tag else ""
        with gzip.open(os.path.join(
                hlo_dir, f"{arch}_{shape_name}_{mesh_kind}{_sfx}.txt.gz"),
                "wt") as zf:
            zf.write(hlo_text)
        n_chips = mesh.devices.size
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                alias_bytes=ma.alias_size_in_bytes,
                peak_bytes=ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
            ),
            cost=dict(
                flops=hlo["flops"],  # per-device, trip-adjusted, dot ops
                bytes_accessed=hlo["traffic_bytes"],  # fusion-boundary model
                xla_flops_raw=ca.get("flops", 0.0),  # XLA's (loop-body-once)
                xla_bytes_raw=ca.get("bytes accessed", 0.0),
            ),
            collectives=hlo["collectives"],
            collective_moved_bytes=hlo["collective_moved_bytes"],
            top_flops=hlo["top_flops"],
            top_traffic=hlo["top_traffic"],
            n_chips=n_chips,
            model_params=cfg.param_count(),
            active_params=cfg.active_param_count(),
        )
    except Exception as e:  # noqa: BLE001 -- a failed cell is a bug to record
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_kind}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--devices", default=None, help="override 512-dev mesh (CI)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--batch", type=int, default=None, help="override global batch")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="", help="suffix for perf-iteration runs")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful baseline paths (dense attn, seq SSD)")
    ap.add_argument("--lean", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="bf16 optimizer states + bf16 grad accumulator")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in sorted(ARCHS) for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, devices=args.devices,
                           microbatches=args.microbatches, remat=args.remat,
                           out_dir=args.out, batch_override=args.batch,
                           tag=args.tag, baseline=args.baseline,
                           lean=args.lean)
            status = rec["status"]
            line = f"[dryrun] {arch:25s} {shape:12s} {mk:8s} {status}"
            if status == "ok":
                mem = rec["memory"]["peak_bytes"] / 2**30
                line += (f" peak={mem:.2f}GiB/dev flops={rec['cost']['flops']:.3e} "
                         f"coll={rec['collective_moved_bytes']/2**30:.2f}GiB "
                         f"compile={rec['compile_s']}s")
            elif status == "error":
                line += f" {rec['error'][:120]}"
                failures += 1
            print(line, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
