"""Production mesh construction.

IMPORTANT: this module must never touch jax device state at import time --
``make_production_mesh`` is a function, and the 512-device host-platform
override happens in dryrun.py's first two lines, before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    Single pod: 256 chips as (data=16, model=16).
    Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) -- the
    "pod" axis carries pure data parallelism (gradient all-reduce over DCI),
    "data" is the in-pod FSDP/batch axis, "model" is TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int, *, multi_pod: bool = False):
    """Scaled-down mesh with the same axis structure (CI / unit tests)."""
    if multi_pod:
        assert devices % 2 == 0 and devices >= 4
        rest = devices // 2
        model = _largest_factor_leq(rest, int(rest ** 0.5))
        return jax.make_mesh((2, rest // model, model), ("pod", "data", "model"))
    model = _largest_factor_leq(devices, int(devices ** 0.5))
    return jax.make_mesh((devices // model, model), ("data", "model"))


def _largest_factor_leq(n: int, k: int) -> int:
    for f in range(min(k, n), 0, -1):
        if n % f == 0:
            return f
    return 1


def make_device_hierarchy(global_window=None, capacity: int = 256):
    """Two-level window over the process's accelerators (DESIGN.md Sec. 14).

    The hierarchy follows the mesh axis convention: the *global* level
    spans devices (super-chunk claims cross the interconnect, default a
    host ``ThreadWindow`` -- on a cluster pass the KV store), while each
    node-local level is a ``DeviceWindow`` whose counter slab lives in
    that device's own memory -- within a device, claims are the
    persistent kernel's atomic counter.  Feed the result to
    ``dls.loop(runtime="hierarchical", nodes=<n_devices>, window=...)``.
    """
    from repro.core.rma import HierarchicalWindow, ThreadWindow
    from repro.device.window import DeviceWindow

    devs = jax.devices()
    locals_ = [DeviceWindow(capacity=capacity, device=d) for d in devs]
    return HierarchicalWindow(
        len(devs),
        global_window=global_window or ThreadWindow(),
        local_windows=locals_,
    )
