"""Serving driver: batched generation + DLS continuous-batching stats.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 64 --batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import api
from repro.serve import ContinuousBatcher, Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--technique", default="gss")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced() if args.reduced else get_config(args.arch)
    params = api.init_params(jax.random.key(args.seed), cfg)
    eng = Engine(cfg, params, batch_size=args.batch)
    rng = np.random.default_rng(args.seed)

    # one real batched generation (throughput probe)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, 16)).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({args.batch*args.max_new/dt:.1f} tok/s)")

    # DLS continuous-batching admission vs static split (simulated clock,
    # heavy-tailed generation lengths -- the variable-cost loop of serving)
    lens = (rng.pareto(1.5, size=args.requests) * 20 + 4).astype(int)
    reqs = [Request(rid=i, prompt=np.zeros(8, np.int32), max_new=int(l))
            for i, l in enumerate(lens)]

    def cost(chunk, worker):
        return float(sum(0.01 * r.max_new + 0.02 for r in chunk))

    cb = ContinuousBatcher(n_workers=args.batch, technique=args.technique)
    t_dls = cb.schedule(reqs, cost)
    t_static = cb.schedule(reqs, cost, static=True)
    print(f"[serve] makespan: DLS({args.technique})={t_dls.max():.2f}s "
          f"static={t_static.max():.2f}s  "
          f"p99 latency: {np.percentile(t_dls,99):.2f}s vs "
          f"{np.percentile(t_static,99):.2f}s")


if __name__ == "__main__":
    main()
