"""Training driver.

Single-host (CPU or one accelerator process):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt /tmp/ck

Multi-host deployment notes (real cluster):
  * run one process per host with jax.distributed.initialize(); the DLS
    sampler then uses the KVStoreWindow automatically (window="auto"),
  * add --mesh to shard params/steps over the local device mesh.
The dry-run (dryrun.py) is the scale-validation path for the 512-chip mesh.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, get_config
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--samples", type=int, default=100_000)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--technique", default="fac2",
                    help="DLS technique for the data sampler")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{jax.device_count()} device(s)")

    tcfg = TrainConfig(
        steps=args.steps, per_host_batch=args.batch, seq_len=args.seq,
        n_samples=args.samples, n_hosts=args.hosts, host_id=args.host_id,
        technique=args.technique, microbatches=args.microbatches,
        remat=args.remat, ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
        seed=args.seed,
    )
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps)
    trainer = Trainer(cfg, tcfg, opt)
    trainer.run()
    print(f"[train] done: final loss {trainer.history[-1]:.4f} "
          f"(first {trainer.history[0]:.4f})")


if __name__ == "__main__":
    main()
