"""Encoder-decoder LM (seamless-m4t backbone).

Per the assignment brief the modality frontend is a STUB: the encoder input
arrives as precomputed frame embeddings (B, S_src, d_model).  The backbone is
a standard transformer enc-dec: bidirectional encoder; decoder with causal
self-attention + cross-attention, all scanned.

Decode caches: per-layer self-attn KV (guarded by the usual ring/append
logic) plus cross-attention K/V precomputed ONCE from the encoder output at
prefill time (recomputing them per step would turn decode into prefill).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.shard.spec import NO_SHARD, ShardCtx, cs

from . import layers as L


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(ks[0], cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "self_attn": L.attention_init(ks[0], cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "cross_attn": L.attention_init(ks[1], cfg, dtype),
        "ln3": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg):
    dtype = L.dtype_of(cfg.dtype)
    k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    p = {
        "embed": L.dense_init(k_emb, (cfg.vocab, cfg.d_model), scale=1.0, dtype=dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "enc_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab), dtype=dtype)
    return p


def encode(params, cfg, src_embeds, *, ctx: ShardCtx = NO_SHARD, backend="xla",
           remat: str = "none"):
    h = cs(src_embeds, "batch", None, None, ctx=ctx)
    positions = jnp.arange(h.shape[1])

    def body(carry, lp):
        a, _ = L.attention_block(
            lp["attn"], L.rmsnorm(carry, lp["ln1"], cfg.norm_eps), cfg, ctx=ctx,
            positions=positions, causal=False, backend=backend)
        carry = carry + a
        carry = carry + L.mlp_block(
            lp["mlp"], L.rmsnorm(carry, lp["ln2"], cfg.norm_eps), ctx=ctx)
        return carry, None

    from .lm import _remat

    h, _ = jax.lax.scan(_remat(body, remat), h, params["enc_layers"])
    return L.rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def _dec_block(lp, h, cfg, ctx, *, positions, enc_out=None, cross_kv=None,
               kv=None, pos=None, backend="xla"):
    a, new_kv = L.attention_block(
        lp["self_attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, ctx=ctx,
        positions=positions, causal=True, kv_cache=kv, cache_pos=pos,
        backend=backend)
    h = h + a
    hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
    if cross_kv is not None:
        h = h + L.attention_with_kv(lp["cross_attn"], hn, cross_kv[0], cross_kv[1],
                                    cfg, ctx=ctx)
    else:
        x, _ = L.attention_block(lp["cross_attn"], hn, cfg, ctx=ctx,
                                 causal=False, xattn_kv=enc_out, backend=backend)
        h = h + x
    h = h + L.mlp_block(lp["mlp"], L.rmsnorm(h, lp["ln3"], cfg.norm_eps), ctx=ctx)
    return h, new_kv


def forward(params, cfg, src_embeds, tgt_tokens, *, ctx: ShardCtx = NO_SHARD,
            backend="xla", remat: str = "none", logits_f32=True):
    """Teacher-forced logits (B, T_tgt, vocab)."""
    enc_out = encode(params, cfg, src_embeds, ctx=ctx, backend=backend, remat=remat)
    h = params["embed"][tgt_tokens]
    h = cs(h, "batch", None, None, ctx=ctx)
    positions = jnp.arange(h.shape[1])

    def body(carry, lp):
        out, _ = _dec_block(lp, carry, cfg, ctx, positions=positions,
                            enc_out=enc_out, backend=backend)
        return out, None

    from .lm import _remat

    h, _ = jax.lax.scan(_remat(body, remat), h, params["dec_layers"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    logits = cs(logits, "batch", None, "model", ctx=ctx)
    return logits.astype(jnp.float32) if logits_f32 else logits


def init_cache(cfg, batch, max_len, src_len, dtype=None):
    dt = L.dtype_of(cfg.dtype) if dtype is None else dtype
    Ld = cfg.n_layers
    kv_shape = (Ld, batch, max_len, cfg.n_kv_heads, cfg.hd)
    cross_shape = (Ld, batch, src_len, cfg.n_kv_heads, cfg.hd)
    return {
        "pos": jnp.zeros((), jnp.int32),
        "kv": {"k": jnp.zeros(kv_shape, dt), "v": jnp.zeros(kv_shape, dt)},
        "cross": {"k": jnp.zeros(cross_shape, dt), "v": jnp.zeros(cross_shape, dt)},
    }


def prefill(params, cfg, src_embeds, tgt_tokens, cache, *,
            ctx: ShardCtx = NO_SHARD, backend="xla"):
    """Encode the source, fill cross-KV, consume the target prompt."""
    enc_out = encode(params, cfg, src_embeds, ctx=ctx, backend=backend)

    def fill_cross(lp):
        k, v = L.project_kv(lp["cross_attn"], enc_out, cfg)
        return {"k": k.astype(cache["cross"]["k"].dtype),
                "v": v.astype(cache["cross"]["v"].dtype)}

    cross = jax.vmap(fill_cross)(params["dec_layers"])
    cache = dict(cache, cross=cross)
    logits, cache = _dec_pass(params, cfg, tgt_tokens, cache, ctx=ctx, backend=backend)
    return logits, cache


def decode_step(params, cfg, token, cache, *, ctx: ShardCtx = NO_SHARD, backend="xla"):
    if token.ndim == 1:
        token = token[:, None]
    return _dec_pass(params, cfg, token, cache, ctx=ctx, backend=backend)


def _dec_pass(params, cfg, tokens, cache, *, ctx, backend):
    h = params["embed"][tokens]
    h = cs(h, "batch", None, None, ctx=ctx)
    pos = cache["pos"]
    positions = pos + jnp.arange(h.shape[1])

    def body(carry, xs):
        lp, kv, cross = xs
        out, new_kv = _dec_block(lp, carry, cfg, ctx, positions=positions,
                                 cross_kv=(cross["k"], cross["v"]),
                                 kv=kv, pos=pos, backend=backend)
        return out, new_kv

    h, new_kv = jax.lax.scan(
        body, h, (params["dec_layers"], cache["kv"], cache["cross"]))
    cache = dict(cache, kv=new_kv, pos=pos + h.shape[1])
    h = L.rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ head)[:, 0].astype(jnp.float32), cache
