"""Mamba2 (SSD) block: projections, short conv, selective scan, gated norm.

Train/prefill use the chunked SSD algorithm (the Pallas kernel on TPU, its
jnp oracle elsewhere); decode advances the recurrence one step against a
carried (state, conv) cache -- constant memory/compute per token, which is
why the SSM archs run the ``long_500k`` cell that quadratic attention can't.

Layout follows Mamba2 (arXiv:2405.21060) with ngroups=1:
  in_proj: d_model -> [z (di), x (di), B (S), C (S), dt (H)]
  conv1d (width cw) over the [x B C] channels, SiLU
  SSD scan over H heads of head_dim P = di / H
  gated RMSNorm: y * silu(z), out_proj: di -> d_model
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.shard.spec import NO_SHARD, ShardCtx, cs

from .layers import dense_init, rmsnorm

#: "chunked" (SSD block decomposition; production) or "sequential" (naive
#: per-step recurrence; the paper-faithful baseline for EXPERIMENTS.md §Perf)
SSD_MODE = "chunked"


def ssm_init(key, cfg, dtype):
    d, di, S, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * S + H), dtype=dtype),
        "conv_w": dense_init(ks[1], (cw, di + 2 * S), scale=cw ** -0.5, dtype=dtype),
        "conv_b": jnp.zeros((di + 2 * S,), dtype),
        # A in (-1, 0): log-decay rates; init log-uniform like mamba2
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[5], (di, d), dtype=dtype),
    }


def _split(cfg, proj):
    di, S, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di : 2 * di + 2 * S]
    dt = proj[..., 2 * di + 2 * S :]
    return z, xBC, dt


def ssm_block(
    params,
    x,  # (B, T, d)
    cfg,
    *,
    ctx: ShardCtx = NO_SHARD,
    cache: Optional[dict] = None,  # {"state" (B,H,S,P), "conv" (B,cw-1,di+2S)}
    backend: str = "xla",
):
    """Returns (out (B,T,d), new_cache | None).

    With ``cache`` and T == 1 this is the O(1) decode step; otherwise the
    chunked scan (cache, if given, is consumed as the initial state and the
    final state is returned -- enabling chunked prefill).
    """
    B, T, d = x.shape
    di, S, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    cw = cfg.ssm_conv

    proj = x @ params["in_proj"]  # (B, T, 2di+2S+H)
    proj = cs(proj, "batch", None, "model", ctx=ctx)
    z, xBC, dt = _split(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,T,H)
    A = -jnp.exp(params["A_log"])  # (H,) negative

    # --- short causal conv over time (prefix from cache during decode) ---
    if cache is not None:
        prev = cache["conv"]  # (B, cw-1, di+2S)
        xBC_ext = jnp.concatenate([prev.astype(xBC.dtype), xBC], axis=1)
        new_conv = xBC_ext[:, -(cw - 1) :, :]
    else:
        xBC_ext = jnp.pad(xBC, ((0, 0), (cw - 1, 0), (0, 0)))
        new_conv = xBC_ext[:, -(cw - 1) :, :]
    # depthwise conv: sum_w xBC_ext[:, t+w, :] * conv_w[w]
    conv = sum(
        xBC_ext[:, w : w + T, :] * params["conv_w"][w][None, None, :]
        for w in range(cw)
    ) + params["conv_b"]
    xBC = jax.nn.silu(conv)

    xs = xBC[..., :di].reshape(B, T, H, P)
    Bm = xBC[..., di : di + S]
    Cm = xBC[..., di + S :]

    state0 = cache["state"] if cache is not None else None
    if T == 1 and cache is not None:
        # O(1) recurrence step
        decay = jnp.exp(dt[:, 0, :] * A[None, :])  # (B,H)
        inject = (
            dt[:, 0, :, None, None]
            * Bm[:, 0, None, :, None].astype(jnp.float32)
            * xs[:, 0, :, None, :].astype(jnp.float32)
        )  # (B,H,S,P)
        state = decay[:, :, None, None] * state0 + inject
        y = jnp.einsum("bs,bhsp->bhp", Cm[:, 0].astype(jnp.float32), state)
        y = y[:, None]  # (B,1,H,P)
        new_state = state
    else:
        if backend == "pallas":
            from repro.kernels import ssd_scan

            y = ssd_scan(xs, dt.astype(xs.dtype), A, Bm, Cm)
            y = y.astype(jnp.float32)
            # closed-form final state for the cache
            acum = jnp.cumsum(dt * A[None, None, :], axis=1)  # (B,T,H)
            w = dt * jnp.exp(acum[:, -1:, :] - acum)
            new_state = jnp.einsum(
                "bts,bth,bthp->bhsp",
                Bm.astype(jnp.float32), w, xs.astype(jnp.float32))
        elif SSD_MODE == "sequential":
            from repro.kernels.ssd_scan.ref import ssd_scan_ref

            y = ssd_scan_ref(xs, dt, A, Bm, Cm).astype(jnp.float32)
            acum = jnp.cumsum(dt * A[None, None, :], axis=1)
            w = dt * jnp.exp(acum[:, -1:, :] - acum)
            new_state = jnp.einsum(
                "bts,bth,bthp->bhsp",
                Bm.astype(jnp.float32), w, xs.astype(jnp.float32))
        else:
            # chunked SSD block decomposition (pure XLA): state round-trips
            # HBM once per 128-step chunk instead of every step -- the same
            # algorithm the Pallas kernel implements on TPU.
            from repro.kernels.ssd_scan.ref import ssd_scan_chunked_xla

            yc, new_state = ssd_scan_chunked_xla(xs, dt, A, Bm, Cm)
            y = yc.astype(jnp.float32)
        if state0 is not None:
            acum = jnp.cumsum(dt * A[None, None, :], axis=1)  # (B,T,H)
            y = y + jnp.einsum(
                "bts,bth,bhsp->bthp", Cm.astype(jnp.float32), jnp.exp(acum), state0
            )
            new_state = new_state + jnp.exp(acum[:, -1, :])[:, :, None, None] * state0

    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)  # skip
    y = y.reshape(B, T, di)
    y = rmsnorm(y.astype(x.dtype) * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    y = cs(y, "batch", None, "model", ctx=ctx)
    out = y @ params["out_proj"]
    out = cs(out, "batch", None, None, ctx=ctx)
    new_cache = {"state": new_state, "conv": new_conv} if cache is not None else None
    return out, new_cache
