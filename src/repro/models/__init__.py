"""Composable LM stack (dense / MoE / SWA / enc-dec / SSM / hybrid / stubs)."""
from . import api, encdec, layers, lm, ssm  # noqa: F401
