"""Transformer building blocks: RMSNorm, RoPE, GQA attention, MLP, MoE.

Pure-functional (params are plain pytrees of jnp arrays); every block takes
an explicit ``ShardCtx`` so the same code runs unsharded on CPU and
TP/FSDP-sharded on the production mesh.

Attention has two execution backends:
  * "xla"    -- einsum attention (default; what the dry-run compiles)
  * "pallas" -- the fused flash-attention kernel (TPU production path;
                validated in interpret mode by tests)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.shard.spec import NO_SHARD, ShardCtx, cs

NEG_INF = -1e30


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = (fan_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps=1e-5):
    # variance/rsqrt in f32 (precision); the (T, d)-sized multiply applies in
    # x.dtype.  (Computing the square in bf16 was tried and REFUTED: it
    # shifted XLA fusion boundaries and increased measured traffic -- see
    # EXPERIMENTS.md §Perf P6.)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x * scale) * w


def rmsnorm_init(d, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_cos_sin(positions, head_dim, theta=10_000.0):
    """positions (...,) int -> cos/sin (..., head_dim//2) f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, T, H, D); cos/sin (B?, T, D//2) or (T, D//2).

    Angles are generated in f32 (rope_cos_sin); the (T, H, D)-sized rotation
    itself runs in x.dtype so the q/k streams (and their cotangents) stay
    bf16 at fusion boundaries -- f32 rope quadrupled the residual-sized HBM
    traffic of every attention layer (EXPERIMENTS.md §Perf P6).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # insert the head axis; leading (batch) axes broadcast from the left
    cos = cos[..., None, :].astype(x.dtype)
    sin = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA, causal / SWA / cross, optional KV cache)
# ---------------------------------------------------------------------------


#: self-attention switches to the chunked online-softmax path (flash-style,
#: pure XLA: double scan over q/kv blocks, O(T*blk) memory) above this
#: length.  Tuned in EXPERIMENTS.md §Perf: at 4k the dense scores fit and
#: cost *less* HBM traffic than the scan-block boundaries, so the chunked
#: path only pays off from 32k (where dense cannot fit at all); on real TPU
#: the Pallas kernel replaces both.
CHUNKED_ATTN_THRESHOLD = 8192
CHUNK_BLK_Q = 1024
CHUNK_BLK_K = 1024


def _blk_mask(rows, cols, Tq, Tk, causal, window):
    mask = (cols < Tk) & (rows < Tq)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return mask


def _flash_fwd_core(q, k, v, causal, window, row0, blk_q, blk_k):
    """Returns (o (B,Tq,H,D), lse (B,Hkv,g,Tq_pad)) -- online softmax over
    kv blocks, scanned over q blocks; scores never reach HBM whole."""
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = D ** -0.5
    nq, nk = -(-Tq // blk_q), -(-Tk // blk_k)
    qp = jnp.pad(q, ((0, 0), (0, nq * blk_q - Tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * blk_k - Tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * blk_k - Tk), (0, 0), (0, 0)))
    qs = jnp.moveaxis(qp.reshape(B, nq, blk_q, H, D), 1, 0)
    ks = jnp.moveaxis(kp.reshape(B, nk, blk_k, Hkv, D), 1, 0)
    vs = jnp.moveaxis(vp.reshape(B, nk, blk_k, Hkv, D), 1, 0)

    def q_block(_, qi_qb):
        qi, qb = qi_qb  # qb (B, blk_q, H, D)
        qf = (qb * jnp.asarray(scale, qb.dtype)).reshape(B, blk_q, Hkv, group, D)
        rows = row0 + qi * blk_q + jnp.arange(blk_q)[:, None]

        def kv_block(carry, ki_kv):
            m_p, l_p, acc = carry
            ki, kb, vb = ki_kv
            s = jnp.einsum("btkgd,bskd->bkgts", qf, kb,
                           preferred_element_type=jnp.float32)
            cols = ki * blk_k + jnp.arange(blk_k)[None, :]
            mask = _blk_mask(rows, cols, row0 + Tq, Tk, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_c = jnp.max(s, axis=-1)
            m_n = jnp.maximum(m_p, m_c)
            p = jnp.exp(s - m_n[..., None]) * mask[None, None, None]
            alpha = jnp.exp(m_p - m_n)
            l_n = alpha * l_p + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgts,bskd->bkgtd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_n, l_n, acc), None

        m0 = jnp.full((B, Hkv, group, blk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, group, blk_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, group, blk_q, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        o = acc / jnp.where(l > 0, l, 1.0)[..., None]
        o = jnp.moveaxis(o, 3, 1).reshape(B, blk_q, H, D)
        # +inf for fully-masked rows => bwd p = exp(s - inf) = 0 (no NaNs)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-38)), jnp.inf)
        return None, (o.astype(q.dtype), lse)

    _, (ob, lse_b) = jax.lax.scan(q_block, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(ob, 0, 1).reshape(B, nq * blk_q, H, D)[:, :Tq]
    # lse blocks (nq, B, Hkv, g, blk_q) -> (B, Hkv, g, Tq_pad)
    lse = jnp.moveaxis(lse_b, 0, 3).reshape(B, Hkv, group, nq * blk_q)
    return out, lse


def _flash_bwd_core(q, k, v, o, lse, do, causal, window, row0, blk_q, blk_k):
    """FlashAttention backward: recompute p per block; O(T*d) residuals."""
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = D ** -0.5
    nq, nk = -(-Tq // blk_q), -(-Tk // blk_k)
    qp = jnp.pad(q, ((0, 0), (0, nq * blk_q - Tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * blk_k - Tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * blk_k - Tk), (0, 0), (0, 0)))
    dop = jnp.pad(do, ((0, 0), (0, nq * blk_q - Tq), (0, 0), (0, 0)))
    op = jnp.pad(o, ((0, 0), (0, nq * blk_q - Tq), (0, 0), (0, 0)))
    # Di = rowsum(do * o): (B, Hkv, g, Tq_pad)
    Df = jnp.einsum("btkgd,btkgd->bkgt",
                    dop.reshape(B, nq * blk_q, Hkv, group, D),
                    op.reshape(B, nq * blk_q, Hkv, group, D),
                    preferred_element_type=jnp.float32)

    qs = jnp.moveaxis(qp.reshape(B, nq, blk_q, H, D), 1, 0)
    dos = jnp.moveaxis(dop.reshape(B, nq, blk_q, H, D), 1, 0)
    ks = jnp.moveaxis(kp.reshape(B, nk, blk_k, Hkv, D), 1, 0)
    vs = jnp.moveaxis(vp.reshape(B, nk, blk_k, Hkv, D), 1, 0)
    lse_s = jnp.moveaxis(lse.reshape(B, Hkv, group, nq, blk_q), 3, 0)
    D_s = jnp.moveaxis(Df.reshape(B, Hkv, group, nq, blk_q), 3, 0)

    def kv_step(dq_acc, ki_kv):
        ki, kb, vb = ki_kv
        cols = ki * blk_k + jnp.arange(blk_k)[None, :]

        def q_step(carry, xs):
            dk_b, dv_b = carry
            qi, qb, dob, lseb, Db = xs
            qf = qb.reshape(B, blk_q, Hkv, group, D)
            dof = dob.reshape(B, blk_q, Hkv, group, D)
            rows = row0 + qi * blk_q + jnp.arange(blk_q)[:, None]
            mask = _blk_mask(rows, cols, row0 + Tq, Tk, causal, window)
            s = jnp.einsum("btkgd,bskd->bkgts", qf, kb,
                           preferred_element_type=jnp.float32) * scale
            p = jnp.exp(s - lseb[..., None]) * mask[None, None, None]
            pb = p.astype(qb.dtype)
            dv_b = dv_b + jnp.einsum("bkgts,btkgd->bskd", pb, dof,
                                     preferred_element_type=jnp.float32)
            dp = jnp.einsum("btkgd,bskd->bkgts", dof, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - Db[..., None]) * scale
            dsb = ds.astype(qb.dtype)
            dk_b = dk_b + jnp.einsum("bkgts,btkgd->bskd", dsb, qf,
                                     preferred_element_type=jnp.float32)
            dq_b = jnp.einsum("bkgts,bskd->btkgd", dsb, kb,
                              preferred_element_type=jnp.float32).reshape(
                B, blk_q, H, D)
            return (dk_b, dv_b), dq_b

        zk = jnp.zeros((B, blk_k, Hkv, D), jnp.float32)
        (dk_b, dv_b), dq_blocks = jax.lax.scan(
            q_step, (zk, zk), (jnp.arange(nq), qs, dos, lse_s, D_s))
        return dq_acc + dq_blocks, (dk_b, dv_b)

    dq0 = jnp.zeros((nq, B, blk_q, H, D), jnp.float32)
    dq_acc, (dk_blocks, dv_blocks) = jax.lax.scan(
        kv_step, dq0, (jnp.arange(nk), ks, vs))
    dq = jnp.moveaxis(dq_acc, 0, 1).reshape(B, nq * blk_q, H, D)[:, :Tq]
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(B, nk * blk_k, Hkv, D)[:, :Tk]
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(B, nk * blk_k, Hkv, D)[:, :Tk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_xla(q, k, v, causal, window, row0, blk_q, blk_k):
    return _flash_fwd_core(q, k, v, causal, window, row0, blk_q, blk_k)[0]


def _flash_xla_fwd(q, k, v, causal, window, row0, blk_q, blk_k):
    o, lse = _flash_fwd_core(q, k, v, causal, window, row0, blk_q, blk_k)
    return o, (q, k, v, o, lse)


def _flash_xla_bwd(causal, window, row0, blk_q, blk_k, res, do):
    q, k, v, o, lse = res
    return _flash_bwd_core(q, k, v, o, lse, do, causal, window, row0,
                           blk_q, blk_k)


_flash_xla.defvjp(_flash_xla_fwd, _flash_xla_bwd)


def _sdpa_chunked(q, k, v, *, causal, window, row0=0,
                  blk_q=CHUNK_BLK_Q, blk_k=CHUNK_BLK_K):
    """Flash-style attention in pure XLA with a flash *backward* too.

    Forward: double scan (q blocks x kv blocks) with online softmax -- the
    (Tq, Tk) score matrix never reaches HBM.  Backward: custom VJP that
    recomputes p per block (residuals are O(T*d): q, k, v, o, lse), the
    standard FlashAttention dq/dk/dv two-scan.  When ``row0`` is traced
    (prefill against a cache at a dynamic position -- an inference path, no
    grads), the plain forward core is used directly.
    """
    if isinstance(row0, int):
        return _flash_xla(q, k, v, causal, window, row0, blk_q, blk_k)
    return _flash_fwd_core(q, k, v, causal, window, row0, blk_q, blk_k)[0]


def attention_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype=dtype),
    }


def _sdpa_xla(q, k, v, *, causal, window, row_pos=None, col_pos=None):
    """q (B,Tq,H,D), k/v (B,Tk,Hkv,D).  Dense masked attention, f32 accum.

    ``row_pos``/``col_pos`` are the *absolute* token positions of queries and
    keys (defaults: 0..Tq-1 / 0..Tk-1).  Ring-buffer caches pass permuted /
    partially-negative ``col_pos`` (negative = slot never written).
    """
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    qf = q * jnp.asarray(D ** -0.5, q.dtype)
    # (B, Hkv, group, Tq, Tk): bf16 operands, f32 MXU accumulation
    s = jnp.einsum(
        "btkgd,bskd->bkgts",
        qf.reshape(B, Tq, Hkv, group, D), k,
        preferred_element_type=jnp.float32,
    )
    rows = (jnp.arange(Tq) if row_pos is None else row_pos)[:, None]
    cols = (jnp.arange(Tk) if col_pos is None else col_pos)[None, :]
    mask = cols >= 0
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Tq, H, D).astype(q.dtype)


def attention_block(
    params,
    x,  # (B, T, d)
    cfg,
    *,
    ctx: ShardCtx = NO_SHARD,
    positions=None,  # (T,) or (B, T) absolute positions for RoPE
    causal: bool = True,
    kv_cache: Optional[dict] = None,  # {"k","v": (B,S,Hkv,hd)}
    cache_pos=None,  # scalar: current length of the cache
    xattn_kv=None,  # (B, S_src, d) encoder output for cross-attention
    backend: str = "xla",
):
    """Returns (out (B,T,d), updated_cache | None)."""
    B, T, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    q = (x @ params["wq"]).reshape(B, T, H, hd)
    kv_src = xattn_kv if xattn_kv is not None else x
    k = (kv_src @ params["wk"]).reshape(B, kv_src.shape[1], Hkv, hd)
    v = (kv_src @ params["wv"]).reshape(B, kv_src.shape[1], Hkv, hd)
    q = cs(q, "batch", None, "model", None, ctx=ctx)
    k = cs(k, "batch", None, "model", None, ctx=ctx)
    v = cs(v, "batch", None, "model", None, ctx=ctx)

    if xattn_kv is None:  # RoPE only for self-attention
        if positions is None:
            positions = jnp.arange(T)
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    row_pos = col_pos = None
    row0 = 0
    if kv_cache is not None:
        pos = cache_pos
        S_c = kv_cache["k"].shape[1]
        tail = min(T, S_c)  # only the last S_c tokens can survive in a ring
        if tail == T and cfg.window is None:
            # plain append cache (no SWA): positions == slots
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), pos, axis=1)
            slots = jnp.arange(S_c)
            col_pos = jnp.where(slots < pos + T, slots, -1)
        else:
            # ring buffer (SWA): slot of absolute position a is a % S_c
            idx = (pos + T - tail + jnp.arange(tail)) % S_c
            ck = kv_cache["k"].at[:, idx].set(k[:, T - tail :].astype(kv_cache["k"].dtype))
            cv = kv_cache["v"].at[:, idx].set(v[:, T - tail :].astype(kv_cache["v"].dtype))
            slots = jnp.arange(S_c)
            # absolute position held by each slot (negative = never written)
            col_pos = (pos + T - 1) - ((pos + T - 1 - slots) % S_c)
        new_cache = {"k": ck, "v": cv}
        if T > 1:
            # prefill: attend over this call's own keys (banded/causal).
            # The cache cannot serve early queries in the ring case (later
            # keys overwrite theirs), and in the append case the live k/v
            # are identical to the cache content anyway.  Assumes prefill
            # starts at pos=0 (chunked prefill would concat ring+current).
            col_pos = None  # cols are this call's 0..T-1 (+row0 below)
            row0 = pos
        else:
            k, v = ck, cv
        row_pos = pos + jnp.arange(T)

    if backend == "pallas" and kv_cache is None and xattn_kv is None:
        from repro.kernels import flash_attention

        o = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=causal, window=cfg.window,
        ).transpose(0, 2, 1, 3)
    elif (T > 1 and col_pos is None
          and k.shape[1] >= (4096 if cfg.d_model >= 8192
                             else CHUNKED_ATTN_THRESHOLD)):
        # very wide models (deepseek-67b) take the flash path already at 4k:
        # their dense-attention residuals alone overflow HBM (§Perf)
        # long attention (32k+ prefill/train, self or cross): flash-style
        # chunked path -- never materializes (Tq, Tk) scores
        o = _sdpa_chunked(
            q, k, v,
            causal=causal and xattn_kv is None,
            window=cfg.window if xattn_kv is None else None,
            row0=row0)
    else:
        o = _sdpa_xla(
            q, k, v,
            causal=causal and xattn_kv is None,
            window=cfg.window if xattn_kv is None else None,
            row_pos=row_pos, col_pos=col_pos,
        )
    o = cs(o, "batch", None, "model", None, ctx=ctx)
    out = o.reshape(B, T, H * hd) @ params["wo"]
    return cs(out, "batch", None, None, ctx=ctx), new_cache


def project_kv(params, src, cfg):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    B, S, _ = src.shape
    k = (src @ params["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (src @ params["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return k, v


def attention_with_kv(params, x, k, v, cfg, *, ctx: ShardCtx = NO_SHARD):
    """Cross-attention against precomputed K/V (decode-time path)."""
    B, T, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (x @ params["wq"]).reshape(B, T, H, hd)
    q = cs(q, "batch", None, "model", None, ctx=ctx)
    if T > 1 and k.shape[1] >= CHUNKED_ATTN_THRESHOLD:
        o = _sdpa_chunked(q, k, v, causal=False, window=None)
    else:
        o = _sdpa_xla(q, k, v, causal=False, window=None)
    out = o.reshape(B, T, H * hd) @ params["wo"]
    return cs(out, "batch", None, None, ctx=ctx)


# ---------------------------------------------------------------------------
# Gated MLP (llama-style SwiGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d, ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], (d, ff), dtype=dtype),
        "wu": dense_init(ks[1], (d, ff), dtype=dtype),
        "wd": dense_init(ks[2], (ff, d), dtype=dtype),
    }


def mlp_block(params, x, *, ctx: ShardCtx = NO_SHARD):
    h = jax.nn.silu(x @ params["wg"]) * (x @ params["wu"])
    h = cs(h, "batch", None, "model", ctx=ctx)
    out = h @ params["wd"]
    return cs(out, "batch", None, None, ctx=ctx)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k routing, capacity-based, expert-parallel layout)
# ---------------------------------------------------------------------------


def moe_init(key, cfg, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "wg": dense_init(ks[1], (E, d, ff), dtype=dtype),
        "wu": dense_init(ks[2], (E, d, ff), dtype=dtype),
        "wd": dense_init(ks[3], (E, ff, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.d_ff * cfg.n_shared_experts, dtype)
    return p


def moe_block(params, x, cfg, *, ctx: ShardCtx = NO_SHARD):
    """Top-k capacity MoE with **group-local dispatch** (standard EP layout).

    Tokens are processed in G groups (G = the data-parallel degree): each
    group routes its own tokens, computes position-in-expert with a
    group-local cumsum, and gathers/scatters only within the group -- so
    under GSPMD nothing token-sized ever crosses the data axis.  The only
    cross-device movement is the (group -> expert) transpose of the slot
    tensor: the EP all-to-all.  Per-group capacity C_g = cf*K*N_g/E
    (overflow dropped -- training-time approximation; small-N calls are
    floored dropless for decode).

    A naive *global* dispatch (one cumsum over all N tokens) forces every
    shard to materialize the full token table per layer per microbatch --
    measured at 4+ TiB/device/step of all-reduce on qwen3 (§Perf P5).
    """
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * T
    G = ctx.batch_size_product if (ctx.enabled and N >= 4096) else 1
    while N % G:  # awkward batch extents: fall back to fewer groups
        G //= 2
    n = N // G  # tokens per group
    xg = x.reshape(G, n, d)
    xg = cs(xg, "batch", None, None, ctx=ctx)

    gates = jax.nn.softmax(
        (xg.astype(jnp.float32) @ params["router"]), axis=-1)  # (G, n, E)
    top_w, top_e = jax.lax.top_k(gates, K)  # (G, n, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    C = int(max(1, round(cfg.capacity_factor * K * n / E), min(n, 256)))
    flat_e = top_e.reshape(G, n * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (G, n*K, E)
    pos = jnp.cumsum(onehot, axis=1) * onehot - 1  # group-local positions
    pos_in_e = pos.max(axis=-1)  # (G, n*K)
    keep = pos_in_e < C

    # group-local slot table: token row n = empty (points at the pad row)
    tok_ids = jnp.broadcast_to(
        jnp.repeat(jnp.arange(n, dtype=jnp.int32), K)[None], (G, n * K))
    slot_tok = jnp.full((G, E, C), n, jnp.int32)
    gidx = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32)[:, None], (G, n * K))
    slot_tok = slot_tok.at[
        gidx,
        jnp.where(keep, flat_e, E),  # dropped -> out of bounds, mode="drop"
        jnp.where(keep, pos_in_e, C),
    ].set(tok_ids, mode="drop")

    xpad = jnp.concatenate([xg, jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xpad, slot_tok.reshape(G, E * C, 1), axis=1)  # group-local gather
    xe = xe.reshape(G, E, C, d).transpose(1, 0, 2, 3)  # (E, G, C, d): EP a2a
    xe = cs(xe, "model", "batch", None, None, ctx=ctx)

    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, params["wg"]))
    h = h * jnp.einsum("egcd,edf->egcf", xe, params["wu"])
    h = cs(h, "model", "batch", None, None, ctx=ctx)
    ye = jnp.einsum("egcf,efd->egcd", h, params["wd"])  # (E, G, C, d)
    ye = ye.transpose(1, 0, 2, 3).reshape(G, E * C, d)  # back: second a2a
    ye = cs(ye, "batch", None, None, ctx=ctx)

    # combine (group-local): gather each pair's slot output, weight, sum K
    w_flat = jnp.where(keep, top_w.reshape(G, n * K), 0.0)  # (G, n*K)
    slot_of_pair = jnp.where(keep, flat_e * C + pos_in_e, E * C)  # (G, n*K)
    ye_pad = jnp.concatenate([ye, jnp.zeros((G, 1, d), ye.dtype)], axis=1)
    y_pairs = jnp.take_along_axis(
        ye_pad, slot_of_pair.reshape(G, n * K, 1), axis=1)
    y_pairs = y_pairs * w_flat[..., None].astype(ye.dtype)
    y = y_pairs.reshape(G, n, K, d).sum(axis=2)

    out = y.reshape(B, T, d).astype(x.dtype)
    if cfg.n_shared_experts:
        # NB: must be called on the (B, T, d) view -- a flat (1, N, d) view
        # would hang the batch sharding on the dummy leading dim and
        # replicate every token's shared-expert compute across the data axis
        # (16x per-device FLOPs; see EXPERIMENTS.md §Perf P5).
        out = out + mlp_block(params["shared"], x, ctx=ctx)
    return cs(out, "batch", None, None, ctx=ctx)
