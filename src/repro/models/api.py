"""Family-dispatching facade: one API for all ten architectures.

The launcher, trainer, server, and dry-run all talk to this module only.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.shard.spec import NO_SHARD, ShardCtx

from . import encdec, lm


def init_params(key, cfg):
    if cfg.is_encdec:
        return encdec.init_params(key, cfg)
    return lm.init_params(key, cfg)


def abstract_params(cfg, seed: int = 0):
    """Parameter pytree of ShapeDtypeStructs -- no allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(seed))


def forward(params, cfg, batch, *, ctx: ShardCtx = NO_SHARD, backend="xla",
            remat="none"):
    """Teacher-forced logits for a training batch dict."""
    if cfg.is_encdec:
        return encdec.forward(params, cfg, batch["src_embeds"], batch["tokens"],
                              ctx=ctx, backend=backend, remat=remat)
    return lm.forward(params, cfg, batch["tokens"], ctx=ctx,
                      prefix_embeds=batch.get("prefix_embeds"),
                      backend=backend, remat=remat)


def init_cache(cfg, batch_size, max_len, src_len: Optional[int] = None, dtype=None):
    if cfg.is_encdec:
        return encdec.init_cache(cfg, batch_size, max_len, src_len or max_len, dtype)
    return lm.init_cache(cfg, batch_size, max_len, dtype)


def prefill(params, cfg, batch, cache, *, ctx: ShardCtx = NO_SHARD, backend="xla"):
    if cfg.is_encdec:
        return encdec.prefill(params, cfg, batch["src_embeds"], batch["tokens"],
                              cache, ctx=ctx, backend=backend)
    return lm.prefill(params, cfg, batch["tokens"], cache,
                      prefix_embeds=batch.get("prefix_embeds"),
                      ctx=ctx, backend=backend)


def decode_step(params, cfg, token, cache, *, ctx: ShardCtx = NO_SHARD, backend="xla"):
    if cfg.is_encdec:
        return encdec.decode_step(params, cfg, token, cache, ctx=ctx, backend=backend)
    return lm.decode_step(params, cfg, token, cache, ctx=ctx, backend=backend)


# ---------------------------------------------------------------------------
# Modality frontend stubs (per the brief: precomputed frame/patch embeddings)
# ---------------------------------------------------------------------------


def frontend_stub_embeds(cfg, batch, seq, key=None):
    """Synthetic frontend output: (batch, seq, d_model) unit-scale embeds."""
    key = jax.random.key(0) if key is None else key
    from .layers import dtype_of

    return jax.random.normal(key, (batch, seq, cfg.d_model), dtype_of(cfg.dtype))
