"""Unified decoder-only LM covering the dense / MoE / SWA / SSM / hybrid /
VLM-prefix families, with scan-over-layers (small HLO => fast 512-device
compiles) and configurable remat.

Entry points:
  init_params(key, cfg)                 -> params pytree (stacked layer leaves)
  forward(params, cfg, tokens, ...)     -> logits (train / teacher-forced)
  init_cache(cfg, batch, max_len, ...)  -> decode cache pytree
  prefill(params, cfg, tokens, cache)   -> (last-token logits, cache)
  decode_step(params, cfg, token, cache)-> (logits, cache)

Hybrid (zamba2-style) layout: the mamba backbone is scanned in groups of
``attn_every`` layers; ONE shared transformer block (attention + MLP) runs
after each group, its weights reused across all groups (its KV caches are
per-group).  This keeps the whole stack inside two nested scans -- no
per-layer Python unrolling anywhere.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.shard.spec import NO_SHARD, ShardCtx, cs

from . import layers as L
from . import ssm as SSM


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg, dtype):
    """One repeated-stack layer for the arch family."""
    ks = jax.random.split(key, 4)
    if cfg.family in ("dense", "vlm"):
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": L.attention_init(ks[0], cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
        }
    if cfg.family == "moe":
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": L.attention_init(ks[0], cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
            "moe": L.moe_init(ks[1], cfg, dtype),
        }
    if cfg.family in ("ssm", "hybrid"):
        return {
            "ln": L.rmsnorm_init(cfg.d_model, dtype),
            "ssm": SSM.ssm_init(ks[0], cfg, dtype),
        }
    raise ValueError(cfg.family)


def shared_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(ks[0], cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg):
    dtype = L.dtype_of(cfg.dtype)
    k_emb, k_layers, k_shared, k_head = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": L.dense_init(k_emb, (cfg.vocab, cfg.d_model), scale=1.0, dtype=dtype),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab), dtype=dtype)
    if cfg.family == "hybrid":
        assert cfg.attn_every > 0 and cfg.n_layers % cfg.attn_every == 0, (
            "hybrid stack must be divisible into attn_every-sized groups"
        )
        params["shared"] = shared_block_init(k_shared, cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------


def _tblock(p, h, cfg, ctx, *, positions, causal, kv=None, pos=None, backend):
    """Transformer block: attn + (mlp | moe) with pre-norms and residuals."""
    a, new_kv = L.attention_block(
        p["attn"], L.rmsnorm(h, p["ln1"], cfg.norm_eps), cfg, ctx=ctx,
        positions=positions, causal=causal, kv_cache=kv, cache_pos=pos,
        backend=backend,
    )
    h = h + a
    hn = L.rmsnorm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        h = h + L.moe_block(p["moe"], hn, cfg, ctx=ctx)
    else:
        h = h + L.mlp_block(p["mlp"], hn, ctx=ctx)
    return h, new_kv


def _ssm_layer(p, h, cfg, ctx, *, cache=None, backend):
    o, new_cache = SSM.ssm_block(
        p["ssm"], L.rmsnorm(h, p["ln"], cfg.norm_eps), cfg, ctx=ctx,
        cache=cache, backend=backend,
    )
    return h + o, new_cache


def _remat(fn, policy: str):
    if policy == "none" or policy.startswith("group"):
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    raise ValueError(policy)


def _scan_layers(body, h, stacked, remat: str):
    """Scan the layer stack under the remat policy.

    ``group:G`` = recursive checkpointing: only every G-th layer input is
    saved; the backward re-runs one group at a time (activation saves drop
    G-fold for ~one extra forward of recompute within the live group).
    """
    if remat.startswith("group"):
        G = int(remat.split(":")[1]) if ":" in remat else 8
        L = jax.tree.leaves(stacked)[0].shape[0]
        while L % G:
            G -= 1
        grouped = jax.tree.map(
            lambda a: a.reshape((L // G, G) + a.shape[1:]), stacked)

        @jax.checkpoint
        def group_body(carry, gp):
            out, _ = jax.lax.scan(body, carry, gp)
            return out, None

        h, _ = jax.lax.scan(group_body, h, grouped)
        return h
    h, _ = jax.lax.scan(_remat(body, remat), h, stacked)
    return h


# ---------------------------------------------------------------------------
# forward (train / teacher-forced full sequence)
# ---------------------------------------------------------------------------


def forward(
    params,
    cfg,
    tokens,  # (B, T) int32
    *,
    ctx: ShardCtx = NO_SHARD,
    prefix_embeds=None,  # (B, Tp, d) vlm/audio stub frontend output
    backend: str = "xla",
    remat: str = "none",
    logits_f32: bool = True,
):
    """Token logits (B, T(+Tp), vocab)."""
    h = params["embed"][tokens]
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, T, _ = h.shape
    h = cs(h, "batch", None, None, ctx=ctx)
    positions = jnp.arange(T)

    if cfg.family in ("dense", "vlm", "moe"):

        def body(carry, lp):
            out, _ = _tblock(lp, carry, cfg, ctx, positions=positions,
                             causal=True, backend=backend)
            return out, None

        h = _scan_layers(body, h, params["layers"], remat)

    elif cfg.family == "ssm":

        def body(carry, lp):
            out, _ = _ssm_layer(lp, carry, cfg, ctx, backend=backend)
            return out, None

        h = _scan_layers(body, h, params["layers"], remat)

    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda a: a.reshape((G, cfg.attn_every) + a.shape[1:]), params["layers"]
        )
        shared = params["shared"]

        def inner(carry, lp):
            out, _ = _ssm_layer(lp, carry, cfg, ctx, backend=backend)
            return out, None

        def group(carry, gp):
            out, _ = jax.lax.scan(_remat(inner, remat), carry, gp)
            out, _ = _tblock(shared, out, cfg, ctx, positions=positions,
                             causal=True, backend=backend)
            return out, None

        h, _ = jax.lax.scan(group, h, grouped)
    else:
        raise ValueError(cfg.family)

    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    logits = cs(logits, "batch", None, "model", ctx=ctx)
    return logits.astype(jnp.float32) if logits_f32 else logits


# ---------------------------------------------------------------------------
# decode cache
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, max_len, dtype=None):
    """Stacked per-layer caches + a global position counter."""
    dt = L.dtype_of(cfg.dtype) if dtype is None else dtype
    cache = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "moe"):
        kv_len = min(max_len, cfg.window) if cfg.window else max_len
        shape = (cfg.n_layers, batch, kv_len, cfg.n_kv_heads, cfg.hd)
        cache["kv"] = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    elif cfg.family in ("ssm", "hybrid"):
        cache["ssm"] = {
            "state": jnp.zeros(
                (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                jnp.float32,
            ),
            "conv": jnp.zeros(
                (cfg.n_layers, batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state),
                dt,
            ),
        }
        if cfg.family == "hybrid":
            G = cfg.n_layers // cfg.attn_every
            shape = (G, batch, max_len, cfg.n_kv_heads, cfg.hd)
            cache["kv"] = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    return cache


# NOTE on SWA caches: for cfg.window we still allocate min(max_len, window)
# slots and address them linearly (no ring buffer) -- decode positions past
# the window reuse dynamic_update at pos % window via the mask; see
# _swa_cache_pos below.


def _step(params, cfg, h, cache, *, ctx, positions, backend):
    """One full pass over the stack with caches; h (B, T, d)."""
    pos = cache["pos"]

    if cfg.family in ("dense", "vlm", "moe"):

        def body(carry, xs):
            lp, kv = xs
            out, new_kv = _tblock(lp, carry, cfg, ctx, positions=positions,
                                  causal=True, kv=kv, pos=pos, backend=backend)
            return out, new_kv

        h, new_kv = jax.lax.scan(body, h, (params["layers"], cache["kv"]))
        new_cache = {"pos": pos + h.shape[1], "kv": new_kv}

    elif cfg.family == "ssm":

        def body(carry, xs):
            lp, c = xs
            out, nc = _ssm_layer(lp, carry, cfg, ctx, cache=c, backend=backend)
            return out, nc

        h, new_ssm = jax.lax.scan(body, h, (params["layers"], cache["ssm"]))
        new_cache = {"pos": pos + h.shape[1], "ssm": new_ssm}

    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda a: a.reshape((G, cfg.attn_every) + a.shape[1:]), params["layers"]
        )
        gssm = jax.tree.map(
            lambda a: a.reshape((G, cfg.attn_every) + a.shape[1:]), cache["ssm"]
        )
        shared = params["shared"]

        def inner(carry, xs):
            lp, c = xs
            out, nc = _ssm_layer(lp, carry, cfg, ctx, cache=c, backend=backend)
            return out, nc

        def group(carry, xs):
            gp, gc, kv = xs
            out, ncs = jax.lax.scan(inner, carry, (gp, gc))
            out, nkv = _tblock(shared, out, cfg, ctx, positions=positions,
                               causal=True, kv=kv, pos=pos, backend=backend)
            return out, (ncs, nkv)

        h, (new_ssm, new_kv) = jax.lax.scan(group, h, (grouped, gssm, cache["kv"]))
        new_ssm = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_ssm
        )
        new_cache = {"pos": pos + h.shape[1], "ssm": new_ssm, "kv": new_kv}
    else:
        raise ValueError(cfg.family)

    return h, new_cache


def prefill(
    params, cfg, tokens, cache, *, ctx: ShardCtx = NO_SHARD,
    prefix_embeds=None, backend: str = "xla",
):
    """Consume the prompt; returns (last-position logits (B, vocab), cache)."""
    h = params["embed"][tokens]
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = cs(h, "batch", None, None, ctx=ctx)
    positions = cache["pos"] + jnp.arange(h.shape[1])
    h, cache = _step(params, cfg, h, cache, ctx=ctx, positions=positions,
                     backend=backend)
    h = L.rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ head)[:, 0].astype(jnp.float32), cache


def decode_step(
    params, cfg, token, cache, *, ctx: ShardCtx = NO_SHARD, backend: str = "xla"
):
    """One new token (B,) or (B,1); returns (logits (B, vocab), cache)."""
    if token.ndim == 1:
        token = token[:, None]
    h = params["embed"][token]
    h = cs(h, "batch", None, None, ctx=ctx)
    positions = cache["pos"] + jnp.arange(1)
    h, cache = _step(params, cfg, h, cache, ctx=ctx, positions=positions,
                     backend=backend)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ head)[:, 0].astype(jnp.float32), cache
