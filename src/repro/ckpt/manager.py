"""Fault-tolerant checkpointing: async, atomic, keep-N, auto-resume.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json          tree structure + dtypes/shapes + extra state
        arrays_h<host>.npz     flat param/opt arrays (this host's shards)
    <root>/LATEST              text file: "step_000123"  (atomic rename)

Writes happen on a background thread against ``step_xxx.tmp`` and are
published by a single atomic rename + LATEST update, so a killed process can
never leave a half-written checkpoint as "latest" (restart-safe).  The DLS
window counters (data-pipeline epoch state) ride along in the manifest --
after a crash the self-scheduled epoch resumes at the exact loop pointer.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _manifest_entry(x):
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


class CheckpointManager:
    def __init__(self, root: str, *, keep_n: int = 3, host_id: int = 0,
                 async_save: bool = True):
        self.root = root
        self.keep_n = keep_n
        self.host_id = host_id
        os.makedirs(root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._async = async_save
        if async_save:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = False):
        """Snapshot ``tree`` (any pytree of arrays) at ``step``.

        Arrays are device_get *synchronously* (a consistent snapshot), the
        file I/O happens on the writer thread.
        """
        if self._err is not None:
            raise RuntimeError("previous async save failed") from self._err
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        payload = (step, host_leaves, jax.tree_util.tree_structure(tree),
                   [ _manifest_entry(x) for x in host_leaves ], extra or {})
        if self._async:
            # all writes go through the single worker thread (no concurrent
            # _write: LATEST.tmp and GC are not multi-writer safe)
            self._q.put(payload)
            if block:
                self.wait()
        else:
            self._write(*payload)

    def wait(self):
        """Block until all queued saves are on disk."""
        self._q.join()
        if self._err is not None:
            raise RuntimeError("async save failed") from self._err

    def _worker(self):
        while True:
            payload = self._q.get()
            try:
                self._write(*payload)
            except BaseException as e:  # surfaced on next save()/wait()
                self._err = e
            finally:
                self._q.task_done()

    @staticmethod
    def _to_npz_safe(a: np.ndarray) -> np.ndarray:
        """npz cannot store ml_dtypes (bfloat16 etc.) -- view as raw uint."""
        if a.dtype.kind == "V" or a.dtype.name in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
            return a.view(np.dtype(f"u{a.dtype.itemsize}"))
        return a

    @staticmethod
    def _from_npz_safe(a: np.ndarray, dtype_name: str) -> np.ndarray:
        if a.dtype.kind == "u" and dtype_name in ("bfloat16", "float8_e4m3fn",
                                                  "float8_e5m2"):
            import ml_dtypes

            return a.view(np.dtype(getattr(ml_dtypes, dtype_name)))
        return a

    def _write(self, step, leaves, treedef, manifest_entries, extra):
        name = f"step_{step:09d}"
        tmp = os.path.join(self.root, name + f".tmp{self.host_id}")
        final = os.path.join(self.root, name)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"arrays_h{self.host_id}.npz"),
                 **{str(i): self._to_npz_safe(a) for i, a in enumerate(leaves)})
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "leaves": manifest_entries,
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # atomic publish
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        with open(os.path.join(self.root, "LATEST.tmp"), "w") as f:
            f.write(name)
        os.replace(os.path.join(self.root, "LATEST.tmp"),
                   os.path.join(self.root, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.root) if d.startswith("step_")
                       and not d.endswith(".tmp%d" % self.host_id))
        for d in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.root, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.root, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, like_tree: Any, step: Optional[int] = None):
        """Returns (tree, extra) with arrays shaped/dtyped like ``like_tree``.

        ``like_tree`` provides the pytree structure (and sanity-checks
        shapes); pass e.g. the freshly-initialized params.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        z = np.load(os.path.join(d, f"arrays_h{self.host_id}.npz"))
        leaves_ref, treedef = _flatten(like_tree)
        leaves = []
        for i, ref in enumerate(leaves_ref):
            arr = self._from_npz_safe(z[str(i)], manifest["leaves"][i]["dtype"])
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"checkpoint leaf {i} shape {arr.shape} != expected {ref.shape}")
            leaves.append(arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["extra"]
