"""Parameter / activation / cache sharding rules for the production mesh.

Scheme (MaxText-style 2D): every weight matrix is sharded
  * on the **model** axis along its TP dimension (heads, ffn hidden,
    experts, vocab), and
  * on the **fsdp** axis (= the mesh "data" axis) along the other dimension
    (ZeRO-3: params, grads and optimizer states all carry the same specs).
The "pod" axis is pure DP by default (gradients all-reduce across pods;
params replicated pod-wise) -- cross-pod FSDP would put per-layer
all-gathers on the slow inter-pod links.  `fsdp_pods=True` flips that
trade-off for models that do not fit one pod's HBM.

Rules match leaves by their path suffix inside the (possibly stacked) param
pytree; stacked layer dims get a leading None automatically.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _rule_for(path_keys, leaf_ndim, *, fsdp: Optional[str], tp: Optional[str]):
    """PartitionSpec for one param leaf, *excluding* any stacked-layer dims."""
    name = path_keys[-1]
    parent = path_keys[-2] if len(path_keys) >= 2 else ""

    # ---- embeddings / head ----
    if name == "embed":
        return P(tp, fsdp)  # (vocab, d)
    if name == "lm_head":
        return P(fsdp, tp)  # (d, vocab)

    # ---- attention ----
    if name in ("wq", "wk", "wv"):
        return P(fsdp, tp)  # (d, heads*hd) column-parallel
    if name == "wo":
        return P(tp, fsdp)  # (heads*hd, d) row-parallel

    # ---- dense mlp ----
    if name in ("wg", "wu") and parent != "moe":
        return P(fsdp, tp)  # (d, ff)
    if name == "wd" and parent != "moe":
        return P(tp, fsdp)  # (ff, d)

    # ---- moe ----
    if name == "router":
        return P(fsdp, None)  # (d, E) small; replicate E
    if parent == "moe" or (len(path_keys) >= 2 and "moe" in path_keys):
        if name in ("wg", "wu"):
            return P(tp, fsdp, None)  # (E, d, ff): expert-parallel
        if name == "wd":
            return P(tp, None, fsdp)  # (E, ff, d)

    # ---- ssm ----
    if name == "in_proj":
        return P(fsdp, tp)  # (d, 2di+2S+H)
    if name == "out_proj":
        return P(tp, fsdp)  # (di, d)
    if name == "conv_w":
        return P(None, tp)  # (cw, di+2S)
    if name in ("conv_b", "norm_w"):
        return P(tp)
    if name in ("A_log", "D", "dt_bias"):
        return P(None)

    # ---- norms & anything 1-D ----
    if leaf_ndim == 1:
        return P(None)
    return P(*([None] * leaf_ndim))


# param leaves that live under a stacked layer axis
_STACKED_ROOTS = ("layers", "enc_layers", "dec_layers")


def params_pspecs(params_tree, *, fsdp="data", tp="model", fsdp_pods=False):
    """PartitionSpec pytree matching ``params_tree`` (arrays or SDS)."""
    fsdp_axes = ("pod", fsdp) if fsdp_pods else fsdp

    def visit(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        keys = [k for k in keys if k is not None]
        stacked = any(k in _STACKED_ROOTS for k in keys)
        ndim = leaf.ndim - (1 if stacked else 0)
        spec = _rule_for(keys, ndim, fsdp=fsdp_axes, tp=tp)
        if stacked:
            spec = P(None, *spec)
        # guard: spec rank must match
        if len(spec) != leaf.ndim:
            spec = P(*(list(spec) + [None] * (leaf.ndim - len(spec))))[: leaf.ndim] \
                if len(spec) < leaf.ndim else P(*list(spec)[: leaf.ndim])
        return spec

    return jax.tree_util.tree_map_with_path(visit, params_tree)


def resolve_batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_pspecs_for_mesh(batch_tree, mesh):
    axes = resolve_batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def visit(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim >= 1 and axes:
            # shard the batch dim over as many of (pod, data) as divide it
            # (long_500k has global_batch=1: fully replicated)
            use = []
            rem = leaf.shape[0]
            for a in axes:
                if rem % sizes[a] == 0:
                    use.append(a)
                    rem //= sizes[a]
            if use:
                spec[0] = tuple(use) if len(use) > 1 else use[0]
        return P(*spec)

    return jax.tree.map(visit, batch_tree)


def cache_pspecs(cache_tree, mesh, *, tp="model", kv_heads: Optional[int] = None):
    """KV/SSM caches: batch dim on data axes, heads (or head_dim) on model.

    Cache leaves: kv (L, B, S, Hkv, hd); ssm state (L, B, H, S, P);
    conv (L, B, cw-1, C); pos scalar.

    GQA wrinkle: when Hkv < |model| (e.g. qwen3's kv=4 on a 16-way TP axis),
    sharding the head dim would pad it |model|/Hkv-fold.  In that case we
    shard ``hd`` instead (attention then reduces partial sums over model).
    """
    axes = resolve_batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp_size = sizes.get(tp, 1)
    heads_ok = kv_heads is None or (kv_heads % tp_size == 0)

    def baxes_for(extent):
        """Batch axes that actually divide the batch extent (1 => replicate)."""
        use, rem = [], extent
        for a in axes:
            if rem % sizes[a] == 0:
                use.append(a)
                rem //= sizes[a]
        return tuple(use) if len(use) > 1 else (use[0] if use else None)

    def visit(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if leaf.ndim == 0:
            return P()
        if "conv" in keys:
            return P(None, baxes_for(leaf.shape[1]), None, tp)
        if "state" in keys:
            return P(None, baxes_for(leaf.shape[1]), tp, None, None)
        if leaf.ndim == 5:  # kv / cross kv: (L, B, S, Hkv, hd)
            b = baxes_for(leaf.shape[1])
            if heads_ok:
                return P(None, b, None, tp, None)
            return P(None, b, None, None, tp)  # shard hd instead
        spec = [None] * leaf.ndim
        spec[0] = baxes_for(leaf.shape[0])
        return P(*spec)

    return jax.tree_util.tree_map_with_path(visit, cache_tree)


def to_shardings(pspec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def fix_divisibility(tree, pspec_tree, mesh):
    """Drop sharding axes that do not divide their dimension.

    Explicit pjit in/out shardings must divide evenly (unlike internal
    constraints, which GSPMD pads).  For awkward extents -- vocab 256206,
    head_dim 120, batch 1 -- we keep the maximal prefix of each dim's axes
    that divides; the rest of the dim is replicated.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(leaf, spec):
        out = []
        for dim, ax in enumerate(spec):
            if ax is None:
                out.append(None)
                continue
            axes = list(ax) if isinstance(ax, tuple) else [ax]
            keep, rem = [], leaf.shape[dim]
            for a in axes:
                if rem % sizes[a] == 0:
                    keep.append(a)
                    rem //= sizes[a]
                else:
                    break
            out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
        return P(*out)

    arr_leaves, td = jax.tree_util.tree_flatten(tree)
    spec_leaves, _ = jax.tree_util.tree_flatten(
        pspec_tree, is_leaf=lambda x: isinstance(x, P))
    fixed = [fix(a, s) for a, s in zip(arr_leaves, spec_leaves)]
    return jax.tree_util.tree_unflatten(td, fixed)


def shardings_for(tree, pspec_tree, mesh):
    """Divisibility-fixed NamedShardings for ``tree``."""
    return to_shardings(fix_divisibility(tree, pspec_tree, mesh), mesh)


def validate_pspecs(params_tree, pspec_tree, mesh):
    """Every sharded dim must divide by its mesh-axes product."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    problems = []

    def visit(path, leaf, spec):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([sizes[a] for a in axes]))
            if leaf.shape[dim] % n != 0:
                problems.append(
                    f"{jax.tree_util.keystr(path)}: dim {dim} ({leaf.shape[dim]}) "
                    f"% mesh{axes}={n} != 0"
                )

    jax.tree_util.tree_map_with_path(visit, params_tree, pspec_tree)
    return problems
