"""Sharding: logical axes, production mesh, param rules."""
from .spec import NO_SHARD, ShardCtx, cs, make_ctx  # noqa: F401
from .rules import (  # noqa: F401
    fix_divisibility,
    shardings_for,
    batch_pspecs_for_mesh,
    cache_pspecs,
    params_pspecs,
    to_shardings,
    validate_pspecs,
)
