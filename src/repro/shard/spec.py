"""Sharding vocabulary shared by models and the launcher.

The model code is written against *logical* axes and applies
``with_sharding_constraint`` hints only when a ``ShardCtx`` is active --
on a bare CPU (tests, smoke runs) the hints are no-ops.

Logical axis conventions (see DESIGN.md Sec. 6):
  batch  -> ("pod", "data")   activations' batch dim; FSDP axis for params
  model  -> "model"           TP: heads / ffn hidden / experts / vocab
  seq    -> None              sequence stays unsharded (SP was considered
                              and deferred: the hillclimb cells were memory/
                              collective-bound per device, which SP does not
                              change at fixed chip count -- EXPERIMENTS §Perf)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Active logical->physical axis binding. None axes mean 'replicated'."""

    batch_axes: Optional[Tuple[str, ...]] = None  # e.g. ("pod", "data")
    model_axis: Optional[str] = None  # e.g. "model"
    enabled: bool = True
    # physical sizes of the batch/model axes (for group-local algorithms
    # like the MoE dispatch, which need the data-parallel degree)
    batch_size_product: int = 1
    model_size: int = 1

    @property
    def batch(self):
        return self.batch_axes if self.batch_axes else None

    @property
    def model(self):
        return self.model_axis


#: disabled context used by CPU tests / smoke runs
NO_SHARD = ShardCtx(enabled=False)


def make_ctx(mesh: "jax.sharding.Mesh") -> ShardCtx:
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    batch = tuple(n for n in names if n in ("pod", "data"))
    model = "model" if "model" in names else None
    bprod = 1
    for a in batch:
        bprod *= sizes[a]
    return ShardCtx(batch_axes=batch or None, model_axis=model,
                    batch_size_product=bprod, model_size=sizes.get("model", 1))


def cs(x, *spec, ctx: ShardCtx):
    """Constrain ``x`` to PartitionSpec(*spec); no-op when ctx disabled.

    Spec entries are the *logical* tokens "batch" / "model" / None, resolved
    through the context.
    """
    if not ctx.enabled:
        return x
    resolved = []
    for s in spec:
        if s == "batch":
            resolved.append(ctx.batch)
        elif s == "model":
            resolved.append(ctx.model)
        else:
            resolved.append(s)
    if all(r is None for r in resolved):
        return x  # fully replicated: constraint is a no-op (and needs no mesh)
    return jax.lax.with_sharding_constraint(x, P(*resolved))
