"""Unified event-kernel DES: one kernel, three runtime topologies.

This package replaces the three near-duplicate hand-rolled event loops
that ``core/sim.py`` grew through PR 1-4 with a single event-driven
kernel (``EventQueue`` + ``Resource`` + a shared PE process model) over
which the one-sided, two-sided, and hierarchical runtimes are
declarative topology descriptions -- see DESIGN.md Sec. 10.

Layers:

  kernel        -- EventQueue, Resource (serialization points), Engine
  one_sided / two_sided / hierarchical -- the topology engines
  fast          -- vectorized fast path for non-adaptive, unperturbed
                   runs on any topology (DESIGN.md Sec. 12)
  fast_batch    -- ``simulate_fast_many``: batched roster sweeps over a
                   shared ``SweepCache`` (DESIGN.md Sec. 15)
  telemetry     -- shared adaptive-technique noise/lag front end
  perturb       -- PE failure/churn, stragglers, speed drift scenarios
  batch         -- ``simulate_many`` process-pool prediction sweeps

``repro.core.sim`` remains the stable public API (``SimConfig`` /
``SimResult`` / ``simulate``) and delegates here; non-adaptive event
streams are pinned byte-identical to the pre-refactor implementations
by ``tests/test_sim_equivalence.py``.
"""
from .batch import estimate_batch_iters, resolve_workers, simulate_many  # noqa: F401,E501
from .fast import fast_qualifies, simulate_fast  # noqa: F401
from .fast_batch import SweepCache, simulate_fast_many  # noqa: F401
from .kernel import Engine, EventQueue, Resource  # noqa: F401
from .perturb import (  # noqa: F401
    PEFailure,
    Perturbation,
    SpeedDrift,
    Straggler,
)
from .run import ENGINES, simulate  # noqa: F401
from .telemetry import AdaptiveTelemetry, telemetry_for  # noqa: F401
