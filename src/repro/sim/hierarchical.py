"""Hierarchical topology: two-level DLS (arXiv:1903.09510's scheme).

A topology description over the kernel with ``1 + nodes`` Resources:
the global window (super-chunk claims, ``o_rma_global``) plus one
node-local window per node (``o_rma_local``), each its own
serialization point so nodes overlap.  One PE per node refills at a
time; node mates arriving mid-refill park until the super-chunk is
published -- the DES analogue of the runtime's election protocol.

Topology + level specs come from the same ``chunk_calculus`` helpers
``HierarchicalRuntime`` uses, so the simulated schedule cannot drift
from the real one.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import chunk_calculus as cc

from .kernel import Engine, Resource
from .telemetry import telemetry_for


class HierarchicalEngine(Engine):
    impl = "hierarchical"

    def __init__(self, cf):
        super().__init__(cf)
        self.nodes = cf.nodes
        self.tele = telemetry_for(cf, self.rng, inner=cf.inner_technique)
        # hot-path constants (inner claim handlers run once per sub-chunk)
        self.o_issue = cf.o_issue
        self.o_issue_local = cf.o_issue_local
        self.o_claim_net = cf.o_claim_net
        self.t_calc = cf.t_calc
        bounds, n_pes = cc.node_blocks(self.P, cf.nodes)
        self.bounds = bounds
        self.n_pes = n_pes
        self.node_of = np.searchsorted(np.array(bounds[1:]),
                                       np.arange(self.P), side="right")
        self.outer = cc.hierarchical_outer_spec(self.spec, cf.nodes)
        self._inner_specs = {}
        # Global window state (outer level)
        self.glob_i = 0
        self.glob_lp = 0
        pol = "random" if cf.lock_polling_random else "fifo"
        self.gwin = Resource(self.evq, cf.o_rma_global,
                             done_kinds={1: "g1_done", 2: "g2_done"},
                             free_kind="g_free", policy=pol, rng=self.rng)
        # Per-node state (inner level)
        self.lwin = [Resource(self.evq, cf.o_rma_local,
                              done_kinds={1: "l1_done", 2: "l2_done"},
                              free_kind="l_free", free_payload=n,
                              policy=pol, rng=self.rng)
                     for n in range(cf.nodes)]
        self.sc: list = [None] * cf.nodes  # live super-chunk per node
        self.refilling = [False] * cf.nodes
        self.node_parked = [[] for _ in range(cf.nodes)]
        self.node_done = [False] * cf.nodes
        for kind, fn in (
            ("want_l1", self._want_l1), ("l1_done", self._l1_done),
            ("want_l2", self._want_l2), ("l2_done", self._l2_done),
            ("want_g1", self._want_g1), ("g1_done", self._g1_done),
            ("want_g2", self._want_g2), ("g2_done", self._g2_done),
            ("g_free", self._g_free), ("l_free", self._l_free),
        ):
            self.on(kind, fn)

    def start(self):
        for pe in range(self.P):
            self.push(self.o_issue_local / self.speeds[pe], "want_l1", pe)

    def _inner_spec(self, node: int, size: int) -> cc.LoopSpec:
        key = (node, size)
        spec = self._inner_specs.get(key)
        if spec is None:
            spec = cc.hierarchical_inner_spec(
                self.spec, self.cf.inner_technique, self.bounds, node, size)
            self._inner_specs[key] = spec
        return spec

    # ------------------------------------------------------------------
    # drain / refill protocol
    # ------------------------------------------------------------------
    def pe_finish(self, pe, t):
        self.claim_started.pop(pe, None)
        super().pe_finish(pe, t)
        if self.plan is not None and \
                not self.plan.alive(pe, self.finish[pe]):
            self._maybe_orphan_extinct_node(self.node_of[pe], t)

    def _maybe_orphan_extinct_node(self, node: int, t: float) -> None:
        """Work never migrates across nodes -- unless a node goes extinct.

        When the last alive PE of a node dies, the undistributed
        remainder of the node's live super-chunk belongs to nobody (its
        local window has no claimers left); hand it to the cluster-wide
        re-claim pool so a survivor from another node executes it (the
        cross-node repair hand-off of the churn scenario)."""
        if self.node_done[node]:
            return  # node drained normally; nothing undistributed remains
        pes = range(self.bounds[node], self.bounds[node] + self.n_pes[node])
        if any(not self._finished[q] or self.plan.alive(q, self.finish[q])
               for q in pes):
            return  # somebody local can (or could still) pick the pool up
        s = self.sc[node]
        self.node_done[node] = True
        self.refilling[node] = False
        self.sc[node] = None
        if s is not None:
            off = min(s["lp"], s["size"])
            if off < s["size"]:
                self.add_orphan(s["start"] + off,
                                s["start"] + s["size"], t)

    def _start_refill(self, pe: int, node: int, t: float) -> None:
        """This PE refills; node mates park until the super-chunk lands."""
        if self.node_done[node]:
            self.retire(pe, t)
            return
        if self.refilling[node]:
            self.node_parked[node].append(pe)
            return
        if self.glob_lp >= self.N:  # fast path: drained, no RMWs burned
            self._drain_node(node, t)
            self.retire(pe, t)
            return
        self.refilling[node] = True
        self.push(t + self.o_issue / self.speeds[pe], "want_g1", pe)

    def _drain_node(self, node: int, t: float) -> None:
        self.node_done[node] = True
        self.refilling[node] = False
        for q in self.node_parked[node]:
            self.retire(q, t)
        self.node_parked[node].clear()

    def _want_local(self, pe: int, t: float) -> None:
        node = self.node_of[pe]
        if self.node_done[node]:
            self.retire(pe, t)
            return
        if self.sc[node] is None:
            self._start_refill(pe, node, t)
            return
        self.claim_started.setdefault(pe, t)
        self.lwin[node].enqueue(t, pe, 1, self.sc[node])

    # ------------------------------------------------------------------
    # handlers
    # ------------------------------------------------------------------
    def _want_l1(self, t, pe, payload):
        if self.plan is not None and self.claim_gate(pe, t):
            return
        self._want_local(pe, t)

    def _l1_done(self, t, pe, s):
        node = self.node_of[pe]
        i_l = s["i"]  # the super-chunk this PE claimed against
        s["i"] += 1
        if self.tele is None or self.cf.inner_technique not in cc.ADAPTIVE:
            k = cc.chunk_size_closed(
                self._inner_spec(s["node"], s["size"]), i_l,
                pe - self.bounds[node])
        else:
            self.tele.deliver(t)
            k = cc.chunk_size_closed(
                self._inner_spec(s["node"], s["size"]), i_l,
                pe - self.bounds[node], weight=self.tele.weight(pe),
                af_stats=self.tele.af_stats(pe),
                remaining=s["size"] - s["lp"])
        self.push(t + self.t_calc / self.speeds[pe], "want_l2", pe, (s, k))

    def _want_l2(self, t, pe, payload):
        self.lwin[self.node_of[pe]].enqueue(t, pe, 2, payload)

    def _l2_done(self, t, pe, payload):
        node = self.node_of[pe]
        s, k = payload
        off = s["lp"]
        s["lp"] += k
        if off >= s["size"]:
            # epoch exhausted (or stale): first discoverer clears it
            if self.sc[node] is s:
                self.sc[node] = None
            self._want_local(pe, t)
            return
        lat = t - self.claim_started.pop(pe)
        self.claim_latencies.append(lat)
        a = s["start"] + off
        b = s["start"] + min(off + k, s["size"])
        t1 = self.run_chunk(pe, a, b, t, lat)
        if t1 is not None:
            self.push(t1 + self.o_issue_local / self.speeds[pe], "want_l1", pe)

    def _want_g1(self, t, pe, payload):
        self.claim_started.setdefault(pe, t)
        self.gwin.enqueue(t, pe, 1, None)

    def _g1_done(self, t, pe, payload):
        node = self.node_of[pe]
        i_g = self.glob_i
        self.glob_i += 1
        # Weighted outer techniques consume telemetry aggregated to node
        # level (PerfModel.node_weights) -- an adaptive *outer* AF has
        # no node-level (mu, sigma), so it rides its FAC2 bootstrap.
        nw = None
        if self.tele is not None and self.spec.technique in cc.WEIGHTED:
            self.tele.deliver(t)
            nw = self.tele.node_weight(node, self.bounds)
        K = cc.chunk_size_closed(self.outer, i_g, node, weight=nw)
        self.push(t + self.o_claim_net + self.t_calc / self.speeds[pe],
                  "want_g2", pe, K)

    def _want_g2(self, t, pe, K):
        self.gwin.enqueue(t, pe, 2, K)

    def _g2_done(self, t, pe, K):
        node = self.node_of[pe]
        start = self.glob_lp
        self.glob_lp += K
        t_got = t + self.o_claim_net
        if start >= self.N:
            self._drain_node(node, t_got)
            self.retire(pe, t_got)
            return
        self.sc[node] = {"node": node, "start": start,
                         "size": min(K, self.N - start), "i": 0, "lp": 0}
        self.refilling[node] = False
        woken = [pe] + self.node_parked[node]
        self.node_parked[node].clear()
        for q in woken:
            self.push(t_got, "want_l1", q)

    def _g_free(self, t, pe, payload):
        self.gwin.grant(t)

    def _l_free(self, t, pe, node):
        self.lwin[node].grant(t)

    # ------------------------------------------------------------------
    def resume_claim(self, pe, t):
        self.push(t + self.o_issue_local / self.speeds[pe], "want_l1", pe)

    def n_rmw_global(self):
        return self.gwin.n_grants

    def n_rmw_local(self):
        return sum(w.n_grants for w in self.lwin)
