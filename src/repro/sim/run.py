"""Topology dispatch: one ``simulate`` over the unified kernel."""
from __future__ import annotations

from repro.core.sim import SimConfig, SimResult

from .hierarchical import HierarchicalEngine
from .one_sided import OneSidedEngine
from .two_sided import TwoSidedEngine

ENGINES = {
    "one_sided": OneSidedEngine,
    "two_sided": TwoSidedEngine,
    "hierarchical": HierarchicalEngine,
}


def simulate(cf: SimConfig) -> SimResult:
    """Run one configuration through its topology engine."""
    try:
        engine = ENGINES[cf.impl]
    except KeyError:
        raise ValueError(f"unknown impl {cf.impl!r}") from None
    return engine(cf).run()
