"""Topology dispatch: one ``simulate`` over the unified kernel.

``engine`` selects the execution strategy, not the physics:

* ``"auto"`` (default) -- the vectorized fast path
  (``repro.sim.fast``) when the config qualifies (non-adaptive,
  unperturbed, no trace; see ``fast_qualifies``), else the event
  kernel.  The two are equivalence-pinned by ``tests/test_sim_fast.py``
  so auto-routing never changes results.
* ``"kernel"`` -- force the event kernel (the reference
  implementation; also what every non-qualifying config runs on).
* ``"fast"`` -- force the fast path; raises for configs that do not
  qualify instead of silently approximating them.
"""
from __future__ import annotations

from repro.core.sim import SimConfig, SimResult

from .fast import fast_qualifies, simulate_fast
from .hierarchical import HierarchicalEngine
from .one_sided import OneSidedEngine
from .two_sided import TwoSidedEngine

ENGINES = {
    "one_sided": OneSidedEngine,
    "two_sided": TwoSidedEngine,
    "hierarchical": HierarchicalEngine,
}


def simulate(cf: SimConfig, engine: str = "auto",
             backend: str = "numpy") -> SimResult:
    """Run one configuration; ``engine``/``backend`` select the strategy."""
    if engine == "auto":
        if fast_qualifies(cf):
            return simulate_fast(cf, backend=backend)
    elif engine == "fast":
        return simulate_fast(cf, backend=backend)
    elif engine != "kernel":
        raise ValueError(f"unknown engine {engine!r} "
                         "(expected 'auto', 'kernel', or 'fast')")
    try:
        cls = ENGINES[cf.impl]
    except KeyError:
        raise ValueError(f"unknown impl {cf.impl!r}") from None
    return cls(cf).run()
