"""Two_Sided topology: the master-worker baseline.

A topology description over the kernel: the master's request queue is a
``Resource`` with ``policy="rank"`` (Intel MPI serves the smallest rank
first per the paper) whose server -- the non-dedicated master -- decides
when to serve via explicit ``take``.  Master service time scales with
the *master's* core speed (the asymmetry the paper measures), and the
master interleaves serving with executing its own chunks in
``master_quantum`` time slices (fine-grained ``MPI_Iprobe`` polling).

The master owns the Table-2 recurrence (``next_chunk``), so master
death is rejected by the perturbation layer; dead *workers* orphan
their in-flight remainder, which surviving workers -- or the master
itself, between serves -- re-claim.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.core import chunk_calculus as cc

from .kernel import Engine, Resource
from .telemetry import telemetry_for


class TwoSidedEngine(Engine):
    impl = "two_sided"
    drain_all_events = True  # the master may outlive every worker

    def __init__(self, cf):
        super().__init__(cf)
        spec = cf.spec
        self.m = cf.coordinator
        self.s_m = cf.speeds[self.m]
        # hot-path constants (request/serve handlers run once per claim)
        self.o_issue = cf.o_issue
        self.o_req_net = cf.o_req_net
        self.o_serve = cf.o_serve
        self.master_quantum = cf.master_quantum
        # Adaptive techniques only: telemetry lives master-side (the master
        # already serializes claims), so measurements apply at the next
        # serve with noise but no extra visibility lag.
        self.tele = telemetry_for(cf, self.rng, lag=0.0)
        # Master-side recurrence state (Table 2)
        self.R = self.N
        self.i_step = 0
        self.k_tss: Optional[int] = None
        self.batch_base: Optional[int] = None
        self.K0, self.Klast, self.S, self.C = cc.tss_constants(
            spec.N, spec.P, spec.min_chunk)
        # The request queue: smallest-rank-first, served when the master
        # decides (explicit take) -- its grant accounting is the number of
        # requests served.
        self.queue = Resource(self.evq, cf.o_serve, policy="rank")
        # Master's own work: a claimed chunk it burns down in time slices,
        # checking the queue in between.
        # [remaining_s, iters, exec_s, start, step, t_claimed]
        self.master_chunk: Optional[list] = None
        self.master_done_own = False
        self.master_busy = False
        # The master self-claims without MPI, so its first own chunk is
        # taken at t=0, *before* any worker request can arrive -- with GSS
        # this is what puts K_0 on the master core (and makes a slow master
        # catastrophic, paper Fig. 4a).
        self.master_may_claim_at = 0.0
        for kind, fn in (
            ("request_arrive", self._request_arrive),
            ("serve_done", self._serve_done),
            ("reply_arrive", self._reply_arrive),
            ("worker_done_chunk", self._worker_done_chunk),
            ("master_slice_done", self._master_slice_done),
            ("master_claimed", self._master_claimed),
            ("master_kick", self._master_kick),
        ):
            self.on(kind, fn)

    def start(self):
        # workers request at t=0 (paying issue cost); master starts at t=0
        for pe in range(self.P):
            if pe == self.m:
                continue
            self.claim_started[pe] = 0.0
            self.push(self.o_issue / self.speeds[pe]
                      + self.o_req_net / 2, "request_arrive", pe)
        self.push(0.0, "master_kick", self.m)

    # ------------------------------------------------------------------
    # master-side recurrence (Table 2)
    # ------------------------------------------------------------------
    def next_chunk(self, pe: int, now: float = 0.0):
        if self.R <= 0:
            return None
        if self.tele is not None:
            self.tele.deliver(now)
        spec = self.spec
        t_, Pn, N, R = spec.technique, spec.P, self.N, self.R
        if t_ == "static":
            k = int(math.ceil(N / Pn))
        elif t_ == "ss":
            k = spec.min_chunk
        elif t_ == "gss":
            k = max(int(math.ceil(R / Pn)), spec.min_chunk)
        elif t_ == "tss":
            self.k_tss = self.K0 if self.k_tss is None \
                else max(self.k_tss - self.C, self.Klast)
            k = self.k_tss
        elif t_ in cc.FAC_FAMILY:
            # batch bookkeeping advances on every claim of the family, so a
            # telemetry-less bootstrap claim never reads a stale/None base
            if self.i_step % Pn == 0:
                self.batch_base = max(int(math.ceil(R / (2.0 * Pn))),
                                      spec.min_chunk)
            stats = self.tele.af_stats(pe) if t_ == "af" and \
                self.tele is not None else None
            if stats is not None:
                k = cc.af_chunk_size(stats, R, spec.min_chunk)
            else:  # includes AF's telemetry-less bootstrap
                k = self.batch_base
                if t_ in cc.WEIGHTED:
                    w = self.tele.weight(pe) if self.tele is not None else None
                    if w is None:
                        w = spec.weight(pe)
                    k = max(int(math.ceil(w * self.batch_base)),
                            spec.min_chunk)
        elif t_ == "tfss":
            if self.i_step % Pn == 0:
                first = self.K0 - self.i_step * self.C
                mean = first - (Pn - 1) / 2.0 * self.C
                self.batch_base = max(int(math.ceil(mean)), self.Klast)
            k = self.batch_base
        else:
            raise AssertionError(t_)
        k = min(k, R)
        start = N - R
        self.R -= k
        self.i_step += 1
        return start, k

    # ------------------------------------------------------------------
    # master state machine
    # ------------------------------------------------------------------
    def _kick(self, now: float) -> None:
        """Master picks its next action.  Called whenever it may be free."""
        if self.master_busy:
            return
        # 1) serve pending requests first (smallest rank, per Intel MPI)
        if self.queue.pending():
            rank, t_arr = self.queue.take()
            dt = self.o_serve / self.s_m
            self.serve_time += dt
            self.master_busy = True
            res = self.next_chunk(rank, now)
            self.push(now + dt, "serve_done", rank, res)
            return
        # 2) own work: burn one time quantum
        if self.master_chunk is not None:
            dt = min(self.master_quantum, self.master_chunk[0])
            self.master_chunk[0] -= dt
            self.master_busy = True
            self.push(now + dt, "master_slice_done", self.m, None)
            return
        # 2b) perturbation layer: an orphaned remainder outranks a fresh
        # own-claim (the recovery hand-off needs no recurrence step)
        if self.plan is not None and self._orphans:
            a, b = self._orphans.pop(0)
            exec_t = self.exec_time(self.m, a, b, now)
            self.n_claims += 1
            self.iters[self.m] += b - a
            self.master_chunk = [exec_t, b - a, exec_t, a,
                                 self.n_claims - 1, now]
            self.master_busy = True
            self.push(now, "master_claimed", self.m, None)
            return
        if not self.master_done_own and now >= self.master_may_claim_at:
            res = self.next_chunk(self.m, now)
            if res is None:
                self.master_done_own = True
                self.finish[self.m] = max(self.finish[self.m], now)
            else:
                self.n_claims += 1
                start, k = res
                self.iters[self.m] += k
                exec_t = self.exec_time(self.m, start, start + k, now)
                self.master_chunk = [exec_t, k, exec_t, start,
                                     self.n_claims - 1, now]
                dt = self.cf.t_calc / self.s_m
                self.master_busy = True
                self.push(now + dt, "master_claimed", self.m, None)
            return
        if not self.master_done_own and now < self.master_may_claim_at:
            # poll again once the issue window has passed
            self.push(self.master_may_claim_at, "master_kick", self.m)
        # 3) idle: wake on next request arrival (event-driven)

    # ------------------------------------------------------------------
    # handlers
    # ------------------------------------------------------------------
    def _request_arrive(self, t, pe, payload):
        self.queue.put((pe, t))
        self._kick(t)

    def _serve_done(self, t, pe, res):
        self.master_busy = False
        self.push(t + self.o_req_net / 2, "reply_arrive", pe, res)
        self._kick(t)

    def _reply_arrive(self, t, pe, payload):
        lat = t - self.claim_started.pop(pe)
        self.claim_latencies.append(lat)
        if payload is None:
            self.retire(pe, t)
            return
        start, k = payload
        t1 = self.run_chunk(pe, start, start + k, t, lat)
        if t1 is not None:
            self.push(t1, "worker_done_chunk", pe)

    def _worker_done_chunk(self, t, pe, payload):
        if self.plan is not None and self.claim_gate(pe, t):
            return
        self.claim_started[pe] = t
        self.push(t + self.o_issue / self.speeds[pe]
                  + self.o_req_net / 2, "request_arrive", pe)

    def _master_slice_done(self, t, pe, payload):
        self.master_busy = False
        mc = self.master_chunk
        if mc[0] <= 1e-15:
            if self.trace is not None:
                # t0 is claim time: master chunks interleave with serving,
                # so t1 - t0 >= exec_s (the serve slices are inside).
                self.trace.append({"pe": self.m, "step": mc[4],
                                   "start": mc[3], "size": mc[1],
                                   "t0": mc[5], "t1": t, "lat": 0.0})
            if self.tele is not None:
                self.tele.observe(self.m, mc[1], mc[2], 0.0, t)
            self.master_chunk = None
            self.finish[self.m] = t
        self._kick(t)

    def _master_claimed(self, t, pe, payload):
        self.master_busy = False
        self._kick(t)

    def _master_kick(self, t, pe, payload):
        self._kick(t)

    # ------------------------------------------------------------------
    # perturbation hooks
    # ------------------------------------------------------------------
    def add_orphan(self, a, b, t):
        super().add_orphan(a, b, t)
        # the idle master is event-driven: poke it so it can re-claim
        self.push(t, "master_kick", self.m)

    def resume_claim(self, pe, t):
        self.claim_started[pe] = t
        self.push(t + self.o_issue / self.speeds[pe]
                  + self.o_req_net / 2, "request_arrive", pe)
