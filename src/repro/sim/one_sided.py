"""One_Sided topology: distributed chunk calculation over passive RMA.

The paper's protocol as a topology description over the kernel: one
``Resource`` (the coordinator's window -- its NIC is the serialization
point, so RMW service does **not** depend on the coordinator core's
speed), and a three-state PE machine:

    want_rmw1 -> rmw1_done (step counter + local chunk calculation)
    want_rmw2 -> rmw2_done (loop pointer; execute [lp, lp+K))

Chunk calculations of different PEs overlap in time (paper Fig. 3);
Lock-Polling fairness is the window's ``policy="random"`` grant.
"""
from __future__ import annotations

from repro.core import chunk_calculus as cc

from .kernel import Engine, Resource
from .telemetry import telemetry_for


class OneSidedEngine(Engine):
    impl = "one_sided"

    def __init__(self, cf):
        super().__init__(cf)
        self.tele = telemetry_for(cf, self.rng)
        # hot-path constants (claim handlers run once per scheduling step)
        self.o_issue = cf.o_issue
        self.o_claim_net = cf.o_claim_net
        self.t_calc = cf.t_calc
        # Window state (the two shared integers of the paper)
        self.glob_i = 0
        self.glob_lp = 0
        self.window = Resource(
            self.evq, cf.o_rma,
            done_kinds={1: "rmw1_done", 2: "rmw2_done"},
            free_kind="win_free",
            policy="random" if cf.lock_polling_random else "fifo",
            rng=self.rng)
        self.on("want_rmw1", self._want_rmw1)
        self.on("rmw1_done", self._rmw1_done)
        self.on("want_rmw2", self._want_rmw2)
        self.on("rmw2_done", self._rmw2_done)
        self.on("win_free", self._win_free)

    def start(self):
        # All PEs start by claiming at t=0 (paying their issue cost first)
        for pe in range(self.P):
            self.push(self.o_issue / self.speeds[pe], "want_rmw1", pe)

    # ------------------------------------------------------------------
    def _want_rmw1(self, t, pe, payload):
        if self.plan is not None and self.claim_gate(pe, t):
            return
        if self.glob_lp >= self.N:  # fast-path exit (stale-read safe)
            self.retire(pe, t)
            return
        self.claim_started[pe] = t
        # grants only if the window is free *now*; otherwise the pending
        # win_free event picks a (random) waiter -- Lock-Polling fairness
        self.window.enqueue(t, pe, 1, None)

    def _rmw1_done(self, t, pe, payload):
        i_local = self.glob_i
        self.glob_i += 1
        # Step 2: local closed-form chunk calculation (overlaps other PEs)
        if self.tele is None:
            k = cc.chunk_size_closed(self.spec, i_local, pe)
        else:
            self.tele.deliver(t)
            k = cc.chunk_size_closed(
                self.spec, i_local, pe, weight=self.tele.weight(pe),
                af_stats=self.tele.af_stats(pe),
                remaining=self.N - self.glob_lp)
        t_ready = t + self.o_claim_net + self.t_calc / self.speeds[pe]
        self.push(t_ready, "want_rmw2", pe, k)

    def _want_rmw2(self, t, pe, k):
        self.window.enqueue(t, pe, 2, k)

    def _rmw2_done(self, t, pe, k):
        start = self.glob_lp
        self.glob_lp += k
        t_got = t + self.o_claim_net
        lat = t_got - self.claim_started.pop(pe)
        self.claim_latencies.append(lat)
        if start >= self.N:
            self.retire(pe, t_got)
            return
        stop = min(start + k, self.N)
        t1 = self.run_chunk(pe, start, stop, t_got, lat)
        if t1 is not None:
            self.push(t1 + self.o_issue / self.speeds[pe], "want_rmw1", pe)

    def _win_free(self, t, pe, payload):
        self.window.grant(t)

    # ------------------------------------------------------------------
    def resume_claim(self, pe, t):
        self.push(t + self.o_issue / self.speeds[pe], "want_rmw1", pe)

    def n_rmw_global(self):
        return self.window.n_grants
