"""Adaptive-technique telemetry for the DES (af / awf_b..e).

The kernel drives the *same* weight models the runtime policies use
(``core/weights.py``), feeding them noise-perturbed, lag-delayed
observations on the virtual clock -- so simulated and real adaptation
can never use different math.  Shared by every topology: the old
triplicated loops each carried their own copy of this wiring.
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
from typing import List, Optional

from repro.core import chunk_calculus as cc


def make_adaptive_model(technique: str, P: int):
    from repro.core.weights import AdaptiveFactoringModel, AdaptiveWeightModel

    if technique == "af":
        return AdaptiveFactoringModel(P)
    update, overhead = cc.AWF_VARIANTS[technique]
    return AdaptiveWeightModel(P, update=update, include_overhead=overhead)


class AdaptiveTelemetry:
    """Noise + adaptation-lag front end over an adaptive weight model.

    ``observe`` queues a completed chunk's measurement (compute time
    perturbed by lognormal noise with c.o.v. ``o_meas_cov``); ``deliver``
    feeds the model every observation that has become visible by ``now``
    (completion + ``o_adapt_lag``) -- the DES analogue of telemetry RMWs
    propagating through the window before claimers can read them.
    """

    def __init__(self, model, cov: float, lag: float, rng: random.Random):
        self.model = model
        self.lag = lag
        self.rng = rng
        self.sig = math.sqrt(math.log(1.0 + cov * cov)) if cov > 0 else 0.0
        self._heap: List[tuple] = []
        self._seq = itertools.count()

    def observe(self, pe: int, iters: int, exec_t: float, sched_t: float,
                t_done: float) -> None:
        if iters <= 0:
            return
        sec = exec_t
        if self.sig:
            sec *= self.rng.lognormvariate(-0.5 * self.sig * self.sig, self.sig)
        heapq.heappush(self._heap,
                       (t_done + self.lag, next(self._seq), pe, iters, sec,
                        sched_t))

    def deliver(self, now: float) -> None:
        while self._heap and self._heap[0][0] <= now:
            _, _, pe, iters, sec, sched = heapq.heappop(self._heap)
            self.model.record(pe, iters, sec, sched)

    # -- claim-time lookups -------------------------------------------------
    def weight(self, pe: int) -> Optional[float]:
        return self.model.weight(pe)

    def af_stats(self, pe: int):
        fn = getattr(self.model, "af_stats", None)
        return fn(pe) if fn is not None else None

    def node_weight(self, node: int, bounds) -> Optional[float]:
        return self.model.node_weight(node, bounds)


def telemetry_for(cf, rng: random.Random,
                  inner: Optional[str] = None,
                  lag: Optional[float] = None) -> Optional[AdaptiveTelemetry]:
    """A telemetry front end if any scheduling level is adaptive, else None.

    When both levels are adaptive the *inner* (per-PE claim) technique
    picks the model -- claims are per-PE; the outer level only consumes the
    node-aggregated weights, which every model exposes.  ``lag`` overrides
    ``o_adapt_lag`` (the two-sided engine passes 0: telemetry is
    master-local, no window traversal to wait for).
    """
    names = [t for t in (inner, cf.spec.technique) if t in cc.ADAPTIVE]
    if not names:
        return None
    return AdaptiveTelemetry(make_adaptive_model(names[0], cf.spec.P),
                             cf.o_meas_cov,
                             cf.o_adapt_lag if lag is None else lag, rng)
