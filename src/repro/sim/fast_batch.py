"""Batched multi-candidate fast-path sweeps (DESIGN.md Sec. 15).

``replay.sweep`` evaluates a technique x runtime roster whose
candidates all reference the *same* empirical cost array, the same
speed vector, and -- across the three runtimes of one technique -- the
same ``LoopSpec``.  Run one at a time, each ``simulate_fast`` call
re-cumsums that shared workload, re-lists the speeds, and rebuilds the
technique's chunk table from scratch: for a 24-candidate roster at
P=1024 the duplicated setup work rivals the replays themselves.

``simulate_fast_many`` runs the roster through one ``SweepCache``:

* **Workload prefix sums** are computed once per distinct cost array
  (keyed by object identity, with the array reference pinned so the id
  cannot be recycled under the cache) and shared by every candidate --
  both the ndarray the one-sided vector round consumes and the Python
  list the serial interpreters index.
* **Speed vectors** likewise: one float-list + ndarray pair per
  distinct speeds object.
* **Chunk-sequence tables** (``fast._chunk_fns``) are keyed by the
  frozen ``LoopSpec`` itself, so the three runtime variants of one
  technique share a single table build.

Sharing setup does not change a single float: each candidate still
replays through the per-config interpreters, so batched results are
byte-identical to per-config ``simulate_fast`` -- which is itself
pinned byte-identical to the event kernel.  Per-candidate *hazard
demotion* is inherited from the interpreters: a one-sided candidate
that hits a tie/near-EPS hazard drops out of the vector round to its
serial cooldown without affecting its batch peers, and a non-qualifying
candidate (adaptive, perturbed, traced) is demoted to the event kernel
while the rest stay on the cache.

The cache is also the serving loop's warm-start handle: a persistent
``SweepCache`` carried across ``reselect_every_s`` ticks makes a
re-selection a re-rank over already-built tables rather than a rebuild
(``serve.scenarios``).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .fast import _chunk_fns, fast_qualifies, simulate_fast
from .run import simulate

__all__ = ["SweepCache", "simulate_fast_many"]


class SweepCache:
    """Shared per-sweep setup: prefix sums, speed vectors, chunk tables.

    Identity-keyed entries pin the keyed object itself, so an id cannot
    be garbage-collected and recycled while its entry lives; an
    eviction cap bounds the footprint of long-lived caches (the serving
    loop holds one across re-selection ticks, each tick bringing a
    fresh window's cost array).
    """

    def __init__(self, max_entries: int = 8):
        self.max_entries = max_entries
        self._pref: Dict[int, tuple] = {}     # id(costs) -> (ref, arr, list)
        self._speeds: Dict[int, tuple] = {}   # id(speeds) -> (ref, list, arr)
        self._chunk: Dict[object, tuple] = {}  # LoopSpec -> (scalar, vector)

    def pref(self, costs) -> Tuple[np.ndarray, list]:
        """(prefix-sum ndarray, prefix-sum list) for a cost array."""
        hit = self._pref.get(id(costs))
        if hit is not None and hit[0] is costs:
            return hit[1], hit[2]
        arr = np.concatenate([[0.0], np.cumsum(costs)])
        entry = (costs, arr, arr.tolist())
        if len(self._pref) >= self.max_entries:
            self._pref.pop(next(iter(self._pref)))
        self._pref[id(costs)] = entry
        return entry[1], entry[2]

    def speeds(self, speeds) -> Tuple[list, np.ndarray]:
        """(float list, float64 ndarray) for a speed vector."""
        hit = self._speeds.get(id(speeds))
        if hit is not None and hit[0] is speeds:
            return hit[1], hit[2]
        entry = (speeds, [float(x) for x in speeds],
                 np.asarray(speeds, dtype=np.float64))
        if len(self._speeds) >= self.max_entries:
            self._speeds.pop(next(iter(self._speeds)))
        self._speeds[id(speeds)] = entry
        return entry[1], entry[2]

    def chunk_fns(self, spec):
        """(scalar, vector) chunk evaluators, shared across runtimes."""
        try:
            hit = self._chunk.get(spec)
        except TypeError:  # unhashable spec variant: build uncached
            return _chunk_fns(spec)
        if hit is None:
            hit = _chunk_fns(spec)
            if len(self._chunk) >= 4 * self.max_entries:
                self._chunk.pop(next(iter(self._chunk)))
            self._chunk[spec] = hit
        return hit


def simulate_fast_many(configs: Sequence, *, engine: str = "auto",
                       backend: str = "numpy",
                       budget_s: Optional[float] = None,
                       cache: Optional[SweepCache] = None,
                       info: Optional[dict] = None) -> List:
    """Simulate a candidate roster through one shared ``SweepCache``.

    Results align with ``configs``.  Qualifying candidates replay on
    the fast path sharing the cache; with ``engine="auto"`` the rest
    are demoted to the event kernel, with ``engine="fast"`` a
    non-qualifying candidate raises (mirroring ``simulate``).

    ``budget_s`` keeps the serial budget contract of ``simulate_many``:
    the first candidate is always evaluated, later candidates are
    dropped (``None``) once the wall clock runs out.

    ``info``, when given, gains ``info["engines"]``: per-candidate
    labels aligned with ``configs`` -- ``"fast-batch"`` (fast path over
    the shared cache), ``"kernel"`` (demoted), or ``None`` (dropped on
    budget).
    """
    if engine not in ("auto", "fast"):
        raise ValueError(f"unknown engine {engine!r} "
                         "(expected 'auto' or 'fast')")
    configs = list(configs)
    results: List = [None] * len(configs)
    engines: List[Optional[str]] = [None] * len(configs)
    if cache is None:
        cache = SweepCache()
    deadline = None if budget_s is None else time.monotonic() + budget_s
    for i, cf in enumerate(configs):
        if i and deadline is not None and time.monotonic() > deadline:
            break  # budget spent: keep what's already evaluated
        if fast_qualifies(cf):
            results[i] = simulate_fast(cf, backend=backend, cache=cache)
            engines[i] = "fast-batch"
        elif engine == "fast":
            raise ValueError(
                f"candidate {i} ({cf.spec.technique}/{cf.impl}) does not "
                "qualify for the fast path; use engine='auto' for "
                "automatic kernel demotion")
        else:
            results[i] = simulate(cf, engine="kernel")
            engines[i] = "kernel"
    if info is not None:
        info["engines"] = engines
    return results
