"""Scenario layer: perturbations every topology inherits from the kernel.

The triplicated pre-refactor event loops could not express runtime
faults without three parallel edits; the unified kernel applies these
uniformly in its shared chunk-execution path, so one_sided, two_sided,
and hierarchical all support them by construction:

* ``PEFailure`` -- the PE dies at a virtual time.  Iterations of its
  in-flight chunk that finished before death stay executed; the
  remainder is **orphaned** and re-claimed by a surviving PE (the
  recovery handoff bypasses the window -- a direct repair transfer, the
  DES analogue of the FT re-claim protocol).  Death models *compute*
  failure only: the passive-target window has no CPU in the loop (the
  paper's point), so a dead coordinator's window keeps serving RMWs.
  The two-sided master is the one PE that may not die (it owns the
  recurrence); ``simulate`` rejects such scenarios.
* ``Straggler`` -- a transient slowdown: the PE runs at ``factor`` of
  its configured speed inside ``[at, until)``.
* ``SpeedDrift`` -- smooth sinusoidal per-PE speed variation (period,
  amplitude, per-PE phase), the time-varying heterogeneity scenario of
  the adaptive-technique studies.

Speed effects are sampled at chunk start (chunk-granular drift -- the
same granularity at which the adaptive techniques can observe it).
Conservation (every iteration executed exactly once) holds under any
survivable scenario and is pinned by ``tests/test_invariants.py``;
``SimConfig.perturbations=None`` compiles to no plan and leaves event
streams byte-identical to the unperturbed simulator.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Perturbation:
    """Marker base class for DES scenario perturbations."""


@dataclass(frozen=True)
class PEFailure(Perturbation):
    """PE ``pe`` dies at virtual time ``at`` (its in-flight remainder is
    orphaned and re-claimed by a survivor)."""

    pe: int
    at: float


@dataclass(frozen=True)
class Straggler(Perturbation):
    """PE ``pe`` runs at ``factor`` of its speed inside ``[at, until)``."""

    pe: int
    at: float
    factor: float = 0.25
    until: float = math.inf


@dataclass(frozen=True)
class SpeedDrift(Perturbation):
    """Sinusoidal per-PE speed drift: ``1 + amplitude*sin(2pi(t/period +
    pe/P))`` -- PEs are phase-shifted so the cluster's aggregate speed
    stays roughly constant while individual ranks trade places."""

    amplitude: float = 0.3
    period: float = 60.0


class PerturbationPlan:
    """Compiled scenario state the kernel consults on its hot paths."""

    __slots__ = ("death", "stragglers", "drifts", "P", "_plain_speed")

    def __init__(self, death: np.ndarray, stragglers: Tuple[Straggler, ...],
                 drifts: Tuple[SpeedDrift, ...], P: int):
        self.death = death
        self.stragglers = stragglers
        self.drifts = drifts
        self.P = P
        self._plain_speed = not stragglers and not drifts

    def speed_factor(self, pe: int, t: float) -> float:
        """Multiplicative speed factor for ``pe`` at virtual time ``t``."""
        if self._plain_speed:
            return 1.0
        f = 1.0
        for s in self.stragglers:
            if s.pe == pe and s.at <= t < s.until:
                f *= s.factor
        for d in self.drifts:
            f *= 1.0 + d.amplitude * math.sin(
                2.0 * math.pi * (t / d.period + pe / self.P))
        return f

    def alive(self, pe: int, t: float) -> bool:
        return t < self.death[pe]


def compile_plan(cf) -> Optional[PerturbationPlan]:
    """Validate + compile ``cf.perturbations``; None when there are none."""
    ps = cf.perturbations
    if not ps:
        return None
    P = cf.spec.P
    death = np.full(P, math.inf)
    stragglers, drifts = [], []
    for p in ps:
        if isinstance(p, PEFailure):
            if not 0 <= p.pe < P:
                raise ValueError(f"PEFailure.pe {p.pe} outside [0, {P})")
            if p.at < 0:
                raise ValueError("PEFailure.at must be >= 0")
            death[p.pe] = min(death[p.pe], p.at)
        elif isinstance(p, Straggler):
            if not 0 <= p.pe < P:
                raise ValueError(f"Straggler.pe {p.pe} outside [0, {P})")
            if not 0.0 < p.factor:
                raise ValueError("Straggler.factor must be > 0")
            stragglers.append(p)
        elif isinstance(p, SpeedDrift):
            if not 0.0 <= p.amplitude < 1.0:
                raise ValueError("SpeedDrift.amplitude must be in [0, 1)")
            if p.period <= 0:
                raise ValueError("SpeedDrift.period must be > 0")
            drifts.append(p)
        else:
            raise TypeError(f"unknown perturbation {p!r}")
    if np.isfinite(death).all():
        raise ValueError(
            "scenario kills every PE; at least one must survive to re-claim "
            "orphaned work (conservation would be impossible)")
    if cf.impl == "two_sided" and np.isfinite(death[cf.coordinator]):
        raise ValueError(
            "two_sided master death is not supported: the master owns the "
            "scheduling recurrence (this asymmetry is the paper's point -- "
            "one_sided/hierarchical tolerate any PE death)")
    return PerturbationPlan(death, tuple(stragglers), tuple(drifts), P)
