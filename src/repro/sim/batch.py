"""``simulate_many``: batched prediction sweeps with process fan-out.

``replay.predict`` used to evaluate its technique x runtime roster one
``simulate()`` at a time in roster order; this module fans the whole
roster out over a process pool instead.  Configs are shipped to the
workers **once** via the pool initializer -- under the default ``fork``
start method the shared cost arrays (every candidate of a sweep
references the *same* empirical-workload array) reach the children by
copy-on-write, not per-task pickling.

The parallel path returns exactly what the serial path returns: each
candidate is an independently seeded DES run, so results are
reproducible regardless of worker count (pinned by
``tests/test_sim_equivalence.py``).  A wall-clock budget translates to
"keep every candidate that finished in time" (at least the first one is
always kept), mirroring the old roster-order budget semantics; dropped
candidates come back as ``None``.
"""
from __future__ import annotations

import multiprocessing
import os
import sys
import threading
import time
from concurrent.futures import ProcessPoolExecutor, wait
from concurrent.futures.process import BrokenProcessPool
from typing import List, Optional, Sequence, Union

from .fast import fast_qualifies, simulate_fast
from .fast_batch import SweepCache, simulate_fast_many
from .run import simulate

# Worker-side shared state, installed once per pool worker (fork: COW).
_SHARED_CONFIGS: Optional[list] = None
_SHARED_ENGINE: str = "auto"
_SHARED_CACHE: Optional[SweepCache] = None


def _pool_init(configs: list, engine: str = "auto") -> None:
    global _SHARED_CONFIGS, _SHARED_ENGINE, _SHARED_CACHE
    _SHARED_CONFIGS = configs
    _SHARED_ENGINE = engine
    # Worker-local sweep cache: tasks landing on the same worker share
    # prefix sums / chunk tables (the shared cost array is COW-identical
    # across the forked configs, so identity keying still hits).
    _SHARED_CACHE = SweepCache()


def _pool_run(i: int):
    cf = _SHARED_CONFIGS[i]
    if _SHARED_ENGINE != "kernel" and fast_qualifies(cf):
        return simulate_fast(cf, cache=_SHARED_CACHE)
    return simulate(cf, engine=_SHARED_ENGINE)


def _pool_context(explicit: bool):
    """Pick a start method; None means "no pool" (caller runs serial).

    ``fork`` is the fast path -- configs (and the cost array every sweep
    candidate shares) reach workers by copy-on-write, no pickling -- and
    is used whenever it is provably safe: fork available, parent still
    single-threaded, no JAX runtime loaded (forking a multithreaded
    parent can deadlock on locks held by other threads).

    When fork is unsafe, ``spawn`` is used only if the caller asked for
    parallelism *explicitly* (``workers=`` an int or "auto") and the
    parent's ``__main__`` is importable: spawn re-imports it, so an
    unguarded top-level script would re-execute (and multiprocessing's
    recursion guard then wedges the pool).  The adaptive default never
    takes that risk -- in multithreaded parents it stays serial.
    Spawn workers re-import only ``repro.sim``'s numpy-level dependency
    chain (JAX is lazily imported elsewhere and never loads in workers)
    and receive the configs pickled once per worker.
    """
    fork_ok = "fork" in multiprocessing.get_all_start_methods()
    if fork_ok and threading.active_count() == 1 \
            and "jax" not in sys.modules:
        return multiprocessing.get_context("fork")
    if not explicit:
        return None
    main_file = getattr(sys.modules.get("__main__"), "__file__", None)
    if main_file is None or os.path.exists(main_file):
        return multiprocessing.get_context("spawn")
    return None


#: Adaptive-parallelism floor (``workers=None``): total simulated
#: iterations across the batch below which pool startup (~hundreds of ms)
#: would outweigh the fan-out -- small selection sweeps (``technique=
#: "auto"`` subsamples to ~4k iterations/candidate) stay in-process.
PARALLEL_MIN_ITERS = 500_000

#: Pool-startup amortization bound for the adaptive default: spinning a
#: process pool up costs a few hundred ms, so an adaptive sweep whose
#: wall-clock budget is below this can only lose by fanning out.
POOL_STARTUP_S = 0.5

#: Fast-path work discount for the adaptive guard: a fast-qualifying
#: candidate costs roughly an order of magnitude less wall-clock per
#: simulated iteration than a kernel-bound one, so counting its
#: iterations at face value overestimates the batch and spins up pools
#: that can only lose (the ``technique="auto"`` selection sweep is
#: all-fast after subsampling and should stay in-process).
FAST_DISCOUNT = 8


def estimate_batch_iters(configs: Sequence, engine: str = "auto") -> int:
    """Kernel-equivalent iteration estimate for the adaptive pool guard.

    Counts each candidate's *actual* cost-array length (what the DES
    replays -- under ``max_sim_iters`` subsampling this is the
    subsampled workload), discounted by ``FAST_DISCOUNT`` for
    candidates that will route to the vectorized fast path.
    """
    total = 0
    for cf in configs:
        n = len(cf.costs)
        if engine != "kernel" and fast_qualifies(cf):
            n //= FAST_DISCOUNT
        total += n
    return total


def resolve_workers(workers: Union[int, str, None], n_tasks: int,
                    total_iters: int = 0,
                    budget_s: Optional[float] = None) -> int:
    """Effective worker count.

    "auto" fills the machine (capped at the task count).  None is the
    adaptive default: fill the machine only when the batch is big
    enough (``PARALLEL_MIN_ITERS`` simulated iterations) *and* any
    wall-clock budget is large enough (``POOL_STARTUP_S``) to amortize
    pool startup, else run serial.  <=1 forces serial.  An explicit
    int or "auto" bypasses both adaptive guards.
    """
    if workers is None:
        if total_iters < PARALLEL_MIN_ITERS:
            return 1
        if budget_s is not None and budget_s < POOL_STARTUP_S:
            return 1
        workers = "auto"
    if workers == "auto":
        workers = os.cpu_count() or 1
    return max(min(int(workers), n_tasks), 1)


def simulate_many(configs: Sequence, workers: Union[int, str, None] = None,
                  budget_s: Optional[float] = None,
                  engine: str = "auto",
                  cache: Optional[SweepCache] = None,
                  info: Optional[dict] = None) -> List:
    """Simulate every config; returns results aligned with ``configs``.

    workers: None = adaptive (process pool when the batch is big enough
        to amortize startup, else serial); "auto" = always one process
        per core (capped at the number of configs); 0/1 = serial.
    budget_s: wall-clock budget.  Serial: evaluate in order until the
        budget is spent.  Parallel: keep every candidate that completed
        within the budget; candidates still running when it expires are
        abandoned to finish in the background.  Either way the first
        config is always evaluated, and dropped candidates are ``None``
        in the result.
    engine: per-config execution strategy ("auto" routes qualifying
        configs to the vectorized fast path; routing never changes
        results).
    cache: optional ``SweepCache`` for the serial batched path --
        candidates sharing cost/speed arrays share their prefix sums
        and chunk tables (``simulate_fast_many``); callers running
        repeated sweeps (the serving loop) pass a persistent one.
    info: optional dict; gains ``info["engines"]``, per-candidate
        labels aligned with ``configs`` (``"fast-batch"``/``"fast"``/
        ``"kernel"``, ``None`` for budget-dropped candidates).
    """
    configs = list(configs)
    results: List = [None] * len(configs)
    if not configs:
        if info is not None:
            info["engines"] = []
        return results
    n = resolve_workers(workers, len(configs),
                        estimate_batch_iters(configs, engine),
                        budget_s=budget_s)
    if (n <= 1 or len(configs) == 1) and engine != "kernel":
        # Serial sweeps run batched: one shared SweepCache across the
        # roster (byte-identical to per-config runs, pinned by
        # tests/test_sim_fast.py).
        return simulate_fast_many(configs, engine=engine,
                                  budget_s=budget_s, cache=cache, info=info)
    if n <= 1 or len(configs) == 1:
        deadline = None if budget_s is None else time.monotonic() + budget_s
        engines: List[Optional[str]] = [None] * len(configs)
        for i, cf in enumerate(configs):
            if i and deadline is not None and time.monotonic() > deadline:
                break  # budget spent: keep what's already evaluated
            results[i] = simulate(cf, engine=engine)
            engines[i] = "kernel"
        if info is not None:
            info["engines"] = engines
        return results
    ctx = _pool_context(explicit=workers is not None)
    if ctx is None:
        return simulate_many(configs, workers=1, budget_s=budget_s,
                             engine=engine, cache=cache, info=info)
    try:
        ex = ProcessPoolExecutor(max_workers=n, mp_context=ctx,
                                 initializer=_pool_init,
                                 initargs=(configs, engine))
    except (OSError, PermissionError):  # no subprocesses: degrade to serial
        return simulate_many(configs, workers=1, budget_s=budget_s,
                             engine=engine, cache=cache, info=info)
    # The budget clock covers the whole sweep, first candidate included
    # (like the serial branch -- candidate 0 is merely exempt from being
    # dropped, not from being timed).
    deadline = None if budget_s is None else time.monotonic() + budget_s
    try:
        futs = [ex.submit(_pool_run, i) for i in range(len(configs))]
        results[0] = futs[0].result()  # >= 1 candidate always evaluated
        timeout = None if deadline is None \
            else max(deadline - time.monotonic(), 0.0)
        wait(futs, timeout=timeout)
    except BrokenProcessPool:  # workers died (sandbox, OOM): go serial
        ex.shutdown(wait=False, cancel_futures=True)
        return simulate_many(configs, workers=1, budget_s=budget_s,
                             engine=engine, cache=cache, info=info)
    # Snapshot what finished inside the budget *before* shutdown: running
    # candidates cannot be interrupted, so on a blown budget they are
    # abandoned (shutdown(wait=False) -- they burn down in the background)
    # and reported as None rather than silently blocking the sweep until
    # the slowest one completes.
    done_in_time = [f.done() for f in futs]
    ex.shutdown(wait=deadline is None, cancel_futures=True)
    for i, f in enumerate(futs):
        if results[i] is None and done_in_time[i] and not f.cancelled():
            results[i] = f.result()
    if info is not None:
        # Routing is deterministic (fast_qualifies), so the labels the
        # workers acted on can be reconstructed parent-side.
        info["engines"] = [
            None if results[i] is None else
            ("fast" if engine != "kernel" and fast_qualifies(cf)
             else "kernel")
            for i, cf in enumerate(configs)]
    return results
