"""The unified DES event kernel all three runtime topologies run on.

Before this package existed, ``core/sim.py`` carried three hand-rolled
event loops (one-sided / two-sided / hierarchical) that each
re-implemented the event heap, serialization-point queuing, telemetry
delivery, and trace emission.  The kernel factors those four planes out
once:

* ``EventQueue`` -- a seeded-deterministic heap of ``(time, seq, kind,
  pe, payload)`` events; ``seq`` is a single monotone counter so ties
  break in push order (the property every equivalence pin rests on).
* ``Resource`` -- one serialization point: a service latency, a waiter
  queue, a grant policy, and grant accounting.  The paper's global RMA
  window, each hierarchical node-local window, and the two-sided
  master's request queue are all instances -- ``policy="random"`` is
  Intel MPI's Lock-Polling fairness (grant a *random* waiter, paper
  Sec. 5), ``"fifo"`` is deterministic polling, ``"rank"`` is the
  master's smallest-rank-first ``MPI_Iprobe`` service order.
* ``Engine`` -- the shared PE process model: prefix-summed costs, one
  ``run_chunk`` execution path (trace emission + telemetry feed +
  perturbation handling), the drain/retire bookkeeping, and the result
  assembly.  Topologies subclass it and declare handlers per event
  kind; they own only their protocol state machines.

Because the perturbation layer (``repro.sim.perturb``) lives in the
kernel's shared paths -- ``run_chunk`` for death/straggler/drift,
``claim_gate``/``retire`` for orphan re-claim -- every topology
inherits every scenario with zero per-topology code beyond its
``resume_claim`` re-entry point.

With ``SimConfig.perturbations=None`` every perturbation hook is
compiled out (``plan is None`` guards), and the kernel's event streams
are **byte-identical** to the pre-refactor triplicated loops -- pinned
against golden fixtures in ``tests/test_sim_equivalence.py``.
"""
from __future__ import annotations

import heapq
import itertools
import random
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.sim import SimResult

from .perturb import compile_plan

#: Busy-window guard: a resource whose service ends exactly "now" is free.
EPS = 1e-18


class EventQueue:
    """Deterministic event heap: ties in time break in push order.

    ``heap`` is exposed so the engine's dispatch loop (and any other
    per-event hot path) can pop without a method-call frame -- at DES
    scale (millions of events) wrapper frames are measurable.
    """

    __slots__ = ("heap", "_seq")

    def __init__(self):
        self.heap: List[tuple] = []
        self._seq = itertools.count()

    def push(self, t: float, kind: str, pe: int, payload=None) -> None:
        heapq.heappush(self.heap, (t, next(self._seq), kind, pe, payload))

    def pop(self) -> tuple:
        return heapq.heappop(self.heap)

    def __bool__(self) -> bool:
        return bool(self.heap)

    def __len__(self) -> int:
        return len(self.heap)


class Resource:
    """A serialization point with latency and queue accounting.

    Waiters are ``(pe, phase, payload)`` tuples.  ``grant`` serves one
    waiter if the resource is free *now*: it goes busy for ``service``
    seconds, then emits the waiter's completion event
    (``done_kinds[phase]``) plus a ``free_kind`` event that re-arms the
    grant loop -- exactly the window protocol of the paper's DES.
    ``take`` is the explicit-dequeue form for resources whose server
    decides when to serve (the two-sided non-dedicated master).
    """

    __slots__ = ("evq", "_push", "service", "policy", "rng", "done_kinds",
                 "free_kind", "free_payload", "busy_until", "waiters",
                 "n_grants")

    def __init__(self, evq: EventQueue, service: float,
                 done_kinds: Optional[Dict[int, str]] = None,
                 free_kind: Optional[str] = None, free_payload=None,
                 policy: str = "fifo",
                 rng: Optional[random.Random] = None):
        if policy not in ("fifo", "random", "rank"):
            raise ValueError(f"unknown grant policy {policy!r}")
        if policy == "random" and rng is None:
            raise ValueError("policy='random' needs the engine rng")
        self.evq = evq
        self._push = evq.push  # grant() is the hottest kernel path
        self.service = service
        self.policy = policy
        self.rng = rng
        self.done_kinds = done_kinds or {}
        self.free_kind = free_kind
        self.free_payload = free_payload
        self.busy_until = 0.0
        self.waiters: List[tuple] = []
        self.n_grants = 0

    def put(self, waiter: tuple) -> None:
        """Queue a waiter without attempting a grant (explicit servers)."""
        self.waiters.append(waiter)

    def enqueue(self, now: float, pe: int, phase: int, payload=None) -> None:
        """Queue a waiter and grant immediately if the resource is free."""
        self.waiters.append((pe, phase, payload))
        self.grant(now)

    def grant(self, now: float) -> None:
        """If free and someone waits, serve one waiter (policy-picked)."""
        waiters = self.waiters
        if not waiters or self.busy_until > now + EPS:
            return
        idx = self.rng.randrange(len(waiters)) \
            if self.policy == "random" else 0
        pe, phase, payload = waiters.pop(idx)
        t = now + self.service
        self.busy_until = t
        self.n_grants += 1
        self._push(t, self.done_kinds[phase], pe, payload)
        self._push(t, self.free_kind, -1, self.free_payload)

    def take(self) -> Optional[tuple]:
        """Dequeue one waiter by policy; None when idle (explicit servers)."""
        if not self.waiters:
            return None
        if self.policy == "rank":
            self.waiters.sort()
        self.n_grants += 1
        return self.waiters.pop(0)

    def pending(self) -> bool:
        return bool(self.waiters)


class Engine:
    """Shared DES state + event loop; topologies subclass and add handlers.

    Subclass contract: implement ``start()`` (seed the initial events),
    register handlers via ``self.on(kind, fn)``, call ``run_chunk`` /
    ``retire`` / ``claim_gate`` from the protocol state machine, and
    implement ``resume_claim(pe, t)`` (how a PE re-enters the claim loop
    after executing a re-claimed orphan chunk).
    """

    impl = "?"
    #: False: run until every PE retired (one-sided/hierarchical).  True:
    #: drain the event queue (two-sided -- the master may outlive workers).
    drain_all_events = False

    def __init__(self, cf):
        self.cf = cf
        self.spec = cf.spec
        self.N = cf.spec.N
        self.P = cf.spec.P
        self.rng = random.Random(cf.seed)
        self.pref = np.concatenate([[0.0], np.cumsum(cf.costs)])
        self.speeds = cf.speeds  # hot-path alias (one attribute hop)
        self.evq = EventQueue()
        self.push = self.evq.push
        self.finish = np.zeros(self.P)
        self.iters = np.zeros(self.P, dtype=np.int64)
        self.claim_started: Dict[int, float] = {}
        self.claim_latencies: List[float] = []
        self.n_claims = 0
        self.done_pes = 0
        self.serve_time = 0.0
        self.trace: Optional[List[dict]] = [] if cf.collect_trace else None
        self.tele = None  # set by topologies that model adaptive telemetry
        self._handlers: Dict[str, Callable] = {}
        # -- perturbation layer (compiled out when there are none) ----------
        self.plan = compile_plan(cf)
        self._orphans: List[Tuple[int, int]] = []  # re-claimable [a, b) ranges
        self._parked: List[int] = []  # retired-but-alive PEs (wake on orphan)
        self._finished = np.zeros(self.P, dtype=bool)
        if self.plan is not None:
            self.on("reclaim_wake", self._on_reclaim_wake)

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------
    def on(self, kind: str, fn: Callable) -> None:
        self._handlers[kind] = fn

    def start(self) -> None:
        raise NotImplementedError

    def run(self) -> SimResult:
        self.start()
        handlers = self._handlers
        heap = self.evq.heap
        pop = heapq.heappop
        if self.drain_all_events:
            while heap:
                t, _, kind, pe, payload = pop(heap)
                handlers[kind](t, pe, payload)
        else:
            P = self.P
            while heap and self.done_pes < P:
                t, _, kind, pe, payload = pop(heap)
                handlers[kind](t, pe, payload)
        return self.result()

    # ------------------------------------------------------------------
    # PE process model: chunk execution (the one shared hot path)
    # ------------------------------------------------------------------
    def exec_time(self, pe: int, a: int, b: int, t0: float) -> float:
        """Virtual seconds to execute iterations [a, b) on ``pe`` at t0."""
        s = self.cf.speeds[pe]
        if self.plan is not None:
            s = s * self.plan.speed_factor(pe, t0)
        return (self.pref[b] - self.pref[a]) / s

    def run_chunk(self, pe: int, a: int, b: int, t0: float,
                  lat: float) -> Optional[float]:
        """Execute iterations [a, b) on ``pe`` starting at ``t0``.

        Counts the claim, emits the trace record, feeds telemetry, and
        returns the completion time -- or None when the PE dies
        mid-chunk (the unexecuted remainder is orphaned for re-claim
        and the PE is retired at its death time).
        """
        plan = self.plan
        pref = self.pref
        s = self.speeds[pe]
        if plan is not None:
            s = s * plan.speed_factor(pe, t0)
            death = plan.death[pe]
            if t0 + (pref[b] - pref[a]) / s > death:
                self._die_mid_chunk(pe, a, b, t0, s, death, lat)
                return None
        exec_t = (pref[b] - pref[a]) / s
        self.n_claims += 1
        self.iters[pe] += b - a
        t1 = t0 + exec_t
        if self.trace is not None:
            self.trace.append({"pe": pe, "step": self.n_claims - 1,
                               "start": a, "size": b - a, "t0": t0,
                               "t1": t1, "lat": lat})
        if self.tele is not None:
            self.tele.observe(pe, b - a, exec_t, lat, t1)
        return t1

    def _die_mid_chunk(self, pe: int, a: int, b: int, t0: float,
                       s_eff: float, death: float, lat: float) -> None:
        """PE death inside [t0, t1): keep the executed prefix, orphan the
        rest.  The executed prefix is the largest [a, x) that fits in the
        time budget before death at the effective speed."""
        budget = max(death - t0, 0.0) * s_eff
        x = int(np.searchsorted(self.pref, self.pref[a] + budget,
                                side="right")) - 1
        x = min(max(x, a), b)
        if x > a:
            self.n_claims += 1
            self.iters[pe] += x - a
            if self.trace is not None:
                self.trace.append({"pe": pe, "step": self.n_claims - 1,
                                   "start": a, "size": x - a, "t0": t0,
                                   "t1": death, "lat": lat})
        if x < b:
            self.add_orphan(x, b, death)
        self.pe_finish(pe, death)

    # ------------------------------------------------------------------
    # drain / churn bookkeeping
    # ------------------------------------------------------------------
    def pe_finish(self, pe: int, t: float) -> None:
        """Raw retirement: final finish time + done accounting."""
        if self.plan is not None:
            # a dead PE retired by a later protocol event (node drain,
            # posthumous claim) still finished at its death time
            t = min(t, float(self.plan.death[pe]))
        self.finish[pe] = t
        self.done_pes += 1
        self._finished[pe] = True
        if self.plan is not None and self.plan.alive(pe, t):
            self._parked.append(pe)

    def retire(self, pe: int, t: float) -> None:
        """A topology's drain exit for ``pe`` -- orphans outrank retiring."""
        if self.plan is not None and self._orphans and self.plan.alive(pe, t):
            a, b = self._orphans.pop(0)
            t1 = self.run_chunk(pe, a, b, t, 0.0)
            if t1 is not None:
                self.resume_claim(pe, t1)
            return
        self.pe_finish(pe, t)

    def claim_gate(self, pe: int, t: float) -> bool:
        """Perturbation gate at claim start: True when the PE was diverted
        (idle death, or an orphaned range to re-claim) and the caller
        must not continue with a window claim.  Call sites guard with
        ``self.plan is not None`` to keep the unperturbed path call-free."""
        plan = self.plan
        if plan is None:
            return False
        if not plan.alive(pe, t):
            self.pe_finish(pe, float(plan.death[pe]))
            return True
        if self._orphans:
            a, b = self._orphans.pop(0)
            t1 = self.run_chunk(pe, a, b, t, 0.0)
            if t1 is not None:
                self.resume_claim(pe, t1)
            return True
        return False

    def add_orphan(self, a: int, b: int, t: float) -> None:
        """Register a re-claimable range; wake a parked survivor if any.

        The woken PE is taken back in flight *now* (``done_pes`` drops
        before its wake event fires) so the main loop cannot drain to
        completion with the hand-off still pending."""
        self._orphans.append((a, b))
        if self._parked:
            pe = min(self._parked, key=lambda q: (self.finish[q], q))
            self._parked.remove(pe)
            self.done_pes -= 1
            self.push(t, "reclaim_wake", pe)

    def _on_reclaim_wake(self, t: float, pe: int, payload) -> None:
        if self._orphans and self.plan.alive(pe, t):
            a, b = self._orphans.pop(0)
            t1 = self.run_chunk(pe, a, b, t, 0.0)
            if t1 is not None:
                self.resume_claim(pe, t1)
            return
        # raced (an active PE re-claimed it first) or died while parked:
        # fall back to retired, keeping the original finish time
        self.done_pes += 1
        if self.plan.alive(pe, t):
            self._parked.append(pe)

    def resume_claim(self, pe: int, t: float) -> None:
        """Re-enter the topology's claim loop after a re-claimed chunk."""
        raise NotImplementedError

    # ------------------------------------------------------------------
    # result assembly
    # ------------------------------------------------------------------
    def n_rmw_global(self) -> int:
        return 0

    def n_rmw_local(self) -> int:
        return 0

    def result(self) -> SimResult:
        if self._orphans:
            raise RuntimeError(
                f"{len(self._orphans)} orphaned range(s) left unexecuted: "
                "every surviving PE drained before the re-claim hand-off "
                "(scenario leaves too few survivors)")
        mean = np.mean(self.finish)
        cov = float(np.std(self.finish) / mean) if mean > 0 else 0.0
        return SimResult(
            T_loop=float(self.finish.max()),
            finish=self.finish,
            n_claims=self.n_claims,
            cov=cov,
            per_pe_iters=self.iters,
            master_serve_time=self.serve_time,
            mean_claim_latency=float(np.mean(self.claim_latencies))
            if self.claim_latencies else 0.0,
            n_rmw_global=self.n_rmw_global(),
            n_rmw_local=self.n_rmw_local(),
            chunk_trace=self.trace,
        )
