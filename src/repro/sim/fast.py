"""Vectorized DES fast path for non-adaptive one-sided / hierarchical runs.

The event kernel (``repro.sim.kernel``) pays a Python-level price per
event: a heap push/pop, a tuple unpack, and a handler dict dispatch --
six of them per scheduling step.  For the configurations the predict
sweep actually runs (non-adaptive technique, no perturbations, no trace
collection) the schedule is a *closed* function of the chunk calculus
and the window-serialization order, so most of that machinery can be
replaced by batched numpy work:

* **Chunk sizes** come from per-technique tables/closed forms that are
  bit-identical to scalar ``chunk_calculus.chunk_size_closed`` (the
  vectorized ``chunk_sizes_closed`` has a different float op order and
  is deliberately *not* used).
* **Window serialization** under FIFO polling is a prefix-max over RMW
  issue times: while the window is saturated its grant clock never
  idles, so the next ``B`` completion times are the running maximum of
  (arrival, previous completion) + service -- which for a backlogged
  window collapses to the cumulative sum ``f_j = F0 + (j+1)*o_rma``.
  ``_OneSided._batch`` serves an entire backlog in one shot of numpy
  vector ops (the "round"), including the per-PE spawn times of the
  next claim round.
* **Lock-Polling randomness** (``policy="random"``) is replayed through
  a numpy MT19937 clone of CPython's ``random.Random`` so the grant
  order -- and therefore the event stream -- is *bit-identical* to the
  kernel's, at a fraction of the per-draw cost.

Everything that is not provably batchable runs through a lean serial
mini-interpreter that replicates the kernel's event order exactly
(same tie-breaking sequence numbers, same ``EPS`` busy-window guard,
same float expression trees).  The contract, pinned by
``tests/test_sim_fast.py``, is *equivalence*: ``simulate_fast(cf)``
returns the same ``SimResult`` the event kernel returns, only faster.

``fast_qualifies`` is the routing predicate ``repro.sim.run.simulate``
uses: fast path iff the topology is one-sided/hierarchical, there are
no perturbations, no chunk trace is requested, and neither the outer
nor (hierarchical) inner technique is adaptive -- adaptive telemetry
consumes the shared RNG mid-flight and must stay on the kernel.

``backend="jax"`` additionally routes the one-sided batch round's
float math through a ``jax.jit``-compiled core (requires x64); because
XLA's scan association may differ in the last ulp it promises 1e-9
relative -- not byte -- equivalence, and is opt-in only.
"""
from __future__ import annotations

import heapq
import math
import random
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core import chunk_calculus as cc
from repro.core.sim import SimResult

from .kernel import EPS

#: FIFO backlog size at which the one-sided serial loop hands the whole
#: waiter queue to the vectorized batch round.  Below this the numpy
#: call overhead beats the per-event saving.
BATCH_MIN = 24

#: Serial events to interpret after a round hits an off-grid hazard
#: before paying round setup again -- the hazard sits at most one grid
#: step ahead, so immediate retries would rediscover it at index 0.
COOL_EVENTS = 8


# ---------------------------------------------------------------------------
# qualification predicate (the routing contract)
# ---------------------------------------------------------------------------

def fast_qualifies(cf) -> bool:
    """True iff ``cf`` may be routed to ``simulate_fast``.

    The fast path replays only what it can reproduce bit-identically:
    one-sided / two-sided / hierarchical topologies, no perturbation
    plan, no chunk trace, and no adaptive telemetry at either level
    (adaptive techniques draw lognormal noise from the shared engine
    RNG between grants, which only the kernel models; the two-sided
    master's rank-policy queue draws no RNG at all, so every
    non-adaptive two-sided run qualifies).
    """
    if cf.impl not in ("one_sided", "two_sided", "hierarchical"):
        return False
    if cf.perturbations:
        return False
    if cf.collect_trace:
        return False
    if cf.spec.technique in cc.ADAPTIVE:
        return False
    if cf.impl == "hierarchical" and cf.inner_technique in cc.ADAPTIVE:
        return False
    return True


# ---------------------------------------------------------------------------
# MT19937 replay of random.Random (Lock-Polling grant order)
# ---------------------------------------------------------------------------

class _MTReplay:
    """Bit-exact numpy replay of ``random.Random(seed).randrange(n)``.

    Seeded from ``random.Random(seed).getstate()`` (so CPython's own
    ``init_by_array`` seeding is reused, not re-implemented), then the
    624-word Mersenne Twister state is advanced with vectorized
    twist/temper passes and consumed through the same
    ``_randbelow_with_getrandbits`` rejection loop CPython uses:
    ``k = n.bit_length(); r = getrandbits(k); while r >= n: redraw``.
    """

    __slots__ = ("_mt", "_pos", "_buf", "_cur")

    _N, _M = 624, 397
    _MATRIX_A = np.uint32(0x9908B0DF)
    _UPPER = np.uint32(0x80000000)
    _LOWER = np.uint32(0x7FFFFFFF)

    def __init__(self, seed):
        state = random.Random(seed).getstate()[1]
        self._mt = np.array(state[:624], dtype=np.uint32)
        self._pos = state[624]
        self._buf: List[int] = []
        self._cur = 0

    def _twist(self) -> None:
        n, m = self._N, self._M
        mt = self._mt
        up, lo, ma = self._UPPER, self._LOWER, self._MATRIX_A
        new = np.empty(n, np.uint32)
        y = (mt[: n - m] & up) | (mt[1: n - m + 1] & lo)
        new[: n - m] = mt[m:] ^ (y >> 1) ^ \
            np.where(y & 1, ma, np.uint32(0))
        # the tail reads freshly twisted words with lag n-m: walk it in
        # lag-sized blocks so every read is already written
        for s in range(n - m, n - 1, n - m):
            e = min(s + (n - m), n - 1)
            y = (mt[s:e] & up) | (mt[s + 1: e + 1] & lo)
            new[s:e] = new[s - (n - m): e - (n - m)] ^ (y >> 1) ^ \
                np.where(y & 1, ma, np.uint32(0))
        y = int((mt[n - 1] & up) | (new[0] & lo))
        new[n - 1] = new[m - 1] ^ np.uint32(y >> 1) ^ \
            (ma if (y & 1) else np.uint32(0))
        self._mt = new
        self._pos = 0

    def _refill(self) -> None:
        if self._pos >= self._N:
            self._twist()
        y = self._mt[self._pos:].astype(np.uint32)
        y ^= y >> 11
        y ^= (y << 7) & np.uint32(0x9D2C5680)
        y ^= (y << 15) & np.uint32(0xEFC60000)
        y ^= y >> 18
        self._pos = self._N
        self._buf = y.tolist()
        self._cur = 0

    def getrandbits(self, k: int) -> int:
        """k <= 32 bits, one MT output word (CPython's fast path)."""
        if self._cur >= len(self._buf):
            self._refill()
        w = self._buf[self._cur]
        self._cur += 1
        return w >> (32 - k)

    def randrange(self, n: int) -> int:
        k = n.bit_length()
        r = self.getrandbits(k)
        while r >= n:
            r = self.getrandbits(k)
        return r


_MT_OK: Optional[bool] = None


def _draw_factory(seed) -> Callable[[int], int]:
    """randrange(n) callable: the MT replay when it verifies against
    this interpreter's ``random.Random``, else ``random.Random`` itself
    (correct on any platform, merely slower)."""
    global _MT_OK
    if _MT_OK is None:
        ref = random.Random(20240807)
        rep = _MTReplay(20240807)
        sizes = [1, 2, 3, 5, 7, 31, 64, 200, 1000, 65537] * 40
        _MT_OK = all(rep.randrange(n) == ref.randrange(n) for n in sizes)
    if _MT_OK:
        return _MTReplay(seed).randrange
    return random.Random(seed).randrange


# ---------------------------------------------------------------------------
# chunk-size evaluators: bit-identical to scalar chunk_size_closed
# ---------------------------------------------------------------------------

def _chunk_fns(spec) -> Tuple[Callable, Callable]:
    """(scalar ``k(i, pe)``, vector ``k(i_arr, pe_arr)``) for a
    non-adaptive technique.

    Exactness rule: every float expression is either lifted verbatim
    from ``_chunk_size_closed`` (same op order, so the same IEEE-754
    doubles) or replaced by a table built *with* the scalar function,
    so both callables agree with ``cc.chunk_size_closed`` bit for bit.
    Tables stop at the technique's floor value (all these chunk series
    are non-increasing in ``i``), keeping setup O(steps-to-floor), not
    O(N).
    """
    t, N, P = spec.technique, spec.N, spec.P
    maxc = spec.max_chunk
    minc = spec.min_chunk

    if t in ("static", "ss"):
        k0 = cc.chunk_size_closed(spec, 0, 0)
        return (lambda i, pe: k0,
                lambda ia, pa: np.full(len(ia), k0, dtype=np.int64))

    if t == "tss":
        K0, Klast, _, C = cc.tss_constants(N, P, minc)

        def sc(i, pe):
            k = max(K0 - i * C, Klast)
            return min(k, maxc) if maxc else k

        def vec(ia, pa):
            k = np.maximum(K0 - ia * C, Klast)
            return np.minimum(k, maxc) if maxc else k

        return sc, vec

    if t == "gss":
        floor = cc.chunk_size_closed(spec, 1 << 40, 0)
        bound = cc.max_steps_bound(spec) + P + 8
        tab = []
        i = 0
        while True:
            v = cc.chunk_size_closed(spec, i, 0)
            tab.append(v)
            if v == floor or i > bound:
                break
            i += 1
        n_tab = len(tab)
        arr = np.asarray(tab, dtype=np.int64)

        def sc(i, pe):
            return tab[i] if i < n_tab else floor

        def vec(ia, pa):
            return arr[np.minimum(ia, n_tab - 1)]

        return sc, vec

    if t in ("fac2", "tfss"):
        # batch-indexed: k depends on i only through b
        shift = 1 if t == "fac2" else 0  # fac2: b = i//P + 1; tfss: b = i//P
        floor = cc.chunk_size_closed(spec, P * 1200, 0)
        tab = []
        b = 0
        while True:
            v = cc.chunk_size_closed(spec, b * P, 0)
            tab.append(v)
            if v == floor or b > 1200:
                break
            b += 1
        n_tab = len(tab)
        arr = np.asarray(tab, dtype=np.int64)

        def sc(i, pe, _div=P, _tab=tab, _n=n_tab, _f=floor):
            b = i // _div
            return _tab[b] if b < _n else _f

        def vec(ia, pa):
            return arr[np.minimum(ia // P, n_tab - 1)]

        return sc, vec

    if t in cc.WEIGHTED:  # wf / awf with externally supplied weights
        w_list = [spec.weight(pe) for pe in range(P)]
        w_arr = np.asarray(w_list, dtype=np.float64)
        wmax = max(w_list) if w_list else 1.0
        bases: List[float] = []  # bases[j] is the FAC2 base for b = j+1
        b = 1
        while b < 1200:
            base = 0.5 ** b * N / P  # verbatim from _chunk_size_closed
            if int(math.ceil(wmax * base)) <= minc:
                break
            bases.append(base)
            b += 1
        n_b = len(bases)
        bases_arr = np.asarray(bases, dtype=np.float64)
        cap_floor = min(minc, maxc) if maxc else minc

        def sc(i, pe):
            j = i // P  # == b - 1
            if j >= n_b:
                return cap_floor
            k = max(int(math.ceil(w_list[pe] * bases[j])), minc)
            return min(k, maxc) if maxc else k

        def vec(ia, pa):
            if n_b == 0:
                return np.full(len(ia), cap_floor, dtype=np.int64)
            j = ia // P
            base = bases_arr[np.minimum(j, n_b - 1)]
            k = np.maximum(
                np.ceil(w_arr[pa] * base).astype(np.int64), minc)
            k = np.where(j < n_b, k, minc)
            return np.minimum(k, maxc) if maxc else k

        return sc, vec

    raise ValueError(f"technique {t!r} has no fast-path chunk form")


# ---------------------------------------------------------------------------
# shared result assembly (matches Engine.result float for float)
# ---------------------------------------------------------------------------

def _result(finish, iters, n_claims, lats, n_rmw_g, n_rmw_l,
            serve_time: float = 0.0) -> SimResult:
    mean = np.mean(finish)
    cov = float(np.std(finish) / mean) if mean > 0 else 0.0
    return SimResult(
        T_loop=float(finish.max()),
        finish=finish,
        n_claims=n_claims,
        cov=cov,
        per_pe_iters=iters,
        master_serve_time=serve_time,
        mean_claim_latency=float(np.mean(lats)) if len(lats) else 0.0,
        n_rmw_global=n_rmw_g,
        n_rmw_local=n_rmw_l,
        chunk_trace=None,
    )


# ---------------------------------------------------------------------------
# one-sided topology
# ---------------------------------------------------------------------------

class _OneSided:
    """Lean replay of ``OneSidedEngine``: one window, two RMW phases.

    Events are ``(t, seq, phase, pe, k)`` tuples where phase 1/2 are
    the ``want_rmw1``/``want_rmw2`` arrivals; window completions live
    in ``svcq`` (at most a couple in flight) instead of the heap, and
    ``win_free`` is folded into the completion step.  ``seq`` tracks
    the kernel's single monotone push counter exactly -- a grant
    reserves two numbers (done + free), every handler push takes one --
    so event ties break in the kernel's order.
    """

    def __init__(self, cf, backend: str = "numpy", cache=None):
        spec = cf.spec
        self.N = spec.N
        self.P = spec.P
        if cache is not None:
            self.s_list, self.s_arr = cache.speeds(cf.speeds)
            self.pref_arr, self.pref = cache.pref(cf.costs)
            self.k_scalar, self.k_vec = cache.chunk_fns(spec)
        else:
            self.s_list = [float(x) for x in cf.speeds]
            self.s_arr = np.asarray(cf.speeds, dtype=np.float64)
            self.pref_arr = np.concatenate([[0.0], np.cumsum(cf.costs)])
            self.pref = self.pref_arr.tolist()
            self.k_scalar, self.k_vec = _chunk_fns(spec)
        self.o_rma = cf.o_rma
        self.o_net = cf.o_claim_net
        self.o_issue = cf.o_issue
        self.random_policy = cf.lock_polling_random
        self.draw = _draw_factory(cf.seed) if self.random_policy else None
        # step-index-free techniques skip the per-round index cumsum
        self.k_const = self.k_scalar(0, 0) \
            if spec.technique in ("static", "ss") else None
        # per-PE constant offsets (same divisions the kernel performs)
        self.tds = [cf.t_calc / s for s in self.s_list]
        self.oids = [cf.o_issue / s for s in self.s_list]
        self.tds_arr = np.asarray(self.tds)
        self.oids_arr = np.asarray(self.oids)
        self.backend = backend
        self._jax_core = _jax_batch_core() if backend == "jax" else None
        # mutable run state
        self.heap: List[tuple] = []
        self.waiters: List[tuple] = []
        self.svcq: List[tuple] = []
        self.busy_until = 0.0
        self.counter = 0
        self.i_glob = 0
        self.lp = 0
        self.done = 0
        self.n_grants = 0
        self.n_claims = 0
        self.finish = np.zeros(self.P)
        self.iters = np.zeros(self.P, dtype=np.int64)
        self.claim_start = np.zeros(self.P)
        self.lats: List[float] = []  # current serial latency segment
        self.lat_parts: List = []  # closed segments (lists/arrays), in order
        self.cool = 0  # serial events left before retrying a round
        self.pend = None  # out-of-round spawns pended as column arrays
        self.wq = None  # waiter-queue tail as column arrays (batch mode)

    # -- window ---------------------------------------------------------
    def _flush_pending(self) -> None:
        """Hand pended future arrivals back to the serial event heap."""
        pend = self.pend
        if pend is None:
            return
        self.heap.extend(zip(pend[0].tolist(), pend[1].tolist(),
                             pend[2].tolist(), pend[3].tolist(),
                             pend[4].tolist()))
        heapq.heapify(self.heap)
        self.pend = None

    def _flush_wq(self) -> None:
        """Materialize the column-array queue tail into the waiter list."""
        wq = self.wq
        if wq is None:
            return
        self.waiters.extend(zip(wq[0].tolist(), wq[1].tolist(),
                                wq[2].tolist()))
        self.wq = None

    def _grant(self, now: float) -> None:
        waiters = self.waiters
        idx = self.draw(len(waiters)) if self.random_policy else 0
        pe, ph, k = waiters.pop(idx)
        self.busy_until = now + self.o_rma
        self.svcq.append((self.busy_until, self.counter, pe, ph, k))
        self.counter += 2  # done + free seq numbers
        self.n_grants += 1

    def _arrival(self, ev: tuple) -> None:
        t, _, ph, pe, k = ev
        if ph == 1:
            if self.lp >= self.N:
                self.finish[pe] = t
                self.done += 1
                return
            self.claim_start[pe] = t
            self.waiters.append((pe, 1, 0))
        else:
            self.waiters.append((pe, 2, k))
        if self.busy_until <= t + EPS:
            self._grant(t)

    def _complete(self) -> None:
        """One window completion (done + inlined free/grant)."""
        f, _, pe, ph, k = self.svcq.pop(0)
        heap = self.heap
        if ph == 1:
            i_local = self.i_glob
            self.i_glob += 1
            kk = self.k_scalar(i_local, pe)
            heapq.heappush(
                heap, (f + self.o_net + self.tds[pe], self.counter, 2,
                       pe, kk))
            self.counter += 1
        else:
            start = self.lp
            self.lp += k
            t_got = f + self.o_net
            self.lats.append(t_got - self.claim_start[pe])
            if start >= self.N:
                self.finish[pe] = t_got
                self.done += 1
            else:
                stop = start + k
                if stop > self.N:
                    stop = self.N
                self.n_claims += 1
                self.iters[pe] += stop - start
                t1 = t_got + (self.pref[stop] - self.pref[start]) \
                    / self.s_list[pe]
                heapq.heappush(heap, (t1 + self.oids[pe], self.counter, 1,
                                      pe, 0))
                self.counter += 1
        if self.done >= self.P:
            return
        # win_free: serve the backlog -- batched when provably FIFO
        while (self.waiters or self.wq is not None) \
                and self.busy_until <= f + EPS:
            if not self.random_policy and not self.svcq \
                    and len(self.waiters) + (
                        0 if self.wq is None else self.wq[0].size
                    ) >= BATCH_MIN:
                if self.cool:
                    self.cool -= 1
                elif self._batch(f):
                    if self.svcq:  # final-boundary tie fired next grant
                        break
                    f = self.busy_until
                    continue
            self._flush_wq()
            self._grant(f)
            break

    # -- the vectorized FIFO round -------------------------------------
    def _batch(self, F0: float) -> bool:
        """Serve the whole FIFO backlog in one vectorized round.

        While the window is backlogged its grant clock never idles, so
        the next ``B`` completion times are the prefix-max of issue
        times collapsed to a running sum: ``f_j = f_{j-1} + o_rma``.
        Everything downstream of that grid -- step indices, loop
        pointers, chunk sizes, execution spans, next-claim spawn times,
        tie-breaking sequence numbers -- is computed with numpy in one
        pass.

        Mid-round arrivals that land *exactly* on a grant boundary
        ``f_j`` are common with round-decimal overheads (a spawn at
        ``f_{j-1} + o_net + t_calc/s`` can equal ``f_j`` bit for bit)
        and are handled, not aborted: the kernel's busy-window guard
        lets such an arrival fire the next grant itself -- same waiter,
        same completion time, but the grant's two sequence numbers are
        allocated *before* the concurrent completion handler's push
        instead of after.  The replay walk reproduces that allocation
        order step by step (``tie`` bookkeeping below), including the
        extra grant a tie on the final boundary issues.  Only arrivals
        *within* ``EPS`` of a boundary without equality -- where the
        guard would start a grant mid-service, off the grid -- limit the
        round: it commits the hazard-free prefix and the serial
        interpreter absorbs the irregular grant.  FIFO grants draw no
        RNG, so cutting a round short is always safe.
        """
        N = self.N
        o_rma = self.o_rma
        # queue = python-list front (serial appends) + column-array tail
        # (the previous round's arrivals, never materialized)
        wq = self.wq
        if wq is None or self.waiters:
            w_pe, w_ph, w_k = zip(*self.waiters)
            pes = np.array(w_pe, dtype=np.int64)
            phs = np.array(w_ph, dtype=np.int64)
            ks = np.array(w_k, dtype=np.int64)
            if wq is not None:
                pes = np.concatenate([pes, wq[0]])
                phs = np.concatenate([phs, wq[1]])
                ks = np.concatenate([ks, wq[2]])
        else:
            pes, phs, ks = wq
        B = int(pes.size)
        m1 = phs == 1
        m2 = ~m1
        # chunk sizes for this round's phase-1 completions
        n1 = int(m1.sum())
        knew = np.zeros(B, dtype=np.int64)
        if n1:
            if self.k_const is not None:
                knew[m1] = self.k_const
            else:  # step index at each phase-1 slot
                i_of = self.i_glob + np.cumsum(m1) - 1
                knew[m1] = self.k_vec(i_of[m1], pes[m1])
        # loop-pointer trajectory across the round's phase-2 completions
        kcontrib = np.where(m2, ks, 0)
        lp_cum = np.cumsum(kcontrib)
        lp_before = self.lp + (lp_cum - kcontrib)
        no_retire = self.lp + int(lp_cum[B - 1]) < N
        if no_retire:
            # common mid-sim case: every slot pushes a follow-up event,
            # so the seq bookkeeping collapses to closed forms
            retire_m = np.zeros(B, dtype=bool)
            exec_m = m2
            push = np.ones(B, dtype=np.int64)
            push_cum = np.arange(1, B + 1)
        else:
            retire_m = m2 & (lp_before >= N)
            exec_m = m2 & ~retire_m
            push = (m1 | exec_m).astype(np.int64)
            push_cum = np.cumsum(push)
        # completion-time grid + spawn times (optionally jax-jitted)
        if self._jax_core is not None:
            f, t_spawn_exec_base, t_got = self._jax_core(
                F0, o_rma, B, self.pref_arr, self.s_arr, self.o_net,
                lp_before, np.minimum(lp_before + ks, N), pes, exec_m)
            t_spawn = np.empty(B)
            t_spawn[m1] = (f[m1] + self.o_net) + self.tds_arr[pes[m1]]
            if exec_m.any():
                t_spawn[exec_m] = t_spawn_exec_base[exec_m] \
                    + self.oids_arr[pes[exec_m]]
        else:
            inc = np.full(B, o_rma)
            inc[0] = F0 + o_rma
            f = np.cumsum(inc)  # sequential adds == the kernel's clock
            t_got = f + self.o_net
            t_spawn = np.empty(B)
            t_spawn[m1] = (f[m1] + self.o_net) + self.tds_arr[pes[m1]]
            if exec_m.any():
                a = lp_before[exec_m]
                b = np.minimum(a + ks[exec_m], N)
                et = (self.pref_arr[b] - self.pref_arr[a]) \
                    / self.s_arr[pes[exec_m]]
                t_spawn[exec_m] = (t_got[exec_m] + et) \
                    + self.oids_arr[pes[exec_m]]
        f_last = f[B - 1]
        # Sequence numbers: grant_j reserves 2, each prior push takes 1.
        # Cj[j] is the counter just before step j's events fire; the
        # first number a step allocates is its completion handler's push
        # (the default spawn seq), unless a boundary tie reorders it.
        c0 = self.counter
        if no_retire:  # push == 1 everywhere: Cj[j] = c0 + 3j + 2
            Cj = 3 * np.arange(B) + (c0 + 2)
        else:
            Cj = c0 + 2 * np.arange(1, B + 1) + (push_cum - push)
        # ---- gather every mid-round arrival: heap stragglers, spawns
        # pended by earlier rounds, and this round's own spawns.  Heap
        # and pend seqs all predate c0, so any arrival at t <= f_last
        # sorts before the round's trailing events and belongs to the
        # replay.
        popped: List[tuple] = []
        heap = self.heap
        while heap and heap[0][0] <= f_last:
            popped.append(heapq.heappop(heap))
        pend = self.pend
        take = None if pend is None else pend[0] <= f_last
        pm = t_spawn <= f_last
        push_m = (m1 | exec_m)
        in_round = push_m & pm
        if not popped and take is None:
            arr_t = t_spawn[in_round]
        else:
            arr_t = np.concatenate(
                [np.array([p[0] for p in popped], dtype=np.float64),
                 np.empty(0) if take is None else pend[0][take],
                 t_spawn[in_round]])
        # ---- guard: an arrival within EPS of the next boundary without
        # *equality* (an off-by-an-ulp near-miss of the structural ties
        # above) makes the kernel's busy-window check issue a grant
        # mid-service, off the grid.  The prefix before the first such
        # boundary is still exact: truncate the round to it and let the
        # serial interpreter absorb the irregular grant (a short cooldown
        # stops the next few frees from re-paying round setup just to
        # rediscover the same hazard one step ahead).  Exact boundary
        # hits are handled by the tie walk below instead.
        nxt = None
        if arr_t.size:
            nxt = np.searchsorted(f, arr_t, side="right")
            hz = (nxt < B) & (f[np.minimum(nxt, B - 1)] <= arr_t + EPS)
            if bool(hz.any()):
                self.cool = COOL_EVENTS
                jh = int(nxt[hz].min())
                if jh < 1:
                    for item in popped:
                        heapq.heappush(heap, item)
                    self._flush_pending()
                    self._flush_wq()
                    return False
                self._flush_wq()  # truncation keeps leftovers as a list
                B = jh
                (pes, phs, ks, m1, m2, knew, lp_cum, lp_before, retire_m,
                 exec_m, push, push_cum, f, t_got, t_spawn, Cj) = (
                    a[:B] for a in (pes, phs, ks, m1, m2, knew, lp_cum,
                                    lp_before, retire_m, exec_m, push,
                                    push_cum, f, t_got, t_spawn, Cj))
                n1 = int(m1.sum())
                f_last = f[B - 1]
                while popped and popped[-1][0] > f_last:
                    heapq.heappush(heap, popped.pop())
                if take is not None:
                    take = pend[0] <= f_last
                push_m = m1 | exec_m
                pm = t_spawn <= f_last
                in_round = push_m & pm
                arr_t = np.concatenate(
                    [np.array([p[0] for p in popped], dtype=np.float64),
                     np.empty(0) if take is None else pend[0][take],
                     t_spawn[in_round]])
                nxt = np.searchsorted(f, arr_t, side="right")
        # ---- commit: window/global state --------------------------------
        self.busy_until = float(f_last)
        self.n_grants += B
        self.i_glob += n1
        lp0 = self.lp
        self.lp += int(lp_cum[B - 1])
        self.counter = int(c0 + 2 * B + push_cum[B - 1])
        # phase-2 bookkeeping (kernel appends latency even when retiring)
        if m2.any():
            cs = self.claim_start[pes[m2]]
            if self.lats:
                self.lat_parts.append(self.lats)
                self.lats = []
            self.lat_parts.append(t_got[m2] - cs)
        if retire_m.any():
            rp = pes[retire_m]
            self.finish[rp] = t_got[retire_m]
            self.done += int(retire_m.sum())
        if exec_m.any():
            ep = pes[exec_m]
            sizes = np.minimum(lp_before[exec_m] + ks[exec_m], N) \
                - lp_before[exec_m]
            self.iters[ep] += sizes
            self.n_claims += int(exec_m.sum())
        # ---- replay mid-round arrivals in (t, seq) order ----------------
        sphase = np.where(m1, 2, 1)  # phase of each slot's spawned event
        sp_seq = Cj[in_round]
        sp_pe = pes[in_round]
        sp_ph = sphase[in_round]
        sp_k = knew[in_round]
        ev_t = arr_t
        if not popped and take is None:
            ev_seq, ev_ph, ev_pe, ev_k = sp_seq, sp_ph, sp_pe, sp_k
        else:
            e0 = np.empty(0, np.int64)
            if popped:
                _, p_seq, p_ph, p_pe, p_k = zip(*popped)
                pop_cols = (np.array(p_seq, np.int64),
                            np.array(p_ph, np.int64),
                            np.array(p_pe, np.int64),
                            np.array(p_k, np.int64))
            else:
                pop_cols = (e0, e0, e0, e0)
            pd_cols = (e0, e0, e0, e0) if take is None else (
                pend[1][take], pend[2][take], pend[3][take], pend[4][take])
            ev_seq = np.concatenate([pop_cols[0], pd_cols[0], sp_seq])
            ev_ph = np.concatenate([pop_cols[1], pd_cols[1], sp_ph])
            ev_pe = np.concatenate([pop_cols[2], pd_cols[2], sp_pe])
            ev_k = np.concatenate([pop_cols[3], pd_cols[3], sp_k])
        # tie[j]: an arrival at exactly f_j, sequenced before done_j,
        # enqueued and fired grant_{j+1} itself (same waiter and timing
        # as the batch's free-step grant, but its done/free seqs are
        # allocated *before* step j's handler push -- so step j's spawn
        # seq shifts +2 and dseq_{j+1} drops by push_j).
        tie = np.zeros(B, dtype=bool)
        grant_b = False
        wq_new = None
        if ev_t.size:
            order = np.lexsort((ev_seq, ev_t))
            ot = ev_t[order]
            cnt = nxt[order]
            exact = (cnt > 0) & (f[np.maximum(cnt - 1, 0)] == ot)
            oph = ev_ph[order]
            om1 = oph == 1
            if no_retire:
                risky = False  # lp stays below N all round
            else:
                lp_def = lp0 + np.concatenate([[0], lp_cum])[cnt]
                risky = bool((om1 & (lp_def >= N)).any())
            if not risky:
                # no mid-round retires: every arrival enqueues, so the
                # replay is queue appends done wholesale, and the tie
                # recurrence tie[j] = strong[j] | (weak[j] & ~tie[j-1])
                # (strong: seq below dseq_j either way; weak: the spawn
                # of step j-1, pre-done only if j-1 did not itself tie)
                # solves by anchor parity: every strong boundary or run
                # start fires, then ties alternate until the next anchor.
                if bool(exact.any()):
                    oseq = ev_seq[order]
                    jb = cnt - 1
                    jp = np.maximum(jb - 1, 0)
                    Cprev = np.where(jb > 0, Cj[jp], c0)
                    strong_a = exact & (oseq < Cprev)
                    weak_a = exact & (jb > 0) & (oseq == Cprev) \
                        & (push[jp] == 1)
                    strong = np.zeros(B, dtype=bool)
                    strong[jb[strong_a]] = True
                    cand = strong.copy()
                    cand[jb[weak_a]] = True
                    if bool(cand.any()):
                        runstart = cand.copy()
                        runstart[1:] &= ~cand[:-1]
                        jarr = np.arange(B)
                        anchor = np.maximum.accumulate(
                            np.where(strong | runstart, jarr, -1))
                        tie = cand & (anchor >= 0) \
                            & (((jarr - anchor) & 1) == 0)
                ope = ev_pe[order]
                if om1.any():
                    self.claim_start[ope[om1]] = ot[om1]
                a_k = np.where(om1, 0, ev_k[order])
                if bool(tie[B - 1]):
                    # a tie on the final boundary issues the round's
                    # successor grant itself, serving the head of the
                    # queue: after a truncated round that is the first
                    # unserved backlog waiter, not the first arrival
                    if len(self.waiters) > B:
                        pe2, ph2, k2 = self.waiters.pop(B)
                        self.svcq.append(
                            (float(f_last) + o_rma, int(Cj[B - 1]),
                             pe2, ph2, k2))
                    else:
                        self.svcq.append(
                            (float(f_last) + o_rma, int(Cj[B - 1]),
                             int(ope[0]), int(oph[0]), int(a_k[0])))
                        ope, oph, a_k = ope[1:], oph[1:], a_k[1:]
                    grant_b = True
                if ope.size:
                    wq_new = (ope, oph, a_k)
            else:
                self._flush_wq()  # serial walk appends to the list
                Cj_l = Cj.tolist()
                push_l = push.tolist()
                lpc_l = lp_cum.tolist()
                waiters = self.waiters
                for t, sq, ph, pe, k, lp_at, cn, ex in zip(
                        ot.tolist(), ev_seq[order].tolist(),
                        oph.tolist(), ev_pe[order].tolist(),
                        ev_k[order].tolist(), lp_def.tolist(),
                        cnt.tolist(), exact.tolist()):
                    pre_done = False
                    if ex:
                        j = cn - 1
                        if j == 0:
                            d = c0
                        elif tie[j - 1]:
                            d = Cj_l[j - 1]
                        else:
                            d = Cj_l[j - 1] + push_l[j - 1]
                        if sq < d:  # sequenced before done_j fires
                            pre_done = True
                            lp_at = lp0 + (lpc_l[j - 1] if j else 0)
                    if ph == 1:
                        if lp_at >= N:
                            self.finish[pe] = t
                            self.done += 1
                            continue
                        self.claim_start[pe] = t
                        waiters.append((pe, 1, 0))
                    else:
                        waiters.append((pe, 2, k))
                    if pre_done and not tie[cn - 1]:
                        j = cn - 1
                        tie[j] = True
                        if j == B - 1:
                            # a tie on the final boundary issues the
                            # round's successor grant (head of queue)
                            pe2, ph2, k2 = waiters.pop(B)
                            self.svcq.append(
                                (float(f_last) + o_rma, int(Cj_l[B - 1]),
                                 pe2, ph2, k2))
                            grant_b = True
        # spawns beyond the round are pended as raw arrays -- consumed
        # directly by later rounds, handed to the event heap only when
        # the serial interpreter takes over.  (Tie steps allocate their
        # handler push two numbers later.)
        out = push_m & ~pm
        keep = None if take is None else ~take
        if bool(out.any()) or (keep is not None and bool(keep.any())):
            spawn_fin = Cj + 2 * tie
            if keep is None:
                self.pend = (t_spawn[out], spawn_fin[out], sphase[out],
                             pes[out], knew[out])
            else:
                self.pend = (
                    np.concatenate([pend[0][keep], t_spawn[out]]),
                    np.concatenate([pend[1][keep], spawn_fin[out]]),
                    np.concatenate([pend[2][keep], sphase[out]]),
                    np.concatenate([pend[3][keep], pes[out]]),
                    np.concatenate([pend[4][keep], knew[out]]))
        else:
            self.pend = None
        del self.waiters[:B]
        self.wq = wq_new
        if grant_b:
            self.busy_until = float(f_last) + o_rma
            self.n_grants += 1
            self.counter += 2
        # the serial interpreter resumes unless the very next step is
        # another round: give it back the pended arrivals and the
        # column-array queue tail
        if grant_b or self.cool or len(self.waiters) + (
                0 if wq_new is None else wq_new[0].size) < BATCH_MIN:
            self._flush_pending()
            self._flush_wq()
        return True

    # -- driver ---------------------------------------------------------
    def run(self) -> SimResult:
        for pe in range(self.P):
            heapq.heappush(self.heap,
                           (self.o_issue / self.s_list[pe], pe, 1, pe, 0))
        self.counter = self.P
        heap = self.heap
        svcq = self.svcq
        P = self.P
        while self.done < P:
            if svcq:
                head = svcq[0]
                if heap and (heap[0][0], heap[0][1]) < (head[0], head[1]):
                    self._arrival(heapq.heappop(heap))
                else:
                    self._complete()
            elif heap:
                self._arrival(heapq.heappop(heap))
            else:  # pragma: no cover - defensive
                raise RuntimeError("fast path drained events early")
        parts = self.lat_parts + ([self.lats] if self.lats else [])
        lat_all = np.concatenate(
            [np.asarray(p, dtype=np.float64) for p in parts]) \
            if parts else np.empty(0)
        return _result(self.finish, self.iters, self.n_claims, lat_all,
                       self.n_grants, 0)


# ---------------------------------------------------------------------------
# optional jax backend for the one-sided batch round
# ---------------------------------------------------------------------------

_JAX_CORE = None


def _jax_batch_core():
    """Build (once) the jitted round core; requires jax with x64."""
    global _JAX_CORE
    if _JAX_CORE is not None:
        return _JAX_CORE
    try:
        import jax
        import jax.numpy as jnp
    except Exception as e:  # pragma: no cover - jax is a baked-in dep
        raise RuntimeError(f"backend='jax' unavailable: {e}") from None
    if not jax.config.jax_enable_x64:
        raise RuntimeError(
            "backend='jax' needs float64 event times: enable x64 "
            "(jax.config.update('jax_enable_x64', True)) or use the "
            "default numpy backend")

    @jax.jit
    def core(F0, o_rma, pref, speeds, o_net, a, b, pes, exec_m):
        n = a.shape[0]
        f = F0 + o_rma * jnp.cumsum(jnp.ones(n, jnp.float64))
        t_got = f + o_net
        et = (pref[b] - pref[a]) / speeds[pes]
        return f, jnp.where(exec_m, t_got + et, 0.0), t_got

    def run(F0, o_rma, B, pref, speeds, o_net, a, b, pes, exec_m):
        f, base, t_got = core(F0, o_rma, pref, speeds, o_net, a, b, pes,
                              exec_m)
        return (np.asarray(f), np.asarray(base), np.asarray(t_got))

    _JAX_CORE = run
    return run


# ---------------------------------------------------------------------------
# hierarchical topology
# ---------------------------------------------------------------------------

# event codes (heap tuples are (t, seq, code, pe, payload))
_W_L1, _D_L1, _W_L2, _D_L2 = 0, 1, 2, 3
_W_G1, _D_G1, _W_G2, _D_G2 = 4, 5, 6, 7


class _Win:
    """A serialization point of the lean interpreter (mirrors Resource)."""

    __slots__ = ("service", "d1", "d2", "busy", "waiters", "n_grants")

    def __init__(self, service, d1, d2):
        self.service = service
        self.d1 = d1
        self.d2 = d2
        self.busy = 0.0
        self.waiters: List[tuple] = []
        self.n_grants = 0


class _Hierarchical:
    """Lean replay of ``HierarchicalEngine``: global + per-node windows.

    Window completions live in the shared heap (multiple resources can
    have services in flight), frees are inlined after each completion,
    and the refill/park/epoch protocol is a line-by-line transliteration
    of the engine's handlers.  No vector round here -- hierarchical
    claims fan out over per-node windows so no single queue gets long --
    but the per-event cost is a fraction of the kernel's.
    """

    def __init__(self, cf, cache=None):
        spec = cf.spec
        self.cf = cf
        self.N = spec.N
        self.P = spec.P
        self._cache = cache
        if cache is not None:
            self.s_list, _ = cache.speeds(cf.speeds)
            _, self.pref = cache.pref(cf.costs)
        else:
            self.s_list = [float(x) for x in cf.speeds]
            self.pref = np.concatenate([[0.0], np.cumsum(cf.costs)]).tolist()
        self.o_issue = cf.o_issue
        self.o_issue_local = cf.o_issue_local
        self.o_net = cf.o_claim_net
        self.t_calc = cf.t_calc
        self.random_policy = cf.lock_polling_random
        self.draw = _draw_factory(cf.seed) if self.random_policy else None
        bounds, n_pes = cc.node_blocks(self.P, cf.nodes)
        self.bounds = bounds
        self.node_of = np.searchsorted(
            np.array(bounds[1:]), np.arange(self.P), side="right").tolist()
        self.outer = cc.hierarchical_outer_spec(spec, cf.nodes)
        self.spec = spec
        self._inner_k = {}
        self.gwin = _Win(cf.o_rma_global if cf.o_rma_global is not None
                         else cf.o_rma, _D_G1, _D_G2)
        self.lwin = [_Win(cf.o_rma_local, _D_L1, _D_L2)
                     for _ in range(cf.nodes)]
        self.sc: List[Optional[dict]] = [None] * cf.nodes
        self.refilling = [False] * cf.nodes
        self.node_parked: List[List[int]] = [[] for _ in range(cf.nodes)]
        self.node_done = [False] * cf.nodes
        self.heap: List[tuple] = []
        self.counter = 0
        self.glob_i = 0
        self.glob_lp = 0
        self.done = 0
        self.n_claims = 0
        self.finish = np.zeros(self.P)
        self.iters = np.zeros(self.P, dtype=np.int64)
        self.claim_start: dict = {}
        self.lats: List[float] = []

    def _inner_kfn(self, node: int, size: int):
        key = (node, size)
        fn = self._inner_k.get(key)
        if fn is None:
            ispec = cc.hierarchical_inner_spec(
                self.spec, self.cf.inner_technique, self.bounds, node, size)
            fn = (_chunk_fns(ispec) if self._cache is None
                  else self._cache.chunk_fns(ispec))[0]
            self._inner_k[key] = fn
        return fn

    def _push(self, t, code, pe, payload=None):
        heapq.heappush(self.heap, (t, self.counter, code, pe, payload))
        self.counter += 1

    def _grant(self, win: _Win, now: float) -> None:
        if not win.waiters or win.busy > now + EPS:
            return
        idx = self.draw(len(win.waiters)) if self.random_policy else 0
        pe, ph, payload = win.waiters.pop(idx)
        t = now + win.service
        win.busy = t
        win.n_grants += 1
        heapq.heappush(self.heap, (t, self.counter,
                                   win.d1 if ph == 1 else win.d2,
                                   pe, payload))
        self.counter += 2  # done + (inlined) free

    def _enqueue(self, win: _Win, now: float, pe: int, ph: int,
                 payload) -> None:
        win.waiters.append((pe, ph, payload))
        self._grant(win, now)

    # -- drain / refill protocol (mirrors the engine) -------------------
    def _retire(self, pe: int, t: float) -> None:
        self.claim_start.pop(pe, None)
        self.finish[pe] = t
        self.done += 1

    def _drain_node(self, node: int, t: float) -> None:
        self.node_done[node] = True
        self.refilling[node] = False
        for q in self.node_parked[node]:
            self._retire(q, t)
        self.node_parked[node].clear()

    def _start_refill(self, pe: int, node: int, t: float) -> None:
        if self.node_done[node]:
            self._retire(pe, t)
            return
        if self.refilling[node]:
            self.node_parked[node].append(pe)
            return
        if self.glob_lp >= self.N:
            self._drain_node(node, t)
            self._retire(pe, t)
            return
        self.refilling[node] = True
        self._push(t + self.o_issue / self.s_list[pe], _W_G1, pe)

    def _want_local(self, pe: int, t: float) -> None:
        node = self.node_of[pe]
        if self.node_done[node]:
            self._retire(pe, t)
            return
        if self.sc[node] is None:
            self._start_refill(pe, node, t)
            return
        self.claim_start.setdefault(pe, t)
        self._enqueue(self.lwin[node], t, pe, 1, self.sc[node])

    # -- handlers -------------------------------------------------------
    def _dispatch(self, t, code, pe, payload):
        if code == _W_L1:
            self._want_local(pe, t)
        elif code == _D_L1:
            s = payload
            node = self.node_of[pe]
            i_l = s["i"]
            s["i"] += 1
            k = self._inner_kfn(s["node"], s["size"])(
                i_l, pe - self.bounds[node])
            self._push(t + self.t_calc / self.s_list[pe], _W_L2, pe, (s, k))
            self._free(self.lwin[node], t)
        elif code == _W_L2:
            self._enqueue(self.lwin[self.node_of[pe]], t, pe, 2, payload)
        elif code == _D_L2:
            self._l2_done(t, pe, payload)
        elif code == _W_G1:
            self.claim_start.setdefault(pe, t)
            self._enqueue(self.gwin, t, pe, 1, None)
        elif code == _D_G1:
            i_g = self.glob_i
            self.glob_i += 1
            node = self.node_of[pe]
            K = cc.chunk_size_closed(self.outer, i_g, node)
            self._push(t + self.o_net + self.t_calc / self.s_list[pe],
                       _W_G2, pe, K)
            self._free(self.gwin, t)
        elif code == _W_G2:
            self._enqueue(self.gwin, t, pe, 2, payload)
        else:  # _D_G2
            self._g2_done(t, pe, payload)

    def _free(self, win: _Win, t: float) -> None:
        if win.waiters and win.busy <= t + EPS:
            self._grant(win, t)

    def _l2_done(self, t, pe, payload):
        node = self.node_of[pe]
        s, k = payload
        off = s["lp"]
        s["lp"] += k
        if off >= s["size"]:
            if self.sc[node] is s:
                self.sc[node] = None
            self._want_local(pe, t)
            self._free(self.lwin[node], t)
            return
        lat = t - self.claim_start.pop(pe)
        self.lats.append(lat)
        a = s["start"] + off
        b = s["start"] + min(off + k, s["size"])
        self.n_claims += 1
        self.iters[pe] += b - a
        t1 = t + (self.pref[b] - self.pref[a]) / self.s_list[pe]
        self._push(t1 + self.o_issue_local / self.s_list[pe], _W_L1, pe)
        self._free(self.lwin[node], t)

    def _g2_done(self, t, pe, K):
        node = self.node_of[pe]
        start = self.glob_lp
        self.glob_lp += K
        t_got = t + self.o_net
        if start >= self.N:
            self._drain_node(node, t_got)
            self._retire(pe, t_got)
            self._free(self.gwin, t)
            return
        self.sc[node] = {"node": node, "start": start,
                         "size": min(K, self.N - start), "i": 0, "lp": 0}
        self.refilling[node] = False
        woken = [pe] + self.node_parked[node]
        self.node_parked[node].clear()
        for q in woken:
            self._push(t_got, _W_L1, q)
        self._free(self.gwin, t)

    # -- driver ---------------------------------------------------------
    def run(self) -> SimResult:
        for pe in range(self.P):
            heapq.heappush(
                self.heap,
                (self.o_issue_local / self.s_list[pe], pe, _W_L1, pe, None))
        self.counter = self.P
        heap = self.heap
        pop = heapq.heappop
        P = self.P
        while heap and self.done < P:
            t, _, code, pe, payload = pop(heap)
            self._dispatch(t, code, pe, payload)
        return _result(self.finish, self.iters, self.n_claims, self.lats,
                       self.gwin.n_grants,
                       sum(w.n_grants for w in self.lwin))


# ---------------------------------------------------------------------------
# two-sided topology
# ---------------------------------------------------------------------------

# event codes (heap tuples are (t, seq, code, pe, payload))
_REQ, _SRV, _RPL, _WDC, _MSD, _MCL, _MKK = 0, 1, 2, 3, 4, 5, 6


class _TwoSided:
    """Lean replay of ``TwoSidedEngine``: master-worker request/serve.

    The kernel's dominant cost at large P is the master's rank-policy
    request queue: ``Resource.take()`` sorts the *whole* waiter list on
    every serve (O(Q log Q) with Q up to P-1), then ``pop(0)`` shifts
    it.  A worker has at most one outstanding request, so waiter PEs
    are unique and sorting ``(pe, t)`` tuples picks exactly what a
    min-heap on the same tuples pops -- the replay swaps the sort for
    ``heapq`` and keeps everything else a line-by-line transliteration
    of the engine's handlers (same float expression trees, same push
    order, one monotone seq counter).  Non-adaptive techniques never
    touch telemetry, so the Table-2 recurrence (``next_chunk``) is
    RNG-free and replays verbatim.
    """

    def __init__(self, cf, cache=None):
        spec = cf.spec
        self.spec = spec
        self.N = spec.N
        self.P = spec.P
        self.m = cf.coordinator
        if cache is not None:
            self.s_list, _ = cache.speeds(cf.speeds)
            _, self.pref = cache.pref(cf.costs)
        else:
            self.s_list = [float(x) for x in cf.speeds]
            self.pref = np.concatenate([[0.0], np.cumsum(cf.costs)]).tolist()
        self.s_m = self.s_list[self.m]
        self.o_issue = cf.o_issue
        self.o_req_net = cf.o_req_net
        self.o_serve = cf.o_serve
        self.master_quantum = cf.master_quantum
        self.t_calc = cf.t_calc
        # Table-2 recurrence state (mirrors TwoSidedEngine)
        self.R = self.N
        self.i_step = 0
        self.k_tss: Optional[int] = None
        self.batch_base: Optional[int] = None
        self.K0, self.Klast, self.S, self.C = cc.tss_constants(
            spec.N, spec.P, spec.min_chunk)
        # the rank-policy request queue as a heap of (pe, t_arrival)
        self.rq: List[tuple] = []
        self.master_chunk: Optional[list] = None
        self.master_done_own = False
        self.master_busy = False
        self.heap: List[tuple] = []
        self.counter = 0
        self.serve_time = 0.0
        self.n_claims = 0
        self.finish = np.zeros(self.P)
        self.iters = np.zeros(self.P, dtype=np.int64)
        self.claim_start: dict = {}
        self.lats: List[float] = []

    def _push(self, t, code, pe, payload=None) -> None:
        heapq.heappush(self.heap, (t, self.counter, code, pe, payload))
        self.counter += 1

    # -- master-side recurrence (verbatim from TwoSidedEngine) ----------
    def next_chunk(self, pe: int):
        if self.R <= 0:
            return None
        spec = self.spec
        t_, Pn, N, R = spec.technique, spec.P, self.N, self.R
        if t_ == "static":
            k = int(math.ceil(N / Pn))
        elif t_ == "ss":
            k = spec.min_chunk
        elif t_ == "gss":
            k = max(int(math.ceil(R / Pn)), spec.min_chunk)
        elif t_ == "tss":
            self.k_tss = self.K0 if self.k_tss is None \
                else max(self.k_tss - self.C, self.Klast)
            k = self.k_tss
        elif t_ in cc.FAC_FAMILY:
            if self.i_step % Pn == 0:
                self.batch_base = max(int(math.ceil(R / (2.0 * Pn))),
                                      spec.min_chunk)
            k = self.batch_base
            if t_ in cc.WEIGHTED:  # static weights only (tele is None)
                k = max(int(math.ceil(spec.weight(pe) * self.batch_base)),
                        spec.min_chunk)
        elif t_ == "tfss":
            if self.i_step % Pn == 0:
                first = self.K0 - self.i_step * self.C
                mean = first - (Pn - 1) / 2.0 * self.C
                self.batch_base = max(int(math.ceil(mean)), self.Klast)
            k = self.batch_base
        else:  # pragma: no cover - fast_qualifies filters adaptive
            raise AssertionError(t_)
        k = min(k, R)
        start = N - R
        self.R -= k
        self.i_step += 1
        return start, k

    # -- master state machine (mirrors TwoSidedEngine._kick) ------------
    def _kick(self, now: float) -> None:
        if self.master_busy:
            return
        if self.rq:  # serve pending requests first (smallest rank)
            rank, _ = heapq.heappop(self.rq)
            dt = self.o_serve / self.s_m
            self.serve_time += dt
            self.master_busy = True
            self._push(now + dt, _SRV, rank, self.next_chunk(rank))
            return
        mc = self.master_chunk
        if mc is not None:  # own work: burn one time quantum
            dt = min(self.master_quantum, mc[0])
            mc[0] -= dt
            self.master_busy = True
            self._push(now + dt, _MSD, self.m)
            return
        if not self.master_done_own:  # master_may_claim_at is always 0.0
            res = self.next_chunk(self.m)
            if res is None:
                self.master_done_own = True
                self.finish[self.m] = max(self.finish[self.m], now)
            else:
                self.n_claims += 1
                start, k = res
                self.iters[self.m] += k
                exec_t = (self.pref[start + k] - self.pref[start]) / self.s_m
                self.master_chunk = [exec_t, k, exec_t, start,
                                     self.n_claims - 1, now]
                self.master_busy = True
                self._push(now + self.t_calc / self.s_m, _MCL, self.m)

    # -- driver ---------------------------------------------------------
    def run(self) -> SimResult:
        pref = self.pref
        s_list = self.s_list
        for pe in range(self.P):
            if pe == self.m:
                continue
            self.claim_start[pe] = 0.0
            self._push(self.o_issue / s_list[pe] + self.o_req_net / 2,
                       _REQ, pe)
        self._push(0.0, _MKK, self.m)
        heap = self.heap
        pop = heapq.heappop
        while heap:  # drain all events (the master may outlive workers)
            t, _, code, pe, payload = pop(heap)
            if code == _REQ:
                heapq.heappush(self.rq, (pe, t))
                self._kick(t)
            elif code == _SRV:
                self.master_busy = False
                self._push(t + self.o_req_net / 2, _RPL, pe, payload)
                self._kick(t)
            elif code == _RPL:
                self.lats.append(t - self.claim_start.pop(pe))
                if payload is None:
                    self.finish[pe] = t
                    continue
                start, k = payload
                exec_t = (pref[start + k] - pref[start]) / s_list[pe]
                self.n_claims += 1
                self.iters[pe] += k
                self._push(t + exec_t, _WDC, pe)
            elif code == _WDC:
                self.claim_start[pe] = t
                self._push(t + self.o_issue / s_list[pe]
                           + self.o_req_net / 2, _REQ, pe)
            elif code == _MSD:
                self.master_busy = False
                mc = self.master_chunk
                if mc[0] <= 1e-15:
                    self.master_chunk = None
                    self.finish[self.m] = t
                self._kick(t)
            else:  # _MCL / _MKK
                if code == _MCL:
                    self.master_busy = False
                self._kick(t)
        return _result(self.finish, self.iters, self.n_claims, self.lats,
                       0, 0, serve_time=self.serve_time)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def simulate_fast(cf, backend: str = "numpy", cache=None) -> SimResult:
    """Run a qualifying config through the fast path.

    Raises ``ValueError`` for configs that do not qualify (callers
    wanting automatic routing should use ``repro.sim.run.simulate``,
    which falls back to the event kernel).  ``cache`` is an optional
    ``repro.sim.fast_batch.SweepCache``: candidates of one sweep that
    share cost/speed arrays then share their prefix sums and chunk
    tables instead of recomputing them per candidate -- results are
    byte-identical with or without it.
    """
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    if not fast_qualifies(cf):
        raise ValueError(
            "config does not qualify for the fast path (adaptive "
            "technique, perturbations, or trace collection); use "
            "simulate() for automatic kernel fallback")
    if cf.impl == "one_sided":
        return _OneSided(cf, backend=backend, cache=cache).run()
    if cf.impl == "two_sided":
        return _TwoSided(cf, cache=cache).run()
    return _Hierarchical(cf, cache=cache).run()
