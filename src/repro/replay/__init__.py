"""repro.replay -- trace capture, calibrated DES replay, and prediction.

The reproduction-and-prediction loop of arXiv:1805.07998 over this
repo's DES (DESIGN.md Sec. 9):

  record    executors + DLSession emit per-chunk timing -> ``Trace`` /
            ``TraceStore`` (versioned JSONL, byte-stable round trip)
  calibrate fit ``SimConfig`` (per-PE speeds, empirical per-iteration
            costs, window/master service times, measurement c.o.v.)
            from a trace; ``percent_error()`` = replay vs native T_loop
  predict   sweep techniques x runtimes through the calibrated DES and
            rank by predicted T_loop
  select    ``dls.loop(N, technique="auto")`` adopts the predicted best
            (decision recorded in ``SessionReport.auto_decision``)
  gantt     ASCII + SVG renderings of any trace

CLI: ``python -m repro.replay {record,calibrate,predict,gantt}``.
"""
from .calibrate import Calibration, calibrate  # noqa: F401
from .gantt import gantt_ascii, gantt_svg, save_svg  # noqa: F401
from .predict import (  # noqa: F401
    Prediction,
    predict,
    ranking_table,
    sweep,
)
from .select import choose_technique  # noqa: F401
from .trace import (  # noqa: F401
    TRACE_SCHEMA_VERSION,
    ChunkRecord,
    Trace,
    TraceStore,
    load_trace,
)

__all__ = [
    "Calibration",
    "ChunkRecord",
    "Prediction",
    "TRACE_SCHEMA_VERSION",
    "Trace",
    "TraceStore",
    "calibrate",
    "choose_technique",
    "gantt_ascii",
    "gantt_svg",
    "load_trace",
    "predict",
    "ranking_table",
    "save_svg",
    "sweep",
]
