import sys

from .cli import main

# The __name__ guard matters: spawn-based multiprocessing workers
# (repro.sim.batch on multithreaded parents) re-import the parent's main
# module, and an unguarded sys.exit(main()) would re-run the CLI there.
if __name__ == "__main__":
    sys.exit(main())
