"""``technique="auto"``: pick the predicted-best technique before running.

``dls.loop(N, technique="auto", ...)`` calls ``choose_technique`` -- a
seeded, bounded-time calibrated sweep -- and adopts the winner.  The
workload model, in preference order:

1. ``trace=`` -- a recorded ``repro.replay`` Trace (or path): full
   calibration (empirical costs, fitted speeds and overheads), resampled
   to the new loop's N if it differs;
2. ``costs=`` / ``speeds=`` hints -- e.g. per-request token counts from a
   serving queue (any length; resampled to N) and per-PE speed estimates;
3. nothing -- a seeded lognormal workload with moderate variability
   (c.o.v. 0.3) over homogeneous PEs, the "no prior knowledge" default.

The sweep subsamples to ``max_sim_iters`` simulated iterations so
selection stays cheap even for huge loops: predicted times then *rank*
candidates rather than reproduce magnitudes, which is all selection
needs.  The returned decision dict is recorded verbatim in
``SessionReport.auto_decision``.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.chunk_calculus import TECHNIQUES

from .calibrate import Calibration, calibrate
from .predict import resample_profile, subsample_costs, sweep
from .trace import Trace, load_trace

#: Default per-candidate simulated-iteration cap for selection sweeps.
MAX_SIM_ITERS = 4096

#: Default synthetic workload: per-iteration cost scale and variability
#: used when the caller supplies no trace and no hints.
DEFAULT_COST_MEAN = 1e-4
DEFAULT_COST_COV = 0.3


def _workload(N: int, P: int, costs, speeds, trace, seed: int,
              calib_overrides: Optional[dict] = None):
    """Resolve (costs[N], speeds[P], source, base_calibration|None)."""
    if trace is not None:
        tr: Trace = load_trace(trace)
        calib = calibrate(tr, seed=seed, **(calib_overrides or {}))
        c = resample_profile(calib.costs, N)
        s = calib.speeds
        if len(s) != P:  # trace recorded on a different PE count
            s = resample_profile(s, P)
        return c, s, "trace", calib
    if costs is not None:
        c = resample_profile(np.asarray(costs, dtype=np.float64), N)
        c = np.clip(c, 1e-12, None)
        s = (np.asarray(speeds, dtype=np.float64) if speeds is not None
             else np.ones(P))
        return c, s, "hints", None
    rng = np.random.default_rng(seed)
    sigma = np.sqrt(np.log(1.0 + DEFAULT_COST_COV ** 2))
    mu = np.log(DEFAULT_COST_MEAN) - sigma ** 2 / 2.0
    c = rng.lognormal(mu, sigma, size=N)
    s = (np.asarray(speeds, dtype=np.float64) if speeds is not None
         else np.ones(P))
    return c, s, "default", None


def choose_technique(
    N: int,
    P: int,
    *,
    runtime: str = "one_sided",
    nodes: Optional[int] = None,
    inner_technique: Optional[str] = None,
    costs=None,
    speeds=None,
    trace=None,
    min_chunk: int = 1,
    max_chunk: Optional[int] = None,
    seed: int = 0,
    budget_s: Optional[float] = 2.0,
    max_sim_iters: int = MAX_SIM_ITERS,
    techniques=None,
    workers=None,
    engine: str = "auto",
    cache=None,
    calib_overrides: Optional[dict] = None,
) -> dict:
    """The calibrated selection sweep behind ``technique="auto"``.

    The candidate roster runs through ``repro.sim.simulate_many``
    (``workers=None`` adapts: the default subsampled sweep stays
    in-process and batched over one ``SweepCache``, full-workload
    sweeps fan out over a process pool -- rankings are identical either
    way).  ``engine`` is forwarded per candidate ("auto" routes
    non-adaptive candidates to the vectorized fast path; fast/kernel
    equivalence pinning keeps the ranking independent of the route
    taken).  ``cache`` is an optional persistent ``SweepCache`` for
    repeated selection (the serving loop's re-rank warm start);
    ``calib_overrides`` pins already-fitted overhead constants
    (``o_rma``/``o_rma_local``/``o_serve``) so a trace-path call skips
    re-fitting them.  Returns the decision record: ``chosen`` (argmin
    predicted T_loop), the full ``ranking`` (each entry carrying the
    ``engine`` route taken), the provenance (source, seed, budget,
    simulated-N, engine), the sweep's wall time ``sweep_s``, and the
    ``fitted`` overhead constants for warm-starting the next call.
    """
    c, s, source, base = _workload(N, P, costs, speeds, trace, seed,
                                   calib_overrides)
    if len(s) != P:
        raise ValueError(f"speeds hint must have length P={P}, got {len(s)}")
    c_sim = subsample_costs(c, max_sim_iters)
    if base is not None:
        calib = base  # fitted overheads carry over; workload swapped below
        calib = Calibration(
            **{**base.__dict__, "N": len(c_sim), "P": P,
               "costs": c_sim, "speeds": np.asarray(s, dtype=np.float64),
               "runtime": runtime, "seed": seed})
    else:
        # No measured overheads: ride the DES's paper-calibrated defaults.
        from repro.core.sim import SimConfig

        sf = SimConfig.__dataclass_fields__
        calib = Calibration(
            technique="fac2", runtime=runtime, N=len(c_sim), P=P,
            native_T=0.0, speeds=np.asarray(s, dtype=np.float64),
            costs=c_sim, cost_mean=float(np.mean(c_sim)),
            cost_cov=float(np.std(c_sim) / np.mean(c_sim)),
            meas_cov=sf["o_meas_cov"].default,
            o_rma=sf["o_rma"].default,
            o_rma_local=sf["o_rma_local"].default,
            o_serve=sf["o_serve"].default,
            claim_lat_min=0.0, claim_lat_mean=0.0, seed=seed)
        for k, v in (calib_overrides or {}).items():
            setattr(calib, k, v)  # warm constants beat paper defaults
    if runtime == "hierarchical":
        calib.nodes = int(nodes or 1)
        calib.inner_technique = inner_technique or "ss"
    t0 = time.monotonic()
    ranking = sweep(calib, techniques=techniques or TECHNIQUES,
                    runtimes=(runtime,), seed=seed, budget_s=budget_s,
                    min_chunk=min_chunk, max_chunk=max_chunk,
                    workers=workers, engine=engine, cache=cache)
    sweep_s = time.monotonic() - t0
    return {
        "chosen": ranking[0].technique,
        "runtime": runtime,
        "ranking": [p.to_dict() for p in ranking],
        "source": source,
        "seed": seed,
        "budget_s": budget_s,
        "engine": engine,
        "sweep_s": sweep_s,
        "fitted": {"o_rma": float(calib.o_rma),
                   "o_rma_local": float(calib.o_rma_local),
                   "o_serve": float(calib.o_serve)},
        "N_sim": len(c_sim),
        "n_candidates": len(TECHNIQUES if techniques is None
                            else tuple(techniques)),
        "n_evaluated": len(ranking),
    }
