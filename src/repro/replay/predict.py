"""Cross-technique performance prediction over a calibrated DES.

Given a calibration (fitted speeds, empirical per-iteration costs, fitted
overheads), sweep candidate (technique, runtime) configurations through
``core.sim.simulate`` and rank them by predicted ``T_loop`` -- the
selection use-case of arXiv:1804.11115 driven by the reproduction
machinery of arXiv:1805.07998.

The sweep is seeded (deterministic for a fixed calibration + seed,
regardless of worker count) and runs through ``repro.sim.simulate_many``:
big rosters fan out over a process pool with fork-shared cost arrays
instead of the old roster-order serial loop, while small selection
sweeps stay in-process (adaptive ``workers=None`` default).  An optional
wall-clock budget keeps every candidate that finished in time -- at
least one is always evaluated.  For very long loops the empirical
workload can be subsampled (``max_sim_iters``) -- predicted times then
rank configurations rather than reproduce absolute magnitudes; `scale`
on each prediction records the subsampling factor.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.chunk_calculus import TECHNIQUES
from repro.sim import simulate_many

from .calibrate import Calibration, calibrate
from .trace import load_trace


@dataclasses.dataclass(frozen=True)
class Prediction:
    """One swept configuration and its simulated outcome."""

    technique: str
    runtime: str
    T_loop: float  # predicted parallel loop time [s] (subsampled workload
    # predicts the subsample; compare within a sweep, see `scale`)
    cov: float  # predicted load imbalance (c.o.v. of finish times)
    steps: int  # predicted scheduling steps
    scale: float = 1.0  # fraction of the workload actually simulated
    engine: str = "kernel"  # execution route taken ("fast-batch" =
    # shared-cache fast path, "fast" = pooled fast path, "kernel")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def resample_profile(arr: np.ndarray, n: int) -> np.ndarray:
    """Stretch/shrink a 1-D profile to length n (strided, deterministic)."""
    arr = np.asarray(arr, dtype=np.float64)
    if len(arr) == 0:
        raise ValueError("cannot resample an empty cost profile")
    if len(arr) == n:
        return arr
    idx = np.linspace(0, len(arr) - 1, n).astype(np.int64)
    return arr[idx]


def subsample_costs(costs: np.ndarray, max_iters: int) -> np.ndarray:
    """Deterministic strided subsample preserving the cost profile's shape."""
    if len(costs) <= max_iters:
        return costs
    return resample_profile(costs, max_iters)


def sweep(
    calib: Calibration,
    techniques: Optional[Sequence[str]] = None,
    runtimes: Optional[Sequence[str]] = None,
    *,
    seed: Optional[int] = None,
    budget_s: Optional[float] = None,
    max_sim_iters: Optional[int] = None,
    min_chunk: Optional[int] = None,  # None = the calibration's bounds
    max_chunk: Optional[int] = ...,
    workers=None,
    engine: str = "auto",
    cache=None,
) -> List[Prediction]:
    """Simulate every candidate; return predictions sorted by ``T_loop``.

    The whole roster goes through ``simulate_many`` (one seeded DES per
    candidate, so rankings are identical at any worker count).
    ``budget_s`` bounds the sweep's wall time -- candidates that did not
    finish in time are dropped, >= 1 is always evaluated;
    ``max_sim_iters`` caps the simulated iterations per candidate via
    strided subsampling; ``workers`` is ``simulate_many``'s knob
    (None = adaptive, "auto" = all cores, <=1 = serial); ``engine``
    picks the per-candidate execution strategy ("auto" routes
    qualifying non-adaptive candidates to the vectorized fast path --
    routing never changes the ranking because fast and kernel results
    are equivalence-pinned); ``cache`` is an optional
    ``repro.sim.SweepCache`` serial sweeps share across candidates (and
    repeated calls -- the serving loop's warm start).  Each returned
    prediction records the route taken in ``engine``.
    """
    techniques = tuple(techniques) if techniques else TECHNIQUES
    runtimes = tuple(runtimes) if runtimes else (calib.runtime,)
    costs = calib.costs
    scale = 1.0
    if max_sim_iters is not None and len(costs) > max_sim_iters:
        costs = subsample_costs(costs, max_sim_iters)
        scale = len(costs) / calib.N
    candidates = [(rt, tech) for rt in runtimes for tech in techniques]
    configs = [calib.sim_config(technique=tech, runtime=rt, seed=seed,
                                costs=costs, min_chunk=min_chunk,
                                max_chunk=max_chunk)
               for rt, tech in candidates]
    info: dict = {}
    results = simulate_many(configs, workers=workers, budget_s=budget_s,
                            engine=engine, cache=cache, info=info)
    engines = info.get("engines") or [None] * len(configs)
    out = [Prediction(technique=tech, runtime=rt, T_loop=float(r.T_loop),
                      cov=float(r.cov), steps=int(r.n_claims), scale=scale,
                      engine=engines[i] or "kernel")
           for i, ((rt, tech), r) in enumerate(zip(candidates, results))
           if r is not None]
    out.sort(key=lambda p: (p.T_loop, p.technique, p.runtime))
    return out


def predict(
    trace,
    techniques: Optional[Sequence[str]] = None,
    runtimes: Optional[Sequence[str]] = None,
    *,
    seed: int = 0,
    budget_s: Optional[float] = None,
    max_sim_iters: Optional[int] = None,
    workers=None,
    engine: str = "auto",
) -> dict:
    """Calibrate a trace, sweep candidates, and report the ranking.

    Returns ``{"calibration", "percent_error", "ranking"}`` where
    ``percent_error`` is the replay-vs-native error for the trace's own
    configuration (the paper's reproduction metric) and ``ranking`` the
    sorted predictions.
    """
    tr = load_trace(trace)
    calib = calibrate(tr, seed=seed)
    err = calib.percent_error()
    ranking = sweep(calib, techniques, runtimes, seed=seed,
                    budget_s=budget_s, max_sim_iters=max_sim_iters,
                    workers=workers, engine=engine)
    return {"calibration": calib, "percent_error": err, "ranking": ranking}


def ranking_table(ranking: Sequence[Prediction],
                  native_T: Optional[float] = None) -> str:
    """A fixed-width text table of a sweep's ranking (CLI / benchmarks)."""
    rows = [f"{'rank':>4} {'technique':<10} {'runtime':<13} "
            f"{'T_loop[s]':>12} {'cov':>7} {'steps':>7}"]
    for i, p in enumerate(ranking):
        mark = ""
        if native_T is not None and i == 0:
            mark = f"  (native T={native_T:.4f}s)"
        rows.append(f"{i + 1:>4} {p.technique:<10} {p.runtime:<13} "
                    f"{p.T_loop:>12.5f} {p.cov:>7.3f} {p.steps:>7}{mark}")
    return "\n".join(rows)
