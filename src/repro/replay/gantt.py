"""Gantt rendering of chunk traces (ASCII for terminals, SVG for CI).

One row per PE, one bar per executed chunk on the trace's clock (wall
for native executors, virtual for the DES).  The ASCII form cycles
per-chunk glyphs so adjacent chunks stay distinguishable; the SVG form
colors bars by scheduling-step ordinal (early = large chunks under
decreasing-chunk techniques), which makes the technique's shape visible
at a glance -- the paper's Fig. 3 style view of a run.
"""
from __future__ import annotations

import pathlib
from typing import List, Union

from .trace import Trace, load_trace

_GLYPHS = "#=@%+*o"


def _span(trace: Trace) -> float:
    end = max((r.t1 for r in trace.records), default=0.0)
    return max(end, trace.wall_time, 1e-12)


def gantt_ascii(trace, width: int = 80) -> str:
    """Terminal Gantt: one row per PE, ``.`` = idle, glyphs cycle per chunk."""
    tr = load_trace(trace)
    span = _span(tr)
    per_pe = tr.per_pe()
    lines = [f"{tr.summary()}  [1 col = {span / width:.3e}s]"]
    for pe, recs in enumerate(per_pe):
        row = ["."] * width
        for j, r in enumerate(sorted(recs, key=lambda x: x.t0)):
            a = int(r.t0 / span * width)
            b = int(r.t1 / span * width)
            b = max(b, a + 1)
            glyph = _GLYPHS[j % len(_GLYPHS)]
            for k in range(a, min(b, width)):
                row[k] = glyph
        lines.append(f"pe{pe:>3} |{''.join(row)}|")
    ticks = f"pe    |0{' ' * (width - len(f'{span:.3g}s') - 1)}{span:.3g}s|"
    lines.append(ticks)
    return "\n".join(lines)


def _bar_color(step: int, n_steps: int) -> str:
    """Early steps warm, late steps cool (HSL sweep, deterministic)."""
    frac = step / max(n_steps - 1, 1) if step >= 0 else 0.0
    hue = int(20 + 200 * frac)  # 20 (orange) -> 220 (blue)
    return f"hsl({hue},70%,55%)"


def gantt_svg(trace, width: int = 960, row_h: int = 18,
              margin: int = 56) -> str:
    """Standalone SVG Gantt (returned as text; save with ``save_svg``)."""
    tr = load_trace(trace)
    span = _span(tr)
    per_pe = tr.per_pe()
    P = len(per_pe)
    n_steps = max((r.step for r in tr.records), default=0) + 1
    H = row_h * P + 2 * margin
    W = width + 2 * margin
    parts: List[str] = [
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" '
        f'viewBox="0 0 {W} {H}">',
        f'<rect width="{W}" height="{H}" fill="white"/>',
        f'<text x="{margin}" y="{margin - 28}" font-family="monospace" '
        f'font-size="13">{tr.technique} N={tr.N} P={tr.P} '
        f'[{tr.runtime}/{tr.executor}] chunks={len(tr.records)} '
        f'T={tr.wall_time:.4g}s</text>',
    ]
    for pe, recs in enumerate(per_pe):
        y = margin + pe * row_h
        parts.append(
            f'<text x="4" y="{y + row_h - 5}" font-family="monospace" '
            f'font-size="11">pe{pe}</text>')
        parts.append(
            f'<line x1="{margin}" y1="{y + row_h}" x2="{margin + width}" '
            f'y2="{y + row_h}" stroke="#ddd" stroke-width="0.5"/>')
        for r in recs:
            x = margin + r.t0 / span * width
            w = max((r.t1 - r.t0) / span * width, 0.5)
            parts.append(
                f'<rect x="{x:.2f}" y="{y + 2}" width="{w:.2f}" '
                f'height="{row_h - 4}" fill="{_bar_color(r.step, n_steps)}" '
                f'stroke="#333" stroke-width="0.3">'
                f'<title>pe{r.pe} step {r.step} [{r.start},{r.stop}) '
                f'{r.seconds:.4g}s</title></rect>')
    axis_y = margin + P * row_h + 14
    parts.append(
        f'<text x="{margin}" y="{axis_y}" font-family="monospace" '
        f'font-size="11">0</text>')
    parts.append(
        f'<text x="{margin + width - 40}" y="{axis_y}" '
        f'font-family="monospace" font-size="11">{span:.3g}s</text>')
    parts.append("</svg>")
    return "\n".join(parts)


def save_svg(trace, path: Union[str, pathlib.Path],
             width: int = 960) -> pathlib.Path:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(gantt_svg(trace, width=width))
    return p
