"""Fit a ``SimConfig`` to a recorded trace (arXiv:1805.07998's method).

The reproduction-and-prediction loop needs the DES to be *calibrated
against a measured run* before its cross-technique predictions mean
anything.  From one ``Trace`` this module fits, by moment estimators over
the per-chunk records (derivations: EXPERIMENTS.md Sec. 4):

* **per-PE speeds** -- each PE's mean measured seconds/iteration; the
  fastest PE defines speed 1.0 (the paper's reference-core convention);
* **empirical per-iteration costs** at reference speed -- each chunk's
  duration, de-skewed by its PE's speed, spread over its iterations.
  Replay then drives the DES with the *measured* workload, not a
  synthetic distribution (iterations never covered get the mean cost);
* **window / master service time** -- from the *minimum* observed claim
  latency (the uncontended claim): one-sided pays two RMWs + wire +
  chunk calculation, so ``o_rma = (lat_min - 2*o_claim_net - t_calc)/2``;
  two-sided clocks from request *issue* and pays issue + wire + serve,
  so ``o_serve = lat_min - o_req_net - o_issue``; hierarchical claims
  are node-local, fitting ``o_rma_local = lat_min / 2``.  Floors
  keep degenerate traces (zero latency, e.g. hand-driven sessions) sane;
* **measurement c.o.v.** -- the within-PE dispersion of per-iteration
  chunk costs, feeding ``o_meas_cov`` for adaptive-technique replays.

``Calibration.percent_error()`` is the paper's headline metric: replay
the trace's own (technique, runtime) through the fitted DES and report
``100 * |T_sim - T_native| / T_native``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.chunk_calculus import LoopSpec
from repro.core.sim import SimConfig, SimResult, simulate

from .trace import Trace, load_trace

# Fitted-parameter floors: a trace with effectively-zero claim latencies
# (virtual drivers, hand claim loops) must not produce a zero-service DES.
_MIN_SERVICE = 1e-9


@dataclasses.dataclass
class Calibration:
    """Fitted DES parameters + the empirical workload of one trace."""

    technique: str
    runtime: str
    N: int
    P: int
    native_T: float  # the trace's measured T_loop
    speeds: np.ndarray  # per-PE relative speed, fastest = 1.0
    costs: np.ndarray  # empirical per-iteration cost at speed 1.0 [s]
    cost_mean: float
    cost_cov: float  # c.o.v. of per-iteration costs (workload variability)
    meas_cov: float  # within-PE dispersion -> SimConfig.o_meas_cov
    o_rma: float  # fitted window RMW service time (one-sided/global)
    o_rma_local: float  # fitted node-local RMW service (hierarchical)
    o_serve: float  # fitted master service time (two-sided)
    claim_lat_min: float
    claim_lat_mean: float
    nodes: int = 1
    inner_technique: str = "ss"
    min_chunk: int = 1  # the recorded spec's chunk bounds
    max_chunk: Optional[int] = None
    seed: int = 0

    def sim_config(self, technique: Optional[str] = None,
                   runtime: Optional[str] = None,
                   seed: Optional[int] = None,
                   costs: Optional[np.ndarray] = None,
                   min_chunk: Optional[int] = None,
                   max_chunk: Optional[int] = ...,  # ... = the trace's
                   **overrides) -> SimConfig:
        """A fitted ``SimConfig``, optionally re-targeted at another
        (technique, runtime) -- the cross-technique prediction knob.
        Chunk bounds default to the recorded spec's."""
        c = self.costs if costs is None else np.asarray(costs)
        spec = LoopSpec(technique or self.technique, N=len(c), P=self.P,
                        min_chunk=(self.min_chunk if min_chunk is None
                                   else min_chunk),
                        max_chunk=(self.max_chunk if max_chunk is ...
                                   else max_chunk))
        kw = dict(
            impl=runtime or self.runtime,
            o_rma=self.o_rma,
            o_rma_local=self.o_rma_local,
            o_serve=self.o_serve,
            o_meas_cov=self.meas_cov,
            seed=self.seed if seed is None else seed,
        )
        if (runtime or self.runtime) == "hierarchical":
            kw["nodes"] = self.nodes
            kw["inner_technique"] = self.inner_technique
        kw.update(overrides)
        return SimConfig(spec, self.speeds.copy(), c, **kw)

    def simulate(self, **kw) -> SimResult:
        return simulate(self.sim_config(**kw))

    def percent_error(self, **kw) -> float:
        """Replay the trace's own configuration; % error vs native T_loop."""
        if self.native_T <= 0:
            return float("inf")
        T_sim = self.simulate(**kw).T_loop
        return 100.0 * abs(T_sim - self.native_T) / self.native_T

    def summary(self) -> str:
        return (f"calibration[{self.technique}/{self.runtime}] N={self.N} "
                f"P={self.P} cost_mean={self.cost_mean:.3e}s "
                f"cost_cov={self.cost_cov:.3f} "
                f"speeds=[{self.speeds.min():.3f}..{self.speeds.max():.3f}] "
                f"o_rma={self.o_rma:.2e}s o_serve={self.o_serve:.2e}s")


def calibrate(trace, nodes: Optional[int] = None,
              inner_technique: Optional[str] = None,
              seed: Optional[int] = None,
              o_rma: Optional[float] = None,
              o_rma_local: Optional[float] = None,
              o_serve: Optional[float] = None) -> Calibration:
    """Fit DES parameters from a recorded trace (see module docstring).

    ``seed`` defaults to the trace's recorded seed (``meta["seed"]``) so
    adaptive-technique replays realize the *same* DES noise stream as the
    native run -- the replay-same-(technique, runtime, seed) methodology
    of EXPERIMENTS.md Sec. 4.

    ``o_rma``/``o_rma_local``/``o_serve`` override the latency-fitted
    service times with *directly measured* constants -- e.g. from
    ``repro.pt.latency.measure_rmw_latency`` against the real
    shared-memory window (``benchmarks/pt_contention.py``).  A measured
    service time beats the moment estimator whenever you have one: the
    minimum-latency fit conflates the RMW with wire/calculation residue.
    """
    tr: Trace = load_trace(trace)
    if not tr.records:
        raise ValueError("trace has no chunk records")
    P, N = tr.P, tr.N

    # -- per-PE speeds: mean measured seconds/iteration, fastest == 1.0 --
    busy = np.zeros(P)
    iters = np.zeros(P, dtype=np.int64)
    for r in tr.records:
        if 0 <= r.pe < P:
            busy[r.pe] += r.seconds
            iters[r.pe] += r.size
    mu = np.divide(busy, iters, out=np.full(P, np.nan), where=iters > 0)
    mu_ref = np.nanmin(mu) if np.isfinite(mu).any() else 1.0
    if not np.isfinite(mu_ref) or mu_ref <= 0:
        mu_ref = 1.0
    speeds = np.where(np.isfinite(mu) & (mu > 0), mu_ref / mu, 1.0)

    # -- empirical per-iteration costs at reference speed --
    costs = np.full(N, np.nan)
    per_iter_by_pe = [[] for _ in range(P)]
    for r in tr.records:
        if r.size <= 0:
            continue
        c = r.seconds * speeds[r.pe] / r.size if 0 <= r.pe < P \
            else r.seconds / r.size
        lo, hi = max(r.start, 0), min(r.stop, N)
        if lo < hi:
            costs[lo:hi] = c
        if 0 <= r.pe < P:
            per_iter_by_pe[r.pe].append(c)
    covered = np.isfinite(costs)
    fill = float(np.nanmean(costs)) if covered.any() else 1e-6
    costs = np.where(covered, costs, fill)
    cost_mean = float(costs.mean())
    cost_cov = float(costs.std() / cost_mean) if cost_mean > 0 else 0.0

    # -- within-PE measurement dispersion -> o_meas_cov --
    pe_covs = [np.std(v) / np.mean(v) for v in per_iter_by_pe
               if len(v) >= 2 and np.mean(v) > 0]
    meas_cov = float(np.median(pe_covs)) if pe_covs else 0.0

    # -- service times from the minimum (uncontended) claim latency --
    lats = tr.claim_latencies()
    pos = lats[lats > 0]
    lat_min = float(pos.min()) if len(pos) else 0.0
    lat_mean = float(lats.mean()) if len(lats) else 0.0
    d = SimConfig.__dataclass_fields__  # library defaults for the constants
    o_claim_net = d["o_claim_net"].default
    t_calc = d["t_calc"].default
    o_req_net = d["o_req_net"].default
    o_issue = d["o_issue"].default
    # a caller-measured constant wins over the latency fit for that param
    fit_rma, fit_rma_local, fit_serve = (
        o_rma is None, o_rma_local is None, o_serve is None)
    if fit_rma:
        o_rma = d["o_rma"].default
    if fit_rma_local:
        o_rma_local = d["o_rma_local"].default
    if fit_serve:
        o_serve = d["o_serve"].default
    if lat_min > 0:
        if tr.runtime == "two_sided":
            # Two-sided latency clocks from request *issue* (unlike
            # one-sided, which clocks after the issue cost is paid), so the
            # origin-side o_issue must come off before the serve time.
            if fit_serve:
                o_serve = max(lat_min - o_req_net - o_issue, _MIN_SERVICE)
        elif tr.runtime == "hierarchical":
            # inner claims dominate the record stream; both RMWs are local
            if fit_rma_local:
                o_rma_local = max(lat_min / 2.0, _MIN_SERVICE)
        elif fit_rma:
            o_rma = max((lat_min - 2.0 * o_claim_net - t_calc) / 2.0,
                        _MIN_SERVICE)

    meta = tr.meta or {}
    return Calibration(
        technique=tr.technique,
        runtime=tr.runtime,
        N=N,
        P=P,
        native_T=tr.wall_time,
        speeds=speeds,
        costs=costs,
        cost_mean=cost_mean,
        cost_cov=cost_cov,
        meas_cov=meas_cov,
        o_rma=o_rma,
        o_rma_local=o_rma_local,
        o_serve=o_serve,
        claim_lat_min=lat_min,
        claim_lat_mean=lat_mean,
        nodes=int(nodes if nodes is not None else meta.get("nodes", 1)),
        inner_technique=(inner_technique
                         or meta.get("inner_technique", "ss")),
        min_chunk=tr.min_chunk,
        max_chunk=tr.max_chunk,
        seed=int(seed if seed is not None else meta.get("seed", 0)),
    )
