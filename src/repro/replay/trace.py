"""Versioned chunk-level execution traces -- the replay data plane.

A ``Trace`` is the recorded ground truth of one loop execution: one
``ChunkRecord`` per executed chunk (claiming PE, scheduling-step ordinal,
iteration range, start/end timestamps, claim latency) plus the session
header (technique, N, P, runtime, executor, native wall time).  Traces are
reconstructable from any runtime (one-sided / two-sided / hierarchical)
and any executor: the native executors stamp wall-clock timestamps, the
DES stamps its virtual clock -- the record shape is identical, which is
what lets ``repro.replay.calibrate`` treat both uniformly.

Serialization is canonical JSONL (sorted keys, compact separators, one
record per line, header first): ``write -> read -> write`` is
byte-stable, the trace store's round-trip contract.  See DESIGN.md
Sec. 9 for the schema.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
from typing import Iterable, List, Optional, Union

import numpy as np

#: Trace schema version.  Bump on any backward-incompatible record or
#: header change; ``Trace.from_jsonl`` rejects newer majors.
TRACE_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ChunkRecord:
    """One executed chunk: who ran what, when, and what the claim cost."""

    pe: int
    step: int  # scheduling-step ordinal (-1 when the producer had none)
    start: int  # first iteration of the chunk
    size: int  # iterations executed
    t0: float  # execution start [s since loop start; DES: virtual clock]
    t1: float  # execution end
    lat: float  # claim (scheduling) latency paid to obtain the chunk

    @property
    def seconds(self) -> float:
        return self.t1 - self.t0

    @property
    def stop(self) -> int:
        return self.start + self.size

    def to_dict(self) -> dict:
        return {"kind": "chunk", "pe": self.pe, "step": self.step,
                "start": self.start, "size": self.size,
                "t0": self.t0, "t1": self.t1, "lat": self.lat}

    @classmethod
    def from_dict(cls, d: dict) -> "ChunkRecord":
        return cls(pe=int(d["pe"]), step=int(d.get("step", -1)),
                   start=int(d["start"]), size=int(d["size"]),
                   t0=float(d["t0"]), t1=float(d["t1"]),
                   lat=float(d.get("lat", 0.0)))


def _canon(obj: dict) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


@dataclasses.dataclass
class Trace:
    """A recorded loop execution (header + per-chunk records)."""

    technique: str
    N: int
    P: int
    runtime: str
    executor: str
    wall_time: float  # native T_loop (the calibration target)
    records: List[ChunkRecord]
    min_chunk: int = 1  # spec chunk bounds: replay must schedule with them
    max_chunk: Optional[int] = None
    meta: dict = dataclasses.field(default_factory=dict)
    version: int = TRACE_SCHEMA_VERSION

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_report(cls, report, meta: Optional[dict] = None) -> "Trace":
        """Build a trace from a ``SessionReport`` carrying ``chunk_times``.

        Works for every executor: serial/threads stamp wall-clock
        timestamps; the sim executor (``collect_trace=True``) stamps the
        DES virtual clock.
        """
        if not report.chunk_times:
            raise ValueError(
                "report has no chunk_times -- drain the session through an "
                "executor (serial/threads, or sim with collect_trace=True)")
        recs = [ChunkRecord.from_dict(d) for d in report.chunk_times]
        return cls(technique=report.technique, N=report.N, P=report.P,
                   runtime=report.runtime, executor=report.executor or "?",
                   wall_time=float(report.wall_time), records=recs,
                   min_chunk=report.min_chunk, max_chunk=report.max_chunk,
                   meta=dict(meta or {}))

    # ------------------------------------------------------------------
    # inspection
    # ------------------------------------------------------------------
    def iters_covered(self) -> int:
        """Total iterations the records account for (== N when complete)."""
        return sum(r.size for r in self.records)

    def per_pe(self) -> List[List[ChunkRecord]]:
        out: List[List[ChunkRecord]] = [[] for _ in range(self.P)]
        for r in self.records:
            if r.pe >= len(out):  # grown sessions
                out.extend([] for _ in range(r.pe - len(out) + 1))
            out[r.pe].append(r)
        return out

    def claim_latencies(self) -> np.ndarray:
        return np.array([r.lat for r in self.records], dtype=np.float64)

    def window(self, t_from: float, t_to: Optional[float] = None) -> "Trace":
        """A sub-trace of the chunks live in ``[t_from, t_to)``.

        Keeps every record that *finished* after ``t_from`` (and started
        before ``t_to``, when given), rebasing timestamps so the window
        opens at 0 -- the shape ``calibrate`` expects.  This is the
        sliding-window view an online controller calibrates from: recent
        chunks reflect the current cost/speed regime, chunks from ten
        epochs ago may not.  ``N`` becomes the windowed iteration count
        and ``wall_time`` the window span, so fitted speeds and
        overheads come purely from live-window evidence.
        """
        recs = [r for r in self.records
                if r.t1 > t_from and (t_to is None or r.t0 < t_to)]
        rebased = [ChunkRecord(pe=r.pe, step=r.step, start=r.start,
                               size=r.size, t0=r.t0 - t_from,
                               t1=r.t1 - t_from, lat=r.lat) for r in recs]
        if rebased:
            span = max(r.t1 for r in rebased)
        else:
            span = 0.0
        return Trace(technique=self.technique,
                     N=max(sum(r.size for r in rebased), 1), P=self.P,
                     runtime=self.runtime, executor=self.executor,
                     wall_time=float(span), records=rebased,
                     min_chunk=self.min_chunk, max_chunk=self.max_chunk,
                     meta={**self.meta,
                           "window": [float(t_from),
                                      None if t_to is None else float(t_to)]},
                     version=self.version)

    def summary(self) -> str:
        return (f"trace {self.technique} N={self.N} P={self.P} "
                f"[{self.runtime}/{self.executor}] chunks={len(self.records)} "
                f"covered={self.iters_covered()} wall={self.wall_time:.4f}s")

    # ------------------------------------------------------------------
    # canonical JSONL serialization (byte-stable round trip)
    # ------------------------------------------------------------------
    def to_jsonl(self) -> str:
        header = {"kind": "trace_header", "version": self.version,
                  "technique": self.technique, "N": self.N, "P": self.P,
                  "runtime": self.runtime, "executor": self.executor,
                  "wall_time": self.wall_time, "min_chunk": self.min_chunk,
                  "max_chunk": self.max_chunk, "meta": self.meta}
        lines = [_canon(header)]
        lines += [_canon(r.to_dict()) for r in self.records]
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "Trace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty trace")
        header = json.loads(lines[0])
        if header.get("kind") != "trace_header":
            raise ValueError("first JSONL line must be the trace_header")
        ver = header.get("version")
        if ver is None or ver > TRACE_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported trace version {ver!r} "
                f"(this build reads <= {TRACE_SCHEMA_VERSION})")
        recs = []
        for ln in lines[1:]:
            d = json.loads(ln)
            if d.get("kind") == "chunk":
                recs.append(ChunkRecord.from_dict(d))
        return cls(technique=header["technique"], N=int(header["N"]),
                   P=int(header["P"]), runtime=header["runtime"],
                   executor=header["executor"],
                   wall_time=float(header["wall_time"]), records=recs,
                   min_chunk=int(header.get("min_chunk", 1)),
                   max_chunk=header.get("max_chunk"),
                   meta=header.get("meta", {}), version=ver)


class TraceStore:
    """A directory of JSONL traces, one file per recorded run.

    Filenames are derived from the header (or supplied); ``save`` never
    overwrites -- colliding names get a numeric suffix.
    """

    SUFFIX = ".jsonl"

    def __init__(self, root: Union[str, pathlib.Path]):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _default_name(self, trace: Trace) -> str:
        return (f"{trace.technique}-N{trace.N}-P{trace.P}"
                f"-{trace.runtime}-{trace.executor}")

    def save(self, trace: Trace, name: Optional[str] = None) -> pathlib.Path:
        base = re.sub(r"[^A-Za-z0-9._-]", "_",
                      name or self._default_name(trace))
        path = self.root / (base + self.SUFFIX)
        n = 1
        while path.exists():
            path = self.root / f"{base}.{n}{self.SUFFIX}"
            n += 1
        path.write_text(trace.to_jsonl())
        return path

    def load(self, name_or_path: Union[str, pathlib.Path]) -> Trace:
        p = pathlib.Path(name_or_path)
        if not p.exists():
            p = self.root / str(name_or_path)
        if not p.exists() and not str(name_or_path).endswith(self.SUFFIX):
            p = self.root / (str(name_or_path) + self.SUFFIX)
        return Trace.from_jsonl(p.read_text())

    def list(self) -> List[str]:
        return sorted(p.name for p in self.root.glob(f"*{self.SUFFIX}"))

    def __iter__(self) -> Iterable[Trace]:
        for name in self.list():
            yield self.load(name)


def load_trace(path_or_trace) -> Trace:
    """Coerce a Trace | path | JSONL text into a ``Trace``."""
    if isinstance(path_or_trace, Trace):
        return path_or_trace
    if isinstance(path_or_trace, pathlib.Path):
        return Trace.from_jsonl(path_or_trace.read_text())
    if isinstance(path_or_trace, str):
        if "\n" in path_or_trace or path_or_trace.lstrip().startswith("{"):
            return Trace.from_jsonl(path_or_trace)
        return Trace.from_jsonl(pathlib.Path(path_or_trace).read_text())
    raise TypeError(f"cannot load a Trace from {type(path_or_trace)!r}")
