"""``python -m repro.replay`` -- record / calibrate / predict / gantt.

The zero-to-replay path on one machine:

    python -m repro.replay record --n 2000 --p 4 --technique fac2 \\
        --executor sim --het --store traces/
    python -m repro.replay calibrate --trace traces/fac2-N2000-...jsonl
    python -m repro.replay predict   --trace traces/fac2-N2000-...jsonl
    python -m repro.replay gantt     --trace traces/fac2-N2000-...jsonl \\
        --svg gantt.svg

``record`` drains a real ``dls.loop`` session through the chosen executor
(sim: seeded synthetic workload, optionally a heterogeneous 2:1 speed
mix; serial/threads: a seeded sleep workload) and writes the captured
trace into a ``TraceStore``.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro import dls
from repro.core.chunk_calculus import TECHNIQUES

from .calibrate import calibrate
from .gantt import gantt_ascii, save_svg
from .predict import predict, ranking_table
from .trace import Trace, TraceStore, load_trace


def _workers_arg(value: str):
    """--workers: an int or the literal 'auto' (rejected at parse time)."""
    if value == "auto":
        return value
    try:
        return int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected an integer or 'auto', got {value!r}") from None


def _record(args) -> int:
    rng = np.random.default_rng(args.seed)
    sigma = np.sqrt(np.log(1.0 + args.cost_cov ** 2))
    costs = rng.lognormal(np.log(args.cost_mean) - sigma ** 2 / 2, sigma,
                          size=args.n)
    speeds = np.ones(args.p)
    if args.het:  # the paper's 2:1 fast/slow mix, scaled down
        speeds[args.p // 2:] = 0.5
    kw = {}
    if args.runtime == "hierarchical":
        kw.update(nodes=args.nodes, inner_technique=args.inner_technique)
    session = dls.loop(args.n, technique=args.technique, P=args.p,
                       runtime=args.runtime, **kw)
    if args.executor == "sim":
        report = session.execute(None, executor="sim", costs=costs,
                                 speeds=speeds, seed=args.seed,
                                 collect_trace=True)
    else:
        # Seeded sleep workload: per-iteration costs realized as real time.
        def work_fn(a, b):
            time.sleep(float(costs[a:b].sum()))

        report = session.execute(work_fn, executor=args.executor)
    meta = {"seed": args.seed, "het": bool(args.het)}
    if args.runtime == "hierarchical":
        meta.update(nodes=args.nodes, inner_technique=args.inner_technique)
    trace = Trace.from_report(report, meta=meta)
    store = TraceStore(args.store)
    path = store.save(trace, name=args.name)
    print(trace.summary())
    print(f"saved -> {path}")
    return 0


def _calibrate(args) -> int:
    calib = calibrate(load_trace(args.trace))
    print(calib.summary())
    print(f"claim latency: min={calib.claim_lat_min:.3e}s "
          f"mean={calib.claim_lat_mean:.3e}s  meas_cov={calib.meas_cov:.3f}")
    err = calib.percent_error()
    print(f"replay percent error (native {calib.native_T:.4f}s): {err:.2f}%")
    return 0


def _predict(args) -> int:
    runtimes = args.runtimes.split(",") if args.runtimes else None
    res = predict(load_trace(args.trace), runtimes=runtimes,
                  seed=args.seed, budget_s=args.budget,
                  max_sim_iters=args.max_sim_iters, workers=args.workers)
    calib = res["calibration"]
    print(calib.summary())
    print(f"replay percent error: {res['percent_error']:.2f}%")
    print(ranking_table(res["ranking"], native_T=calib.native_T))
    return 0


def _gantt(args) -> int:
    trace = load_trace(args.trace)
    if not args.no_ascii:
        print(gantt_ascii(trace, width=args.width))
    if args.svg:
        path = save_svg(trace, args.svg)
        print(f"svg -> {path}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="python -m repro.replay",
                                description=__doc__.split("\n")[0])
    sub = p.add_subparsers(dest="cmd", required=True)

    r = sub.add_parser("record", help="run a loop and record its trace")
    r.add_argument("--n", type=int, default=2000)
    r.add_argument("--p", type=int, default=4)
    r.add_argument("--technique", default="fac2", choices=TECHNIQUES)
    r.add_argument("--runtime", default="one_sided",
                   choices=("one_sided", "two_sided", "hierarchical"))
    r.add_argument("--nodes", type=int, default=2)
    r.add_argument("--inner-technique", default="ss")
    r.add_argument("--executor", default="sim",
                   choices=("sim", "serial", "threads"))
    r.add_argument("--seed", type=int, default=0)
    r.add_argument("--cost-mean", type=float, default=1e-4)
    r.add_argument("--cost-cov", type=float, default=0.3)
    r.add_argument("--het", action="store_true",
                   help="2:1 heterogeneous speed mix (sim executor)")
    r.add_argument("--store", default="traces")
    r.add_argument("--name", default=None)
    r.set_defaults(fn=_record)

    c = sub.add_parser("calibrate", help="fit DES params; report % error")
    c.add_argument("--trace", required=True)
    c.set_defaults(fn=_calibrate)

    q = sub.add_parser("predict", help="calibrated cross-technique sweep")
    q.add_argument("--trace", required=True)
    q.add_argument("--runtimes", default=None,
                   help="comma-separated (default: the trace's runtime)")
    q.add_argument("--seed", type=int, default=0)
    q.add_argument("--budget", type=float, default=None,
                   help="sweep wall-clock budget [s] (default unbounded)")
    q.add_argument("--max-sim-iters", type=int, default=None)
    q.add_argument("--workers", type=_workers_arg, default=None,
                   help="sweep fan-out: an int, 'auto' (all cores), or "
                        "unset for the adaptive default (simulate_many)")
    q.set_defaults(fn=_predict)

    g = sub.add_parser("gantt", help="render a trace (ASCII and/or SVG)")
    g.add_argument("--trace", required=True)
    g.add_argument("--width", type=int, default=80)
    g.add_argument("--svg", default=None)
    g.add_argument("--no-ascii", action="store_true")
    g.set_defaults(fn=_gantt)
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
