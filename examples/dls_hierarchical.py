"""Two-level (hierarchical) DLS: node super-chunks + local sub-scheduling.

The follow-up to the source paper (arXiv:1903.09510) nests a cheap
node-local shared-memory window under the global RMA window: a node claims
a *super-chunk* through the global window with the outer technique's
closed form, then its PEs partition the super-chunk locally.  Only
super-chunk claims pay the global serialization point.

This example runs the same loop three ways and prints the per-level RMW
counts -- the claim-count reduction is the whole point:

  1. flat one-sided over a real thread pool (every claim is "global"),
  2. hierarchical over threads (GSS over nodes + SS within each node),
  3. both under the DES at the paper's 288-core heterogeneous mix.

Run:  PYTHONPATH=src python examples/dls_hierarchical.py [--n 20000]
"""
import argparse
import threading

import numpy as np

from repro import dls
from repro.core import paper_cluster, psia_costs
from repro.core.sim import PSIA_MEAN_COST


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=4)
    args = ap.parse_args()
    N, P, nodes = args.n, args.workers, args.nodes

    hits = np.zeros(N, np.int64)
    lock = threading.Lock()

    def work(a, b):
        with lock:
            hits[a:b] += 1

    # ---- flat: every claim pays the (simulated-cost) global window --------
    flat = dls.loop(N, technique="ss", P=P, window="sim")
    r_flat = flat.execute(work, executor="threads")
    assert (hits == 1).all()

    # ---- hierarchical: GSS super-chunks over nodes, SS within -------------
    hits[:] = 0
    hier = dls.loop(N, technique="gss", P=P, runtime="hierarchical",
                    nodes=nodes, window="sim")
    r_hier = hier.execute(work, executor="threads")
    assert (hits == 1).all()

    print("threads executor (real concurrency, clocked windows):")
    print("  flat :", r_flat.summary())
    print("  hier :", r_hier.summary())
    clocks = hier.runtime.window.clocks()
    print(f"  hier modeled window time: global={clocks['global']*1e6:.0f}us "
          f"local={clocks['local']*1e6:.0f}us "
          f"(global RMWs cut {r_flat.n_rmw_global / max(r_hier.n_rmw_global, 1):.0f}x)")

    # ---- the paper's 288-core heterogeneous mix, under the DES ------------
    speeds, _ = paper_cluster("2:1", "xeon")
    costs = psia_costs(N, mean=PSIA_MEAN_COST)
    des_flat = dls.loop(N, technique="ss", P=288).execute(
        None, executor="sim", costs=costs, speeds=speeds)
    des_hier = dls.loop(N, technique="gss", P=288, runtime="hierarchical",
                        nodes=8).execute(
        None, executor="sim", costs=costs, speeds=speeds)
    print("DES at the paper's 288-core 2:1 KNL/Xeon mix:")
    print("  flat :", des_flat.summary())
    print("  hier :", des_hier.summary())
    print(f"  global-RMW reduction: "
          f"{des_flat.n_rmw_global / max(des_hier.n_rmw_global, 1):.0f}x")


if __name__ == "__main__":
    main()
