"""Quickstart: the paper's protocol in 60 lines.

1. Chunk calculus: closed forms (Eq. 1-3) == Table-2 recurrences.
2. Distributed claiming: 8 threads self-schedule a loop via two atomic
   fetch-adds each (the One_Sided protocol), no master.
3. The framework plane: a tiny LM trained with a DLS-claimed data pipeline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import dls
from repro.core import LoopSpec, chunk_series_recurrence, plan

# -- 1. chunk calculus ------------------------------------------------------
spec = LoopSpec("gss", N=10, P=2)
sizes, starts = plan(spec)
print(f"GSS N=10 P=2 (paper Sec.3 example): sizes={list(sizes)} starts={list(starts)}")
assert list(sizes[:2]) == [5, 3]  # K_0=5, K_1=3, as in the paper

spec = LoopSpec("fac2", N=100_000, P=16)
print(f"FAC2 closed-form steps: {len(plan(spec)[0])}, "
      f"recurrence steps: {len(chunk_series_recurrence(spec))}")

# -- 2. one-sided distributed claiming --------------------------------------
N = 50_000
executed = np.zeros(N, np.int32)
with dls.loop(N, technique="fac2", P=8) as session:
    report = session.execute(
        lambda a, b: executed.__setitem__(slice(a, b), executed[a:b] + 1),
        executor="threads")
assert (executed == 1).all(), "not a partition!"
print(f"one-sided threads: {report.steps} claims partition [0,{N}) exactly "
      f"once (cov={report.cov:.2f})")

# -- 3. train a tiny LM with the DLS data plane ------------------------------
from repro.configs.base import ModelConfig
from repro.train import TrainConfig, Trainer

cfg = ModelConfig(name="quickstart", family="dense", n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=2, d_ff=128, vocab=128, dtype="float32")
trainer = Trainer(cfg, TrainConfig(steps=20, per_host_batch=4, seq_len=32,
                                   n_samples=1_000, technique="fac2",
                                   log_every=5))
trainer.run()
print(f"loss: {trainer.history[0]:.3f} -> {trainer.history[-1]:.3f}")
print("quickstart OK")
