"""Serving with DLS continuous batching: real model, variable-length
requests, one-sided admission control.

A tiny LM serves a queue of requests with heavy-tailed generation lengths.
Decode groups claim request chunks via the paper's protocol; compare GSS
(decreasing chunks: big admissions early, small late -> tail-latency
control) against a static split.

Run:  PYTHONPATH=src python examples/serve_dls.py
"""
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serve import ContinuousBatcher, Engine, Request

cfg = ModelConfig(name="serve-tiny", family="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab=256, dtype="float32")
params = api.init_params(jax.random.key(0), cfg)
eng = Engine(cfg, params, batch_size=4)

rng = np.random.default_rng(0)
N_REQ = 48
lens = np.clip((rng.pareto(1.2, N_REQ) * 8 + 2).astype(int), 2, 64)
reqs = [Request(rid=i, prompt=rng.integers(0, 256, 8).astype(np.int32),
                max_new=int(l)) for i, l in enumerate(lens)]
print(f"[serve_dls] {N_REQ} requests, gen lengths p50={np.median(lens):.0f} "
      f"max={lens.max()}")

# measure real per-token decode cost once (after a compile warmup)
eng.generate(np.stack([r.prompt for r in reqs[:4]]), max_new=2)
t0 = time.perf_counter()
eng.generate(np.stack([r.prompt for r in reqs[:4]]), max_new=8)
tok_cost = (time.perf_counter() - t0) / (4 * 8)


def process(chunk, worker):
    """Cost of decoding a chunk of requests as one group (real cost model)."""
    return float(sum(r.max_new for r in chunk)) * tok_cost + 0.01


for tech in ["gss", "fac2", "ss", "auto"]:
    cb = ContinuousBatcher(n_workers=4, technique=tech)
    t = cb.schedule(reqs, process)
    label = tech
    if tech == "auto":  # replay-predicted selection from the queue's shape
        label = f"auto->{cb.last_report.auto_decision['chosen']}"
    ts = cb.schedule(reqs, process, static=True)
    print(f"{label:10s}: makespan={t.max():.2f}s p99={np.percentile(t,99):.2f}s | "
          f"static: makespan={ts.max():.2f}s p99={np.percentile(ts,99):.2f}s")

# and one real generation pass to prove the engine path end-to-end
out = eng.generate(np.stack([r.prompt for r in reqs[:4]]), max_new=12)
print(f"[serve_dls] real generation OK: {out.shape}")
