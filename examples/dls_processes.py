"""Real cross-process DLS: 8 OS processes over a shared-memory window.

The paper's protocol with nothing faked: ``window="shm"`` lays the RMA
window out in ``multiprocessing.shared_memory`` (``repro.pt``), and
``executor="processes"`` runs each PE as a real OS process that attaches
the slab by name and claims through atomic fetch-and-adds -- no GIL, no
master, no simulation.  Three runs:

  1. flat one-sided at P=8 with a sleep-based per-iteration cost
     (sleeps overlap across processes, so T_loop tracks the parallel
     model even on one core);
  2. hierarchical (both levels in shared memory): node super-chunks
     globally, SS within the node -- compare the per-level RMW counts;
  3. the same loop with PE 2 killed mid-chunk (``os._exit``): the
     executed prefix is salvaged from its crash slot, the remainder is
     re-executed by survivors, and conservation still holds to exactly N.

Run:  PYTHONPATH=src python examples/dls_processes.py [--n 2000]
"""
import argparse
import functools

from repro import dls
from repro.pt import workloads


def run(title, N, technique, work, **kw):
    execute_kw = kw.pop("execute_kw", {})
    session = dls.loop(N, technique=technique, window="shm", **kw)
    report = session.execute(work, executor="processes", timeout=120.0,
                             **execute_kw)
    session.close()
    ps = report.process_stats
    print(f"{title:<24} {report.summary()}")
    print(f"{'':<24} pids={[e.get('pid') for e in ps['per_pe']]} "
          f"teardown={ps.get('teardown_s', 0) * 1e3:.0f}ms")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--cost-us", type=float, default=200.0)
    args = ap.parse_args()
    N = args.n

    # 1. flat one-sided, every iteration executed exactly once
    shm, name = workloads.alloc_hits(N)
    try:
        work = functools.partial(_sleep_and_mark, name, args.cost_us)
        rep = run("one-sided P=8", N, "fac2", work, P=8)
        assert set(workloads.read_hits(name, N)) == {1}
        ideal = N * args.cost_us * 1e-6 / 8
        print(f"{'':<24} ideal T_loop={ideal * 1e3:.0f}ms "
              f"measured={rep.wall_time * 1e3:.0f}ms")
    finally:
        shm.close()
        shm.unlink()

    # 2. hierarchical: node-local claims dominate the global window
    shm, name = workloads.alloc_hits(N)
    try:
        rep = run("hierarchical 2 nodes", N, "fac2",
                  functools.partial(workloads.mark_hits, name),
                  P=8, runtime="hierarchical", nodes=2,
                  inner_technique="ss")
        assert set(workloads.read_hits(name, N)) == {1}
        print(f"{'':<24} rmw_global={rep.n_rmw_global} "
              f"rmw_local={rep.n_rmw_local} (locals are cheap)")
    finally:
        shm.close()
        shm.unlink()

    # 3. kill PE 2 mid-chunk; survivors re-claim the orphaned remainder
    shm, name = workloads.alloc_hits(N)
    try:
        rep = run("PE 2 dies mid-chunk", N, "fac2",
                  functools.partial(workloads.die_at, name, 2, 1, 50.0),
                  P=8, execute_kw={"progress": 16})
        assert set(workloads.read_hits(name, N)) == {1}
        ps = rep.process_stats
        victim = next(e for e in ps["per_pe"] if e.get("died"))
        print(f"{'':<24} salvaged={victim['salvaged_iters']} "
              f"orphaned={victim['orphaned_iters']} "
              f"re-executed by {[o['by_pe'] for o in ps['orphans']]} "
              f"-- all {N} iterations still exactly once")
    finally:
        shm.close()
        shm.unlink()


def _sleep_and_mark(name, cost_us, a, b):
    workloads.sleep_iters(cost_us, a, b)
    workloads.mark_hits(name, a, b)


if __name__ == "__main__":
    main()
